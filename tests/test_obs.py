"""repro.obs: tracer semantics, trace-export structure, metrics registry,
and the end-to-end four-track pipeline timeline.

The contracts under test:

* **zero cost when disabled** — a disabled tracer answers ``span()`` with
  the shared ``NULL_SPAN`` singleton (identity-asserted: no per-call
  allocation beyond the flag check) and records nothing;
* **valid Chrome trace JSON** — exported traces load, timestamps are
  monotone per track, and every B has a matching same-name E (including
  spans a thread abandoned mid-flight: end-capped at export);
* **spans survive exceptions** — work recorded before a pipeline failure
  is present in the export, and the span open at unwind is closed with an
  ``error`` tag;
* **bit-effect-free** — a pipeline run with tracing enabled produces
  bit-identical outputs to the same run with tracing disabled;
* **the e2e timeline** — a streaming arena run produces >= 4 distinct
  tracks (shard readers, FE worker, H2D feeder, train loop) whose FE and
  train spans overlap in wall-clock, and ``PipelineStats.
  overlap_fraction > 0`` agrees.
"""

import dataclasses
import json
import threading

import numpy as np
import pytest
from conftest import recording_step

from repro.obs import (
    NULL_SPAN,
    MetricsRegistry,
    TraceError,
    Tracer,
    harvest,
    overlap_seconds,
    pipeline_rollup,
    set_tracer,
    span_intervals,
    validate_trace,
)


@pytest.fixture
def traced():
    """Install a fresh enabled tracer; restore the previous one after."""
    tracer = Tracer(enabled=True)
    prev = set_tracer(tracer)
    yield tracer
    set_tracer(prev)


# ----------------------------------------------------------------- tracer
def test_disabled_tracer_is_noop_singleton():
    t = Tracer(enabled=False)
    # identity, not just equality: the disabled path allocates nothing
    assert t.span("a") is NULL_SPAN
    assert t.span("b", batch=1) is NULL_SPAN
    with t.span("a"):
        pass
    t.instant("x")
    t.counter("q", 3)
    t.complete("c", 0, 10)
    assert t.n_events == 0
    assert t.track_names() == {}


def test_span_records_matched_events_and_validates():
    t = Tracer(enabled=True)
    with t.span("outer", batch=0):
        with t.span("inner"):
            pass
        t.instant("mark", kind="test")
    t.counter("depth", 2)
    summary = validate_trace(t.to_dict())
    assert summary["n_spans"] == 2
    assert summary["n_instants"] == 1
    assert summary["n_counters"] == 1
    assert summary["span_names"] == ["inner", "outer"]
    assert list(summary["tracks"].values()) == [threading.current_thread().name]


def test_spans_survive_exceptions():
    t = Tracer(enabled=True)
    with t.span("before"):
        pass
    with pytest.raises(RuntimeError):
        with t.span("doomed", batch=3):
            with t.span("inner"):
                raise RuntimeError("boom")
    trace = t.to_dict()
    summary = validate_trace(trace)  # every B matched despite the raise
    assert summary["span_names"] == ["before", "doomed", "inner"]
    closes = [ev for ev in trace["traceEvents"]
              if ev.get("ph") == "E" and ev.get("args", {}).get("error")]
    assert {ev["name"] for ev in closes} == {"doomed", "inner"}
    assert all(ev["args"]["error"] == "RuntimeError" for ev in closes)


def test_abandoned_span_is_end_capped_at_export():
    t = Tracer(enabled=True)

    def worker():
        t.span("left.open").__enter__()  # thread dies without __exit__

    th = threading.Thread(target=worker, name="dying-thread")
    th.start()
    th.join()
    trace = t.to_dict()
    validate_trace(trace)  # would raise on an unmatched B
    caps = [ev for ev in trace["traceEvents"]
            if ev.get("args", {}).get("capped")]
    assert len(caps) == 1 and caps[0]["name"] == "left.open"


def test_complete_records_retroactive_span():
    t = Tracer(enabled=True)
    t0 = t.now_ns()
    t1 = t0 + 5_000_000  # 5 ms
    t.complete("stall", t0, t1, pending=2)
    ivals = span_intervals(t.to_dict(), "stall")
    assert len(ivals) == 1
    start, end, name, _ = ivals[0]
    assert name == "stall"
    assert end - start == pytest.approx(5_000.0)  # us


def test_complete_on_virtual_track():
    """complete_on places retroactive spans on a named virtual track (the
    comm.* collectives tier) without violating the per-track timestamp
    monotonicity the validator enforces."""
    t = Tracer(enabled=True)
    with t.span("train.step"):
        pass
    t0 = t.now_ns()
    t.complete_on("comm.allreduce", "comm.allreduce",
                  t0 - 4_000_000, t0 - 2_000_000, interpod_bytes=1234)
    t.complete_on("comm.allreduce", "comm.allreduce",
                  t0 - 2_000_000, t0, interpod_bytes=1234)
    d = t.to_dict()
    names = set(t.track_names().values())
    assert "comm.allreduce" in names
    ivals = span_intervals(d, "comm.")
    assert len(ivals) == 2
    assert ivals[0][1] - ivals[0][0] == pytest.approx(2_000.0)  # us
    args = [e["args"] for e in d["traceEvents"]
            if e.get("ph") == "B" and e["name"] == "comm.allreduce"]
    assert args and all(a["interpod_bytes"] == 1234 for a in args)
    # retroactive spans starting before the last wall-clock event on
    # ANOTHER track must not trip the validator's monotonicity check
    validate_trace(d)


def test_tracks_named_after_threads():
    t = Tracer(enabled=True)
    with t.span("main.work"):
        pass

    def worker():
        with t.span("side.work"):
            pass

    th = threading.Thread(target=worker, name="side-thread")
    th.start()
    th.join()
    names = set(t.track_names().values())
    assert names == {threading.current_thread().name, "side-thread"}


def test_export_roundtrips_through_json(tmp_path, traced):
    with traced.span("a"):
        traced.instant("i")
    path = str(tmp_path / "trace.json")
    traced.export(path)
    with open(path) as f:
        loaded = json.load(f)
    assert validate_trace(loaded)["n_spans"] == 1
    assert loaded["traceEvents"][0]["name"] == "process_name"


# -------------------------------------------------------------- validator
def _base(events):
    return {"traceEvents": events}


def test_validator_rejects_unmatched_and_misnested():
    with pytest.raises(TraceError, match="no open B"):
        validate_trace(_base(
            [{"ph": "E", "pid": 1, "tid": 0, "ts": 1.0, "name": "x"}]))
    with pytest.raises(TraceError, match="improper nesting"):
        validate_trace(_base([
            {"ph": "B", "pid": 1, "tid": 0, "ts": 1.0, "name": "a"},
            {"ph": "B", "pid": 1, "tid": 0, "ts": 2.0, "name": "b"},
            {"ph": "E", "pid": 1, "tid": 0, "ts": 3.0, "name": "a"},
        ]))
    with pytest.raises(TraceError, match="unmatched B"):
        validate_trace(_base(
            [{"ph": "B", "pid": 1, "tid": 0, "ts": 1.0, "name": "a"}]))


def test_validator_rejects_backwards_time_and_bad_events():
    with pytest.raises(TraceError, match="ran backwards"):
        validate_trace(_base([
            {"ph": "i", "pid": 1, "tid": 0, "ts": 5.0, "name": "a", "s": "t"},
            {"ph": "i", "pid": 1, "tid": 0, "ts": 4.0, "name": "b", "s": "t"},
        ]))
    # per-track monotonicity only: another track may be earlier
    validate_trace(_base([
        {"ph": "i", "pid": 1, "tid": 0, "ts": 5.0, "name": "a", "s": "t"},
        {"ph": "i", "pid": 1, "tid": 1, "ts": 1.0, "name": "b", "s": "t"},
    ]))
    with pytest.raises(TraceError, match="missing ph"):
        validate_trace(_base([{"name": "x"}]))
    with pytest.raises(TraceError, match="traceEvents"):
        validate_trace({"events": []})


def test_validator_cli_on_garbage_file(tmp_path):
    from repro.obs.validate import main
    bad = tmp_path / "bad.json"
    bad.write_text("not json{")
    assert main([str(bad)]) == 1


def test_overlap_seconds_on_synthetic_trace():
    trace = _base([
        {"ph": "B", "pid": 1, "tid": 0, "ts": 0.0, "name": "fe.x"},
        {"ph": "E", "pid": 1, "tid": 0, "ts": 100.0, "name": "fe.x"},
        {"ph": "B", "pid": 1, "tid": 1, "ts": 60.0, "name": "train.step"},
        {"ph": "E", "pid": 1, "tid": 1, "ts": 160.0, "name": "train.step"},
    ])
    assert overlap_seconds(trace, "fe.", "train.") == pytest.approx(40e-6)
    assert overlap_seconds(trace, "fe.", "h2d.") == 0.0


# ---------------------------------------------------------------- metrics
def test_harvest_numeric_fields_and_properties():
    @dataclasses.dataclass
    class S:
        n: int = 3
        t: float = 1.5
        flag: bool = True
        name: str = "skip-me"
        items: list = dataclasses.field(default_factory=list)

        @property
        def rate(self) -> float:
            return self.n / 2.0

        @property
        def broken(self) -> float:
            raise ZeroDivisionError

        @property
        def label(self) -> str:
            return "skip-me-too"

    m = harvest(S())
    assert m == {"n": 3, "t": 1.5, "flag": 1, "rate": 1.5}


def test_registry_snapshot_prefixes_and_sources():
    reg = MetricsRegistry()
    reg.register("a", {"x": 1, "y": 2.0, "junk": "no"})
    reg.register("b", lambda: {"z": 3})
    reg.gauge("flops", 7.0)
    snap = reg.snapshot()
    assert snap == {"a.x": 1, "a.y": 2.0, "b.z": 3, "flops": 7.0}
    assert reg.tiers == ("a", "b")
    assert json.loads(reg.to_json()) == snap


def test_all_stats_tiers_implement_as_metrics():
    from repro.core.devicefeed import FeedStats
    from repro.core.metakernel import ExecutionStats
    from repro.core.pipeline import PipelineStats
    from repro.embedding.hierarchy import TierStats
    from repro.fe.modelfeed import TrainFeedStats
    from repro.io.stream import IngestStats
    from repro.train.loop import LoopStats

    for cls, key in ((IngestStats, "bytes_read"),
                     (FeedStats, "bytes_staged"),
                     (ExecutionStats, "n_device_dispatches"),
                     (PipelineStats, "overlap_fraction"),
                     (TrainFeedStats, "unique_ratio"),
                     (LoopStats, "steps"),
                     (TierStats, "host_hit_rate")):
        m = cls().as_metrics()
        assert key in m, f"{cls.__name__} missing {key}"
        assert all(isinstance(v, (int, float)) for v in m.values()), cls


def test_registry_from_pipeline_and_rollup():
    from repro.core.pipeline import PipelineStats
    from repro.io.stream import IngestStats

    stats = PipelineStats(batches=4, fe_seconds=1.0, train_seconds=2.0,
                          wall_seconds=2.5)
    stats.ingest = IngestStats(bytes_read=1000, read_seconds=0.25,
                               reader_stall_seconds=0.5)
    reg = MetricsRegistry.from_pipeline(stats)
    snap = reg.snapshot()
    assert snap["pipeline.batches"] == 4
    assert snap["ingest.bytes_read"] == 1000
    assert snap["rollup.stall_loader_backpressure_seconds"] == 0.5
    assert snap["rollup.overlap_fraction"] == stats.overlap_fraction
    assert "exec.n_device_dispatches" in snap
    roll = pipeline_rollup(stats)
    assert roll["train_busy_fraction"] == pytest.approx(2.0 / 2.5)
    # keys are stable even when tiers are absent
    bare = pipeline_rollup(PipelineStats())
    assert bare["disk_bytes"] == 0 and bare["h2d_seconds"] == 0.0


def test_tier_stats_eviction_accounting(tmp_path):
    from repro.embedding.hierarchy import HierarchicalPS

    ps = HierarchicalPS(str(tmp_path / "table.bin"), total_rows=64, dim=4,
                        host_cache_rows=8)
    ps.pull(np.arange(32))  # 32 unique rows through an 8-row cache
    assert ps.stats.evictions == 32 - 8
    assert ps.host_cache_size == 8
    ps.pull(np.arange(24, 32))  # cached tail: all host hits, no eviction
    assert ps.stats.host_hits == 8
    assert ps.stats.evictions == 32 - 8
    m = ps.stats.as_metrics()
    assert m["evictions"] == 24
    assert 0.0 < m["host_hit_rate"] < 1.0


# ------------------------------------------------------------ pipeline e2e
def _ads_plan():
    from repro.fe import featureplan, get_spec
    return featureplan.compile(get_spec("ads_ctr"))


def test_tracing_is_bit_effect_free(traced):
    """Same batches, tracing on vs off: bit-identical recorded outputs."""
    from repro.core import PipelinedRunner
    from repro.fe.datagen import gen_views

    plan = _ads_plan()
    batches = [gen_views(32, seed=7 + i) for i in range(3)]

    outs = []
    for enabled in (True, False):
        traced.enabled = enabled
        seen = []
        runner = PipelinedRunner.from_plan(plan, recording_step(seen),
                                           feed="arena", rows_hint=32)
        runner.run({"batches": 0}, [dict(b) for b in batches])
        outs.append(seen)
    on, off = outs
    assert len(on) == len(off) == 3
    for a, b in zip(on, off):
        assert set(a) == set(b)
        for k in a:
            assert a[k].dtype == b[k].dtype
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert traced.n_events > 0  # the traced pass really recorded


def test_e2e_streaming_trace_four_tracks_and_overlap(tmp_path, traced):
    """The acceptance timeline: disk -> FE -> H2D -> train on >= 4 tracks,
    FE/train spans overlapping, PipelineStats.overlap_fraction > 0."""
    import time

    from repro.core import PipelinedRunner
    from repro.fe.datagen import write_log_shards
    from repro.io.dataset import ShardDataset
    from repro.io.stream import StreamingLoader

    plan = _ads_plan()
    write_log_shards(str(tmp_path), n_shards=6, rows_per_shard=64, seed=0)
    loader = StreamingLoader(ShardDataset(str(tmp_path)), workers=2,
                             prefetch=2, columns=plan.required_columns)

    def slow_train(state, env):
        time.sleep(0.03)  # make train long enough that FE must overlap it
        return {"batches": state["batches"] + 1}

    runner = PipelinedRunner.from_plan(plan, slow_train, feed="arena",
                                       rows_hint=loader.rows_hint)
    state = runner.run({"batches": 0}, loader)
    runner.stats.ingest = loader.stats
    assert state["batches"] == 6

    path = str(tmp_path / "trace.json")
    trace = traced.export(path)
    summary = validate_trace(path)
    names = set(summary["tracks"].values())
    # loader readers + FE worker + H2D feeder + train loop
    assert {"fe-worker", "h2d-feeder"} <= names
    assert any(n.startswith("shard-reader") for n in names)
    assert threading.current_thread().name in names
    assert len(names) >= 4
    # the pipelining claim, measured two independent ways:
    assert runner.stats.overlap_fraction > 0, runner.stats
    assert overlap_seconds(trace, "fe.", "train.step") > 0
    # the stats tiers all made it into one snapshot
    snap = MetricsRegistry.from_pipeline(runner.stats).snapshot()
    assert snap["rollup.disk_bytes"] > 0
    assert snap["feed.batches"] == 6
    assert snap["pipeline.overlap_fraction"] > 0
