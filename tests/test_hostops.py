"""Vectorized host string ops vs their per-row ``_ref`` oracles, plus
cross-process hash determinism (the PYTHONHASHSEED regression).

Hypothesis property tests live in tests/test_hostops_property.py so these
deterministic checks run on hypothesis-free installs too.
"""

import os
import subprocess
import sys

import numpy as np

from repro.fe.colstore import RaggedColumn
from repro.fe.ops import (
    _WHITESPACE_CODEPOINTS,
    ragged_to_padded,
    ragged_to_padded_ref,
    tokenize_hash,
    tokenize_hash_ref,
)


def assert_ragged_equal(a: RaggedColumn, b: RaggedColumn) -> None:
    np.testing.assert_array_equal(a.lengths, b.lengths)
    np.testing.assert_array_equal(a.values, b.values)
    assert a.values.dtype == b.values.dtype
    assert a.lengths.dtype == b.lengths.dtype


# ------------------------------------------------------------ tokenization
def test_whitespace_table_matches_python_exactly():
    """The vectorized tokenizer's separator set IS ``str.split()``'s: every
    codepoint agrees with ``chr(c).isspace()`` over the whole Unicode range
    (surrogates excluded — they can't appear in well-formed strings)."""
    ws = {int(c) for c in _WHITESPACE_CODEPOINTS}
    for c in range(0x110000):
        if 0xD800 <= c <= 0xDFFF:
            continue
        assert (c in ws) == chr(c).isspace(), hex(c)


def test_tokenize_hash_known_edges():
    cases = [
        ["a b c", "", "a a"],
        ["  leading and trailing  ", "\t\n\x0b\x0c\r mixed \x1c\x1d\x1e\x1f"],
        [" nbsp em　ideographic", "\x00nul\x00separates"],
        ["\U0001f680 emoji tokens \U0001f680", "héllo wörld"],
        ["single"],
        [],
        ["", "", ""],
        ["x" * 500 + " tail"],  # one very long token
    ]
    for ngrams in (1, 2, 3):
        for rows in cases:
            arr = np.asarray(rows, object)
            assert_ragged_equal(
                tokenize_hash(arr, field_size=1009, ngrams=ngrams),
                tokenize_hash_ref(arr, field_size=1009, ngrams=ngrams))


def test_tokenize_hash_bytes_dtype_matches_ref():
    """S-dtype rows must take the same str() route as object rows do in
    the ref (the repr form, not a decode) — regression for a vec/ref
    divergence on bytes columns."""
    for rows in (np.asarray([b"ab cd", b"", b"x"]),
                 np.asarray([b"ab cd", b"x y z", "plain", 3], object),
                 np.asarray([1, 22, 333])):
        assert_ragged_equal(tokenize_hash(rows, field_size=1000, ngrams=2),
                            tokenize_hash_ref(rows, field_size=1000, ngrams=2))


def test_identical_tokens_hash_identically():
    col = tokenize_hash(np.asarray(["tok other tok"], object),
                        field_size=1 << 20)
    row = col.row(0)
    assert row[0] == row[2] != row[1]


def test_tokenize_hash_deterministic_across_processes():
    """Token ids must not depend on the builtin ``hash()``: a fresh
    interpreter with a different PYTHONHASHSEED must produce identical
    ids (multi-host training shards features by id)."""
    code = (
        "import numpy as np\n"
        "from repro.fe.ops import tokenize_hash\n"
        "c = tokenize_hash(np.asarray(['alpha beta gamma', 'x \\u00e9y'],"
        " object), field_size=10007, ngrams=2)\n"
        "print(','.join(map(str, c.values)), ','.join(map(str, c.lengths)))\n"
    )
    src_root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    outs = set()
    for seed in ("0", "1", "424242"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        outs.add(subprocess.check_output(
            [sys.executable, "-c", code], env=env).decode().strip())
    assert len(outs) == 1, f"token ids vary across processes: {outs}"


# ------------------------------------------------------------ ragged pad
def test_ragged_to_padded_truncates_and_masks():
    col = RaggedColumn(values=np.arange(10, dtype=np.int64),
                       lengths=np.asarray([3, 0, 7], np.int32))
    ids, mask = ragged_to_padded(col, max_len=4, pad_id=-5)
    np.testing.assert_array_equal(ids[0], [0, 1, 2, -5])
    np.testing.assert_array_equal(ids[1], [-5] * 4)
    np.testing.assert_array_equal(ids[2], [3, 4, 5, 6])  # truncated at 4
    assert mask.sum() == 3 + 0 + 4


def test_ragged_to_padded_edge_shapes_match_ref():
    empty = RaggedColumn(values=np.zeros((0,), np.int64),
                         lengths=np.zeros((0,), np.int32))
    allzero = RaggedColumn(values=np.zeros((0,), np.int64),
                           lengths=np.zeros((5,), np.int32))
    long = RaggedColumn(values=np.arange(1000, dtype=np.int64),
                        lengths=np.asarray([1000], np.int32))
    for col in (empty, allzero, long):
        for max_len in (0, 1, 8, 2048):
            a_ids, a_mask = ragged_to_padded(col, max_len=max_len)
            b_ids, b_mask = ragged_to_padded_ref(col, max_len=max_len)
            np.testing.assert_array_equal(a_ids, b_ids)
            np.testing.assert_array_equal(a_mask, b_mask)
