"""Hypothesis property: the static aliasing analyzer and the runtime
planners (prefix-sum plan, Pallas kernel, ArenaPool) agree on EVERY random
layout — the analyzer's shadow plan is a faithful model, not a lookalike."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.check.aliasing import (  # noqa: E402
    _shadow_plan,
    check_feed_layout,
    check_plan,
)
from repro.core.devicefeed import FeedLayout, SlotSpec  # noqa: E402
from repro.core.mempool import ALIGN, ArenaPool, align_up  # noqa: E402

_DTYPES = ("float32", "int32", "int64", "float64", "uint8")


@st.composite
def layouts(draw):
    n = draw(st.integers(min_value=1, max_value=8))
    slots = []
    for i in range(n):
        width = draw(st.integers(min_value=1, max_value=64))
        rank1 = draw(st.booleans())
        slots.append(SlotSpec(f"slot{i:02d}", 1 if rank1 else width,
                              draw(st.sampled_from(_DTYPES)), rank1=rank1))
    return FeedLayout(slots=tuple(slots))


@hypothesis.settings(max_examples=60, deadline=None)
@hypothesis.given(layout=layouts(),
                  rows=st.integers(min_value=0, max_value=4096))
def test_analyzer_passes_every_valid_layout(layout, rows):
    findings = check_feed_layout(layout, rows)
    assert findings == [], "\n".join(f.render() for f in findings)


@hypothesis.settings(max_examples=60, deadline=None)
@hypothesis.given(layout=layouts(),
                  rows=st.integers(min_value=0, max_value=4096))
def test_shadow_plan_matches_arena_pool_exactly(layout, rows):
    sizes = layout.sizes(rows)
    offsets, end = _shadow_plan(sizes, layout.align)
    total = align_up(end, layout.align)
    pool = ArenaPool(total, align=layout.align)
    allocs = pool.alloc_block(sizes)
    assert [a.offset for a in allocs] == offsets
    # The runtime planner agrees too (the tri-oracle's second leg).
    plan_offsets, plan_total = layout.plan(rows)
    assert list(np.asarray(plan_offsets)) == offsets
    assert int(plan_total) == total == layout.arena_bytes(rows)


@hypothesis.settings(max_examples=60, deadline=None)
@hypothesis.given(
    sizes=st.lists(st.integers(min_value=0, max_value=1 << 20),
                   min_size=1, max_size=10))
def test_shadow_plan_invariants_hold_for_raw_sizes(sizes):
    offsets, end = _shadow_plan(sizes, ALIGN)
    total = align_up(end, ALIGN)
    assert check_plan(sizes, offsets, total) == []


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(
    sizes=st.lists(st.integers(min_value=1, max_value=1 << 16),
                   min_size=2, max_size=8),
    victim=st.integers(min_value=1, max_value=7),
    shift=st.integers(min_value=1, max_value=ALIGN - 1))
def test_any_offset_perturbation_is_caught(sizes, victim, shift):
    """Completeness: shifting any planned offset off its slot either
    collides (AL201), misaligns (AL202), or overruns (AL201)."""
    offsets, end = _shadow_plan(sizes, ALIGN)
    total = align_up(end, ALIGN)
    victim %= len(sizes)
    bad = list(offsets)
    bad[victim] -= shift  # lands inside the previous slot or misaligns
    findings = check_plan(sizes, bad, total)
    assert findings, "perturbed plan must not verify clean"
    assert {f.rule for f in findings} <= {"AL201", "AL202"}
