"""Pipelined (FeatureBox) vs staged (MapReduce-style) executors:
identical results, intermediate I/O eliminated (paper Table II semantics)."""

import tempfile

from conftest import pipeline_threads_gone

import numpy as np

from repro.core import (
    PipelinedRunner,
    StagedRunner,
    build_schedule,
    compile_layers,
)
from repro.fe.datagen import gen_views
from repro.fe.pipeline_graph import build_fe_graph


def _batches(n, rows=64):
    return [gen_views(rows, seed=100 + i) for i in range(n)]


def _train_step_factory():
    """Accumulate a checksum + count of consumed batches as 'training'."""
    def train_step(state, env):
        s = float(np.asarray(env["batch_dense"]).sum()) + float(
            np.asarray(env["batch_sparse"]).sum())
        return {"sum": state["sum"] + s, "batches": state["batches"] + 1}
    return train_step


def test_pipelined_equals_staged_and_saves_io():
    layers = compile_layers(build_schedule(build_fe_graph()))
    batches = _batches(3)

    pipe = PipelinedRunner(layers, _train_step_factory(), prefetch=2)
    s_pipe = pipe.run({"sum": 0.0, "batches": 0}, [dict(b) for b in batches])

    staged = StagedRunner(layers, _train_step_factory(),
                          workdir=tempfile.mkdtemp())
    s_staged = staged.run({"sum": 0.0, "batches": 0}, [dict(b) for b in batches])

    assert s_pipe["batches"] == s_staged["batches"] == 3
    np.testing.assert_allclose(s_pipe["sum"], s_staged["sum"], rtol=1e-6)

    # the Table II claim: pipelining eliminates ALL intermediate I/O
    assert pipe.stats.intermediate_bytes == 0
    assert staged.stats.intermediate_bytes > 10_000
    assert staged.stats.batches == pipe.stats.batches


def test_pipelined_overlaps_host_and_device():
    """FE for batch i+1 runs while training batch i (wall < fe + train)."""
    import time

    layers = compile_layers(build_schedule(build_fe_graph()))

    def slow_train(state, env):
        time.sleep(0.05)
        return state

    pipe = PipelinedRunner(layers, slow_train, prefetch=2)
    pipe.run({}, [dict(b) for b in _batches(4)])
    overlap = pipe.stats.fe_seconds + pipe.stats.train_seconds - pipe.stats.wall_seconds
    assert overlap > 0, (
        f"no overlap: fe={pipe.stats.fe_seconds:.3f} train={pipe.stats.train_seconds:.3f} "
        f"wall={pipe.stats.wall_seconds:.3f}")


def test_train_step_error_releases_fe_worker():
    """A failing train_step must not leave the FE worker blocked on a full
    prefetch queue (thread + decoded-batch leak per failed run)."""
    import threading
    import time

    layers = compile_layers(build_schedule(build_fe_graph()))

    def bad_step(state, env):
        raise ValueError("train blew up")

    pipe = PipelinedRunner(layers, bad_step, prefetch=1)
    import pytest
    with pytest.raises(ValueError, match="train blew up"):
        pipe.run({}, [dict(b) for b in _batches(4)])
    deadline = time.perf_counter() + 5.0
    while time.perf_counter() < deadline and any(
            t.name == "fe-worker" for t in threading.enumerate()):
        time.sleep(0.05)
    assert not [t for t in threading.enumerate() if t.name == "fe-worker"]


def test_pipeline_propagates_worker_errors():
    layers = compile_layers(build_schedule(build_fe_graph()))
    pipe = PipelinedRunner(layers, lambda s, e: s)

    def bad_batches():
        yield {"impressions": None}  # malformed -> FE worker raises

    import pytest
    with pytest.raises(KeyError):  # the malformed batch's missing view
        pipe.run({}, bad_batches())


def test_train_step_error_mid_run_stops_and_joins_worker():
    """train_step raising mid-run (not on batch 0) must drain the queue and
    join the FE worker within the timeout, with partial progress recorded."""
    import pytest

    layers = compile_layers(build_schedule(build_fe_graph()))
    calls = {"n": 0}

    def explode_later(state, env):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("mid-run failure")
        return state

    pipe = PipelinedRunner(layers, explode_later, prefetch=1)
    with pytest.raises(RuntimeError, match="mid-run failure"):
        pipe.run({}, [dict(b) for b in _batches(5)])
    assert calls["n"] == 2
    assert pipe.stats.batches == 1  # only the pre-failure batch counted
    assert pipeline_threads_gone()
    assert pipe.stats.wall_seconds > 0  # finally-path accounting still runs


def test_batch_source_error_surfaces_original_exception():
    """An iterator raising mid-stream must surface *its* exception (not a
    bare _DONE/stop artifact), after the prior good batches trained."""
    import pytest

    layers = compile_layers(build_schedule(build_fe_graph()))

    def flaky_batches():
        yield dict(_batches(1)[0])
        raise OSError("shard rot at offset 42")

    pipe = PipelinedRunner(layers, lambda s, e: s, prefetch=2)
    with pytest.raises(OSError, match="shard rot at offset 42"):
        pipe.run({}, flaky_batches())
    assert pipe.stats.batches == 1
    assert pipeline_threads_gone()


def test_staged_drain_time_accounted():
    """StagedRunner: time draining a slow batch source must land in
    drain_seconds — not in the wall - fe - train gap — so the accounting
    closes (the gap no longer misreads ingest time as overhead)."""
    import time

    layers = compile_layers(build_schedule(build_fe_graph()))
    delay = 0.08

    def slow_source():
        for b in _batches(3, rows=32):
            time.sleep(delay)
            yield dict(b)

    staged = StagedRunner(layers, _train_step_factory(),
                          workdir=tempfile.mkdtemp())
    staged.run({"sum": 0.0, "batches": 0}, slow_source())
    s = staged.stats
    assert s.drain_seconds >= 3 * delay * 0.9
    overhead = s.wall_seconds - s.fe_seconds - s.train_seconds - s.drain_seconds
    assert overhead >= 0
    assert overhead < 3 * delay  # the drain time left the "overhead" gap


def test_pipelined_drain_seconds_zero():
    """The pipelined runner never drains up front: the field stays 0."""
    layers = compile_layers(build_schedule(build_fe_graph()))
    pipe = PipelinedRunner(layers, _train_step_factory(), prefetch=2)
    pipe.run({"sum": 0.0, "batches": 0}, [dict(b) for b in _batches(2)])
    assert pipe.stats.drain_seconds == 0.0


# -------------------------------------------------------- train-feed tier
def _adapting_step_factory(adapt_delay=0.0):
    """Train step carrying modelfeed-style feed_stats: the runners must
    adopt them into PipelineStats.train_feed and split adapt from train."""
    import time

    from repro.fe.modelfeed import TrainFeedStats

    stats = TrainFeedStats()

    def train_step(state, env):
        t0 = time.perf_counter()
        if adapt_delay:
            time.sleep(adapt_delay)
        stats.adapt_seconds += time.perf_counter() - t0
        stats.steps += 1
        stats.fused_steps += 1
        return {"sum": state["sum"], "batches": state["batches"] + 1}

    train_step.feed_stats = stats
    return train_step


def test_runners_adopt_train_feed_stats():
    layers = compile_layers(build_schedule(build_fe_graph()))
    for make in (
        lambda s: PipelinedRunner(layers, s, prefetch=2),
        lambda s: StagedRunner(layers, s, workdir=tempfile.mkdtemp()),
    ):
        step = _adapting_step_factory()
        runner = make(step)
        runner.run({"sum": 0.0, "batches": 0},
                   [dict(b) for b in _batches(2, rows=32)])
        assert runner.stats.train_feed is step.feed_stats
        assert runner.stats.train_feed.steps == 2


def test_train_feed_splits_adapt_from_train():
    """The adapt share measured by the boundary step is split out of the
    train bucket: train_net_seconds + adapt_seconds == train_seconds."""
    delay = 0.05
    layers = compile_layers(build_schedule(build_fe_graph()))
    step = _adapting_step_factory(adapt_delay=delay)
    runner = PipelinedRunner(layers, step, prefetch=2)
    runner.run({"sum": 0.0, "batches": 0},
               [dict(b) for b in _batches(3, rows=32)])
    s = runner.stats
    assert s.adapt_seconds >= 3 * delay * 0.9
    assert s.train_net_seconds <= s.train_seconds - s.adapt_seconds + 1e-9
    assert abs((s.train_net_seconds + s.adapt_seconds) - s.train_seconds) \
        < 1e-6


def test_train_feed_absent_without_feed_stats():
    layers = compile_layers(build_schedule(build_fe_graph()))
    runner = PipelinedRunner(layers, _train_step_factory(), prefetch=2)
    runner.run({"sum": 0.0, "batches": 0},
               [dict(b) for b in _batches(1, rows=16)])
    assert runner.stats.train_feed is None
    assert runner.stats.adapt_seconds == 0.0
    assert runner.stats.train_net_seconds == runner.stats.train_seconds


# ------------------------------------------- derived accounting invariants
def test_accounting_identity_exact_arithmetic():
    """overhead/overlap are exact complements of wall - busy: overhead is
    never negative, at most one of the two is nonzero, and
    wall == busy + overhead - overlap holds to float precision."""
    from repro.core.pipeline import PipelineStats

    cases = [
        # (fe, train, drain, wall)
        (1.0, 2.0, 0.0, 3.5),   # serial-ish: overhead 0.5
        (1.0, 2.0, 0.0, 2.4),   # pipelined: overlap 0.6
        (1.0, 2.0, 0.5, 3.5),   # exact: overhead == overlap == 0
        (0.0, 0.0, 0.0, 0.0),   # empty run
        (0.3, 5.0, 0.0, 5.05),  # train-bound
    ]
    for fe, train, drain, wall in cases:
        s = PipelineStats(fe_seconds=fe, train_seconds=train,
                          drain_seconds=drain, wall_seconds=wall)
        assert s.busy_seconds == fe + train + drain
        assert s.overhead_seconds >= 0.0
        assert s.overlap_seconds >= 0.0
        assert s.overhead_seconds * s.overlap_seconds == 0.0
        assert abs(s.wall_seconds
                   - (s.busy_seconds + s.overhead_seconds
                      - s.overlap_seconds)) < 1e-12
        # the ISSUE invariant: wall <= fe + train_net + adapt + drain + overhead
        assert s.wall_seconds <= (s.fe_seconds + s.train_net_seconds
                                  + s.adapt_seconds + s.drain_seconds
                                  + s.overhead_seconds + 1e-12)
        assert 0.0 <= s.overlap_fraction <= 1.0


def test_overlap_fraction_bounds_and_degenerate_cases():
    from repro.core.pipeline import PipelineStats

    # full overlap: the shorter stage entirely hidden
    s = PipelineStats(fe_seconds=1.0, train_seconds=3.0, wall_seconds=3.0)
    assert s.overlap_fraction == 1.0
    # no train stage at all: fraction defined as 0, not a ZeroDivision
    s = PipelineStats(fe_seconds=1.0, train_seconds=0.0, wall_seconds=1.0)
    assert s.overlap_fraction == 0.0
    # overlap can exceed min(fe, train) only through float noise: clamped
    s = PipelineStats(fe_seconds=0.5, train_seconds=10.0, wall_seconds=9.0)
    assert s.overlap_fraction == 1.0


def test_accounting_invariant_real_pipelined_run():
    """A real pipelined run: overhead never negative, identity closes, and
    the overlap the run was built to produce is visible."""
    import time

    layers = compile_layers(build_schedule(build_fe_graph()))

    def slow_train(state, env):
        time.sleep(0.03)
        return {"sum": state["sum"], "batches": state["batches"] + 1}

    pipe = PipelinedRunner(layers, slow_train, prefetch=2)
    pipe.run({"sum": 0.0, "batches": 0}, [dict(b) for b in _batches(4)])
    s = pipe.stats
    assert s.overhead_seconds >= 0.0
    assert s.wall_seconds <= (s.fe_seconds + s.train_net_seconds
                              + s.adapt_seconds + s.drain_seconds
                              + s.overhead_seconds + 1e-9)
    assert s.overlap_fraction > 0.0


def test_accounting_invariant_serial_staged_run():
    """StagedRunner is serial: busy time can never exceed wall, so the
    identity holds with equality (overlap exactly 0)."""
    layers = compile_layers(build_schedule(build_fe_graph()))
    staged = StagedRunner(layers, _train_step_factory(),
                          workdir=tempfile.mkdtemp())
    staged.run({"sum": 0.0, "batches": 0}, [dict(b) for b in _batches(3)])
    s = staged.stats
    assert s.overlap_seconds == 0.0
    assert s.overlap_fraction == 0.0
    assert abs(s.wall_seconds - (s.fe_seconds + s.train_net_seconds
                                 + s.adapt_seconds + s.drain_seconds
                                 + s.overhead_seconds)) < 1e-9
