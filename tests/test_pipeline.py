"""Pipelined (FeatureBox) vs staged (MapReduce-style) executors:
identical results, intermediate I/O eliminated (paper Table II semantics)."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    PipelinedRunner,
    StagedRunner,
    build_schedule,
    compile_layers,
)
from repro.fe.datagen import gen_views
from repro.fe.pipeline_graph import build_fe_graph


def _batches(n, rows=64):
    return [gen_views(rows, seed=100 + i) for i in range(n)]


def _train_step_factory():
    """Accumulate a checksum + count of consumed batches as 'training'."""
    def train_step(state, env):
        s = float(np.asarray(env["batch_dense"]).sum()) + float(
            np.asarray(env["batch_sparse"]).sum())
        return {"sum": state["sum"] + s, "batches": state["batches"] + 1}
    return train_step


def test_pipelined_equals_staged_and_saves_io():
    layers = compile_layers(build_schedule(build_fe_graph()))
    batches = _batches(3)

    pipe = PipelinedRunner(layers, _train_step_factory(), prefetch=2)
    s_pipe = pipe.run({"sum": 0.0, "batches": 0}, [dict(b) for b in batches])

    staged = StagedRunner(layers, _train_step_factory(),
                          workdir=tempfile.mkdtemp())
    s_staged = staged.run({"sum": 0.0, "batches": 0}, [dict(b) for b in batches])

    assert s_pipe["batches"] == s_staged["batches"] == 3
    np.testing.assert_allclose(s_pipe["sum"], s_staged["sum"], rtol=1e-6)

    # the Table II claim: pipelining eliminates ALL intermediate I/O
    assert pipe.stats.intermediate_bytes == 0
    assert staged.stats.intermediate_bytes > 10_000
    assert staged.stats.batches == pipe.stats.batches


def test_pipelined_overlaps_host_and_device():
    """FE for batch i+1 runs while training batch i (wall < fe + train)."""
    import time

    layers = compile_layers(build_schedule(build_fe_graph()))

    def slow_train(state, env):
        time.sleep(0.05)
        return state

    pipe = PipelinedRunner(layers, slow_train, prefetch=2)
    pipe.run({}, [dict(b) for b in _batches(4)])
    overlap = pipe.stats.fe_seconds + pipe.stats.train_seconds - pipe.stats.wall_seconds
    assert overlap > 0, (
        f"no overlap: fe={pipe.stats.fe_seconds:.3f} train={pipe.stats.train_seconds:.3f} "
        f"wall={pipe.stats.wall_seconds:.3f}")


def test_train_step_error_releases_fe_worker():
    """A failing train_step must not leave the FE worker blocked on a full
    prefetch queue (thread + decoded-batch leak per failed run)."""
    import threading
    import time

    layers = compile_layers(build_schedule(build_fe_graph()))

    def bad_step(state, env):
        raise ValueError("train blew up")

    pipe = PipelinedRunner(layers, bad_step, prefetch=1)
    import pytest
    with pytest.raises(ValueError, match="train blew up"):
        pipe.run({}, [dict(b) for b in _batches(4)])
    deadline = time.perf_counter() + 5.0
    while time.perf_counter() < deadline and any(
            t.name == "fe-worker" for t in threading.enumerate()):
        time.sleep(0.05)
    assert not [t for t in threading.enumerate() if t.name == "fe-worker"]


def test_pipeline_propagates_worker_errors():
    layers = compile_layers(build_schedule(build_fe_graph()))
    pipe = PipelinedRunner(layers, lambda s, e: s)

    def bad_batches():
        yield {"impressions": None}  # malformed -> FE worker raises

    import pytest
    with pytest.raises(Exception):
        pipe.run({}, bad_batches())
