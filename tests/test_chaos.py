"""Chaos regression tests: injected faults vs the loader's recovery story.

The contract under test (ROADMAP item 4): kill/delay/starve readers
mid-epoch and the consumed stream is *bit-identical* to the failure-free
run — zero lost shards, zero duplicates — with the recovery visible in the
``fault.*`` metrics tier and trace spans. Transient I/O errors are
absorbed by bounded retry; corruption still fails fast.
"""

import numpy as np
import pytest

from repro.fe.datagen import gen_views, write_log_shards
from repro.io import (
    ChaosEvent,
    ChaosInjector,
    ChaosTransientIOError,
    ShardDataset,
    ShardFormatError,
    StreamingLoader,
    parse_chaos_spec,
    random_schedule,
)
from repro.obs import MetricsRegistry, Tracer, set_tracer


@pytest.fixture
def traced():
    tracer = Tracer(enabled=True)
    prev = set_tracer(tracer)
    yield tracer
    set_tracer(prev)


def _ids(env):
    return env["impressions"]["instance_id"]


def _loader(d, *, chaos=None, ordered=True, workers=2, lease_timeout=0.4,
            **kw):
    return StreamingLoader(ShardDataset(d), workers=workers, prefetch=2,
                           ordered=ordered, lease_timeout=lease_timeout,
                           chaos=chaos, **kw)


# ------------------------------------------------------------ kill recovery
@pytest.mark.parametrize("spec", ["kill@3", "kill@2:commit,kill@5:acquire"])
def test_chaos_kill_consumed_stream_bit_identical(tmp_path, spec):
    """Readers killed mid-epoch (at every injection point) must not lose
    or duplicate a shard: the ordered consumed stream equals the
    failure-free run bit for bit, and the recovery shows up in stats."""
    d = str(tmp_path)
    write_log_shards(d, n_shards=8, rows_per_shard=32, seed=7)

    baseline = [_ids(env) for env in _loader(d)]
    assert len(baseline) == 8

    chaos = ChaosInjector.from_spec(spec)
    loader = _loader(d, chaos=chaos)
    got = [_ids(env) for env in loader]

    assert len(got) == len(baseline)
    for a, b in zip(got, baseline):
        np.testing.assert_array_equal(a, b)
    assert chaos.exhausted(), "scheduled kills never fired"
    fs = loader.fault_stats
    assert fs.completed == 8
    # the killed shard came back via reap/reissue or a backup lease
    assert fs.reissued + fs.backup_wins >= 1
    assert fs.respawned >= 1  # dead reader replaced by the consumer
    assert loader.stats.shards == 8  # exactly-once ingest accounting


def test_chaos_kill_multiset_identical_unordered(tmp_path):
    """Without the reorder buffer order may differ, but the multiset of
    consumed shards must still be exact (no loss, no dups)."""
    d = str(tmp_path)
    write_log_shards(d, n_shards=6, rows_per_shard=16, seed=3)
    chaos = ChaosInjector.from_spec("kill@1,kill@4")
    loader = _loader(d, chaos=chaos, ordered=False, workers=3)
    got = sorted(int(_ids(env)[0]) for env in loader)
    want = sorted(int(gen_views(16, seed=3 + i)["impressions"]
                      ["instance_id"][0]) for i in range(6))
    assert got == want
    assert loader.fault_stats.completed == 6


def test_chaos_kill_single_worker_pool_respawns(tmp_path):
    """workers=1 and the only reader dies: the consumer must respawn a
    replacement (otherwise the epoch hangs forever)."""
    d = str(tmp_path)
    write_log_shards(d, n_shards=4, rows_per_shard=16, seed=1)
    chaos = ChaosInjector.from_spec("kill@2")
    loader = _loader(d, chaos=chaos, workers=1, lease_timeout=0.3)
    assert len(list(loader)) == 4
    assert loader.fault_stats.respawned >= 1


def test_chaos_kill_everything_exhausts_respawn_budget(tmp_path):
    """A schedule that kills every attempt at a shard must surface as a
    pool-exhausted error, not an infinite respawn loop."""
    d = str(tmp_path)
    write_log_shards(d, n_shards=2, rows_per_shard=8, seed=0)
    chaos = ChaosInjector([ChaosEvent("kill", 0, "read", count=100)])
    loader = _loader(d, chaos=chaos, workers=1, lease_timeout=0.1,
                     max_respawns=3)
    with pytest.raises(RuntimeError, match="reader pool exhausted"):
        list(loader)
    loader.close()


# ------------------------------------------------------------ retry policy
def test_chaos_transient_errors_absorbed_by_retry(tmp_path, traced):
    d = str(tmp_path)
    write_log_shards(d, n_shards=4, rows_per_shard=16, seed=2)
    chaos = ChaosInjector.from_spec("transient@1:read:2")
    loader = _loader(d, chaos=chaos, retries=2, retry_backoff=0.01)
    baseline = [_ids(e) for e in _loader(d)]
    got = [_ids(e) for e in loader]
    for a, b in zip(got, baseline):
        np.testing.assert_array_equal(a, b)
    fs = loader.fault_stats
    assert fs.retries == 2
    assert fs.completed == 4 and fs.failed_workers == 0
    names = {ev["name"] for ev in traced.to_dict()["traceEvents"]}
    assert "io.retry" in names  # each retry leaves a span


def test_chaos_transient_beyond_retry_budget_fails(tmp_path):
    d = str(tmp_path)
    write_log_shards(d, n_shards=2, rows_per_shard=8, seed=4)
    chaos = ChaosInjector.from_spec("transient@0:read:3")
    loader = _loader(d, chaos=chaos, retries=0)
    with pytest.raises(RuntimeError, match="shard reader failed") as ei:
        list(loader)
    assert isinstance(ei.value.__cause__, ChaosTransientIOError)
    assert isinstance(ei.value.__cause__, OSError)
    loader.close()


def test_chaos_corruption_fails_fast_never_retried(tmp_path):
    """ShardFormatError must not be absorbed by the OSError retry loop —
    corruption means wrong bytes, and retrying wrong bytes is data loss."""
    d = str(tmp_path)
    write_log_shards(d, n_shards=3, rows_per_shard=8, seed=6)
    chaos = ChaosInjector.from_spec("corrupt@1")
    loader = _loader(d, chaos=chaos, retries=5)
    with pytest.raises(RuntimeError, match="shard reader failed") as ei:
        list(loader)
    assert isinstance(ei.value.__cause__, ShardFormatError)
    assert loader.fault_stats.retries == 0  # fail fast, zero retries
    loader.close()


def test_chaos_delay_only_changes_nothing(tmp_path):
    d = str(tmp_path)
    write_log_shards(d, n_shards=3, rows_per_shard=8, seed=8)
    chaos = ChaosInjector.from_spec("delay@0:read:0.02,delay@2:read:0.02")
    loader = _loader(d, chaos=chaos)
    baseline = [_ids(e) for e in _loader(d)]
    got = [_ids(e) for e in loader]
    for a, b in zip(got, baseline):
        np.testing.assert_array_equal(a, b)
    assert chaos.fired["delay"] == 2
    assert loader.fault_stats.reissued == 0


def test_chaos_random_soak_completes_exactly_once(tmp_path):
    """Seeded random schedule (kills + transients + delays, no corrupt):
    the epoch still completes with the exact shard multiset."""
    d = str(tmp_path)
    write_log_shards(d, n_shards=10, rows_per_shard=8, seed=9)
    chaos = ChaosInjector.random(seed=1234, n_shards=10, p_kill=0.3,
                                 p_transient=0.3, p_delay=0.3)
    loader = _loader(d, chaos=chaos, workers=3, lease_timeout=0.3,
                     retries=3, retry_backoff=0.01)
    got = sorted(int(_ids(env)[0]) for env in loader)
    want = sorted(int(gen_views(8, seed=9 + i)["impressions"]
                      ["instance_id"][0]) for i in range(10))
    assert got == want
    assert loader.fault_stats.completed == 10


# ------------------------------------------------- observability surfacing
def test_fault_tier_flows_into_pipeline_metrics(tmp_path, traced):
    """PipelinedRunner captures the loader's FaultStats; the registry
    exposes it as the fault.* tier and the rollup's fault_* keys."""
    from repro.core import PipelinedRunner, build_schedule, compile_layers
    from repro.fe.pipeline_graph import build_fe_graph

    d = str(tmp_path / "log")
    write_log_shards(d, n_shards=4, rows_per_shard=32, seed=11)
    chaos = ChaosInjector.from_spec("kill@1")
    loader = _loader(d, chaos=chaos)

    def step(state, env):
        return {"batches": state["batches"] + 1}

    pipe = PipelinedRunner(compile_layers(build_schedule(build_fe_graph())),
                           step, prefetch=2)
    final = pipe.run({"batches": 0}, loader)
    assert final["batches"] == 4
    assert pipe.stats.fault is not None
    snap = MetricsRegistry.from_pipeline(pipe.stats).snapshot()
    assert snap["fault.completed"] == 4
    assert snap["fault.reissued"] + snap["fault.backup_wins"] >= 1
    assert snap["rollup.fault_reissued"] == snap["fault.reissued"]
    assert "rollup.fault_backup_wins" in snap
    names = {ev["name"] for ev in traced.to_dict()["traceEvents"]}
    assert "fault.kill" in names
    # (fault.respawn is only guaranteed when the pool has no survivor to
    # cover the shard — asserted in the single-worker respawn test)


# ------------------------------------------------------- schedule plumbing
def test_parse_chaos_spec_mini_language():
    evs = parse_chaos_spec(
        "kill@3,transient@1:read:2,delay@2:read:0.05,corrupt@5,kill@4:commit")
    assert [(e.kind, e.shard, e.point) for e in evs] == [
        ("kill", 3, "read"), ("transient", 1, "read"), ("delay", 2, "read"),
        ("corrupt", 5, "read"), ("kill", 4, "commit")]
    assert evs[1].count == 2
    assert evs[2].delay_seconds == pytest.approx(0.05)
    assert parse_chaos_spec("delay@0")[0].delay_seconds > 0  # default delay
    for bad in ("kill3", "kill@", "kill@x", "kill@1:read:2:junk",
                "frob@1", "kill@1:lunch"):
        with pytest.raises(ValueError):
            parse_chaos_spec(bad)


def test_random_schedule_is_seed_deterministic():
    a = random_schedule(seed=7, n_shards=50, p_kill=0.5, p_transient=0.5)
    b = random_schedule(seed=7, n_shards=50, p_kill=0.5, p_transient=0.5)
    assert a == b and len(a) > 0
    assert all(e.kind != "corrupt" for e in a)  # soaks stay completable
    assert random_schedule(seed=8, n_shards=50, p_kill=0.5) != a


def test_injector_counts_fires_and_exhaustion():
    inj = ChaosInjector([ChaosEvent("transient", 0, "read", count=2)])
    assert not inj.exhausted()
    for _ in range(2):
        with pytest.raises(ChaosTransientIOError):
            inj.trip("read", 0)
    inj.trip("read", 0)  # schedule spent: passes clean
    inj.trip("read", 1)  # unscheduled shard: passes clean
    assert inj.exhausted()
    assert inj.fired == {"kill": 0, "delay": 0, "transient": 2, "corrupt": 0}
    with pytest.raises(ValueError):
        ChaosEvent("kill", 0, point="lunch")
    with pytest.raises(ValueError):
        ChaosEvent("delay", 0)  # delay needs delay_seconds > 0


# ------------------------------------------------- remesh-resume contract
def test_checkpoint_meta_records_mesh_for_remesh_resume(tmp_path):
    """The driver stamps the save-time mesh into the checkpoint manifest;
    a restart under a different device count reads it back to report the
    topology change (the arrays themselves are host numpy — topology-free
    — and get re-placed by shard_train_state on the new mesh)."""
    from repro.train.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    mgr.save(3, tree, meta={"mesh": [2, 4]})
    assert mgr.latest_meta() == {"mesh": [2, 4]}
    step, restored = mgr.restore_latest({"w": np.zeros((2, 3), np.float32)})
    assert step == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])
    # meta-less checkpoints (pre-fault-tolerance) read back as {}
    mgr2 = CheckpointManager(str(tmp_path / "bare"))
    mgr2.save(1, tree)
    assert mgr2.latest_meta() == {}


def test_elastic_remesh_shrink_grow_roundtrip():
    """8 -> 4 -> 8 devices: the remesh keeps model parallelism intact and
    resizes the data axis; total used devices is always dp * mp."""
    from repro.train.fault import elastic_remesh

    for n, mp in ((8, 2), (4, 2), (8, 2), (6, 2), (3, 1)):
        shape, axes, used = elastic_remesh(n, model_parallel=mp)
        assert int(np.prod(shape)) == used == (n // mp) * mp
        assert axes[-1] == "model" and shape[-1] == mp
    with pytest.raises(ValueError):
        elastic_remesh(1, model_parallel=2)
