"""End-to-end behaviour tests: raw logs -> FeatureBox pipeline -> CTR training
with the hierarchical parameter server (the paper's full workflow, small)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_schedule, compile_layers, run_layers
from repro.embedding.hierarchy import HierarchicalPS
from repro.fe.colstore import ColumnStore
from repro.fe.datagen import (
    AD_INVENTORY,
    BASIC_FEATURES,
    IMPRESSIONS,
    USER_PROFILE,
    gen_views,
    write_views,
)
from repro.fe.pipeline_graph import build_fe_graph
from repro.models.common import sigmoid_bce
from repro.train.fault import ShardServer
from repro.train.optimizer import adamw

TABLE = 50_000
DIM = 8


def test_full_system_training_run():
    workdir = tempfile.mkdtemp()
    store = ColumnStore(os.path.join(workdir, "cols"))
    write_views(store, gen_views(1024, seed=0), chunk_rows=256)

    layers = compile_layers(build_schedule(build_fe_graph()))
    ps = HierarchicalPS(os.path.join(workdir, "emb.bin"),
                        total_rows=TABLE, dim=DIM, host_cache_rows=5000)
    srv = ShardServer(n_shards=len(store.chunks("impressions")))

    key = jax.random.PRNGKey(0)
    from repro.fe.pipeline_graph import N_DENSE_FEATS, N_SPARSE_FIELDS
    d_in = N_DENSE_FEATS + N_SPARSE_FIELDS * DIM
    dense_p = {
        "w1": jax.random.normal(key, (d_in, 32)) * 0.1,
        "b1": jnp.zeros(32),
        "w2": jax.random.normal(jax.random.fold_in(key, 1), (32, 1)) * 0.1,
        "b2": jnp.zeros(1),
    }
    opt = adamw(5e-3)
    opt_state = opt.init(dense_p)

    @jax.jit
    def train_step(dp, os_, working, inv, dense_feats, label):
        def loss_fn(dp, w):
            emb = jnp.take(w, inv, axis=0).reshape(inv.shape[0], -1)
            x = jnp.concatenate([dense_feats, emb], axis=1)
            h = jax.nn.relu(x @ dp["w1"] + dp["b1"])
            logits = (h @ dp["w2"] + dp["b2"])[:, 0]
            return sigmoid_bce(logits, label).mean()
        loss, (gd, gw) = jax.value_and_grad(loss_fn, argnums=(0, 1))(dp, working)
        dp, os_ = opt.update(dp, gd, os_)
        return dp, os_, loss, gw

    losses = []
    for _ in range(4):  # a few epochs over the leased shards
        if srv.done():
            srv = ShardServer(n_shards=len(store.chunks("impressions")))
        while not srv.done() and len(losses) < 16:
            shard = srv.acquire("w0")
            env = _run_shard(store, layers, shard)
            ids = np.asarray(env["batch_sparse"]) % TABLE
            working, uniq, inv = ps.pull(ids.reshape(-1))
            inv = inv.reshape(ids.shape)
            dense_p, opt_state, loss, gw = train_step(
                dense_p, opt_state, jnp.asarray(working), jnp.asarray(inv),
                env["batch_dense"], env["batch_label"])
            ps.push(uniq, np.asarray(working) - 0.05 * np.asarray(gw))
            srv.commit("w0", shard)
            losses.append(float(loss))
        if len(losses) >= 16:
            break

    assert np.isfinite(losses).all()
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
    assert ps.stats.pulls == len(losses)


def _run_shard(store, layers, shard):
    env = {}
    for vname, sch in (("impressions", IMPRESSIONS), ("user_profile", USER_PROFILE),
                       ("ad_inventory", AD_INVENTORY), ("basic_features", BASIC_FEATURES)):
        cid = shard % max(1, len(store.chunks(vname)))
        env[vname] = store.read_columns(vname, cid, [c.name for c in sch.columns])
    return run_layers(layers, env)
