"""Layer-wise scheduler: DAG properties (paper Fig. 4), incl. hypothesis."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax.numpy as jnp
import numpy as np

from repro.core import (
    Device,
    FuncDef,
    OpCost,
    Operator,
    OpGraph,
    build_schedule,
    compile_layers,
    run_layers,
    run_unfused,
    validate_schedule,
)


def _paper_graph():
    """The exact Fig. 4 example: 3 ops, 3 shared functions."""
    g = OpGraph()
    g.mark_external("x")
    g.add_func(FuncDef("Func1", lambda x: {"f1": x + 1}, ("x",), ("f1",)))
    g.add_func(FuncDef("Func2", lambda x: {"f2": x * 2}, ("x",), ("f2",),
                       device=Device.HOST, cost=OpCost(bytes_touched=1 << 40)))
    g.add_func(FuncDef("Func3", lambda **kw: {k: v + 100 for k, v in kw.items()},
                       (), ()))
    g.add(Operator("Op1", lambda x: {"a": x * 3}, ("x",), ("a",),
                   post_calls=("Func3",)))
    g.add(Operator("Op2", lambda x, **kw: {"b": x + list(kw.values())[0]},
                   ("x",), ("b",), pre_calls=("Func1",), post_calls=("Func3",)))
    g.add(Operator("Op3", lambda x, **kw: {"c": x - list(kw.values())[0]},
                   ("x",), ("c",), pre_calls=("Func2",), post_calls=("Func3",)))
    return g


def test_paper_example_layers_and_results():
    g = _paper_graph()
    sched = build_schedule(g)
    validate_schedule(g, sched)
    # Fig 4(b): 8 fine-grained operators in 3 layers
    assert sched.n_layers == 3
    assert sum(len(l.ops) for l in sched.layers) == 8
    # Func2 call must land on HOST (memory-intensive dictionary lookup)
    placements = {p.op.name: p.device for l in sched.layers for p in l.ops}
    assert placements["Func2@Op3"] is Device.HOST

    layers = compile_layers(sched)
    x = np.arange(8.0)
    env = run_layers(layers, {"x": jnp.asarray(x)})
    np.testing.assert_allclose(env["a"], x * 3 + 100)
    np.testing.assert_allclose(env["b"], x + (x + 1) + 100)
    np.testing.assert_allclose(env["c"], x - (x * 2) + 100)


def test_fused_vs_unfused_identical():
    g = _paper_graph()
    layers = compile_layers(build_schedule(g))
    x = jnp.arange(16.0)
    a = run_layers(layers, {"x": x})
    b = run_unfused(layers, {"x": x})
    for k in ("a", "b", "c"):
        np.testing.assert_allclose(a[k], b[k])


def test_meta_kernel_reduces_dispatches():
    g = _paper_graph()
    sched = build_schedule(g)
    # Table I: fused = one dispatch per layer-with-device-ops
    assert sched.n_device_dispatches < sched.n_unfused_dispatches


def test_cycle_detection():
    g = OpGraph()
    g.add(Operator("A", lambda b: {"a": b}, ("b",), ("a",)))
    g.add(Operator("B", lambda a: {"b": a}, ("a",), ("b",)))
    with pytest.raises(ValueError, match="cycle"):
        build_schedule(g, expand=False)


def test_unresolved_slot_raises():
    g = OpGraph()
    g.add(Operator("A", lambda zzz: {"a": zzz}, ("zzz",), ("a",)))
    with pytest.raises(KeyError, match="zzz"):
        build_schedule(g, expand=False)


@st.composite
def random_dags(draw):
    """Random DAG: op i depends on a subset of earlier ops' outputs."""
    n = draw(st.integers(min_value=1, max_value=24))
    deps = []
    for i in range(n):
        k = draw(st.integers(min_value=0, max_value=min(i, 4)))
        deps.append(sorted(draw(st.sets(
            st.integers(min_value=0, max_value=i - 1), min_size=k, max_size=k))
        ) if i else [])
    return deps


@hypothesis.given(random_dags())
@hypothesis.settings(deadline=None, max_examples=60)
def test_schedule_properties_random_dags(deps):
    g = OpGraph()
    g.mark_external("x0")
    for i, dlist in enumerate(deps):
        inputs = tuple(f"s{j}" for j in dlist) or ("x0",)

        def fn(_i=i, **kw):
            return {f"s{_i}": sum(v for v in kw.values())}

        g.add(Operator(f"op{i}", fn, inputs, (f"s{i}",)))
    sched = build_schedule(g, expand=False)
    validate_schedule(g, sched, expanded=False)
    # depth optimality: every op is exactly one deeper than its deepest dep
    for i, dlist in enumerate(deps):
        expected = 0 if not dlist else 1 + max(sched.depth_of[f"op{j}"] for j in dlist)
        assert sched.depth_of[f"op{i}"] == expected
