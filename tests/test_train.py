"""Train substrate: checkpoint/restart, fault handling, compression, loop."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (
    bf16_compress,
    bf16_decompress,
    compressed_bytes,
    int8_compress,
    int8_decompress,
)
from repro.train.fault import ShardServer, StragglerPolicy, elastic_remesh
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import adagrad, adamw, sgd


# ------------------------------------------------------------- checkpoints
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(3.0), "step": jnp.int32(7)}}


def test_checkpoint_roundtrip_and_latest():
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, keep=2)
    t0, t1 = _tree(0), _tree(1)
    mgr.save(10, t0)
    mgr.save(20, t1)
    assert mgr.latest_step() == 20
    step, restored = mgr.restore_latest(t0)
    assert step == 20
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(t1)):
        np.testing.assert_allclose(a, b)


def test_checkpoint_retention_gc():
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    steps = mgr._steps_on_disk()
    assert steps == [3, 4]


def test_checkpoint_async_and_wait():
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d)
    mgr.save_async(5, _tree(5))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch_rejected():
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d)
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.zeros((5,))})


# ------------------------------------------------------------ fault/shards
def test_shard_server_lease_commit():
    srv = ShardServer(4, lease_timeout=100)
    got = [srv.acquire("w0") for _ in range(4)]
    assert sorted(got) == [0, 1, 2, 3]
    assert srv.acquire("w0") is None
    for s in got:
        assert srv.commit("w0", s)
    assert srv.done()


def test_shard_server_reissues_on_timeout():
    srv = ShardServer(2, lease_timeout=0.5)
    s0 = srv.acquire("dead", now=0.0)
    # worker dies silently; lease expires
    s0b = srv.acquire("w1", now=10.0)
    assert s0b == s0
    assert srv.stats.reissued == 1
    assert srv.commit("w1", s0b)
    # zombie's late commit is rejected
    assert not srv.commit("dead", s0)


def test_shard_server_explicit_failure():
    srv = ShardServer(3)
    a = srv.acquire("w0")
    b = srv.acquire("w0")
    lost = srv.fail_worker("w0")
    assert lost == 2
    assert srv.stats.failed_workers == 1
    # shards come back for others
    assert srv.acquire("w1") in (a, b)


def test_shard_server_heartbeat_keeps_lease():
    srv = ShardServer(1, lease_timeout=1.0)
    s = srv.acquire("w0", now=0.0)
    assert srv.heartbeat("w0", s, now=0.9)
    # heartbeat refreshed the lease, so at t=1.5 it hasn't expired
    assert srv.acquire("w1", now=1.5) is None


def test_straggler_policy_backup_decision():
    p = StragglerPolicy(factor=3.0, min_samples=3)
    for d in (1.0, 1.1, 0.9):
        p.record(d)
    assert not p.should_backup(2.0)
    assert p.should_backup(3.5)


def test_elastic_remesh():
    shape, axes, used = elastic_remesh(512, model_parallel=16, pod_size=256)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
    shape, axes, used = elastic_remesh(250, model_parallel=16)
    assert shape == (15, 16) and used == 240  # 10 devices sit out
    with pytest.raises(ValueError):
        elastic_remesh(8, model_parallel=16)


# ------------------------------------------------------------- compression
def test_bf16_error_feedback_converges():
    g = {"w": jnp.asarray(np.linspace(-1e-3, 1e-3, 64).astype(np.float32))}
    residual = None
    acc = jnp.zeros(64)
    for _ in range(50):
        wire, residual = bf16_compress(g, residual)
        acc = acc + bf16_decompress(wire)["w"]
    # with feedback, the accumulated sum matches the true sum closely
    np.testing.assert_allclose(np.asarray(acc), np.asarray(g["w"]) * 50,
                               rtol=2e-3, atol=2e-6)


def test_int8_compression_ratio_and_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=1024).astype(np.float32))}
    wire, scales, residual = int8_compress(g)
    assert compressed_bytes(wire) == compressed_bytes(g) // 4
    dec = int8_decompress(wire, scales)
    err = np.abs(np.asarray(dec["w"]) - np.asarray(g["w"])).max()
    assert err <= float(scales["w"])  # quantization bound
    # error feedback carries the residual
    np.testing.assert_allclose(
        np.asarray(residual["w"]),
        np.asarray(g["w"]) - np.asarray(dec["w"]), rtol=1e-6, atol=1e-7)


# -------------------------------------------------------------- optimizers
@pytest.mark.parametrize("opt", [adamw(1e-1), adagrad(0.5), sgd(0.1, momentum=0.9)])
def test_optimizers_reduce_quadratic(opt):
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    for _ in range(120):
        grads = jax.grad(lambda p: (p["w"] ** 2).sum())(params)
        params, state = opt.update(params, grads, state)
    assert float((params["w"] ** 2).sum()) < 0.5


def test_abstract_state_matches_concrete():
    opt = adamw(1e-3)
    params = {"w": jnp.zeros((3, 3)), "b": jnp.zeros(3)}
    conc = opt.init(params)
    ab = opt.abstract_state(params)
    assert jax.tree.structure(ab) == jax.tree.structure(conc)
    for a, c in zip(jax.tree.leaves(ab), jax.tree.leaves(conc)):
        assert a.shape == c.shape and a.dtype == c.dtype


# -------------------------------------------------------------------- loop
def test_loop_trains_and_restarts():
    d = tempfile.mkdtemp()
    opt = sgd(0.2)

    def batch_source(step):
        rng = np.random.default_rng(step)
        x = rng.normal(size=(16, 4)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(x.sum(1))}

    @jax.jit
    def train_step(state, batch):
        def loss_fn(w):
            pred = batch["x"] @ w
            return ((pred - batch["y"]) ** 2).mean()
        loss, g = jax.value_and_grad(loss_fn)(state["w"])
        new_w, _ = opt.update({"w": state["w"]}, {"w": g}, {})
        return {"w": new_w["w"]}, {"loss": loss}

    cfg = LoopConfig(n_steps=30, checkpoint_every=10, checkpoint_dir=d)
    state = {"w": jnp.zeros(4)}
    state, stats = run_training(cfg=cfg, state=state, train_step=train_step,
                                batch_source=batch_source)
    assert stats.steps == 30
    assert stats.losses[-1] < stats.losses[0]

    # "crash" and restart: resumes from latest checkpoint, not step 0
    cfg2 = LoopConfig(n_steps=40, checkpoint_every=10, checkpoint_dir=d)
    state2, stats2 = run_training(cfg=cfg2, state={"w": jnp.zeros(4)},
                                  train_step=train_step, batch_source=batch_source)
    assert stats2.restarts == 1
    assert stats2.steps == 40 - 30  # only the remaining steps ran
