"""Alg. 1 allocator invariants: host pool, jnp planner, Pallas kernel agree."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax.numpy as jnp
import numpy as np

from repro.core.mempool import ALIGN, ArenaPool, align_up, plan_offsets, required_capacity
from repro.kernels.mempool_alloc.ops import plan_allocation
from repro.kernels.mempool_alloc.ref import alloc_offsets_ref


@hypothesis.given(st.lists(st.integers(min_value=0, max_value=10_000),
                           min_size=1, max_size=500))
@hypothesis.settings(deadline=None, max_examples=80)
def test_allocator_invariants(sizes):
    pool = ArenaPool(capacity=align_up(sum(sizes) + ALIGN * len(sizes)))
    allocs = pool.alloc_block(sizes)
    # one offset per request, alignment, no overlap, ordered, within capacity
    assert len(allocs) == len(sizes)
    for a, size in zip(allocs, sizes):
        assert a.offset % ALIGN == 0
        assert a.size == size
    for prev, nxt in zip(allocs, allocs[1:]):
        assert prev.offset + prev.size <= nxt.offset
    assert pool.head <= pool.capacity
    assert pool.head == sum(align_up(s) for s in sizes)
    # O(1) reset (paper §V)
    pool.reset()
    assert pool.head == 0
    # allocations after reset reuse the same space deterministically
    again = pool.alloc_block(sizes)
    assert [a.offset for a in again] == [a.offset for a in allocs]


@hypothesis.given(st.lists(st.integers(min_value=0, max_value=5000),
                           min_size=1, max_size=300))
@hypothesis.settings(deadline=None, max_examples=40)
def test_kernel_matches_ref_and_pool(sizes):
    arr = jnp.asarray(np.asarray(sizes, np.int32))
    off_k, head_k = plan_allocation(arr)
    off_r, head_r = alloc_offsets_ref(arr)
    assert (np.asarray(off_k) == np.asarray(off_r)).all()
    assert int(head_k[0]) == int(head_r[0])
    pool = ArenaPool(capacity=max(align_up(int(head_k[0])), ALIGN))
    allocs = pool.alloc_block(sizes)
    assert [a.offset for a in allocs] == np.asarray(off_k).tolist()


def test_exhaustion_raises():
    pool = ArenaPool(capacity=ALIGN * 2)
    with pytest.raises(MemoryError):
        pool.alloc_block([ALIGN, ALIGN, 1])


def test_negative_size_rejected():
    pool = ArenaPool(capacity=ALIGN * 4)
    with pytest.raises(ValueError):
        pool.alloc_block([4, -1])


def test_plan_offsets_jit_matches_pool():
    sizes = jnp.asarray([5, 130, 1, 0, 257], jnp.int32)
    offs, total = plan_offsets(sizes)
    pool = ArenaPool(capacity=1 << 16)
    allocs = pool.alloc_block(np.asarray(sizes).tolist())
    assert [a.offset for a in allocs] == np.asarray(offs).tolist()
    assert pool.head == int(total)


def test_required_capacity_sizes_worst_layer():
    layers = [[100, 200], [5000], [1, 1, 1]]
    cap = required_capacity(layers)
    pool = ArenaPool(capacity=cap)
    for layer in layers:
        pool.alloc_block(layer)   # must fit with reset between layers
        pool.reset()


def test_high_water_tracks_peak():
    pool = ArenaPool(capacity=1 << 20)
    pool.alloc_block([1000])
    pool.reset()
    pool.alloc_block([10])
    assert pool.high_water == align_up(1000)
