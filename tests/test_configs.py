"""Config/cell plumbing: every (arch x shape x variant) cell CONSTRUCTS
(abstract shapes + shardings), without compiling. Structure-level checks
that guard the dry-run from registry/spec drift."""

import subprocess
import sys
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import numpy as np
import jax
from repro.configs import get_arch, list_archs
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh(multi_pod={multi_pod})
built = skipped = 0
for arch_id in list_archs():
    spec = get_arch(arch_id)
    variants = ["base"]
    if spec.family == "recsys":
        variants += ["nodedup", "cap_expected", "batchall"]
    if spec.family == "gnn":
        variants += ["halo_bf16"]
    if arch_id == "yi-9b":
        variants += ["puredp", "accum4"]
    if arch_id == "deepseek-v2-236b":
        variants += ["accum8", "accum8+cf100"]
    for shape in spec.shapes:
        for variant in variants:
            cell = spec.build_cell(shape, mesh, variant=variant)
            if cell.skip:
                skipped += 1
                continue
            built += 1
            assert cell.fn is not None
            # args and shardings must be tree-compatible
            assert len(cell.args) == len(cell.in_shardings)
            for a, s in zip(cell.args, cell.in_shardings):
                la = len(jax.tree.leaves(a))
                ls = len(jax.tree.leaves(
                    s, is_leaf=lambda x: hasattr(x, "spec")))
                assert la == ls, (arch_id, shape, variant, la, ls)
            assert cell.model_flops > 0, (arch_id, shape, variant)
print(f"BUILT {{built}} SKIPPED {{skipped}}")
assert built >= 50
"""


@pytest.mark.parametrize("multi_pod", [False, True])
def test_all_cells_construct(multi_pod):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", _CODE.format(multi_pod=multi_pod)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "BUILT" in out.stdout
