"""Finding/Report contract + ``run_check`` end-to-end on the presets."""

import json

import pytest

from repro.check import Finding, Report, run_check


def _f(rule="PV101", severity="error", msg="boom"):
    return Finding(rule=rule, severity=severity, location="here",
                   message=msg, hint="fix it")


# ------------------------------------------------------------------ Finding
def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError):
        Finding(rule="PV101", severity="fatal", location="x", message="m")


def test_finding_render_carries_rule_location_hint():
    text = _f().render()
    assert "PV101" in text and "here" in text and "fix it" in text


def test_finding_to_dict_roundtrips_through_json():
    d = json.loads(json.dumps(_f().to_dict()))
    assert d["rule"] == "PV101" and d["severity"] == "error"


# ------------------------------------------------------------- exit contract
def test_exit_0_when_clean():
    r = Report()
    r.record_analyzer("plan", [])
    assert r.exit_code == 0


def test_exit_2_on_error_findings():
    r = Report()
    r.record_analyzer("plan", [_f()])
    assert r.exit_code == 2


def test_warnings_do_not_gate():
    r = Report()
    r.record_analyzer("plan", [_f(severity="warning")])
    assert r.exit_code == 0
    assert len(r.warnings) == 1


def test_exit_1_crash_takes_precedence_over_errors():
    r = Report()
    r.record_analyzer("plan", [_f()])
    r.record_crash("effects", RuntimeError("tracer exploded"))
    assert r.exit_code == 1
    assert "effects" in r.crashed


def test_as_metrics_counts_by_severity():
    r = Report()
    r.record_analyzer("plan", [_f(), _f(severity="warning"),
                               _f(severity="info")])
    m = r.as_metrics()
    assert m["errors"] == 1 and m["warnings"] == 1 and m["infos"] == 1
    assert m["findings"] == 3 and m["exit_code"] == 2


def test_to_json_is_stable_and_parseable():
    r = Report()
    r.record_analyzer("plan", [_f()])
    d = json.loads(r.to_json())
    assert d["n_errors"] == 1
    assert d["findings"][0]["rule"] == "PV101"


# ---------------------------------------------------------------- run_check
def test_run_check_ads_ctr_is_clean():
    r = run_check("ads_ctr", "dlrm-mlperf")
    assert r.exit_code == 0, r.render() + "\n" + "\n".join(
        f.render() for f in r.findings)
    assert set(r.analyzers_run) == {"lockset", "plan", "aliasing", "effects"}


@pytest.mark.parametrize("preset,arch", [("dlrm", "dlrm-mlperf"),
                                         ("bst", "bst")])
def test_run_check_other_presets_clean(preset, arch):
    # effects lowering is the expensive analyzer; the CI plan-verify job
    # runs the full set across every preset x arch pair.
    r = run_check(preset, arch, analyzers=("plan", "aliasing", "lockset"))
    assert r.exit_code == 0, "\n".join(f.render() for f in r.findings)


def test_run_check_records_compile_crash_as_exit_1():
    r = run_check("no-such-preset", "dlrm-mlperf")
    assert r.exit_code == 1
    assert "compile" in r.crashed
