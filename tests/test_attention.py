"""Attention: flash vs quadratic oracle; decode-vs-prefill consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    MLAConfig,
    apply_rope,
    attention_ref,
    flash_attention,
    gqa_attention,
    gqa_decode_step,
    gqa_params_shape,
    mla_attention,
    mla_decode_step,
    mla_params_shape,
)
from repro.models.common import dense

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("b,s,h,hk,dh,causal", [
    (2, 128, 8, 2, 32, True),
    (1, 300, 4, 4, 16, False),
    (2, 64, 8, 1, 32, True),
    (1, 96, 6, 3, 8, True),
])
def test_flash_matches_ref(b, s, h, hk, dh, causal):
    q = jnp.asarray(RNG.normal(size=(b, s, h, dh)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, s, hk, dh)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, s, hk, dh)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, q_block=64, kv_block=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_mixed_value_dim():
    """MLA shape regime: value head dim != qk head dim."""
    q = jnp.asarray(RNG.normal(size=(2, 64, 4, 12)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 64, 4, 12)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 64, 4, 8)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_rope_relative_property():
    """RoPE scores depend only on relative position."""
    dh = 16
    x = jnp.asarray(RNG.normal(size=(1, 2, 1, dh)).astype(np.float32))
    s1 = apply_rope(x, jnp.asarray([0, 3]))
    s2 = apply_rope(x, jnp.asarray([7, 10]))
    dot1 = float((s1[0, 0, 0] * s1[0, 1, 0]).sum())
    dot2 = float((s2[0, 0, 0] * s2[0, 1, 0]).sum())
    assert abs(dot1 - dot2) < 1e-4


def _gqa_cache_from_prefill(p, x, s, hk, dh):
    pos = jnp.arange(s)
    k = apply_rope(dense(x[:, :s], p["wk"], p.get("bk")).reshape(x.shape[0], s, hk, dh), pos)
    v = dense(x[:, :s], p["wv"], p.get("bv")).reshape(x.shape[0], s, hk, dh)
    ck = jnp.zeros((x.shape[0], s + 4, hk, dh)).at[:, :s].set(k)
    cv = jnp.zeros((x.shape[0], s + 4, hk, dh)).at[:, :s].set(v)
    return ck, cv


def test_gqa_decode_matches_prefill():
    d, h, hk, dh, b, s = 64, 4, 2, 16, 2, 12
    shapes = gqa_params_shape(d, h, hk, dh, qkv_bias=True)
    kg = jax.random.PRNGKey(0)
    p = {k: jax.random.normal(jax.random.fold_in(kg, i), v) * 0.05
         for i, (k, v) in enumerate(shapes.items())}
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, d)) * 0.5
    full = gqa_attention(p, x, n_heads=h, n_kv=hk, head_dim=dh,
                         q_block=4, kv_block=4)
    ck, cv = _gqa_cache_from_prefill(p, x, s, hk, dh)
    out, (nk, nv) = gqa_decode_step(p, x[:, s:s + 1], ck, cv, jnp.int32(s),
                                    n_heads=h, n_kv=hk, head_dim=dh)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, s]),
                               rtol=2e-4, atol=2e-4)
    assert nk.shape == ck.shape  # fixed-size cache


def test_mla_decode_matches_prefill():
    c = MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8)
    kg = jax.random.PRNGKey(2)
    p = {k: jax.random.normal(jax.random.fold_in(kg, i), v) * 0.1
         for i, (k, v) in enumerate(mla_params_shape(c).items())}
    b, s = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s + 1, 64)) * 0.5
    full = mla_attention(p, x, c, q_block=4, kv_block=4)
    ckv = dense(x[:, :s], p["wdkv"])
    krope = apply_rope(dense(x[:, :s], p["wkrope"])[:, :, None, :],
                       jnp.arange(s))[:, :, 0]
    cc = jnp.zeros((b, s + 4, c.kv_lora_rank)).at[:, :s].set(ckv)
    ck = jnp.zeros((b, s + 4, c.qk_rope_dim)).at[:, :s].set(krope)
    out, _ = mla_decode_step(p, x[:, s:s + 1], cc, ck, jnp.int32(s), c)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, s]),
                               rtol=2e-3, atol=2e-3)


def test_mla_cache_is_compressed():
    """The MLA decode cache stores kv_lora+rope per token, not 2*H*Dh."""
    c = MLAConfig(d_model=64, n_heads=8, kv_lora_rank=16, qk_rope_dim=4,
                  qk_nope_dim=8, v_head_dim=8, q_lora_rank=32)
    full_cache_per_tok = 2 * c.n_heads * c.v_head_dim       # = 128
    mla_cache_per_tok = c.kv_lora_rank + c.qk_rope_dim      # = 20
    assert mla_cache_per_tok < full_cache_per_tok / 6
