"""Per-architecture smoke tests: reduced config of each assigned arch runs a
forward/train step on CPU with correct shapes and no NaNs (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.train.optimizer import adamw

ALL_ARCHS = list_archs()


def test_registry_complete():
    assert set(ALL_ARCHS) == {
        "yi-9b", "qwen2.5-32b", "qwen2.5-14b", "deepseek-v2-236b",
        "deepseek-moe-16b", "pna", "bst", "autoint", "dcn-v2", "dlrm-mlperf",
    }


def _lm_batch(cfg, b=4, s=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def _recsys_batch(cfg, b=32, seed=0):
    rng = np.random.default_rng(seed)
    out = {
        "sparse": jnp.asarray(np.stack(
            [rng.integers(0, v, b) for v in cfg.vocab_sizes[:cfg.n_sparse]],
            axis=1).astype(np.int32)),
        "label": jnp.asarray((rng.random(b) < 0.3).astype(np.float32)),
    }
    if cfg.n_dense:
        out["dense"] = jnp.asarray(rng.exponential(1, (b, cfg.n_dense)).astype(np.float32))
    if cfg.kind == "bst":
        out["seq"] = jnp.asarray(
            rng.integers(0, cfg.vocab_sizes[0], (b, cfg.seq_len)).astype(np.int32))
    return out


@pytest.mark.parametrize("arch_id", [a for a in ALL_ARCHS
                                     if get_arch(a).family == "lm"])
def test_lm_smoke_train_and_decode(arch_id):
    from repro.models import transformer as T

    cfg = get_arch(arch_id).smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(2e-3)
    st = opt.init(params)
    step = jax.jit(T.make_train_step(cfg, opt))
    batch = _lm_batch(cfg)
    losses = []
    for _ in range(6):
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"{arch_id}: loss must decrease"

    # serve path: one decode step with a KV cache
    cache = T.make_cache(cfg, 4, 24)
    logits, cache2 = jax.jit(T.serve_step, static_argnames=("c",))(
        params, batch["tokens"][:, :1], cache, jnp.int32(0), c=cfg)
    assert logits.shape == (4, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # prefill path
    pf = T.prefill(params, batch["tokens"], cfg)
    assert pf.shape == (4, cfg.vocab)


@pytest.mark.parametrize("arch_id", [a for a in ALL_ARCHS
                                     if get_arch(a).family == "recsys"])
def test_recsys_smoke_train_serve_retrieval(arch_id):
    from repro.models import recsys as R

    cfg = get_arch(arch_id).smoke()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(5e-3)
    step_fn, init_st, abstract_st = R.make_sparse_train_step(cfg, opt)
    st = init_st(params)
    step = jax.jit(step_fn)
    batch = _recsys_batch(cfg)
    losses = []
    for _ in range(8):
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"{arch_id}: loss must decrease"

    scores = R.serve_step(params, cfg, _recsys_batch(cfg, b=8, seed=1))
    assert scores.shape == (8,)
    assert (np.asarray(scores) >= 0).all() and (np.asarray(scores) <= 1).all()

    cands = jnp.arange(min(16, cfg.vocab_sizes[cfg.item_field]), dtype=jnp.int32)
    rs = R.retrieval_score(params, cfg, _recsys_batch(cfg, b=1, seed=2), cands)
    assert rs.shape == (cands.shape[0],)
    assert np.isfinite(np.asarray(rs)).all()

    # abstract state matches concrete state structure (dry-run contract)
    ab = abstract_st(params)
    assert jax.tree.structure(ab) == jax.tree.structure(st)


def test_gnn_smoke_all_shapes():
    from repro.models import gnn as G
    from repro.configs.base import gnn_config_for

    cfg = get_arch("pna").smoke()
    params = G.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(5e-3)
    step = jax.jit(G.make_train_step(cfg, opt))
    st = opt.init(params)
    g = G.random_graph(80, 400, cfg.d_in, cfg.n_classes, seed=0)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    losses = []
    for _ in range(10):
        params, st, m = step(params, st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    # graph-level (molecule shape family) forward
    cfg_g = dataclasses.replace(cfg, graph_level=True, n_classes=3)
    pg = G.init_params(cfg_g, jax.random.PRNGKey(1))
    nb, npg = 3, 5
    rng = np.random.default_rng(0)
    batch_g = {
        "features": jnp.asarray(rng.normal(size=(nb * npg, cfg.d_in)).astype(np.float32)),
        "src": jnp.asarray(np.concatenate(
            [rng.integers(0, npg, 7) + i * npg for i in range(nb)]).astype(np.int32)),
        "dst": jnp.asarray(np.concatenate(
            [rng.integers(0, npg, 7) + i * npg for i in range(nb)]).astype(np.int32)),
        "graph_ids": jnp.asarray(np.repeat(np.arange(nb), npg).astype(np.int32)),
        "n_graphs": nb,
    }
    logits = G.forward(pg, cfg_g, batch_g)
    assert logits.shape == (nb, 3)
    assert np.isfinite(np.asarray(logits)).all()

    # per-dataset configs resolve for all four assigned shapes
    for shape in get_arch("pna").shapes:
        c = gnn_config_for("pna", shape)
        assert c.d_in > 0 and c.n_classes > 1


def test_neighbor_sampler_subgraph_validity():
    from repro.models.gnn import NeighborSampler, random_graph

    g = random_graph(200, 1000, 8, 4, seed=1)
    sampler = NeighborSampler.from_edges(
        200, g["src"].astype(np.int64), g["dst"].astype(np.int64), seed=0)
    nodes, src_l, dst_l, seeds = sampler.sample(np.asarray([0, 5, 9]), (4, 3))
    orig = set(zip(g["src"].tolist(), g["dst"].tolist()))
    assert len(np.unique(nodes)) == len(nodes)    # remap is a dedup
    for s, d in zip(src_l, dst_l):
        assert (int(nodes[s]), int(nodes[d])) in orig
