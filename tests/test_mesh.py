"""Multi-device scale-out: mesh train step, two-stage dedup, compressed
hierarchical collectives.

jax locks the device count at first init, so every multi-device check runs
in a subprocess with XLA_FLAGS set before import (same pattern as
tests/test_sharding.py). In-process tests cover the 1x1 degenerate mesh —
the shape the bitwise-equivalence guarantee is stated for — plus the pure
analytics (CommPlan byte model, mesh-spec parsing, codec normalization).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CFG = """
cfg = R.RecsysConfig(name="t", kind="dlrm", n_dense=13, n_sparse=6,
                     embed_dim=16, vocab_sizes=(64, 32, 128, 16, 8, 40),
                     bot_mlp=(32, 16), top_mlp=(64, 32, 1),
                     dedup_capacity=256, row_align=8)

B = 64
def make_batch(i):
    r = np.random.default_rng(i)
    return {
        "dense": jnp.asarray(r.normal(size=(B, 13)).astype(np.float32)),
        "sparse": jnp.asarray(np.stack(
            [r.integers(0, v, B) for v in cfg.vocab_sizes], 1
        ).astype(np.int32)),
        "label": jnp.asarray(r.integers(0, 2, B).astype(np.float32)),
    }
"""


def run_sub(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


# ------------------------------------------------------------ 1x1 guarantee
def test_mesh_1x1_bitwise_identical_to_sparse_step():
    """On a 1x1 mesh with compression off, the mesh step IS the
    single-device step: every collective is an identity, the grad average
    is statically skipped, and five steps stay bitwise equal across
    losses, params, dense optimizer leaves, and the Adagrad accumulator."""
    import jax

    import repro.models.recsys as R
    from repro.launch.mesh import make_train_mesh
    from repro.train.optimizer import adamw

    ns = {"R": R, "np": np, "jnp": jax.numpy}
    exec(CFG, ns)
    cfg, make_batch = ns["cfg"], ns["make_batch"]

    params = R.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    step_s, init_s, _ = R.make_sparse_train_step(cfg, opt)
    step_m, init_m, abstract_m = R.make_mesh_train_step(
        cfg, opt, mesh=make_train_mesh(1, 1), compress=None)

    # no codec -> no residual in the state, identical to the sparse init
    assert set(init_m(params)) == set(init_s(params))
    assert "comm_residual" not in abstract_m(params)

    ps, os_ = dict(params), init_s(params)
    pm, om = dict(params), init_m(params)
    js, jm = jax.jit(step_s), jax.jit(step_m)
    for i in range(5):
        b = make_batch(i)
        ps, os_, ms = js(ps, os_, b)
        pm, om, mm = jm(pm, om, b)
        assert float(ms["loss"]) == float(mm["loss"]), i
        assert int(ms["unique"]) == int(mm["unique"])
        assert int(ms["n_ids"]) == int(mm["n_ids"])
    assert int(mm["local_unique"]) == int(mm["unique"])  # stage 1 == stage 2
    for k in ps:
        assert (np.asarray(ps[k]) == np.asarray(pm[k])).all(), k
    for a, b2 in zip(jax.tree.leaves(os_["dense"]),
                     jax.tree.leaves(om["dense"])):
        assert (np.asarray(a) == np.asarray(b2)).all()
    assert (np.asarray(os_["embed_accum"])
            == np.asarray(om["embed_accum"])).all()


# ------------------------------------------------------- 2x4 vs one device
def test_mesh_2x4_matches_single_device():
    """Sharded 2x4 training (row-sharded table, two-stage dedup,
    hierarchical uncompressed reduction) tracks the single-device step
    within fp32 reduction-order tolerance over 8 steps."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
import repro.models.recsys as R
from repro.train.optimizer import adamw
from repro.launch.mesh import make_train_mesh
""" + CFG + """
assert len(jax.devices()) == 8
params = R.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw(1e-3)
step_s, init_s, _ = R.make_sparse_train_step(cfg, opt)
mesh = make_train_mesh(2, 4)
step_m, init_m, _ = R.make_mesh_train_step(
    cfg, opt, mesh=mesh, compress=None, local_dedup_capacity=64)

ps, os_ = dict(params), init_s(params)
pm, om = R.shard_train_state(mesh, dict(params), init_m(params))
js, jm = jax.jit(step_s), jax.jit(step_m)
for i in range(8):
    b = make_batch(i)
    ps, os_, ms = js(ps, os_, b)
    pm, om, mm = jm(pm, om, b)
    np.testing.assert_allclose(float(ms["loss"]), float(mm["loss"]), rtol=2e-5)
    assert int(ms["unique"]) == int(mm["unique"])
    assert int(ms["n_ids"]) == int(mm["n_ids"])
    assert int(mm["local_unique"]) >= int(mm["unique"])  # pool over-counts
for k in ps:
    np.testing.assert_allclose(np.asarray(ps[k]), np.asarray(pm[k]),
                               rtol=3e-5, atol=2e-6, err_msg=k)
np.testing.assert_allclose(np.asarray(os_["embed_accum"]),
                           np.asarray(om["embed_accum"]),
                           rtol=3e-5, atol=2e-6)

# batch rows must split over the mesh
try:
    jm(pm, om, {k: v[:63] if v.shape[0] == B else v
                for k, v in make_batch(0).items()})
except ValueError as e:
    assert "does not split" in str(e), e
else:
    raise AssertionError("63-row batch on 8 devices should raise")
print("MESH 2x4 OK")
""")


def test_mesh_compressed_drift_bounds():
    """Satellite: bf16/int8 wire compression with fp32 accumulation and
    error feedback stays within a small drift bound of uncompressed
    training after 8 steps, and the residual state is actually carried."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
import repro.models.recsys as R
from repro.train.optimizer import adamw
from repro.launch.mesh import make_train_mesh
""" + CFG + """
params = R.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw(1e-3)
mesh = make_train_mesh(2, 4)
step_m, init_m, _ = R.make_mesh_train_step(
    cfg, opt, mesh=mesh, compress=None, local_dedup_capacity=64)
pm, om = R.shard_train_state(mesh, dict(params), init_m(params))
jm = jax.jit(step_m)
for i in range(8):
    pm, om, mm = jm(pm, om, make_batch(i))

for codec, bound in (("bf16", 5e-3), ("int8", 5e-2)):
    step_c, init_c, abstract_c = R.make_mesh_train_step(
        cfg, opt, mesh=mesh, compress=codec, local_dedup_capacity=64)
    oc0 = init_c(params)
    assert "comm_residual" in oc0 and "comm_residual" in abstract_c(params)
    pc, oc = R.shard_train_state(mesh, dict(params), oc0)
    jc = jax.jit(step_c)
    for i in range(8):
        pc, oc, mc = jc(pc, oc, make_batch(i))
    drift = max(float(np.max(np.abs(np.asarray(pc[k]) - np.asarray(pm[k]))))
                for k in pc)
    assert drift < bound, (codec, drift)
    assert float(np.max(np.abs(np.asarray(oc["comm_residual"])))) > 0, codec
    print(codec, "drift", drift)
print("MESH COMPRESSED OK")
""")


# ------------------------------------------------- two-stage dedup property
def test_two_stage_dedup_matches_flat_dedup():
    """Satellite property test: on a 2x4 mesh, local->global dedup agrees
    with flat single-array dedup — same unique set, and an inverse that
    reconstructs every device's ids — including FILL padding in the input
    and ids near MAX_ID."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat; compat.install()
from repro.embedding.dedup import FILL, MAX_ID, dedup, dedup_two_stage_local
from repro.launch.mesh import make_train_mesh

mesh = make_train_mesh(2, 4)
N_LOCAL, CAP, LOCAL_CAP = 96, 512, 96

def body(ids):
    u, inv, cnt, lcnt = dedup_two_stage_local(
        ids[0], capacity=CAP, local_capacity=LOCAL_CAP,
        gather_axes=("pod", "data"))
    return u[None], inv[None], cnt[None], lcnt[None]

f = jax.jit(jax.shard_map(
    body, mesh=mesh,
    in_specs=P(("pod", "data")),
    out_specs=(P(("pod", "data")), P(("pod", "data")),
               P(("pod", "data")), P(("pod", "data"))),
    check_vma=False))

rng = np.random.default_rng(7)
for trial in range(6):
    ids = rng.integers(0, 500, size=(8, N_LOCAL)).astype(np.int32)
    if trial % 3 == 1:   # FILL padding mixed into the input
        ids[rng.random(ids.shape) < 0.2] = int(FILL)
    if trial % 3 == 2:   # ids hugging the top of the id space
        ids[rng.random(ids.shape) < 0.3] = MAX_ID - 1 - rng.integers(0, 3)
    u, inv, cnt, lcnt = f(jnp.asarray(ids))
    u, inv = np.asarray(u), np.asarray(inv)

    flat_u, flat_inv, flat_cnt = dedup(jnp.asarray(ids.ravel()), capacity=CAP)
    flat_u = np.asarray(flat_u)

    # every device computed the same pooled unique array, == flat dedup's
    for d in range(8):
        assert (u[d] == flat_u).all(), (trial, d)
        assert int(cnt[d]) == int(flat_cnt)
        # inverse reconstructs this device's ids (real and FILL alike:
        # FILL sorts last so searchsorted points at a FILL slot or cnt)
        real = ids[d] != int(FILL)
        assert (u[d][inv[d][real]] == ids[d][real]).all(), (trial, d)
        assert int(lcnt[d]) == len(np.unique(ids[d][real]))
print("TWO-STAGE DEDUP OK")

# capacity overflow: pooled uniques exceed the global capacity -> the kept
# set is exactly the CAP smallest uniques (jnp.unique truncation order) and
# every inverse that lands in range still reconstructs its id
CAP2 = 64
def body2(ids):
    u, inv, cnt, lcnt = dedup_two_stage_local(
        ids[0], capacity=CAP2, local_capacity=LOCAL_CAP,
        gather_axes=("pod", "data"))
    return u[None], inv[None], cnt[None], lcnt[None]
f2 = jax.jit(jax.shard_map(
    body2, mesh=mesh, in_specs=P(("pod", "data")),
    out_specs=(P(("pod", "data")),) * 4, check_vma=False))
ids = rng.integers(0, 100_000, size=(8, N_LOCAL)).astype(np.int32)
u, inv, cnt, lcnt = (np.asarray(x) for x in f2(jnp.asarray(ids)))
true_u = np.unique(ids)
assert len(true_u) > CAP2
assert (u[0] == true_u[:CAP2]).all()
for d in range(8):
    ok = inv[d] < CAP2
    assert (u[d][inv[d][ok]] == ids[d][ok]).all()
    # dropped ids are exactly those larger than the kept range
    assert (ids[d][~ok] > true_u[CAP2 - 1]).all()
print("OVERFLOW OK")

# local-capacity overflow: stage 1 truncates per device; the global set is
# then a subset of the true uniques, never an invented id
LC = 16
def body3(ids):
    u, inv, cnt, lcnt = dedup_two_stage_local(
        ids[0], capacity=CAP, local_capacity=LC,
        gather_axes=("pod", "data"))
    return u[None], inv[None], cnt[None], lcnt[None]
f3 = jax.jit(jax.shard_map(
    body3, mesh=mesh, in_specs=P(("pod", "data")),
    out_specs=(P(("pod", "data")),) * 4, check_vma=False))
u, inv, cnt, lcnt = (np.asarray(x) for x in f3(jnp.asarray(ids)))
kept = u[0][u[0] != int(FILL)]
assert int(cnt[0]) == len(kept) <= 8 * LC
assert np.isin(kept, true_u).all()
assert (lcnt == LC).all()  # every device overflowed stage 1
print("LOCAL OVERFLOW OK")
""")


# ------------------------------------------------------------ byte model
def test_comm_plan_byte_model():
    from repro.train.compression import CommPlan

    plan = CommPlan.for_step(
        n_pods=2, inner=4, compress="bf16", hierarchical=True,
        capacity=256, embed_dim=16, n_dense_elems=1000,
        local_capacity=64, ids_per_device=48)
    n = plan.allreduce_elems
    assert n == 256 * 16 + 1000
    # flat ring all-reduce moves ~2*n fp32 elements over the pod boundary;
    # hierarchical moves 2*(n/inner) wire elements
    assert plan.allreduce_interpod_bytes_flat == 2 * n * 4
    assert plan.allreduce_interpod_bytes == 2 * -(-n // 4) * 2
    # the acceptance ratio: pod_size x (fp32/bf16) = 4 * 2 = 8
    assert plan.allreduce_reduction == pytest.approx(8.0, rel=1e-3)
    assert plan.interpod_reduction > 4.0  # whole step, exchange included
    # dedup pool: (n_dev - inner) local uniques cross pods vs flat raw ids
    assert plan.dedup_interpod_bytes == (8 - 4) * 64 * 4
    assert plan.dedup_interpod_bytes_flat == (8 - 4) * 48 * 4

    int8 = CommPlan.for_step(
        n_pods=2, inner=4, compress="int8", hierarchical=True,
        capacity=256, embed_dim=16, n_dense_elems=1000,
        local_capacity=64, ids_per_device=48)
    assert int8.allreduce_interpod_bytes == 2 * -(-n // 4) * 1 + 8
    assert int8.allreduce_reduction > 12.0  # ~ 4 * 4x minus scale overhead

    one = CommPlan.for_step(
        n_pods=1, inner=1, compress=None, hierarchical=True,
        capacity=256, embed_dim=16, n_dense_elems=1000,
        local_capacity=64, ids_per_device=48)
    assert one.interpod_bytes_per_step == 0
    assert one.interpod_reduction == 1.0

    m = plan.as_metrics()
    assert m["allreduce_reduction"] == plan.allreduce_reduction
    assert m["n_devices"] == 8


def test_comm_stats_accumulates():
    from repro.train.compression import CommPlan, CommStats

    plan = CommPlan.for_step(
        n_pods=2, inner=2, compress="bf16", hierarchical=True,
        capacity=64, embed_dim=8, n_dense_elems=100,
        local_capacity=32, ids_per_device=24)
    cs = CommStats(plan=plan)
    for _ in range(3):
        cs.on_step()
    assert cs.steps == 3
    assert cs.interpod_bytes_total == 3 * plan.interpod_bytes_per_step
    assert cs.interpod_bytes_total_flat == 3 * plan.interpod_bytes_per_step_flat
    assert cs.as_metrics()["plan_n_pods"] == 2
    assert "codec=bf16" in cs.summary()


# ------------------------------------------------------------ parsing/misc
def test_parse_mesh_spec():
    from repro.launch.mesh import parse_mesh_spec

    assert parse_mesh_spec("2x4") == (2, 4)
    assert parse_mesh_spec("1X1") == (1, 1)
    assert parse_mesh_spec("2×4") == (2, 4)
    for bad in ("", "2", "2x4x8", "0x4", "ax4"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


def test_codec_name_normalization():
    from repro.train.compression import codec_name

    assert codec_name(None) is None
    assert codec_name(False) is None
    assert codec_name("off") is None
    assert codec_name("none") is None
    assert codec_name(True) == "bf16"
    assert codec_name("bf16") == "bf16"
    assert codec_name("int8") == "int8"
    with pytest.raises(ValueError):
        codec_name("fp8")


def test_make_train_mesh_rejects_oversubscription():
    import jax

    from repro.launch.mesh import make_train_mesh

    n = len(jax.devices())
    with pytest.raises(ValueError, match="device_count"):
        make_train_mesh(n + 1, 2)


def test_shard_bounds():
    from repro.embedding.table import shard_bounds

    assert shard_bounds(512, 8, 0) == (0, 64)
    assert shard_bounds(512, 8, 7) == (448, 512)
    with pytest.raises(ValueError):
        shard_bounds(100, 8, 0)


# ------------------------------------------------------- EF psum property
def test_hierarchical_psum_error_feedback_converges():
    """With a constant gradient, error feedback makes the *cumulative*
    compressed sum track the exact sum (error stays O(one quantization
    step) instead of growing linearly)."""
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat; compat.install()
from repro.train.compression import flat_psum, hierarchical_psum
from repro.launch.mesh import make_train_mesh

mesh = make_train_mesh(2, 4)
N = 64
x = np.linspace(-1.3, 1.7, 8 * N).reshape(8, N).astype(np.float32)

def step(xs, res):
    out, new_res = hierarchical_psum(
        xs[0], compress="int8", residual=res[0])
    return out[None], new_res[None]

f = jax.jit(jax.shard_map(
    step, mesh=mesh,
    in_specs=(P(("pod", "data")), P(("pod", "data"))),
    out_specs=(P(("pod", "data")), P(("pod", "data"))),
    check_vma=False))

exact = np.asarray(jax.jit(jax.shard_map(
    lambda xs: flat_psum(xs[0])[None], mesh=mesh,
    in_specs=P(("pod", "data")), out_specs=P(("pod", "data")),
    check_vma=False))(jnp.asarray(x)))[0]

# the residual lives on the scattered block: N / inner elements per device
res = jnp.zeros((8, N // 4), jnp.float32)
total = np.zeros(N, np.float64)
T = 16
for t in range(T):
    out, res = f(jnp.asarray(x), res)
    total += np.asarray(out)[0]
one_step_err = float(np.max(np.abs(np.asarray(out)[0] - exact)))
cum_err = float(np.max(np.abs(total - T * exact.astype(np.float64))))
# without EF the cumulative error would be ~T * one_step_err
assert cum_err < 4 * one_step_err, (cum_err, one_step_err)
assert float(np.max(np.abs(np.asarray(res)))) > 0
print("EF OK", one_step_err, cum_err)
""")
