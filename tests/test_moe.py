"""MoE: sort-based dispatch vs dense oracle; capacity dropping; grads."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import (
    MoEConfig,
    _dispatch_indices,
    _route,
    moe_ffn,
    moe_ffn_ref,
    moe_params_shape,
)

RNG = np.random.default_rng(5)


def _params(c, d, seed=0):
    kg = jax.random.PRNGKey(seed)
    return {k: jax.random.normal(jax.random.fold_in(kg, i), s) * 0.1
            for i, (k, s) in enumerate(moe_params_shape(d, c).items())}


def test_moe_matches_dense_oracle_no_drops():
    c = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=2,
                  capacity_factor=8.0)  # capacity >> load: no drops
    d, t = 32, 96
    p = _params(c, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (t, d)) * 0.5
    out, aux = moe_ffn(p, x, c)
    ref = moe_ffn_ref(p, x, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_without_shared_experts():
    c = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8, n_shared=0,
                  capacity_factor=8.0)
    p = _params(c, 16)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    out, _ = moe_ffn(p, x, c)
    ref = moe_ffn_ref(p, x, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_dispatch_respects_capacity():
    c = MoEConfig(n_experts=2, top_k=1, d_ff_expert=4, capacity_factor=1.0)
    # all tokens route to one expert -> beyond-capacity ones must drop
    top_e = jnp.zeros((16, 1), jnp.int32)
    order, sorted_e, pos, keep, token = _dispatch_indices(top_e, c, capacity=8)
    assert int(keep.sum()) == 8
    assert (np.asarray(pos)[np.asarray(keep)] < 8).all()


def test_dispatch_positions_unique_per_expert():
    c = MoEConfig(n_experts=4, top_k=2, d_ff_expert=4)
    top_e = jnp.asarray(RNG.integers(0, 4, (32, 2)).astype(np.int32))
    order, sorted_e, pos, keep, token = _dispatch_indices(top_e, c, capacity=64)
    se, ps = np.asarray(sorted_e), np.asarray(pos)
    slots = se.astype(np.int64) * 64 + ps
    assert len(np.unique(slots)) == len(slots)   # no slot collisions


def test_route_weights_normalized():
    c = MoEConfig(n_experts=8, top_k=3, d_ff_expert=4)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 16))
    router = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    top_e, top_p, aux = _route(x, router, c)
    np.testing.assert_allclose(np.asarray(top_p.sum(-1)), 1.0, rtol=1e-5)
    assert np.asarray(top_e).max() < 8


def test_moe_grads_finite_and_cover_experts():
    c = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, n_shared=1,
                  capacity_factor=4.0)
    p = _params(c, 16)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 16))

    def loss(p):
        o, a = moe_ffn(p, x, c)
        return (o ** 2).mean() + 0.01 * a

    g = jax.grad(loss)(p)
    for k, v in g.items():
        assert np.isfinite(np.asarray(v)).all(), k
    # with 64 tokens x top-2 over 4 experts, every expert's w2 sees gradient
    w2g = np.abs(np.asarray(g["w2"])).sum(axis=(1, 2))
    assert (w2g > 0).all()
