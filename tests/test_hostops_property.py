"""Hypothesis property tests: vectorized host ops == their ``_ref``
oracles bit-for-bit, over adversarial unicode/empty/long-row inputs."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from test_hostops import assert_ragged_equal  # noqa: E402

from repro.fe.colstore import RaggedColumn  # noqa: E402
from repro.fe.ops import (  # noqa: E402
    ragged_to_padded,
    ragged_to_padded_ref,
    tokenize_hash,
    tokenize_hash_ref,
)


@hypothesis.settings(max_examples=60, deadline=None)
@hypothesis.given(
    rows=st.lists(st.text(max_size=40), max_size=12),
    ngrams=st.integers(min_value=1, max_value=3),
    field_size=st.sampled_from([7, 1009, 1 << 20]),
)
def test_tokenize_hash_matches_ref_property(rows, ngrams, field_size):
    arr = np.asarray(rows, object)
    assert_ragged_equal(
        tokenize_hash(arr, field_size=field_size, ngrams=ngrams),
        tokenize_hash_ref(arr, field_size=field_size, ngrams=ngrams))


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    rows=st.lists(
        st.text(alphabet=st.sampled_from(" \t　ab\U0001f680"),
                max_size=200),
        max_size=6))
def test_tokenize_hash_matches_ref_whitespace_heavy(rows):
    """Long separator runs and multi-byte tokens — the boundary cases a
    shifted-mask tokenizer gets wrong first."""
    arr = np.asarray(rows, object)
    assert_ragged_equal(tokenize_hash(arr, field_size=997, ngrams=2),
                        tokenize_hash_ref(arr, field_size=997, ngrams=2))


@st.composite
def _ragged_columns(draw):
    lengths = draw(st.lists(st.integers(min_value=0, max_value=12),
                            max_size=10))
    lengths = np.asarray(lengths, np.int32)
    values = draw(st.lists(st.integers(min_value=-2**40, max_value=2**40),
                           min_size=int(lengths.sum()),
                           max_size=int(lengths.sum())))
    return RaggedColumn(values=np.asarray(values, np.int64), lengths=lengths)


@hypothesis.settings(max_examples=60, deadline=None)
@hypothesis.given(col=_ragged_columns(),
                  max_len=st.integers(min_value=0, max_value=16),
                  pad_id=st.sampled_from([0, -1, 7]))
def test_ragged_to_padded_matches_ref_property(col, max_len, pad_id):
    got_ids, got_mask = ragged_to_padded(col, max_len=max_len, pad_id=pad_id)
    want_ids, want_mask = ragged_to_padded_ref(col, max_len=max_len,
                                               pad_id=pad_id)
    np.testing.assert_array_equal(got_ids, want_ids)
    np.testing.assert_array_equal(got_mask, want_mask)
    assert got_ids.dtype == want_ids.dtype
    assert got_mask.dtype == want_mask.dtype
