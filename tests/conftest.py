"""Shared test helpers."""

import threading
import time

PIPELINE_THREADS = ("fe-worker", "h2d-feeder")


def pipeline_threads_gone(names=PIPELINE_THREADS, timeout=5.0):
    """Poll until no runner worker thread with one of ``names`` is alive."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if not [t for t in threading.enumerate() if t.name in names]:
            return True
        time.sleep(0.05)
    return False
