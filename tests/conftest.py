"""Shared test helpers."""

import threading
import time

import numpy as np

PIPELINE_THREADS = ("fe-worker", "h2d-feeder")


def recording_step(record):
    """Train step that snapshots every ``batch_*`` slot to host numpy —
    the common probe for runner-equivalence assertions."""
    def step(state, env):
        record.append({k: np.asarray(v) for k, v in env.items()
                       if k.startswith("batch_")})
        return {"batches": state["batches"] + 1}
    return step


def pipeline_threads_gone(names=PIPELINE_THREADS, timeout=5.0):
    """Poll until no runner worker thread with one of ``names`` is alive."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        if not [t for t in threading.enumerate() if t.name in names]:
            return True
        time.sleep(0.05)
    return False
