"""Compiled stage->train boundary (repro.fe.modelfeed).

The load-bearing property: ``ModelFeed.apply`` (compiled adaptation, traced
inside the train jit) is **bitwise** equal to the legacy eager adapter
``fe_env_to_model_batch_ref`` — on every preset x smoke arch, on random
layouts x archs (hypothesis), packed and split, eager and jitted.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.fe import featureplan, get_spec, modelfeed
from repro.fe.compiler import OutputLayout, field_slot
from repro.fe.datagen import gen_views
from repro.fe.modelfeed import (
    ModelFeedError,
    TrainFeedStats,
    dedup_capacity_hint,
    fe_env_to_model_batch_ref,
)

ARCHS = ("dlrm-mlperf", "bst", "dcn-v2", "autoint")
SPECS = ("ads_ctr", "dlrm", "bst")


def _split_env(env):
    """Derive the per-field staged form from a packed environment."""
    out = dict(env)
    sparse = np.asarray(env["batch_sparse"])
    for i in range(sparse.shape[1]):
        out[field_slot(i)] = sparse[:, i]
    del out["batch_sparse"]
    return out


def _assert_batches_equal(ref, got, msg=""):
    assert set(ref) == set(got), msg
    for k in ref:
        assert ref[k].dtype == got[k].dtype, f"{msg}{k} dtype"
        np.testing.assert_array_equal(
            np.asarray(ref[k]), np.asarray(got[k]), err_msg=f"{msg}{k}")


@pytest.mark.parametrize("spec_name", SPECS)
@pytest.mark.parametrize("arch", ARCHS)
def test_apply_matches_ref_on_presets(spec_name, arch):
    plan = featureplan.compile(get_spec(spec_name))
    cfg = get_arch(arch).smoke()
    env = plan.run(gen_views(24, seed=7))
    ref = fe_env_to_model_batch_ref(env, cfg)

    mf = plan.model_feed(cfg)
    _assert_batches_equal(ref, mf.apply(mf.select(env)), "packed ")
    _assert_batches_equal(ref, jax.jit(mf.apply)(mf.select(env)), "jit ")

    mfs = plan.model_feed(cfg, split_sparse_fields=True)
    feed = mfs.select(_split_env(env))
    _assert_batches_equal(ref, mfs.apply(feed), "split ")
    _assert_batches_equal(ref, jax.jit(mfs.apply)(feed), "split jit ")


# ------------------------------------------------------- capacity heuristic
def test_capacity_hint_worst_is_exact_bound():
    cfg = get_arch("dlrm-mlperf").smoke()  # vocabs (64, 32, 100, 16, 8, 40)
    cap = dedup_capacity_hint(cfg, 64, multiple=1)
    assert cap == sum(min(64, v) for v in cfg.vocab_sizes)
    # rounding to a multiple never shrinks
    assert dedup_capacity_hint(cfg, 64, multiple=64) >= cap
    assert dedup_capacity_hint(cfg, 64, multiple=64) % 64 == 0


def test_capacity_hint_expected_below_worst_and_seq_counted():
    cfg = get_arch("bst").smoke()
    worst = dedup_capacity_hint(cfg, 512, multiple=1)
    exp = dedup_capacity_hint(cfg, 512, mode="expected", multiple=1)
    assert exp <= worst
    # the behavior sequence references the item vocab beyond the B rows
    no_seq = dataclasses.replace(cfg, kind="dlrm")
    assert dedup_capacity_hint(no_seq, 512, multiple=1) < worst


def test_capacity_hint_rejects_bad_inputs():
    cfg = get_arch("dlrm-mlperf").smoke()
    with pytest.raises(ModelFeedError):
        dedup_capacity_hint(cfg, 0)
    with pytest.raises(ModelFeedError):
        dedup_capacity_hint(cfg, 16, mode="typo")


def test_compile_tunes_untuned_capacity_only():
    plan = featureplan.compile(get_spec("ads_ctr"))
    cfg = get_arch("dlrm-mlperf").smoke()
    assert plan.model_feed(cfg, rows_hint=64).config.dedup_capacity \
        == cfg.dedup_capacity  # already set: respected
    untuned = dataclasses.replace(cfg, dedup_capacity=0)
    mf = plan.model_feed(untuned, rows_hint=64)
    assert mf.config.dedup_capacity == dedup_capacity_hint(untuned, 64)
    assert plan.model_feed(untuned).config.dedup_capacity == 0  # no hint


def test_compile_rejects_sparse_free_layout():
    layout = OutputLayout(n_sparse_fields=0, n_dense_feats=4, seq_len=0,
                          field_size=16)
    with pytest.raises(ModelFeedError):
        modelfeed.compile(layout, get_arch("dlrm-mlperf").smoke())


def test_select_validates_contract():
    plan = featureplan.compile(get_spec("dlrm"))
    cfg = get_arch("dlrm-mlperf").smoke()
    mf = plan.model_feed(cfg)
    env = plan.run(gen_views(8, seed=0))
    with pytest.raises(ModelFeedError, match="missing adapted slot"):
        mf.select({k: v for k, v in env.items() if k != "batch_label"})
    bad = dict(env)
    bad["batch_sparse"] = np.asarray(env["batch_sparse"])[:, :3]
    with pytest.raises(ModelFeedError, match="shape mismatch"):
        mf.select(bad)


# ----------------------------------------------------------- boundary step
def _loss_step(cfg):
    """Minimal (params, opt, batch) -> (params, opt, metrics) train step."""
    def raw(params, opt_state, batch):
        from repro.embedding.dedup import dedup
        gids = batch["sparse"].reshape(-1)
        _, _, count = dedup(gids, capacity=cfg.dedup_capacity or gids.shape[0])
        loss = jnp.mean(batch["label"])
        return params, opt_state, {"loss": loss, "unique": count,
                                   "n_ids": jnp.int32(gids.shape[0])}
    return raw


def test_make_step_fused_one_dispatch_and_dedup_stats():
    plan = featureplan.compile(get_spec("ads_ctr"))
    cfg = dataclasses.replace(get_arch("dlrm-mlperf").smoke(),
                              dedup_capacity=0)
    mf = plan.model_feed(cfg, rows_hint=32)
    step = mf.make_step(_loss_step(mf.config), donate=False)
    assert step.feed_stats is mf.stats
    env = plan.run(gen_views(32, seed=3))
    for _ in range(3):
        _, _, m = step({}, {}, env)
    s = mf.stats
    assert s.steps == 3 and s.fused_steps == 3
    assert s.adapt_dispatches == 0
    assert s.dispatches_per_step == 1.0
    assert 0 < s.unique_ratio < 1.0
    assert s.total_ids == 3 * 32 * cfg.n_sparse
    assert s.overflows == 0


def test_make_step_eager_counts_adapt_dispatches():
    plan = featureplan.compile(get_spec("ads_ctr"))
    cfg = get_arch("dlrm-mlperf").smoke()
    mf = plan.model_feed(cfg)
    step = mf.make_step(_loss_step(cfg), fused=False, donate=False)
    env = plan.run(gen_views(16, seed=5))
    step({}, {}, env)
    s = mf.stats
    assert s.fused_steps == 0
    assert s.adapt_dispatches > 0          # the eager ops the fusion removes
    assert s.dispatches_per_step > 1.0
    assert s.adapt_seconds > 0.0


def test_overflow_detection_surfaced_in_stats():
    plan = featureplan.compile(get_spec("ads_ctr"))
    # force a working set far smaller than the batch's unique ids
    cfg = dataclasses.replace(get_arch("dlrm-mlperf").smoke(),
                              dedup_capacity=4)
    mf = plan.model_feed(cfg)
    step = mf.make_step(_loss_step(cfg), donate=False)
    env = plan.run(gen_views(32, seed=1))
    with pytest.warns(RuntimeWarning, match="working set saturated"):
        step({}, {}, env)
    assert mf.stats.overflows == 1


def test_make_step_fence_receives_a_step_output():
    plan = featureplan.compile(get_spec("dlrm"))
    cfg = get_arch("dlrm-mlperf").smoke()
    mf = plan.model_feed(cfg)
    fences = []
    step = mf.make_step(_loss_step(cfg), donate=True,
                        fence_cb=fences.append)
    env = plan.run(gen_views(8, seed=2))
    step({}, {}, env)
    assert len(fences) == 1
    fences[0].block_until_ready()  # a live step output, awaitable


def test_train_feed_stats_summary_smoke():
    s = TrainFeedStats(steps=2, fused_steps=2, unique_ids=10, total_ids=40)
    assert "unique_ratio=0.250" in s.summary()
    assert s.dispatches_per_step == 1.0
