"""Scheduler placement heuristic: AUTO ops straddling the device budget,
explicit HOST/DEVICE pins (paper §IV placement rule).

Kept hypothesis-free so it runs even where the property-test extras are
not installed (unlike test_scheduler.py).
"""

from repro.core import Device, OpCost, Operator, OpGraph, build_schedule
from repro.core.scheduler import assign_device


def _auto_op(name, bytes_touched):
    return Operator(name, lambda x: {f"{name}_out": x}, ("x",),
                    (f"{name}_out",), device=Device.AUTO,
                    cost=OpCost(bytes_touched=bytes_touched))


def test_auto_placement_budget_boundaries():
    """The paper's heuristic: DEVICE unless the footprint exceeds budget."""
    budget = 1 << 20
    # exactly at budget: still fits on the device (strict > comparison)
    assert assign_device(_auto_op("at", budget), budget) is Device.DEVICE
    assert assign_device(_auto_op("under", budget - 1), budget) is Device.DEVICE
    # one byte over: falls back to host
    assert assign_device(_auto_op("over", budget + 1), budget) is Device.HOST
    assert assign_device(_auto_op("zero", 0), budget) is Device.DEVICE


def test_explicit_pins_override_cost():
    """HOST/DEVICE pins are respected regardless of the cost estimate."""
    budget = 1 << 20
    huge_device = Operator("hd", lambda x: {"hd_out": x}, ("x",), ("hd_out",),
                           device=Device.DEVICE,
                           cost=OpCost(bytes_touched=1 << 50))
    tiny_host = Operator("th", lambda x: {"th_out": x}, ("x",), ("th_out",),
                         device=Device.HOST, cost=OpCost(bytes_touched=0))
    assert assign_device(huge_device, budget) is Device.DEVICE
    assert assign_device(tiny_host, budget) is Device.HOST


def test_schedule_respects_budget_across_graph():
    """End to end: the same AUTO graph splits differently as budget moves."""
    g = OpGraph()
    g.mark_external("x")
    g.add(_auto_op("small", 100))
    g.add(_auto_op("medium", 10_000))
    g.add(_auto_op("large", 1_000_000))

    def places(budget):
        sched = build_schedule(g, device_bytes_budget=budget)
        return {p.op.name: p.device
                for layer in sched.layers for p in layer.ops}

    all_fit = places(1_000_000)
    assert all(d is Device.DEVICE for d in all_fit.values())
    mid = places(10_000)
    assert mid["small"] is Device.DEVICE
    assert mid["medium"] is Device.DEVICE   # exactly at budget
    assert mid["large"] is Device.HOST
    none_fit = places(99)
    assert all(d is Device.HOST for d in none_fit.values())


def test_featureplan_device_budget_reaches_scheduler():
    """device_budget must flow through featureplan.compile into the
    scheduler: an AUTO custom op's placement flips as the budget moves
    across its cost (pinned ops would pass regardless and prove nothing)."""
    from repro.fe import Custom, FeatureSpec, featureplan, get_spec

    base = get_spec("bst")
    auto = Custom("auto_op", lambda label_col: {"auto_out": label_col},
                  ("label_col",), ("auto_out",), device=Device.AUTO,
                  cost=OpCost(bytes_touched=1 << 20))
    spec = FeatureSpec(
        name="bst_auto", base=base.base, sources=base.sources,
        outputs=base.outputs, joins=base.joins,
        transforms=base.transforms + (auto,), label=base.label)

    def place(budget):
        plan = featureplan.compile(spec, device_budget=budget)
        return {p.op.name: p.device
                for layer in plan.schedule.layers
                for p in layer.ops}["auto_op"]

    assert place(1 << 20) is Device.DEVICE        # exactly at budget: fits
    assert place((1 << 20) - 1) is Device.HOST    # over budget: host fallback
