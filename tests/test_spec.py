"""Declarative FeatureSpec API: compiler lowering, schedule equivalence with
the legacy hand-wired graph, scenario presets, projection pushdown."""

import numpy as np
import pytest

from repro.core import Device, OpCost, PipelinedRunner, build_schedule, run_layers
from repro.fe import (
    Custom,
    DenseOutput,
    FeatureSpec,
    Hash,
    Join,
    SparseOutput,
    Source,
    featureplan,
    get_spec,
    list_specs,
)
from repro.fe.compiler import SpecError, required_columns
from repro.fe.datagen import IMPRESSIONS, USER_PROFILE, gen_views
from repro.fe.pipeline_graph import build_fe_graph, build_fe_graph_legacy

BATCH_KEYS = ("batch_dense", "batch_sparse", "batch_seq_ids",
              "batch_seq_mask", "batch_label")


def _layer_shape(schedule):
    return [(len(l.host_ops), len(l.device_ops)) for l in schedule.layers]


# ------------------------------------------------- legacy-graph equivalence
def test_ads_spec_schedule_equivalent_to_legacy():
    """Acceptance: same layers, same placements as the hand-wired graph."""
    s_new = build_schedule(build_fe_graph())
    s_old = build_schedule(build_fe_graph_legacy())
    assert s_new.n_layers == s_old.n_layers
    assert _layer_shape(s_new) == _layer_shape(s_old)
    assert s_new.n_device_dispatches == s_old.n_device_dispatches
    assert s_new.n_unfused_dispatches == s_old.n_unfused_dispatches


def test_ads_spec_outputs_equal_legacy_bitwise():
    views = gen_views(256, seed=3)
    plan = featureplan.compile(get_spec("ads_ctr"))
    from repro.core import compile_layers
    legacy_layers = compile_layers(build_schedule(build_fe_graph_legacy()))
    a = plan.run(dict(views))
    b = run_layers(legacy_layers, dict(views))
    for k in BATCH_KEYS:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_ads_layout_matches_legacy_constants():
    from repro.fe.pipeline_graph import N_DENSE_FEATS, N_SPARSE_FIELDS, SEQ_LEN
    lay = featureplan.compile(get_spec("ads_ctr")).layout
    assert lay.n_sparse_fields == N_SPARSE_FIELDS
    assert lay.n_dense_feats == N_DENSE_FEATS
    assert lay.seq_len == 3 * SEQ_LEN
    assert lay.sparse_id_space == N_SPARSE_FIELDS * lay.field_size


# ------------------------------------------------------------ preset shapes
def test_list_specs():
    assert list_specs() == ["ads_ctr", "bst", "dlrm"]


def test_dlrm_preset_matches_config_shape():
    from repro.configs.dlrm_mlperf import CONFIG
    plan = featureplan.compile(get_spec("dlrm"))
    assert plan.layout.n_dense_feats == CONFIG.n_dense == 13
    assert plan.layout.n_sparse_fields == CONFIG.n_sparse == 26
    b = 64
    out = plan.outputs(plan.run(gen_views(b, seed=7)))
    assert np.asarray(out["batch_dense"]).shape == (b, 13)
    assert np.asarray(out["batch_sparse"]).shape == (b, 26)
    assert np.asarray(out["batch_seq_ids"]).shape == (b, 16)  # multi-hot bag
    assert np.isfinite(np.asarray(out["batch_dense"])).all()
    sparse = np.asarray(out["batch_sparse"])
    fs = plan.layout.field_size
    for f in range(26):  # field id spaces are disjoint
        assert (sparse[:, f] // fs == f).all()


def test_bst_preset_matches_config_shape():
    from repro.configs.bst import CONFIG
    plan = featureplan.compile(get_spec("bst"))
    assert plan.layout.n_sparse_fields == CONFIG.n_sparse == 4
    assert plan.layout.seq_len == CONFIG.seq_len == 20
    assert plan.layout.n_dense_feats == CONFIG.n_dense == 0
    b = 32
    out = plan.outputs(plan.run(gen_views(b, seed=9)))
    assert "batch_dense" not in out  # no dense block in the BST shape
    assert np.asarray(out["batch_sparse"]).shape == (b, 4)
    assert np.asarray(out["batch_seq_ids"]).shape == (b, 20)
    assert np.asarray(out["batch_seq_mask"]).shape == (b, 20)


@pytest.mark.parametrize("name", ["ads_ctr", "dlrm", "bst"])
def test_pipelined_runner_green_on_all_presets(name):
    """Acceptance: PipelinedRunner end-to-end on every bundled preset."""
    plan = featureplan.compile(get_spec(name))
    batches = [gen_views(64, seed=50 + i) for i in range(3)]

    def step(state, env):
        total = float(np.asarray(env["batch_sparse"]).sum())
        return {"batches": state["batches"] + 1, "sum": state["sum"] + total}

    runner = PipelinedRunner(plan.layers, step, prefetch=2)
    state = runner.run({"batches": 0, "sum": 0.0}, batches)
    assert state["batches"] == 3
    assert np.isfinite(state["sum"])


# ------------------------------------------------------ projection pushdown
def test_required_columns_drop_untouched():
    req = featureplan.compile(get_spec("ads_ctr")).required_columns
    assert "gender" not in req["user_profile"]       # never referenced
    assert "campaign_id" not in req["ad_inventory"]  # never referenced
    assert "context_json" in req["impressions"]      # feeds JSON extraction
    assert "interests" in req["user_profile"]

    bst = featureplan.compile(get_spec("bst")).required_columns
    assert "basic_features" not in bst               # whole table untouched
    assert "query_text" not in bst["user_profile"]
    assert set(bst["ad_inventory"]) == {"ad_id", "advertiser_id"}


def test_projection_run_equals_full_run():
    views = gen_views(128, seed=11)
    plan = featureplan.compile(get_spec("ads_ctr"))
    full = plan.run({v: dict(c) if isinstance(c, dict) else c
                     for v, c in views.items()})
    projected_views = {
        v: {c: views[v][c] for c in cols}
        for v, cols in plan.required_columns.items()
    }
    proj = plan.run(projected_views)
    for k in BATCH_KEYS:
        np.testing.assert_array_equal(np.asarray(full[k]), np.asarray(proj[k]))


def test_custom_transform_disables_projection():
    spec = get_spec("ads_ctr")
    custom = Custom("extra", lambda label_col: {"extra": label_col},
                    ("label_col",), ("extra",), device=Device.DEVICE)
    spec = FeatureSpec(
        name="ads_custom", base=spec.base, sources=spec.sources,
        outputs=spec.outputs, joins=spec.joins, merges=spec.merges,
        transforms=spec.transforms + (custom,), label=spec.label)
    req = required_columns(spec)
    # conservative fallback: every column of every source
    assert set(req["user_profile"]) == set(USER_PROFILE.column_names)
    assert set(req["impressions"]) == set(IMPRESSIONS.column_names)


# ------------------------------------------------------- custom ops + errors
def test_custom_op_runs_in_graph():
    spec = get_spec("bst")
    double = Custom("double_label",
                    lambda label_col: {"label2": label_col * 2.0},
                    ("label_col",), ("label2",), device=Device.DEVICE,
                    cost=OpCost(flops=1))
    spec = FeatureSpec(
        name="bst_custom", base=spec.base, sources=spec.sources,
        outputs=spec.outputs, joins=spec.joins,
        transforms=spec.transforms + (double,), label=spec.label)
    plan = featureplan.compile(spec)
    env = plan.run(gen_views(16, seed=2))
    np.testing.assert_allclose(np.asarray(env["label2"]),
                               2.0 * np.asarray(env["batch_label"]))


def test_unknown_column_reference_raises():
    spec = FeatureSpec(
        name="bad", base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=(Hash("f", "nonexistent"),),
        outputs=(SparseOutput(("f",)),))
    with pytest.raises(SpecError, match="nonexistent"):
        featureplan.compile(spec)


def test_transform_input_type_mismatch_raises():
    # Hash on a FLOAT column would silently truncate floats to sparse ids
    spec = FeatureSpec(
        name="badtype", base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=(Hash("f", "dwell_time"),),
        outputs=(SparseOutput(("f",)),))
    with pytest.raises(SpecError, match="categorical INT"):
        featureplan.compile(spec)
    # Bucketize on a STRING column fails at compile time, not runtime
    from repro.fe import Bucketize
    spec2 = FeatureSpec(
        name="badtype2", base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=(Bucketize("d", "context_json", (1, 2)),),
        outputs=(DenseOutput(("d",)),))
    with pytest.raises(SpecError, match="numeric"):
        featureplan.compile(spec2)


def test_required_columns_json_extracted_join_key():
    """A join key that only exists via JSON extraction must map to the JSON
    source column in the projection, not to a phantom on-disk column."""
    from repro.fe import JsonExtract
    from repro.fe.schema import ColType, Column, ViewSchema

    geo_dim = ViewSchema(
        name="geo_dim", key="geo",
        columns=(Column("geo", ColType.INT, nullable=False),
                 Column("region", ColType.INT)))
    spec = FeatureSpec(
        name="geo_join", base="impressions",
        sources=(
            Source("impressions", IMPRESSIONS, json=(
                JsonExtract("context_json", (("geo", ColType.INT),)),)),
            Source("geo_dim", geo_dim),
        ),
        joins=(Join("geo_dim", key="geo", prefix="g_"),),
        transforms=(Hash("f_region", "g_region"),),
        outputs=(SparseOutput(("f_region",)),))
    req = required_columns(spec)
    assert "geo" not in req["impressions"]          # not an on-disk column
    assert "context_json" in req["impressions"]     # its JSON source is
    assert set(req["geo_dim"]) == {"geo", "region"}
    # the projection actually feeds a run (regression: used to KeyError)
    views = gen_views(64, seed=6)
    rng = np.random.default_rng(0)
    views["geo_dim"] = {
        "geo": np.arange(512, dtype=np.int64),
        "region": rng.integers(0, 8, 512).astype(np.int64)}
    projected = {v: {c: views[v][c] for c in cols}
                 for v, cols in req.items()}
    plan = featureplan.compile(spec)
    out = plan.outputs(plan.run(projected))
    assert np.asarray(out["batch_sparse"]).shape == (64, 1)


def test_wrong_output_kind_raises():
    spec = FeatureSpec(
        name="bad2", base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=(Hash("f", "user_id"),),
        outputs=(DenseOutput(("f",)),))  # Hash is not a dense transform
    with pytest.raises(SpecError, match="dense"):
        featureplan.compile(spec)


def test_spec_validation_rejects_bad_refs():
    with pytest.raises(ValueError, match="base view"):
        FeatureSpec(name="x", base="missing",
                    sources=(Source("impressions", IMPRESSIONS),),
                    outputs=())
    with pytest.raises(ValueError, match="unknown view"):
        FeatureSpec(name="x", base="impressions",
                    sources=(Source("impressions", IMPRESSIONS),),
                    joins=(Join("nope", key="user_id"),),
                    outputs=())


def test_field_size_override():
    plan = featureplan.compile(get_spec("bst"), field_size=1 << 10)
    out = plan.outputs(plan.run(gen_views(64, seed=4)))
    sparse = np.asarray(out["batch_sparse"])
    assert (sparse >= 0).all() and (sparse < 4 * (1 << 10)).all()
    assert plan.layout.field_size == 1 << 10
