"""Lockset audit (LK4xx): synthetic racy/clean classes + the real modules."""

import textwrap

from repro.check.lockset import audit_default, check_source


def _rules(src):
    return sorted({f.rule for f in check_source(textwrap.dedent(src))})


# ------------------------------------------------------------------- LK401
def test_lk401_undeclared_write_from_two_threads():
    src = """
    import threading

    class Worker:
        def __init__(self):
            self.count = 0

        def start(self):
            threading.Thread(target=self._run).start()
            self.count += 1          # main

        def _run(self):
            self.count += 1          # thread:_run
    """
    assert _rules(src) == ["LK401"]


def test_lk401_parent_rebind_conflicts_with_child_write():
    src = """
    import threading

    class Loader:
        def start(self):
            threading.Thread(target=self._reader).start()
            self.stats = object()    # rebind from main

        def _reader(self):
            self.stats.rows += 1     # child write from reader thread
    """
    assert _rules(src) == ["LK401"]


def test_lk401_not_raised_for_sibling_fields_each_owned_by_one_thread():
    src = """
    import threading

    class Runner:
        def start(self):
            threading.Thread(target=self._fe).start()
            self.stats.train_seconds += 1.0   # main only

        def _fe(self):
            self.stats.fe_seconds += 1.0      # fe thread only
    """
    assert _rules(src) == []


def test_lk401_deduped_per_path():
    src = """
    import threading

    class W:
        def start(self):
            threading.Thread(target=self._run).start()
            self.n += 1
            self.n += 2

        def _run(self):
            self.n += 3
    """
    findings = check_source(textwrap.dedent(src))
    assert [f.rule for f in findings] == ["LK401"]


# ------------------------------------------------------------------- LK402
def test_lk402_guarded_write_without_lock():
    src = """
    import threading
    from repro.check.annotations import guarded_by, shared_entry

    @guarded_by("_lock", "shared")
    @shared_entry("feeder:stage", "main:flush")
    class Feeder:
        def __init__(self):
            self._lock = threading.Lock()
            self.shared = 0

        def stage(self):
            self.shared += 1         # missing `with self._lock:`

        def flush(self):
            with self._lock:
                self.shared = 0
    """
    assert _rules(src) == ["LK402"]


def test_lk402_clean_when_lock_held():
    src = """
    import threading
    from repro.check.annotations import guarded_by, shared_entry

    @guarded_by("_lock", "shared")
    @shared_entry("feeder:stage", "main:flush")
    class Feeder:
        def __init__(self):
            self._lock = threading.Lock()
            self.shared = 0

        def stage(self):
            with self._lock:
                self.shared += 1

        def flush(self):
            with self._lock:
                self.shared = 0
    """
    assert _rules(src) == []


def test_lk402_nested_def_does_not_inherit_lock():
    # Code deferred into a nested function runs later, without the lock.
    src = """
    import threading
    from repro.check.annotations import guarded_by, shared_entry

    @guarded_by("_lock", "shared")
    @shared_entry("a:go", "b:go2")
    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def go(self):
            with self._lock:
                def later():
                    self.shared = 1
                return later

        def go2(self):
            with self._lock:
                self.shared = 2
    """
    assert _rules(src) == ["LK402"]


def test_lk402_dotted_child_of_guarded_path():
    src = """
    import threading
    from repro.check.annotations import guarded_by, shared_entry

    @guarded_by("_lock", "stats")
    @shared_entry("a:tick", "b:tock")
    class C:
        def __init__(self):
            self._lock = threading.Lock()

        def tick(self):
            self.stats.donated += 1   # child path of guarded 'stats'

        def tock(self):
            with self._lock:
                self.stats.donated += 1
    """
    assert _rules(src) == ["LK402"]


# ------------------------------------------------------------------- LK403
def test_lk403_guarded_by_names_missing_lock():
    src = """
    from repro.check.annotations import guarded_by

    @guarded_by("_no_such_lock", "x")
    class C:
        def __init__(self):
            self.x = 0
    """
    assert _rules(src) == ["LK403"]


def test_lk403_shared_entry_names_missing_method():
    src = """
    import threading
    from repro.check.annotations import shared_entry

    @shared_entry("worker:no_such_method")
    class C:
        def __init__(self):
            self._lock = threading.Lock()
    """
    assert _rules(src) == ["LK403"]


# ------------------------------------------------------------------- LK404
def test_lk404_single_writer_contradicted():
    src = """
    import threading
    from repro.check.annotations import single_writer

    @single_writer("owned")
    class C:
        def start(self):
            threading.Thread(target=self._run).start()
            self.owned += 1

        def _run(self):
            self.owned += 1
    """
    assert _rules(src) == ["LK404"]


def test_single_writer_honest_claim_is_clean():
    src = """
    import threading
    from repro.check.annotations import single_writer

    @single_writer("owned")
    class C:
        def start(self):
            threading.Thread(target=self._run).start()

        def _run(self):
            self.owned += 1          # only the worker thread writes it
    """
    assert _rules(src) == []


# -------------------------------------------------------------- label model
def test_shared_entries_on_same_label_do_not_race():
    # stage and claim_views both run on the feeder thread: same label.
    src = """
    from repro.check.annotations import shared_entry

    @shared_entry("feeder:stage", "feeder:claim")
    class C:
        def stage(self):
            self.cursor = 1

        def claim(self):
            self.cursor = 2
    """
    assert _rules(src) == []


def test_unreachable_method_writes_are_ignored():
    src = """
    import threading

    class C:
        def start(self):
            threading.Thread(target=self._run).start()

        def _run(self):
            pass

        def helper_never_called_from_a_root(self):
            self.x = 1
            self.y = 2
    """
    assert _rules(src) == []


# ------------------------------------------------------------- real modules
def test_pipeline_modules_pass_the_audit():
    findings = audit_default()
    assert findings == [], "\n".join(f.render() for f in findings)
