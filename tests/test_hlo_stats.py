"""Loop-aware HLO analyzer vs XLA cost_analysis ground truth."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_stats import analyze_hlo, cost_analysis_dict


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_equals_unroll_after_correction():
    d = 64
    w = jnp.zeros((d, d))
    x = jnp.zeros((4, d))

    def body(c, _):
        return jnp.tanh(c @ w), None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    def unrolled(x):
        for _ in range(8):
            x, _ = body(x, None)
        return x

    c_scan = _compile(scanned, x)
    c_unr = _compile(unrolled, x)
    # sanity: cost_analysis itself undercounts the scan (the bug we fix)
    assert (cost_analysis_dict(c_scan)["flops"]
            < cost_analysis_dict(c_unr)["flops"] / 4)

    t_scan = analyze_hlo(c_scan.as_text())
    t_unr = analyze_hlo(c_unr.as_text())
    expected_flops = 8 * 2 * 4 * d * d
    assert t_scan.flops == expected_flops
    assert t_unr.flops == expected_flops
    # analyzer flops match XLA's on the unrolled graph (no loops involved)
    assert t_unr.flops == pytest.approx(cost_analysis_dict(c_unr)["flops"], rel=0.01)
    # bytes: within 2x of XLA accounting (copy/layout ops differ slightly)
    assert t_unr.bytes == pytest.approx(
        cost_analysis_dict(c_unr)["bytes accessed"], rel=1.0)


def test_nested_loops_multiply():
    d = 32
    w = jnp.zeros((d, d))
    x = jnp.zeros((2, d))

    def inner(c, _):
        return c @ w, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def fn(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    t = analyze_hlo(_compile(fn, x).as_text())
    assert t.flops == 5 * 3 * 2 * 2 * d * d


def test_collectives_scaled_by_trip_count():
    import subprocess, sys, os
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.hlo_stats import analyze_hlo

mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))

def body(c, _):
    s = jax.lax.psum(c, "d")
    return c + 0 * s, None

def fn(x):
    def shard_fn(x):
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y
    return jax.shard_map(shard_fn, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                         check_vma=False)(x)

x = jnp.zeros((8, 128))
with mesh:
    c = jax.jit(fn).lower(x).compile()
t = analyze_hlo(c.as_text())
per_step = 128 * 4  # one shard row f32
assert t.collective_total >= 4 * per_step, t.collective
print("COLLECTIVE TRIP OK", t.collective)
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr


def test_dot_flops_with_batch_dims():
    a = jnp.zeros((8, 16, 32))
    b = jnp.zeros((8, 32, 24))
    t = analyze_hlo(_compile(lambda a, b: jnp.einsum("bik,bkj->bij", a, b), a, b).as_text())
    assert t.flops == 2 * 8 * 16 * 24 * 32
