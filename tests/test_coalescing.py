"""Super-layer dispatch coalescing: grouping invariants, dispatch
accounting (``n_host_barriers + 1``), and bitwise equivalence of the
coalesced, per-layer, and per-op executors."""

import numpy as np
import pytest

from repro.core import (
    Device,
    ExecutionStats,
    coalesce_layers,
    compile_layers,
    run_layers,
    run_unfused,
)
from repro.fe import (
    Custom,
    DenseOutput,
    FeatureSpec,
    LogNorm,
    Source,
    SparseOutput,
    featureplan,
    get_spec,
    list_specs,
)
from repro.fe.datagen import IMPRESSIONS, gen_views

PRESETS = list_specs()
BATCH_KEYS = ("batch_dense", "batch_sparse", "batch_seq_ids",
              "batch_seq_mask", "batch_label")


# --------------------------------------------------------- grouping invariants
@pytest.mark.parametrize("name", PRESETS)
def test_superlayer_grouping_invariants(name):
    sched = featureplan.compile(get_spec(name)).schedule
    supers = sched.superlayers
    assert supers == coalesce_layers(sched.layers)
    # partition: every schedule layer appears exactly once, in order
    covered = [i for sl in supers for i in sl.layer_indices]
    assert covered == list(range(sched.n_layers))
    for sl in supers:
        # only the first member layer may carry host ops — any later host op
        # would have forced a new super-layer (it is a host barrier)
        for layer in sl.layers[1:]:
            assert not layer.host_ops
        # ops are the members' ops, device ops in layer order
        assert sl.device_ops == tuple(p for layer in sl.layers
                                      for p in layer.device_ops)


@pytest.mark.parametrize("name", PRESETS)
def test_dispatches_drop_to_host_barriers_plus_one(name):
    """The acceptance criterion: per batch, the coalesced executor pays
    exactly ``n_host_barriers + 1`` device dispatches on every preset."""
    plan = featureplan.compile(get_spec(name))
    sched = plan.schedule
    assert sched.n_coalesced_dispatches == sched.n_host_barriers + 1
    assert sched.n_coalesced_dispatches < sched.n_device_dispatches \
        or sched.n_device_dispatches == 1

    stats = ExecutionStats()
    run_layers(plan.layers, dict(gen_views(32, seed=0)), stats=stats)
    assert stats.n_device_dispatches == sched.n_host_barriers + 1
    assert stats.n_source_layers == sched.n_layers
    assert stats.n_layers == len(sched.superlayers)
    assert stats.n_layers_coalesced == sched.n_layers - len(sched.superlayers)


# -------------------------------------------------------- bitwise equivalence
@pytest.mark.parametrize("name", PRESETS)
def test_coalesced_equals_per_layer_and_per_op_bitwise(name):
    plan = featureplan.compile(get_spec(name))
    views = gen_views(48, seed=7)
    coalesced = plan.layers  # compile() coalesces by default
    per_layer = compile_layers(plan.schedule, coalesce=False)

    s_c, s_p, s_u = ExecutionStats(), ExecutionStats(), ExecutionStats()
    a = run_layers(coalesced, dict(views), stats=s_c)
    b = run_layers(per_layer, dict(views), stats=s_p)
    c = run_unfused(per_layer, dict(views), stats=s_u)
    for k in BATCH_KEYS:
        if k not in a:
            continue
        for other in (b, c):
            got, want = np.asarray(other[k]), np.asarray(a[k])
            assert got.dtype == want.dtype
            np.testing.assert_array_equal(got, want)
    assert s_p.n_device_dispatches == plan.schedule.n_device_dispatches
    assert s_u.n_device_dispatches == plan.schedule.n_unfused_dispatches
    assert s_c.n_device_dispatches == plan.schedule.n_coalesced_dispatches


# ----------------------------------------------- a genuine mid-graph barrier
def _barrier_spec():
    """A HOST Custom op that consumes a device op's output forces a host
    barrier in the middle of the device run: dispatches must become 2."""
    from repro.fe import Cross

    def boost(**kw):
        x = np.asarray(kw["x_ua"])
        return {"boost": (x % 97).astype(np.float32)}

    return FeatureSpec(
        name="barrier",
        base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=(
            Cross("x_ua", "user_id", "ad_id"),
            LogNorm("d_dwell", "dwell_time"),
            Custom("boost_op", boost, inputs=("x_ua",),
                   outputs=("boost",), device=Device.HOST),
        ),
        outputs=(SparseOutput(("x_ua",)),
                 DenseOutput(("d_dwell", "boost"))),
        label="label",
    )


def test_host_barrier_splits_the_device_run():
    plan = featureplan.compile(_barrier_spec())
    sched = plan.schedule
    assert sched.n_host_barriers == 1
    assert sched.n_coalesced_dispatches == 2 == sched.n_host_barriers + 1

    views = gen_views(32, seed=3)
    stats = ExecutionStats()
    a = run_layers(plan.layers, dict(views), stats=stats)
    assert stats.n_device_dispatches == 2
    b = run_layers(compile_layers(sched, coalesce=False), dict(views))
    for k in ("batch_dense", "batch_sparse", "batch_label"):
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    # the boost column really flowed through the barrier
    f_user = np.asarray(a["batch_sparse"])[:, 0]
    np.testing.assert_array_equal(np.asarray(a["batch_dense"])[:, 1],
                                  (f_user % 97).astype(np.float32))


def test_consecutive_host_only_layers_collapse_to_one_barrier():
    """Two chained HOST Customs after a device op are ONE barrier: their
    host-only super-layers force no extra dispatch, so dispatches stays
    barriers+1 — regression for barrier counting that tallied host *layers*
    instead of host interruptions."""
    from repro.fe import Cross

    def h1(**kw):
        return {"mid": np.asarray(kw["x_ua"]) % 31}

    def h2(**kw):
        return {"boost": (np.asarray(kw["mid"]) % 7).astype(np.float32)}

    spec = FeatureSpec(
        name="double_host",
        base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=(
            Cross("x_ua", "user_id", "ad_id"),
            LogNorm("d_dwell", "dwell_time"),
            Custom("h1", h1, inputs=("x_ua",), outputs=("mid",),
                   device=Device.HOST),
            Custom("h2", h2, inputs=("mid",), outputs=("boost",),
                   device=Device.HOST),
        ),
        # no SparseOutput: nothing shares h1's layer, so h1/h2 really are
        # consecutive host-ONLY layers between the cross and dense dispatches
        outputs=(DenseOutput(("d_dwell", "boost")),),
        label="label",
    )
    plan = featureplan.compile(spec)
    sched = plan.schedule
    host_only = [layer.index for layer in sched.layers
                 if layer.host_ops and not layer.device_ops]
    assert any(b == a + 1 for a, b in zip(host_only, host_only[1:]))
    # h1 and h2 are consecutive host-only layers: one interruption
    assert sched.n_host_barriers == 1
    assert sched.n_coalesced_dispatches == 2
    stats = ExecutionStats()
    run_layers(plan.layers, dict(gen_views(16, seed=4)), stats=stats)
    assert stats.n_device_dispatches == sched.n_host_barriers + 1 == 2


# ------------------------------------------------- unfused baseline hygiene
def test_run_unfused_uses_compile_time_jits():
    """Satellite: per-op jit wrappers are hoisted into compile so the
    unfused baseline pays dispatch overhead, not a retrace per batch."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    per_layer = compile_layers(plan.schedule, coalesce=False)
    for layer in per_layer:
        assert len(layer.op_jits) == len(layer.device_ops)
    before = [id(f) for layer in per_layer for f in layer.op_jits]
    run_unfused(per_layer, dict(gen_views(16, seed=1)))
    run_unfused(per_layer, dict(gen_views(16, seed=2)))
    after = [id(f) for layer in per_layer for f in layer.op_jits]
    assert before == after  # same wrappers across batches: no rebuild
