"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.fe.ops import cross_feature, fmix32_np, hash_combine_np
from repro.kernels.embedding_bag.ops import bag_lookup
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.feature_hash.ops import run_hash_layer, validate_program
from repro.kernels.feature_hash.ref import hash_layer_ref
from repro.kernels.interaction_dot.ops import pairwise_dots
from repro.kernels.interaction_dot.ref import dot_interaction_ref

RNG = np.random.default_rng(7)


# ------------------------------------------------------------ embedding_bag
@pytest.mark.parametrize("shape", [
    (4, 3, 10, 8), (300, 16, 700, 64), (256, 48, 512, 128),
    (33, 5, 1, 16), (1, 1, 2, 8), (1024, 4, 2000, 32),
])
@pytest.mark.parametrize("dtype", [np.float32])
def test_embedding_bag_sweep(shape, dtype):
    b, l, u, d = shape
    ids = RNG.integers(0, u, (b, l)).astype(np.int32)
    w = (RNG.random((b, l)) < 0.8).astype(dtype) * RNG.random((b, l)).astype(dtype)
    table = RNG.normal(size=(u, d)).astype(dtype)
    out = bag_lookup(jnp.asarray(ids), jnp.asarray(w), jnp.asarray(table))
    ref = embedding_bag_ref(jnp.asarray(ids), jnp.asarray(w), jnp.asarray(table))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_embedding_bag_zero_weights_ignore_ids():
    # padding ids with zero weight must not contribute even if id is garbage
    ids = jnp.asarray([[0, 5]], jnp.int32)
    w = jnp.asarray([[1.0, 0.0]])
    table = jnp.asarray(RNG.normal(size=(6, 4)).astype(np.float32))
    out = bag_lookup(ids, w, table)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(table[0]), rtol=1e-6)


def test_embedding_bag_bad_shapes():
    with pytest.raises(ValueError):
        bag_lookup(jnp.zeros((2,), jnp.int32), jnp.zeros((2,)), jnp.zeros((4, 4)))


# ------------------------------------------------------------- feature_hash
PROG = (("cross", 0, 1, 1 << 20), ("cross", 2, 3, 1 << 18),
        ("hash", 0, 0, 1 << 16), ("mod", 4, 0, 997))


@pytest.mark.parametrize("n", [1, 5, 1024, 3000, 10_000])
def test_feature_hash_sweep(n):
    cols = RNG.integers(0, 1 << 30, (5, n)).astype(np.int32)
    out = run_hash_layer(jnp.asarray(cols), PROG)
    ref = hash_layer_ref(jnp.asarray(cols), program=PROG)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_feature_hash_matches_fe_ops_and_numpy():
    cols = RNG.integers(0, 1 << 30, (2, 2048)).astype(np.int32)
    out = run_hash_layer(jnp.asarray(cols), (("cross", 0, 1, 1 << 20),))
    via_fe = cross_feature(jnp.asarray(cols[0]), jnp.asarray(cols[1]),
                           field_size=1 << 20)
    via_np = (hash_combine_np(cols[0], cols[1]) % np.uint32(1 << 20)).astype(np.int32)
    assert (np.asarray(out[0]) == np.asarray(via_fe)).all()
    assert (np.asarray(out[0]) == via_np).all()


def test_feature_hash_program_validation():
    with pytest.raises(ValueError):
        validate_program([("nope", 0, 0, 10)], 2)
    with pytest.raises(ValueError):
        validate_program([("cross", 0, 5, 10)], 2)
    with pytest.raises(ValueError):
        validate_program([("hash", 0, 0, 0)], 2)


def test_hash_avalanche():
    # adjacent ids must land far apart (hash quality, not just correctness)
    ids = np.arange(100_000, dtype=np.uint32)
    h = fmix32_np(ids) % np.uint32(1 << 20)
    _, counts = np.unique(h, return_counts=True)
    assert counts.max() <= 8  # near-uniform occupancy


# ---------------------------------------------------------- interaction_dot
@pytest.mark.parametrize("shape", [
    (4, 3, 8), (130, 27, 128), (64, 16, 32), (7, 2, 16), (128, 27, 16),
])
def test_interaction_dot_sweep(shape):
    x = RNG.normal(size=shape).astype(np.float32)
    out = pairwise_dots(jnp.asarray(x))
    ref = dot_interaction_ref(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    b, f, _ = shape
    assert out.shape == (b, f * (f - 1) // 2)


def test_interaction_dot_bad_inputs():
    with pytest.raises(ValueError):
        pairwise_dots(jnp.zeros((4, 8)))
    with pytest.raises(ValueError):
        pairwise_dots(jnp.zeros((4, 1, 8)))
