"""Hierarchical-PS streaming backend: psfeed protocol, checkpoint/dedup
seam fixes, and bitwise equivalence against the in-memory table path."""

import json
import os
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.core.pipeline import PipelinedRunner
from repro.embedding.dedup import MAX_ID, dedup_np
from repro.embedding.hierarchy import HierarchicalPS
from repro.embedding.psfeed import (
    WS_META,
    WS_SLOTS,
    HierarchyFeed,
    HierarchyFeedError,
    collect_gids_np,
)
from repro.fe.modelfeed import ModelFeed, ModelFeedError
from repro.models import recsys as R
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adamw

import dataclasses


# ------------------------------------------------------------------ helpers
def _feed(cfg) -> ModelFeed:
    """Direct ModelFeed over packed synthetic envs (no FE plan needed)."""
    return ModelFeed(
        config=cfg, slots=("batch_label", "batch_sparse"), split=False,
        n_spec_fields=cfg.n_sparse,
        field_sources=np.arange(cfg.n_sparse),
        vocab=np.asarray(cfg.vocab_sizes[:cfg.n_sparse], np.int32),
        dense_from="sparse" if cfg.n_dense else None,
        seq_from="sparse" if cfg.kind == "bst" else None,
        dedup_capacity=cfg.dedup_capacity)


def _ps_from_table(tmpdir, cfg, embed, accum, *, host_cache_rows=1 << 20):
    """PS file seeded with the in-memory table's rows + Adagrad column."""
    arr = np.concatenate([np.asarray(embed, np.float32),
                          np.asarray(accum, np.float32)[:, None]], axis=1)
    path = os.path.join(str(tmpdir), "ps.bin")
    arr.tofile(path)
    return HierarchicalPS(path, total_rows=arr.shape[0], dim=arr.shape[1],
                          host_cache_rows=host_cache_rows)


def _envs(cfg, n, batch=16, seed=0):
    rng = np.random.default_rng(seed)
    return [{"batch_sparse": rng.integers(0, 1 << 30, (batch, cfg.n_sparse)
                                          ).astype(np.int64),
             "batch_label": (rng.random(batch) < 0.25).astype(np.float32)}
            for _ in range(n)]


# ------------------------------------------------- checkpoint seam (satellites)
def test_checkpoint_keep_zero_rejected(tmp_path):
    with pytest.raises(ValueError, match="keep"):
        CheckpointManager(str(tmp_path), keep=0)


def test_checkpoint_manifest_crash_preserves_latest(tmp_path, monkeypatch):
    """A crash mid-manifest-write must leave the previous pointer intact."""
    from repro.train import checkpoint as C
    ckpt = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(4.0)}
    ckpt.save(0, tree)
    assert ckpt.latest_step() == 0

    real_dump = json.dump

    def crashing_dump(obj, f, *a, **k):
        if isinstance(obj, dict) and obj.get("latest_step") == 1:
            f.write('{"latest')  # partial bytes, then the "crash"
            raise OSError("disk died mid-manifest")
        return real_dump(obj, f, *a, **k)

    monkeypatch.setattr(C.json, "dump", crashing_dump)
    with pytest.raises(OSError):
        ckpt.save(1, tree)
    monkeypatch.undo()
    # The garbage went to the temp file; the committed manifest still reads.
    ckpt2 = CheckpointManager(str(tmp_path))
    assert ckpt2.latest_step() == 0
    step, restored = ckpt2.restore_latest({"w": np.zeros(4)})
    assert step == 0
    np.testing.assert_array_equal(restored["w"], tree["w"])
    # and the partial temp was swept on init
    assert not any(".tmp" in d for d in os.listdir(str(tmp_path)))


def test_checkpoint_stale_tmp_swept(tmp_path, monkeypatch):
    """Temp dirs of crashed saves are removed on init and on GC."""
    stale = tmp_path / ".tmp_step_0000000007_h0"
    stale.mkdir()
    (stale / "h0_leaf00000.npy").write_bytes(b"junk")
    (tmp_path / ".manifest.json.h0.tmp").write_text("{")
    other_host = tmp_path / ".tmp_step_0000000007_h1"
    other_host.mkdir()

    ckpt = CheckpointManager(str(tmp_path))
    assert not stale.exists()
    assert not (tmp_path / ".manifest.json.h0.tmp").exists()
    assert other_host.exists()  # another host's save is NOT ours to sweep
    assert ckpt.stats["stale_tmp_swept"] == 2

    # a save that crashes before its atomic rename leaks a temp dir ...
    monkeypatch.setattr(os, "rename",
                        lambda *a: (_ for _ in ()).throw(OSError("crash")))
    with pytest.raises(OSError):
        ckpt.save(1, {"w": np.zeros(2)})
    monkeypatch.undo()
    def h0_tmps():
        return [d for d in os.listdir(str(tmp_path))
                if d.startswith(".tmp_step_") and d.endswith("_h0")]

    assert h0_tmps()
    # ... which the next successful save's GC removes
    ckpt.save(2, {"w": np.zeros(2)})
    assert not h0_tmps()


# -------------------------------------------------------- memmap size check
def test_ps_memmap_size_mismatch_rejected(tmp_path):
    path = os.path.join(str(tmp_path), "t.bin")
    HierarchicalPS(path, total_rows=100, dim=8)
    # same file, different declared shape -> must refuse, with byte counts
    with pytest.raises(ValueError) as ei:
        HierarchicalPS(path, total_rows=200, dim=8)
    msg = str(ei.value)
    assert str(200 * 8 * 4) in msg and str(100 * 8 * 4) in msg
    # truncated file -> also refused
    with open(path, "r+b") as f:
        f.truncate(100)
    with pytest.raises(ValueError):
        HierarchicalPS(path, total_rows=100, dim=8)


# ------------------------------------------------------------ dedup id range
def test_dedup_np_rejects_out_of_range_ids():
    with pytest.raises(ValueError, match="wrap"):
        dedup_np(np.array([0, 2**31], np.int64))
    with pytest.raises(ValueError, match="FILL"):
        dedup_np(np.array([MAX_ID], np.int64))  # the sentinel itself
    with pytest.raises(ValueError):
        dedup_np(np.array([-1, 5], np.int64))
    # boundary ids are legal; bounds check can be bypassed explicitly
    u, inv = dedup_np(np.array([0, MAX_ID - 1, 0], np.int64))
    np.testing.assert_array_equal(u, [0, MAX_ID - 1])
    np.testing.assert_array_equal(u[inv], [0, MAX_ID - 1, 0])
    u2, _ = dedup_np(np.array([-5], np.int64), check_bounds=False)
    assert u2[0] == -5


@pytest.mark.parametrize("hi", [100, MAX_ID - 1])
def test_dedup_np_range_property(hi):
    rng = np.random.default_rng(0)
    ids = rng.integers(0, hi + 1, (50,), np.int64)
    u, inv = dedup_np(ids)
    np.testing.assert_array_equal(np.sort(np.unique(ids)), u)
    np.testing.assert_array_equal(u[inv], ids)


# -------------------------------------------------- pull/push vs host mirror
def test_ps_pull_push_matches_mirror_under_eviction(tmp_path):
    """Reads always reflect the latest pushed rows, however the tiny host
    cache thrashes (hits, misses, evictions, oversized working sets)."""
    rows, dim = 64, 5
    rng = np.random.default_rng(1)
    init = rng.normal(size=(rows, dim)).astype(np.float32)
    path = os.path.join(str(tmp_path), "t.bin")
    init.tofile(path)
    ps = HierarchicalPS(path, total_rows=rows, dim=dim, host_cache_rows=4)
    mirror = init.copy()
    for step in range(30):
        ids = rng.integers(0, rows, rng.integers(1, 12))
        got, unique, inverse = ps.pull(ids)
        np.testing.assert_array_equal(got, mirror[unique])
        np.testing.assert_array_equal(unique[inverse], ids)
        newrows = got + np.float32(step + 1)
        ps.push(unique, newrows)
        mirror[unique] = newrows
    assert ps.stats.evictions > 0
    assert ps.stats.host_hits > 0
    ps.flush()
    # SSD tier itself holds the mirror (write-through)
    np.testing.assert_array_equal(
        np.fromfile(path, np.float32).reshape(rows, dim), mirror)


# -------------------------------------------- host/device gid twin equality
@pytest.mark.parametrize("arch", ["dlrm-mlperf", "bst"])
def test_collect_gids_np_matches_device(arch):
    cfg = get_arch(arch).smoke()
    rng = np.random.default_rng(2)
    b = 8
    sparse = np.stack([rng.integers(0, v, b)
                       for v in cfg.vocab_sizes[:cfg.n_sparse]],
                      axis=1).astype(np.int32)
    batch = {"sparse": sparse}
    seq = None
    if cfg.kind == "bst":
        seq = rng.integers(0, cfg.vocab_sizes[0],
                           (b, cfg.seq_len)).astype(np.int32)
        batch["seq"] = seq
    dev = R.collect_gids(cfg, {k: np.asarray(v) for k, v in batch.items()})
    host = collect_gids_np(cfg, sparse, seq)
    assert sorted(dev) == sorted(host)
    shapes = R.gid_site_shapes(cfg, batch)
    for site in dev:
        np.testing.assert_array_equal(np.asarray(dev[site]), host[site])
        assert tuple(host[site].shape) == shapes[site]


# ------------------------------------------- bitwise equivalence (tentpole)
@pytest.mark.parametrize("arch", ["dlrm-mlperf", "bst"])
def test_hierarchy_step_bitwise_vs_in_memory(tmp_path, arch):
    """K steps through HierarchyFeed + make_hierarchy_train_step produce
    the SAME losses, dense params, and final table rows as the in-memory
    make_sparse_train_step — bit for bit."""
    cfg = get_arch(arch).smoke()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    params_full = R.init_params(cfg, key)
    params_dense = R.init_params(cfg, key, include_embed=False)
    for k in params_dense:  # dense init must not shift without "embed"
        np.testing.assert_array_equal(np.asarray(params_full[k]),
                                      np.asarray(params_dense[k]))

    raw_s, init_s, _ = R.make_sparse_train_step(cfg, opt)
    raw_h, init_h, _ = R.make_hierarchy_train_step(cfg, opt)
    st_s = init_s(params_full)
    st_h = init_h(params_dense)
    ps = _ps_from_table(tmp_path, cfg, params_full["embed"],
                        np.asarray(st_s["embed_accum"]),
                        host_cache_rows=8)  # tiny: force SSD traffic
    mf_s, mf_h = _feed(cfg), _feed(cfg)
    hier = HierarchyFeed(ps, mf_h)
    fused_s = mf_s.make_step(raw_s, donate=False)
    fused_h = mf_h.make_step(raw_h, donate=False, extra_slots=WS_SLOTS)

    for env in _envs(cfg, 5):
        ps_env = hier.prepare(env)
        params_dense, st_h, m_h = fused_h(params_dense, st_h, ps_env)
        hier.complete(ps_env[WS_META], m_h["ws_rows"], m_h["ws_accum"])
        params_full, st_s, m_s = fused_s(params_full, st_s, env)
        assert float(m_h["loss"]) == float(m_s["loss"])
        assert int(m_h["unique"]) == int(m_s["unique"])
    hier.drain()

    for k in params_dense:
        np.testing.assert_array_equal(np.asarray(params_full[k]),
                                      np.asarray(params_dense[k]))
    table = np.asarray(ps._ssd)
    np.testing.assert_array_equal(table[:, :-1],
                                  np.asarray(params_full["embed"]))
    np.testing.assert_array_equal(table[:, -1],
                                  np.asarray(st_s["embed_accum"]))
    assert hier.stats.completed == 5 and ps.stats.pushes == 5


# -------------------------------------------- threaded runner == serial run
def test_threaded_runner_bitwise_vs_serial(tmp_path):
    """The pipelined (prefetch + async write-back) execution is bitwise
    identical to serial pull-train-push: the fixup protocol hides latency,
    not determinism."""
    cfg = get_arch("dlrm-mlperf").smoke()
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    envs = _envs(cfg, 6, seed=7)

    def run(threaded: bool):
        params = R.init_params(cfg, key, include_embed=False)
        raw_h, init_h, _ = R.make_hierarchy_train_step(cfg, opt)
        st = init_h(params)
        embed = np.asarray(
            R.init_params(cfg, jax.random.PRNGKey(0))["embed"])
        d = tmp_path / ("t" if threaded else "s")
        d.mkdir(exist_ok=True)
        ps = _ps_from_table(d, cfg, embed,
                            np.full((embed.shape[0],), 0.1, np.float32),
                            host_cache_rows=16)
        mf = _feed(cfg)
        hier = HierarchyFeed(ps, mf)
        fused = mf.make_step(raw_h, donate=False, extra_slots=WS_SLOTS)
        losses = []

        def step_fn(state, e):
            p, o, m = fused(state["params"], state["opt"], e)
            hier.complete(e[WS_META], m["ws_rows"], m["ws_accum"])
            losses.append(float(m["loss"]))
            return {"params": p, "opt": o}

        state = {"params": params, "opt": st}
        if threaded:
            runner = PipelinedRunner([], step_fn, ps_feed=hier)
            runner.run(state, [dict(e) for e in envs])
            assert runner.stats.ps is hier
            assert runner.stats.batches == len(envs)
        else:
            for e in envs:
                state = step_fn(state, hier.prepare(dict(e)))
        hier.drain()
        ps.flush()
        return losses, np.asarray(ps._ssd).copy()

    losses_t, table_t = run(threaded=True)
    losses_s, table_s = run(threaded=False)
    assert losses_t == losses_s
    np.testing.assert_array_equal(table_t, table_s)


def test_prefetch_fixup_sees_concurrent_push(tmp_path):
    """A pull issued before the previous step's write-back must be fixed
    up to the post-push rows before release."""
    cfg = get_arch("dlrm-mlperf").smoke()
    embed = np.zeros((int(cfg.multi_table().total_rows), cfg.embed_dim),
                     np.float32)
    ps = _ps_from_table(tmp_path, cfg, embed,
                        np.full((embed.shape[0],), 0.1, np.float32))
    mf = _feed(cfg)
    hier = HierarchyFeed(ps, mf)
    env = _envs(cfg, 1, seed=3)[0]

    out0 = hier.prepare(dict(env))
    seq0, unique0 = out0[WS_META]
    n0 = len(unique0)

    box = {}

    def prefetch():
        box["out"] = hier.prepare(dict(env))  # same ids: all become stale

    t = threading.Thread(target=prefetch)
    t.start()
    # wait until the prefetch thread's PULL happened (it then blocks on
    # the write-back of step 0)
    deadline = time.time() + 10
    while hier.stats.batches < 2:
        assert time.time() < deadline, "prefetch never pulled"
        time.sleep(0.005)
    assert t.is_alive()  # blocked on the consistency wait, not done

    pushed_rows = np.full((n0, cfg.embed_dim), 7.5, np.float32)
    pushed_accum = np.full((n0,), 2.25, np.float32)
    hier.complete((seq0, unique0), pushed_rows, pushed_accum)
    t.join(timeout=10)
    assert not t.is_alive()
    assert hier.stats.fixups == 1 and hier.stats.fixup_rows == n0

    out1 = box["out"]
    n1 = len(out1[WS_META][1])
    np.testing.assert_array_equal(np.asarray(out1["_ws_rows"])[:n1],
                                  pushed_rows)
    np.testing.assert_array_equal(np.asarray(out1["_ws_accum"])[:n1],
                                  pushed_accum)
    hier.complete(out1[WS_META], out1["_ws_rows"], out1["_ws_accum"])
    hier.drain()


# ------------------------------------------------------------- guard rails
def test_make_step_missing_extra_slot_errors():
    cfg = get_arch("dlrm-mlperf").smoke()
    raw_h, _, _ = R.make_hierarchy_train_step(cfg, adamw(1e-3))
    mf = _feed(cfg)
    step = mf.make_step(raw_h, donate=False, extra_slots=WS_SLOTS)
    with pytest.raises(ModelFeedError, match="extra slot"):
        step({}, {}, _envs(cfg, 1)[0])  # no _ws_* slots: prefetch not wired


def test_working_set_overflow_errors(tmp_path):
    cfg = dataclasses.replace(get_arch("dlrm-mlperf").smoke(),
                              dedup_capacity=4)
    embed = np.zeros((int(cfg.multi_table().total_rows), cfg.embed_dim),
                     np.float32)
    ps = _ps_from_table(tmp_path, cfg, embed,
                        np.zeros((embed.shape[0],), np.float32))
    hier = HierarchyFeed(ps, _feed(cfg))
    with pytest.raises(HierarchyFeedError, match="overflow"):
        hier.prepare(_envs(cfg, 1)[0])
    hier.drain()


def test_ps_metrics_tier_registered(tmp_path):
    """runner.stats.ps feeds the 'ps' tier + rollup keys of the registry."""
    from repro.obs.metrics import MetricsRegistry
    cfg = get_arch("dlrm-mlperf").smoke()
    embed = np.asarray(R.init_params(cfg, jax.random.PRNGKey(0))["embed"])
    ps = _ps_from_table(tmp_path, cfg, embed,
                        np.full((embed.shape[0],), 0.1, np.float32))
    mf = _feed(cfg)
    hier = HierarchyFeed(ps, mf)
    raw_h, init_h, _ = R.make_hierarchy_train_step(cfg, adamw(1e-3))
    params = R.init_params(cfg, jax.random.PRNGKey(0), include_embed=False)
    fused = mf.make_step(raw_h, donate=False, extra_slots=WS_SLOTS)

    def step_fn(state, e):
        p, o, m = fused(state["params"], state["opt"], e)
        hier.complete(e[WS_META], m["ws_rows"], m["ws_accum"])
        return {"params": p, "opt": o}

    runner = PipelinedRunner([], step_fn, ps_feed=hier)
    runner.run({"params": params, "opt": init_h(params)}, _envs(cfg, 3))
    hier.drain()
    snap = MetricsRegistry.from_pipeline(runner.stats).snapshot()
    assert snap["ps.pulls"] == 3 and snap["ps.pushes"] == 3
    assert snap["ps.batches"] == 3 and snap["ps.completed"] == 3
    assert snap["rollup.ps_pull_seconds"] >= 0
    assert 0 <= snap["rollup.ps_host_hit_rate"] <= 1
    assert snap["pipeline.ps_seconds"] > 0
