"""Effects scan (EF3xx): effectful/non-donating steps each hit their rule."""

import dataclasses

import jax
import numpy as np

from repro.check import effects, planverify
from repro.configs import get_arch
from repro.fe import featureplan, get_spec


def _rules(findings):
    return sorted({f.rule for f in findings})


@dataclasses.dataclass
class _FakeEx:
    index: int
    layer_indices: tuple
    fused_fn: object
    device_input_slots: tuple
    host_ops: tuple = ()


_ENV = {"a": jax.ShapeDtypeStruct((4,), np.float32)}

_STEP_ARGS = ({"w": jax.ShapeDtypeStruct((2, 2), np.float32)},
              {"m": jax.ShapeDtypeStruct((2, 2), np.float32)},
              {"x": jax.ShapeDtypeStruct((4,), np.float32)})


# ------------------------------------------------------------------- EF301
def test_ef301_debug_print_in_fused_dispatch():
    def noisy(env):
        jax.debug.print("x={x}", x=env["a"])
        return {"b": env["a"] + 1}

    layers = [_FakeEx(0, (0, 1), noisy, ("a",))]
    assert _rules(effects.scan_executables(layers, _ENV)) == ["EF301"]


def test_ef301_io_callback_in_fused_dispatch():
    def leaky(env):
        jax.experimental.io_callback(lambda v: None, None, env["a"])
        return {"b": env["a"] * 2}

    import jax.experimental  # noqa: F401 - io_callback lives here
    layers = [_FakeEx(0, (0, 1, 2), leaky, ("a",))]
    assert _rules(effects.scan_executables(layers, _ENV)) == ["EF301"]


def test_ef301_missing_abstract_input_reported_not_raised():
    layers = [_FakeEx(0, (0, 1), lambda env: env, ("a", "ghost"))]
    findings = effects.scan_executables(layers, _ENV)
    assert _rules(findings) == ["EF301"]
    assert "ghost" in findings[0].message


def test_pure_fused_dispatch_is_clean():
    layers = [_FakeEx(0, (0, 1), lambda env: {"b": env["a"] + 1}, ("a",)),
              _FakeEx(1, (2,), None, ())]  # host-only layer: skipped
    assert effects.scan_executables(layers, _ENV) == []


# ------------------------------------------------------------------- EF302
def test_ef302_donation_requested_but_nothing_donated():
    def step(params, opt, feed):
        return params, opt, {}

    jitted = jax.jit(step)  # no donate_argnums: no aliasing markers
    findings = effects.check_step(jitted, _STEP_ARGS, expect_donation=True)
    assert _rules(findings) == ["EF302"]


def test_ef302_not_raised_when_donation_not_expected():
    jitted = jax.jit(lambda p, o, f: (p, o, {}))
    assert effects.check_step(jitted, _STEP_ARGS,
                              expect_donation=False) == []


def test_ef302_clean_when_params_actually_donated():
    def step(params, opt, feed):
        new = jax.tree_util.tree_map(lambda a: a + 1.0, params)
        return new, opt, {}

    jitted = jax.jit(step, donate_argnums=(0,))
    assert effects.check_step(jitted, _STEP_ARGS,
                              expect_donation=True) == []


# ------------------------------------------------------------------- EF303
def test_ef303_effectful_train_step():
    def step(params, opt, feed):
        jax.debug.print("loss tick")
        new = jax.tree_util.tree_map(lambda a: a + 1.0, params)
        return new, opt, {}

    jitted = jax.jit(step, donate_argnums=(0,))
    findings = effects.check_step(jitted, _STEP_ARGS, expect_donation=True)
    assert _rules(findings) == ["EF303"]


def test_ef303_tracing_failure_reported_not_raised():
    def step(params, opt, feed):
        return params["no_such_key"], opt, {}

    jitted = jax.jit(step)
    findings = effects.check_step(jitted, _STEP_ARGS, expect_donation=True)
    assert _rules(findings) == ["EF303"]


# ----------------------------------------------------------- preset e2e
def test_ads_ctr_preset_scan_is_clean():
    plan = featureplan.compile(get_spec("ads_ctr"))
    cfg = get_arch("dlrm-mlperf").smoke()
    mf = plan.model_feed(cfg, split_sparse_fields=True)
    findings = effects.scan_preset(plan, mf, rows=8)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_abstract_step_args_match_real_step_signature():
    plan = featureplan.compile(get_spec("ads_ctr"))
    cfg = get_arch("dlrm-mlperf").smoke()
    mf = plan.model_feed(cfg, split_sparse_fields=True)
    params, opt, feed = effects.abstract_step_args(plan, mf)
    # Every feed slot the model consumes is present and batch-shaped.
    assert set(feed) == set(mf.slots)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in feed.values())
    # The flow's abstract env agrees with the staged feed's dtypes.
    env, flow_findings = planverify.abstract_flow(plan, 8)
    assert flow_findings == []


def test_effectful_fused_layer_caught_on_real_plan():
    plan = featureplan.compile(get_spec("ads_ctr"))
    target = next(ex for ex in plan.layers if ex.fused_fn is not None)
    inner = target.fused_fn

    def noisy(env):
        jax.debug.print("smuggled")
        return inner(env)

    bad_ex = dataclasses.replace(target, fused_fn=noisy)
    layers = [bad_ex if e is target else e for e in plan.layers]
    env, _ = planverify.abstract_flow(plan, 8)
    findings = effects.scan_executables(layers, env)
    assert _rules(findings) == ["EF301"]
