"""Feature-extraction substrate: column store, cleaning, joins, FE graph."""

import json
import tempfile

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import numpy as np

from repro.core import build_schedule, compile_layers, run_layers, validate_schedule
from repro.fe.colstore import ColumnStore, RaggedColumn
from repro.fe.datagen import IMPRESSIONS, gen_views
from repro.fe.join import hash_join, merge_on_instance
from repro.fe.ops import ragged_to_bag, ragged_to_padded, tokenize_hash
from repro.fe.pipeline_graph import build_fe_graph
from repro.fe.schema import ColType
from repro.fe.views import extract_json_fields, fill_nulls, filter_rows, n_rows


def test_colstore_roundtrip_all_kinds():
    store = ColumnStore(tempfile.mkdtemp())
    rag = RaggedColumn(values=np.arange(10, dtype=np.int64),
                       lengths=np.asarray([3, 0, 2, 5], np.int32))
    cols = {
        "i": np.asarray([1, 2, 3, 4], np.int64),
        "f": np.asarray([0.5, 1.5, -2.0, np.nan], np.float32),
        "s": np.asarray(["a b", "", "json{", "x"], object),
        "r": rag,
    }
    store.write_chunk("v", 0, cols)
    out = store.read_columns("v", 0, ["i", "f", "s", "r"])
    np.testing.assert_array_equal(out["i"], cols["i"])
    np.testing.assert_array_equal(out["f"][:3], cols["f"][:3])
    assert list(out["s"]) == list(cols["s"])
    np.testing.assert_array_equal(out["r"].values, rag.values)
    np.testing.assert_array_equal(out["r"].lengths, rag.lengths)
    # column store reads ONLY requested columns' bytes
    one = store.column_bytes("v", 0, ["i"])
    all_ = store.column_bytes("v", 0, ["i", "f", "s", "r"])
    assert 0 < one < all_


def test_row_count_mismatch_rejected():
    store = ColumnStore(tempfile.mkdtemp())
    with pytest.raises(ValueError):
        store.write_chunk("v", 0, {"a": np.zeros(3), "b": np.zeros(4)})


def test_fill_nulls_and_json():
    null_i = np.iinfo(np.int64).min
    cols = {
        "instance_id": np.asarray([0, 1], np.int64),
        "user_id": np.asarray([0, 1], np.int64),
        "ad_id": np.asarray([0, 1], np.int64),
        "label": np.asarray([0, 1], np.int64),
        "hour": np.asarray([5, null_i], np.int64),
        "dwell_time": np.asarray([1.0, np.nan], np.float32),
        "context_json": np.asarray(['{"slot": 3}', "not json"], object),
    }
    cols = extract_json_fields(cols, "context_json", {"slot": ColType.INT})
    plain = fill_nulls(cols, IMPRESSIONS)
    assert plain["hour"][1] == 0
    assert plain["dwell_time"][1] == 0.0
    # without `extracted`, non-schema columns keep their sentinel
    assert plain["slot"][0] == 3 and plain["slot"][1] == null_i
    # with `extracted`, JSON-derived columns are filled in the same pass —
    # no caller needs a hand-rolled second sentinel sweep
    filled = fill_nulls(cols, IMPRESSIONS, extracted={"slot": ColType.INT})
    assert filled["slot"][0] == 3 and filled["slot"][1] == 0


def test_fill_nulls_extracted_shadow_rejected():
    cols = {"hour": np.asarray([1, 2], np.int64)}
    with pytest.raises(ValueError, match="shadows"):
        fill_nulls(cols, IMPRESSIONS, extracted={"hour": ColType.INT})


def test_filter_rows_ragged():
    rag = RaggedColumn(values=np.arange(6, dtype=np.int64),
                       lengths=np.asarray([2, 1, 3], np.int32))
    cols = {"k": np.asarray([10, 20, 30]), "r": rag}
    out = filter_rows(cols, np.asarray([True, False, True]))
    assert n_rows(out) == 2
    np.testing.assert_array_equal(out["r"].lengths, [2, 3])
    np.testing.assert_array_equal(out["r"].values, [0, 1, 3, 4, 5])


def _dict_join_oracle(left, right, key):
    """Brute-force last-writer-wins left join for comparison."""
    index = {int(k): i for i, k in enumerate(right[key])}
    rows = [index.get(int(k), -1) for k in left[key]]
    return rows


@hypothesis.given(
    st.lists(st.integers(0, 20), min_size=1, max_size=50),
    st.lists(st.integers(0, 20), min_size=1, max_size=30),
)
@hypothesis.settings(deadline=None, max_examples=40)
def test_hash_join_matches_oracle(lkeys, rkeys):
    left = {"k": np.asarray(lkeys, np.int64),
            "lv": np.arange(len(lkeys), dtype=np.int64)}
    right = {"k": np.asarray(rkeys, np.int64),
             "rv": np.arange(len(rkeys), dtype=np.float32) + 100}
    out = hash_join(left, right, key="k", right_prefix="r_")
    rows = _dict_join_oracle(left, right, "k")
    for i, r in enumerate(rows):
        if r < 0:
            assert out["r_rv"][i] == 0.0
        else:
            assert out["r_rv"][i] == right["rv"][r]
    # left row order preserved
    np.testing.assert_array_equal(out["lv"], left["lv"])


def test_merge_on_instance():
    extracted = {"instance_id": np.asarray([2, 0, 1], np.int64)}
    basic = {"instance_id": np.asarray([0, 1, 2], np.int64),
             "ctr": np.asarray([0.1, 0.2, 0.3], np.float32)}
    out = merge_on_instance(extracted, basic)
    np.testing.assert_allclose(out["basic_ctr"], [0.3, 0.1, 0.2])


def test_tokenize_hash_ragged_and_padded():
    strings = np.asarray(["a b c", "", "a a"], object)
    col = tokenize_hash(strings, field_size=1000, ngrams=2)
    assert col.n_rows == 3
    assert col.lengths[0] == 3 + 2   # 3 unigrams + 2 bigrams
    assert col.lengths[1] == 0
    # identical tokens hash identically
    row2 = col.row(2)
    assert row2[0] == row2[1]
    ids, mask = ragged_to_padded(col, max_len=4)
    assert ids.shape == (3, 4) and mask.sum() == min(5, 4) + 0 + 3
    flat, segs = ragged_to_bag(col)
    assert flat.shape[0] == int(col.lengths.sum())
    np.testing.assert_array_equal(np.bincount(segs, minlength=3), col.lengths)


def test_full_fe_graph_end_to_end():
    views = gen_views(256, seed=3)
    g = build_fe_graph()
    sched = build_schedule(g)
    validate_schedule(g, sched)
    layers = compile_layers(sched)
    env = run_layers(layers, dict(views))
    b = 256
    assert env["batch_dense"].shape == (b, 9)
    assert env["batch_sparse"].shape == (b, 8)
    assert env["batch_label"].shape == (b,)
    dense = np.asarray(env["batch_dense"])
    assert np.isfinite(dense).all()
    sparse = np.asarray(env["batch_sparse"])
    assert (sparse >= 0).all() and (sparse < 8 * (1 << 20)).all()
    # field id spaces are disjoint
    for f in range(8):
        col = sparse[:, f]
        assert (col // (1 << 20) == f).all()


def test_fe_graph_deterministic():
    views = gen_views(64, seed=5)
    layers = compile_layers(build_schedule(build_fe_graph()))
    a = run_layers(layers, dict(views))
    b = run_layers(layers, dict(views))
    np.testing.assert_array_equal(np.asarray(a["batch_sparse"]),
                                  np.asarray(b["batch_sparse"]))
