"""Hypothesis property: ``ModelFeed.apply`` == the legacy eager adapter
``fe_env_to_model_batch_ref`` **bitwise**, on random output layouts x arch
configs, in both the packed and per-field (split) staged forms."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.fe import modelfeed  # noqa: E402
from repro.fe.compiler import OutputLayout  # noqa: E402
from repro.fe.modelfeed import fe_env_to_model_batch_ref  # noqa: E402
from repro.models.recsys import RecsysConfig  # noqa: E402
from test_modelfeed import _assert_batches_equal, _split_env  # noqa: E402


@st.composite
def _layouts(draw):
    return OutputLayout(
        n_sparse_fields=draw(st.integers(1, 6)),
        n_dense_feats=draw(st.integers(0, 5)),
        seq_len=draw(st.sampled_from([0, 4, 10])),
        field_size=draw(st.sampled_from([8, 64, 1024])),
    )


@st.composite
def _arch_cfgs(draw):
    kind = draw(st.sampled_from(["dlrm", "dcnv2", "autoint", "bst"]))
    n_sparse = draw(st.integers(1, 7))
    vocab = tuple(draw(st.lists(st.integers(2, 60), min_size=n_sparse,
                                max_size=n_sparse)))
    return RecsysConfig(
        name="prop", kind=kind, n_sparse=n_sparse, vocab_sizes=vocab,
        n_dense=(draw(st.integers(1, 4)) if kind != "bst"
                 else draw(st.integers(0, 2))),
        embed_dim=4,
        seq_len=(draw(st.integers(1, 9)) if kind == "bst" else 0),
    )


def _env_for(layout: OutputLayout, rows: int, seed: int):
    rng = np.random.default_rng(seed)
    env = {
        "batch_label": (rng.random(rows) < 0.3).astype(np.float32),
        "batch_sparse": rng.integers(
            0, layout.sparse_id_space,
            (rows, layout.n_sparse_fields)).astype(np.int32),
    }
    if layout.n_dense_feats:
        env["batch_dense"] = rng.exponential(
            1.0, (rows, layout.n_dense_feats)).astype(np.float32)
    if layout.seq_len:
        env["batch_seq_ids"] = rng.integers(
            0, layout.field_size, (rows, layout.seq_len)).astype(np.int32)
        env["batch_seq_mask"] = np.ones((rows, layout.seq_len), np.float32)
    return env


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(layout=_layouts(), cfg=_arch_cfgs(),
                  rows=st.integers(1, 48), seed=st.integers(0, 2**16))
def test_apply_matches_ref_on_random_layouts_and_archs(layout, cfg, rows,
                                                       seed):
    env = _env_for(layout, rows, seed)
    ref = fe_env_to_model_batch_ref(env, cfg)

    mf = modelfeed.compile(layout, cfg)
    _assert_batches_equal(ref, mf.apply(mf.select(env)), "packed ")

    mfs = modelfeed.compile(layout, cfg, split_sparse_fields=True)
    _assert_batches_equal(ref, mfs.apply(mfs.select(_split_env(env))),
                          "split ")
