"""Distributed correctness on a small host-device mesh (subprocess).

jax locks the device count at first init, so multi-device tests run in
subprocesses with XLA_FLAGS set before import. Checks:
  * shard_map MoE == single-device MoE numerics;
  * distributed PNA (edge-partitioned shard_map) == local PNA;
  * dlrm sparse train step under pjit == single-device, same loss;
  * dry-run cell builders lower on a small mesh.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_moe_shard_map_matches_local():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models.moe import MoEConfig, moe_ffn, moe_params_shape

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
c = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1, capacity_factor=8.0)
d, t = 32, 64
kg = jax.random.PRNGKey(0)
p = {k: jax.random.normal(jax.random.fold_in(kg, i), s) * 0.1
     for i, (k, s) in enumerate(moe_params_shape(d, c).items())}
x = jax.random.normal(jax.random.PRNGKey(1), (t, d)) * 0.5

local, _ = moe_ffn(p, x, c)

with mesh:
    f = jax.jit(lambda p, x: moe_ffn(p, x, c, mesh=mesh, dp_axes=("data",))[0])
    dist = f(p, x)
np.testing.assert_allclose(np.asarray(local), np.asarray(dist), rtol=3e-4, atol=3e-5)
print("MOE DIST OK")
""")


def test_distributed_pna_matches_local():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import gnn as G

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
c = G.PNAConfig(name="t", n_layers=2, d_in=8, d_hidden=16, n_classes=3)
params = G.init_params(c, jax.random.PRNGKey(0))
g = G.random_graph(64, 256, 8, 3, seed=0)  # 64 nodes: 8 per shard

# local forward
batch = {k: jnp.asarray(v) for k, v in g.items()}
local = G.forward(params, c, batch)

# distributed: partition edges by dst range, pad, shard_map
src_p, dst_p, per = G.partition_edges(g["src"].astype(np.int64),
                                      g["dst"].astype(np.int64), 64, 8)
batch_d = {"features": jnp.asarray(g["features"]),
           "src": jnp.asarray(src_p.astype(np.int32)),
           "dst": jnp.asarray(dst_p.astype(np.int32))}
with mesh:
    f = jax.jit(lambda p, b: G.forward_sharded(p, c, b, mesh=mesh,
                                               node_axes=("data", "model")))
    dist = f(params, batch_d)
np.testing.assert_allclose(np.asarray(local), np.asarray(dist), rtol=2e-4, atol=2e-4)
print("PNA DIST OK")
""")


def test_dlrm_sparse_train_pjit_matches_single():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.models import recsys as R
from repro.train.optimizer import adamw

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
cfg = R.RecsysConfig(name="t", kind="dlrm", n_dense=13, n_sparse=6, embed_dim=16,
                     vocab_sizes=(64, 32, 128, 16, 8, 40),
                     bot_mlp=(32, 16), top_mlp=(64, 32, 1),
                     dedup_capacity=512, row_align=8)
params = R.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw(1e-2)
step, init_st, _ = R.make_sparse_train_step(cfg, opt)
st = init_st(params)
rng = np.random.default_rng(0)
B = 64
batch = {"dense": jnp.asarray(rng.exponential(1, (B, 13)).astype(np.float32)),
         "sparse": jnp.asarray(np.stack([rng.integers(0, v, B) for v in cfg.vocab_sizes], 1).astype(np.int32)),
         "label": jnp.asarray((rng.random(B) < 0.3).astype(np.float32))}

p1, s1, m1 = jax.jit(step)(params, st, batch)           # single-logical-device

pspecs = R.param_specs(cfg)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
with mesh:
    p2, s2, m2 = jax.jit(step, in_shardings=(psh, None, None))(params, st, batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
np.testing.assert_allclose(np.asarray(p1["embed"]), np.asarray(p2["embed"]), rtol=2e-4, atol=1e-6)
print("DLRM PJIT OK")
""")


@pytest.mark.parametrize("arch,shape", [
    ("dlrm-mlperf", "serve_p99"),
    ("bst", "serve_p99"),
    ("pna", "molecule"),
])
def test_dryrun_cells_lower_on_small_mesh(arch, shape):
    """The production cell builders also lower on an 8-device (2x4) mesh
    scaled via monkeypatched mesh (structure check, cheap)."""
    run_sub(f"""
import jax
import repro.launch.mesh as M
def small(multi_pod=False):
    return jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,)*2)
M.make_production_mesh = small
import repro.launch.dryrun as D
D.make_production_mesh = small
rec = D.run_cell("{arch}", "{shape}", verbose=False)
assert rec["status"] == "ok", rec
print("CELL OK", rec["arch"], rec["shape"])
""")


def test_hierarchical_dedup_matches_flat():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.embedding.dedup import dedup, dedup_hierarchical, FILL

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.default_rng(0)
ids = jnp.asarray(rng.integers(0, 200, (64 * 8,)).astype(np.int32))
u1, i1, c1 = dedup(ids, capacity=512)
with mesh:
    f = jax.jit(lambda ids: dedup_hierarchical(
        ids, capacity=512, mesh=mesh, axes=("data", "model"), local_capacity=128))
    u2, i2, c2 = f(ids)
assert int(c1) == int(c2)
# same unique set, and reconstruction holds for both
a1 = np.asarray(u1); a2 = np.asarray(u2)
np.testing.assert_array_equal(np.sort(a1[a1 != 2**31-1]), np.sort(a2[a2 != 2**31-1]))
np.testing.assert_array_equal(np.asarray(u2)[np.asarray(i2)], np.asarray(ids))
print("HIERDEDUP OK")
""")
