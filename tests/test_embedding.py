"""Embedding substrate: dedup, working-set lookups, sparse updates, PS tiers."""

import os
import tempfile

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np

from repro.embedding import (
    HierarchicalPS,
    MultiTable,
    TableSpec,
    bag_lookup_padded,
    bag_lookup_segment,
    dedup,
    dedup_np,
    init_sparse_adagrad,
    sparse_grad_update,
)
from repro.embedding.table import lookup, lookup_dedup

RNG = np.random.default_rng(3)


@hypothesis.given(st.lists(st.integers(0, 99), min_size=1, max_size=200),
                  st.integers(200, 300))
@hypothesis.settings(deadline=None, max_examples=40)
def test_dedup_roundtrip(ids, capacity):
    arr = jnp.asarray(np.asarray(ids, np.int32))
    unique, inverse, count = dedup(arr, capacity=capacity)
    assert int(count) == len(set(ids))
    # reconstruction: unique[inverse] == ids
    np.testing.assert_array_equal(np.asarray(unique)[np.asarray(inverse)], ids)


def test_dedup_matches_np():
    ids = RNG.integers(0, 50, (16, 4)).astype(np.int32)
    u_np, inv_np = dedup_np(ids)
    u_j, inv_j, cnt = dedup(jnp.asarray(ids), capacity=256)
    uj = np.asarray(u_j)
    assert (uj[: int(cnt)] == u_np).all()
    np.testing.assert_array_equal(np.asarray(u_j)[np.asarray(inv_j)], ids)


def test_lookup_dedup_equals_lookup():
    params = jnp.asarray(RNG.normal(size=(100, 8)).astype(np.float32))
    ids = jnp.asarray(RNG.integers(0, 100, (32, 5)).astype(np.int32))
    np.testing.assert_allclose(
        np.asarray(lookup(params, ids)),
        np.asarray(lookup_dedup(params, ids, capacity=200)), rtol=1e-6)


def test_bag_lookup_variants_agree():
    params = jnp.asarray(RNG.normal(size=(50, 4)).astype(np.float32))
    ids = RNG.integers(0, 50, (8, 3)).astype(np.int32)
    mask = np.ones((8, 3), np.float32)
    padded = bag_lookup_padded(params, jnp.asarray(ids), jnp.asarray(mask))
    flat = ids.reshape(-1)
    segs = np.repeat(np.arange(8, dtype=np.int32), 3)
    seg = bag_lookup_segment(params, jnp.asarray(flat), jnp.asarray(segs), 8)
    np.testing.assert_allclose(np.asarray(padded), np.asarray(seg), rtol=1e-6)


def test_sparse_grad_update_touches_only_unique_rows():
    mt = MultiTable.build([TableSpec("a", 60, 8), TableSpec("b", 40, 8)])
    params = mt.init(jax.random.PRNGKey(0))
    st_ = init_sparse_adagrad(mt.total_rows)
    ids = jnp.asarray([3, 3, 7, 99], jnp.int32)
    grads = jnp.asarray(RNG.normal(size=(4, 8)).astype(np.float32))
    p2, st2 = sparse_grad_update(params, st_, ids, grads, capacity=8)
    changed = np.where(np.abs(np.asarray(p2 - params)).sum(1) > 0)[0]
    assert set(changed.tolist()) <= {3, 7, 99}
    acc_changed = np.where(np.asarray(st2.accum) != np.asarray(st_.accum))[0]
    assert set(acc_changed.tolist()) <= {3, 7, 99}


def test_multitable_offsets_and_ids():
    mt = MultiTable.build([TableSpec("a", 100, 8), TableSpec("b", 20, 8),
                           TableSpec("c", 5, 8)])
    assert mt.total_rows == 125
    np.testing.assert_array_equal(mt.offsets, [0, 100, 120])
    gids = mt.global_ids(jnp.asarray([[99, 19, 4], [0, 0, 0]]))
    np.testing.assert_array_equal(np.asarray(gids), [[99, 119, 124], [0, 100, 120]])
    with pytest.raises(ValueError):
        MultiTable.build([TableSpec("a", 10, 8), TableSpec("b", 10, 16)])


# ----------------------------------------------- preset working-set paths
PRESETS = ("ads_ctr", "dlrm", "bst")


def _preset_batch_ids(preset, rows=32):
    """(tuned cfg, per-field local ids) a preset's compiled plan feeds the
    embedding layer: FE outputs adapted through the compiled train-feed
    boundary (repro.fe.modelfeed), exactly as the streaming driver wires."""
    from repro.configs import get_arch
    from repro.fe import featureplan, get_spec
    from repro.fe.datagen import gen_views

    plan = featureplan.compile(get_spec(preset))
    import dataclasses
    cfg = dataclasses.replace(get_arch("dlrm-mlperf").smoke(),
                              dedup_capacity=0)
    mf = plan.model_feed(cfg, rows_hint=rows)
    env = plan.run(gen_views(rows, seed=11))
    return mf.config, mf.apply(mf.select(env))["sparse"]


@pytest.mark.parametrize("preset", PRESETS)
def test_lookup_dedup_bitwise_equals_lookup_on_presets(preset):
    cfg, ids = _preset_batch_ids(preset)
    mt = cfg.multi_table()
    params = mt.init(jax.random.PRNGKey(1))
    plain = lookup(params, mt.global_ids(ids))
    dedup_rows = mt.lookup_dedup(params, ids, capacity=cfg.dedup_capacity)
    assert plain.dtype == dedup_rows.dtype
    # bitwise: the working-set path is gathers only, no arithmetic
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(dedup_rows))


@pytest.mark.parametrize("preset", PRESETS)
def test_sparse_grad_update_touches_only_working_set_on_presets(preset):
    cfg, ids = _preset_batch_ids(preset)
    mt = cfg.multi_table()
    params = mt.init(jax.random.PRNGKey(2))
    st_ = init_sparse_adagrad(mt.total_rows)
    gids = np.asarray(mt.global_ids(ids)).reshape(-1)
    grads = jnp.asarray(
        RNG.normal(size=(gids.size, mt.dim)).astype(np.float32))
    p2, st2 = sparse_grad_update(params, st_, jnp.asarray(gids), grads,
                                 capacity=cfg.dedup_capacity)
    working = set(np.unique(gids).tolist())
    touched = np.where(np.abs(np.asarray(p2 - params)).sum(1) > 0)[0]
    assert set(touched.tolist()) <= working
    acc_touched = np.where(np.asarray(st2.accum) != np.asarray(st_.accum))[0]
    assert set(acc_touched.tolist()) <= working
    # every row outside the working set is bitwise untouched
    outside = np.setdiff1d(np.arange(mt.total_rows), np.asarray(sorted(working)))
    np.testing.assert_array_equal(np.asarray(p2)[outside],
                                  np.asarray(params)[outside])


def test_hierarchy_pull_push_and_cache():
    d = tempfile.mkdtemp()
    ps = HierarchicalPS(os.path.join(d, "t.bin"), total_rows=500, dim=4,
                        host_cache_rows=8)
    ids = RNG.integers(0, 500, 64)
    w, uniq, inv = ps.pull(ids)
    assert (w[inv] == ps._ssd[ids]).all()
    ps.push(uniq, w + 2.0)
    w2, _, _ = ps.pull(ids)
    np.testing.assert_allclose(w2[inv], ps._ssd[ids])
    np.testing.assert_allclose(w2, w + 2.0)
    assert ps.host_cache_size <= 8          # LRU bound respected
    assert ps.stats.pulls == 2 and ps.stats.pushes == 1


def test_hierarchy_persistence_across_reopen():
    d = tempfile.mkdtemp()
    path = os.path.join(d, "t.bin")
    ps = HierarchicalPS(path, total_rows=100, dim=4)
    w, uniq, _ = ps.pull(np.asarray([1, 2, 3]))
    ps.push(uniq, np.full_like(w, 7.0))
    ps.flush()
    ps2 = HierarchicalPS(path, total_rows=100, dim=4, create=False)
    w2, _, _ = ps2.pull(np.asarray([1, 2, 3]))
    np.testing.assert_allclose(w2, 7.0)
