"""Plan verifier (PV1xx): hand-broken plans each hit exactly their rule."""

import dataclasses

import numpy as np
import pytest

from repro.check import planverify
from repro.configs import get_arch
from repro.fe import featureplan, get_spec


def _rules(findings):
    return sorted({f.rule for f in findings})


@pytest.fixture(scope="module")
def plan():
    return featureplan.compile(get_spec("ads_ctr"))


@pytest.fixture(scope="module")
def mf(plan):
    cfg = get_arch("dlrm-mlperf").smoke()
    return plan.model_feed(cfg, split_sparse_fields=True)


@pytest.fixture(scope="module")
def feed_layout(plan, mf):
    return plan.feed_layout(split_sparse_fields=mf.split)


# -------------------------------------------------------------------- clean
@pytest.mark.parametrize("preset,arch", [("ads_ctr", "dlrm-mlperf"),
                                         ("dlrm", "dlrm-mlperf"),
                                         ("bst", "bst")])
def test_compiled_presets_verify_clean(preset, arch):
    p = featureplan.compile(get_spec(preset))
    cfg = get_arch(arch).smoke()
    m = p.model_feed(cfg, split_sparse_fields=True)
    findings = planverify.verify_plan(p, rows=8)
    findings += planverify.verify_model_feed(
        m, p.feed_layout(split_sparse_fields=m.split))
    assert findings == [], "\n".join(f.render() for f in findings)


# ------------------------------------------------------------------- PV101
def test_pv101_layout_declares_phantom_sequence_block(plan):
    bad = dataclasses.replace(plan,
                              layout=dataclasses.replace(plan.layout,
                                                         seq_len=7))
    assert _rules(planverify.verify_plan(bad, rows=8)) == ["PV101"]


def test_pv101_width_mismatch_is_a_shape_finding(plan):
    lay = dataclasses.replace(plan.layout,
                              n_dense_feats=plan.layout.n_dense_feats + 3)
    bad = dataclasses.replace(plan, layout=lay)
    findings = planverify.verify_plan(bad, rows=8)
    assert _rules(findings) == ["PV101"]
    assert any("batch_dense" in f.message for f in findings)


def test_pv101_undeclared_produced_output(plan):
    # Zero out the declared sparse block: the plan still produces
    # batch_sparse, which the layout now fails to declare.
    lay = dataclasses.replace(plan.layout, n_sparse_fields=0)
    bad = dataclasses.replace(plan, layout=lay)
    findings = planverify.verify_plan(bad, rows=8)
    assert "PV101" in _rules(findings)
    assert any("batch_sparse" in f.message for f in findings)


# ------------------------------------------------------------------- PV102
def test_pv102_host_op_inside_coalesced_superlayer(plan):
    multi = [ex for ex in plan.layers if len(ex.layer_indices) > 1]
    host_ops = [p for ex in plan.layers for p in ex.host_ops]
    assert multi and host_ops, "ads_ctr plan should have both"
    target = multi[-1]
    # Graft a real host op whose schedule depth is not the super-layer's
    # first member layer.
    alien = [p for p in host_ops
             if plan.schedule.depth_of[p.op.name] != target.layer_indices[0]]
    bad_ex = dataclasses.replace(target, host_ops=(alien[0],))
    bad = dataclasses.replace(
        plan, layers=[bad_ex if e is target else e for e in plan.layers])
    findings = planverify.check_placement(bad)
    assert _rules(findings) == ["PV102"]


def test_pv102_host_op_at_barrier_layer_is_legal(plan):
    # The real ads_ctr plan carries a host op at a super-layer's first
    # member layer (merge into layers (3, 4, 5)): that placement is the
    # legal form, and the rule must not flag it.
    assert planverify.check_placement(plan) == []


def test_pv102_single_layer_executables_exempt(plan):
    singles = [ex for ex in plan.layers if len(ex.layer_indices) == 1]
    sub = dataclasses.replace(plan, layers=singles)
    assert planverify.check_placement(sub) == []


# ------------------------------------------------------------------- PV103
def test_pv103_unproducible_input_slot(plan):
    last = plan.layers[-1]
    bad_ex = dataclasses.replace(
        last, device_input_slots=("mystery_slot",) + tuple(
            last.device_input_slots))
    bad = dataclasses.replace(
        plan, layers=[bad_ex if e is last else e for e in plan.layers])
    _, findings = planverify.abstract_flow(bad, 8)
    assert _rules(findings) == ["PV103"]
    assert "mystery_slot" in findings[0].message


def test_pv103_tracing_failure_reported_not_raised(plan):
    def broken_fn(env):
        raise TypeError("shape contract violated")

    first_fused = next(ex for ex in plan.layers if ex.fused_fn is not None)
    bad_ex = dataclasses.replace(first_fused, fused_fn=broken_fn)
    bad = dataclasses.replace(
        plan,
        layers=[bad_ex if e is first_fused else e for e in plan.layers])
    _, findings = planverify.abstract_flow(bad, 8)
    assert _rules(findings) == ["PV103"]


def test_pv103_duplicate_producer(plan):
    fused = [ex for ex in plan.layers if ex.fused_fn is not None]
    assert fused, "ads_ctr plan should have fused layers"
    dup = fused[0]
    bad = dataclasses.replace(plan, layers=list(plan.layers) + [dup])
    _, findings = planverify.abstract_flow(bad, 8)
    assert "PV103" in _rules(findings)
    assert any("produced twice" in f.message for f in findings)


# ------------------------------------------------------------------- PV104
def test_pv104_projection_missing_a_column(plan):
    rc = {v: tuple(cols) for v, cols in plan.required_columns.items()}
    view = sorted(v for v, cols in rc.items() if cols)[0]
    dropped = rc[view][-1]
    rc[view] = rc[view][:-1]
    bad = dataclasses.replace(plan, required_columns=rc)
    findings = planverify.verify_plan(bad, rows=8)
    assert _rules(findings) == ["PV104"]
    assert any(dropped in f.message for f in findings)


def test_pv104_missing_view_flags_every_column(plan):
    rc = {v: tuple(cols) for v, cols in plan.required_columns.items()}
    view = sorted(v for v, cols in rc.items() if cols)[0]
    n_cols = len(rc.pop(view))
    bad = dataclasses.replace(plan, required_columns=rc)
    findings = [f for f in planverify.verify_plan(bad, rows=8)
                if f.rule == "PV104"]
    assert len(findings) == n_cols


def test_pv104_superset_projection_is_legal(plan):
    rc = {v: tuple(cols) + ("extra_unused_col",)
          for v, cols in plan.required_columns.items()}
    loose = dataclasses.replace(plan, required_columns=rc)
    assert planverify.verify_plan(loose, rows=8) == []


# ------------------------------------------------------------------- PV105
def test_pv105_modulo_exceeds_table_size(mf, feed_layout):
    bad = dataclasses.replace(mf, vocab=np.asarray(mf.vocab) * 1000)
    findings = planverify.verify_model_feed(bad, feed_layout)
    assert _rules(findings) == ["PV105"]
    assert len(findings) == mf.config.n_sparse


def test_pv105_truncated_vocab_vector(mf, feed_layout):
    bad = dataclasses.replace(mf, vocab=np.asarray(mf.vocab)[:2])
    findings = planverify.verify_model_feed(bad, feed_layout)
    assert _rules(findings) == ["PV105"]
    assert len(findings) == mf.config.n_sparse - 2


def test_pv105_nonpositive_modulo(mf, feed_layout):
    vocab = np.array(mf.vocab).copy()
    vocab[0] = 0
    bad = dataclasses.replace(mf, vocab=vocab)
    findings = planverify.verify_model_feed(bad, feed_layout)
    assert _rules(findings) == ["PV105"]


def test_pv105_field_source_out_of_range(mf, feed_layout):
    sources = np.array(mf.field_sources).copy()
    sources[0] = mf.n_spec_fields + 5
    bad = dataclasses.replace(mf, field_sources=sources)
    findings = planverify.verify_model_feed(bad, feed_layout)
    assert _rules(findings) == ["PV105"]


# ------------------------------------------------------------------- PV106
def test_pv106_feed_consumes_unstaged_slot(mf, feed_layout):
    bad = dataclasses.replace(mf, slots=tuple(mf.slots) + ("batch_phantom",))
    findings = planverify.verify_model_feed(bad, feed_layout)
    assert _rules(findings) == ["PV106"]
    assert "batch_phantom" in findings[0].message


def test_pv106_packed_layout_satisfies_split_feed(plan, mf):
    # The feeder derives batch_field_NN views from a packed batch_sparse;
    # a split-slot feed against the packed layout is therefore legal.
    packed = plan.feed_layout(split_sparse_fields=False)
    assert planverify.verify_model_feed(mf, packed) == []
