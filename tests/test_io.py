"""repro.io: shard format roundtrip, host assignment, streaming loader."""

import os

import numpy as np
import pytest

from repro.fe.colstore import RaggedColumn
from repro.fe.datagen import gen_views, write_log_shards
from repro.io.dataset import (
    MANIFEST_NAME,
    ShardDataset,
    ShardInfo,
    assign_shards,
)
from repro.io.shardfmt import (
    ShardFormatError,
    ShardReader,
    ShardWriter,
    read_shard,
    write_shard,
)
from repro.io.stream import StreamingLoader


# ------------------------------------------------------------------ helpers
def _assert_columns_equal(a, b):
    if isinstance(a, RaggedColumn):
        assert isinstance(b, RaggedColumn)
        np.testing.assert_array_equal(a.values, b.values)
        np.testing.assert_array_equal(a.lengths, b.lengths)
        assert b.values.dtype == a.values.dtype
    elif np.asarray(a).dtype == object:
        assert list(np.asarray(a)) == list(np.asarray(b))
    else:
        arr_a, arr_b = np.asarray(a), np.asarray(b)
        assert arr_b.dtype == arr_a.dtype
        np.testing.assert_array_equal(arr_a, arr_b)  # NaN-tolerant


def _mixed_table():
    """All three column kinds with the nasty cases: null sentinels, NaN,
    empty ragged rows, empty strings, unicode, 2-D dense."""
    null_int = np.iinfo(np.int64).min
    return {
        "ids": np.array([1, 2, null_int, 4], np.int64),
        "score": np.array([0.5, np.nan, -1.0, np.inf], np.float32),
        "emb": np.arange(8, dtype=np.float32).reshape(4, 2),
        "tags": RaggedColumn(
            values=np.array([7, 8, 9], np.int64),
            lengths=np.array([2, 0, 0, 1], np.int32),  # empty rows
        ),
        "text": np.array(["", "héllo wörld", "a b c", "🙂"], dtype=object),
    }


# ---------------------------------------------------------------- shard fmt
def test_shard_roundtrip_all_column_kinds(tmp_path):
    tables = {"t": _mixed_table(),
              "side": {"k": np.array([10, 20], np.int64)}}
    path = write_shard(str(tmp_path / "s"), tables)
    assert path.endswith(".fbshard")
    got = read_shard(path)
    assert set(got) == {"t", "side"}
    for tname, cols in tables.items():
        for cname, col in cols.items():
            _assert_columns_equal(col, got[tname][cname])


def test_shard_roundtrip_gen_views_bit_exact(tmp_path):
    views = gen_views(128, seed=3)
    path = write_shard(str(tmp_path / "v"), views)
    got = read_shard(path)
    for vname, cols in views.items():
        for cname, col in cols.items():
            _assert_columns_equal(col, got[vname][cname])


def test_shard_column_projection_and_metadata(tmp_path):
    path = write_shard(str(tmp_path / "s"), {"t": _mixed_table()},
                       meta={"seq": 7})
    r = ShardReader(path)
    assert r.meta["seq"] == 7
    assert r.n_rows("t") == 4
    sub = r.read_table("t", ["ids", "text"])
    assert set(sub) == {"ids", "text"}
    with pytest.raises(KeyError):
        r.read_table("t", ["nope"])
    with pytest.raises(KeyError):
        r.read_table("missing_table")


def test_shard_string_column_preserves_shape(tmp_path):
    col = np.array([["a", "bb"], ["", "dd"]], dtype=object)
    dense = np.array([1, 2], np.int64)  # 2 rows, same as col.shape[0]
    path = write_shard(str(tmp_path / "s"), {"t": {"s": col, "d": dense}})
    got = read_shard(path)["t"]["s"]
    assert got.shape == (2, 2)
    assert [list(r) for r in got] == [["a", "bb"], ["", "dd"]]


def test_shard_rejects_non_string_objects(tmp_path):
    """str(None)/str(b"..") reprs must not silently replace payloads."""
    for bad in (np.array([None, "ok"], dtype=object),
                np.array([b"bytes", "ok"], dtype=object),
                np.array([3, "ok"], dtype=object)):
        with pytest.raises(ShardFormatError, match="only str"):
            write_shard(str(tmp_path / "bad"), {"t": {"c": bad}})


def test_shard_rejects_row_count_mismatch(tmp_path):
    w = ShardWriter(str(tmp_path / "bad"))
    with pytest.raises(ShardFormatError):
        w.add_table("t", {"a": np.zeros(3), "b": np.zeros(4)})
    w.abort()


def test_shard_detects_payload_corruption(tmp_path):
    path = write_shard(str(tmp_path / "s"), {"t": _mixed_table()})
    data = bytearray(open(path, "rb").read())
    data[40] ^= 0xFF  # flip a byte inside the payload region
    with open(path, "wb") as f:
        f.write(data)
    with pytest.raises(ShardFormatError):
        ShardReader(path).read_all()
    # verify=False skips payload CRCs (index CRC still guards structure)
    ShardReader(path, verify=False)


def test_shard_detects_truncation(tmp_path):
    path = write_shard(str(tmp_path / "s"), {"t": _mixed_table()})
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[:-10])
    with pytest.raises(ShardFormatError):
        ShardReader(path)


def test_writer_abort_leaves_no_file(tmp_path):
    path = str(tmp_path / "gone")
    try:
        with ShardWriter(path) as w:
            w.add_table("t", {"a": np.zeros(2)})
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert os.listdir(tmp_path) == []  # no shard, no .tmp left behind


# ------------------------------------------------------------------ dataset
def test_assignment_is_disjoint_cover():
    shards = [ShardInfo(path=f"s{i}", nbytes=1, n_rows=1, seq=i)
              for i in range(11)]
    for n_hosts in (1, 2, 3, 5, 11, 13):
        parts = [assign_shards(shards, h, n_hosts) for h in range(n_hosts)]
        flat = [s.seq for p in parts for s in p]
        assert sorted(flat) == list(range(11))          # cover, no dupes
        sizes = [len(p) for p in parts]
        assert max(sizes) - min(sizes) <= 1             # balanced
    with pytest.raises(ValueError):
        assign_shards(shards, 3, 3)
    with pytest.raises(ValueError):
        assign_shards(shards, 0, 0)


def test_dataset_discovery_manifest_and_scan(tmp_path):
    d = str(tmp_path)
    paths = write_log_shards(d, n_shards=5, rows_per_shard=64, seed=1)
    assert len(paths) == 5
    ds = ShardDataset(d)  # via manifest
    assert len(ds.shards) == 5 and ds.total_rows == 5 * 64
    os.remove(os.path.join(d, MANIFEST_NAME))
    ds2 = ShardDataset(d)  # via directory scan
    assert [s.name for s in ds2.shards] == [s.name for s in ds.shards]
    assert ds2.total_rows == ds.total_rows

    # host views partition the shard set
    a = ShardDataset(d, host_id=0, n_hosts=2)
    b = ShardDataset(d, host_id=1, n_hosts=2)
    names = sorted(s.name for s in a.local_shards) + \
        sorted(s.name for s in b.local_shards)
    assert sorted(names) == sorted(s.name for s in ds.shards)


def test_epoch_order_deterministic_shuffle(tmp_path):
    d = str(tmp_path)
    write_log_shards(d, n_shards=6, rows_per_shard=32)
    ds = ShardDataset(d)
    e0 = [s.seq for s in ds.epoch_order(0, shuffle=True, seed=7)]
    assert e0 == [s.seq for s in ds.epoch_order(0, shuffle=True, seed=7)]
    assert sorted(e0) == list(range(6))
    e1 = [s.seq for s in ds.epoch_order(1, shuffle=True, seed=7)]
    assert e0 != e1  # epochs reshuffle


def test_colstore_to_shards_non_contiguous_chunk_ids(tmp_path):
    """Chunk ids parsed from dir names need not start at 0 — every chunk
    must land in exactly one shard (no silent dup/drop)."""
    from repro.fe.colstore import ColumnStore
    from repro.io.convert import colstore_to_shards

    store = ColumnStore(str(tmp_path / "cs"))
    for cid in (3, 5, 9):  # deliberately non-contiguous, non-zero-based
        store.write_chunk("impressions", cid,
                          {"instance_id": np.array([cid * 10], np.int64)})
        store.write_chunk("user_profile", cid,
                          {"user_id": np.array([cid], np.int64)})
    paths = colstore_to_shards(
        store, str(tmp_path / "out"),
        {"impressions": ["instance_id"], "user_profile": ["user_id"]})
    assert len(paths) == 3
    got = sorted(int(read_shard(p)["impressions"]["instance_id"][0])
                 for p in paths)
    assert got == [30, 50, 90]
    ds = ShardDataset(str(tmp_path / "out"))  # manifest written, rows right
    assert ds.total_rows == 3


# ------------------------------------------------------------------- stream
def test_streaming_loader_yields_every_shard_once(tmp_path):
    d = str(tmp_path)
    write_log_shards(d, n_shards=6, rows_per_shard=64, seed=5)
    loader = StreamingLoader(ShardDataset(d), workers=3, prefetch=2)
    seen = [env["impressions"]["instance_id"] for env in loader]
    assert len(seen) == 6
    s = loader.stats
    assert s.shards == 6 and s.bytes_read > 0 and s.read_seconds > 0


def test_streaming_loader_single_worker_is_ordered(tmp_path):
    d = str(tmp_path)
    write_log_shards(d, n_shards=4, rows_per_shard=32, seed=2)
    loader = StreamingLoader(ShardDataset(d), workers=1, prefetch=1)
    got = [int(env["impressions"]["user_id"][0]) for env in loader]
    want = [int(gen_views(32, seed=2 + i)["impressions"]["user_id"][0])
            for i in range(4)]
    assert got == want


def test_streaming_loader_propagates_reader_errors(tmp_path):
    d = str(tmp_path)
    paths = write_log_shards(d, n_shards=3, rows_per_shard=32)
    data = bytearray(open(paths[1], "rb").read())
    data[40] ^= 0xFF
    with open(paths[1], "wb") as f:
        f.write(data)
    loader = StreamingLoader(ShardDataset(d), workers=2, prefetch=2)
    with pytest.raises(RuntimeError, match="shard reader failed") as ei:
        list(loader)
    assert isinstance(ei.value.__cause__, ShardFormatError)


def test_streaming_loader_early_abandonment_releases_readers(tmp_path):
    """Abandoning iteration mid-stream must not leak spinning readers,
    even when in-flight decodes outnumber the queue capacity."""
    import threading
    import time as _time

    d = str(tmp_path)
    write_log_shards(d, n_shards=8, rows_per_shard=16)
    loader = StreamingLoader(ShardDataset(d), workers=4, prefetch=2)
    it = iter(loader)
    next(it)
    t0 = _time.perf_counter()
    it.close()  # generator finally -> loader.close()
    assert _time.perf_counter() - t0 < 2.0, "close() stalled on readers"
    assert not [t for t in threading.enumerate()
                if t.name.startswith("shard-reader")]
    # reusable for a fresh full pass, with stats of THIS pass only
    assert sum(1 for _ in loader) == 8
    assert loader.stats.shards == 8


def test_streaming_loader_epochs_and_transform(tmp_path):
    d = str(tmp_path)
    write_log_shards(d, n_shards=2, rows_per_shard=16)
    loader = StreamingLoader(
        ShardDataset(d), workers=1, epochs=3,
        transform=lambda env, info: {"n": len(env["impressions"]["user_id"]),
                                     "seq": info.seq})
    envs = list(loader)
    assert len(envs) == 6
    assert all(e["n"] == 16 for e in envs)


def test_runners_consume_loader_and_capture_ingest_stats(tmp_path):
    """PipelinedRunner fed from disk == staged fed from disk, and the
    pipelined run attaches IngestStats (paper: disk+FE overlap training)."""
    from repro.core import PipelinedRunner, StagedRunner, build_schedule, \
        compile_layers
    from repro.fe.pipeline_graph import build_fe_graph

    d = str(tmp_path / "log")
    write_log_shards(d, n_shards=3, rows_per_shard=48, seed=9)
    layers = compile_layers(build_schedule(build_fe_graph()))

    def step(state, env):
        s = float(np.asarray(env["batch_dense"]).sum()) + float(
            np.asarray(env["batch_sparse"]).sum())
        return {"sum": state["sum"] + s, "batches": state["batches"] + 1}

    pipe = PipelinedRunner(layers, step, prefetch=2)
    s1 = pipe.run({"sum": 0.0, "batches": 0},
                  StreamingLoader(ShardDataset(d), workers=2))
    staged = StagedRunner(layers, step, workdir=str(tmp_path / "staged"))
    s2 = staged.run({"sum": 0.0, "batches": 0},
                    StreamingLoader(ShardDataset(d), workers=1))

    assert s1["batches"] == s2["batches"] == 3
    np.testing.assert_allclose(s1["sum"], s2["sum"], rtol=1e-6)
    assert pipe.stats.ingest is not None
    assert pipe.stats.ingest.bytes_read > 0
    assert pipe.stats.intermediate_bytes == 0
    assert staged.stats.intermediate_bytes > 10_000


# ------------------------------------------------------ projection pushdown
def test_read_all_projection_skips_tables_and_columns(tmp_path):
    views = gen_views(64, seed=5)
    path = write_shard(str(tmp_path / "p.fbshard"), views)

    full_reader = ShardReader(path)
    full = full_reader.read_all()
    assert full_reader.columns_decoded == sum(
        len(cols) for cols in full.values())
    assert full_reader.bytes_decoded > 0

    proj = {"impressions": ("user_id", "label"),
            "user_profile": ("interests",)}
    proj_reader = ShardReader(path)
    env = proj_reader.read_all(proj)
    assert set(env) == {"impressions", "user_profile"}
    assert set(env["impressions"]) == {"user_id", "label"}
    np.testing.assert_array_equal(env["impressions"]["user_id"],
                                  full["impressions"]["user_id"])
    np.testing.assert_array_equal(env["user_profile"]["interests"].values,
                                  full["user_profile"]["interests"].values)
    assert proj_reader.columns_decoded == 3
    assert proj_reader.bytes_decoded < full_reader.bytes_decoded


def test_read_all_projection_unknown_column_raises(tmp_path):
    path = write_shard(str(tmp_path / "p.fbshard"), gen_views(8, seed=0))
    with pytest.raises(KeyError, match="typo"):
        ShardReader(path).read_all({"impressions": ("typo",)})


def test_streaming_loader_projection_reduces_decode(tmp_path):
    write_log_shards(str(tmp_path), n_shards=3, rows_per_shard=128, seed=1)
    ds = ShardDataset(str(tmp_path))

    full = StreamingLoader(ds, workers=1)
    n_full = sum(1 for _ in full)

    from repro.fe import featureplan, get_spec
    plan = featureplan.compile(get_spec("bst"))
    proj = StreamingLoader(ds, workers=1, columns=plan.required_columns)
    envs = list(proj)

    assert n_full == len(envs) == 3
    assert proj.stats.columns_decoded < full.stats.columns_decoded
    assert proj.stats.bytes_decoded < full.stats.bytes_decoded
    assert full.stats.bytes_decoded > 0
    # projected envs still run through the compiled plan
    out = plan.outputs(plan.run(envs[0]))
    assert np.asarray(out["batch_sparse"]).shape[1] == 4
