"""Arena aliasing analysis (AL2xx): negatives per rule + clean layouts."""

import numpy as np
import pytest

from repro.check.aliasing import (
    check_agreement,
    check_feed_layout,
    check_plan,
    check_ring,
)
from repro.core.devicefeed import FeedLayout, SlotSpec


def _rules(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------------- AL201
def test_al201_overlapping_intervals():
    assert _rules(check_plan([256, 256], [0, 128], 512)) == ["AL201"]


def test_al201_last_slot_overruns_total():
    assert _rules(check_plan([128, 256], [0, 128], 256)) == ["AL201"]


def test_al201_unordered_offsets_still_detected():
    # Overlap check sorts by offset first.
    assert _rules(check_plan([256, 256], [128, 0], 512)) == ["AL201"]


# ------------------------------------------------------------------- AL202
def test_al202_misaligned_offset():
    assert _rules(check_plan([64], [8], 128)) == ["AL202"]


def test_al202_misaligned_total():
    assert _rules(check_plan([64], [0], 100)) == ["AL202"]


def test_al202_custom_alignment():
    assert check_plan([64], [8], 128, align=8) == []
    assert _rules(check_plan([64], [4], 128, align=8)) == ["AL202"]


# ------------------------------------------------------------------- AL203
def test_al203_negative_size():
    assert _rules(check_plan([-1], [0], 128)) == ["AL203"]


def test_al203_int32_overflow():
    assert "AL203" in _rules(check_plan([2**31], [0], 2**31 + 128))


def test_al203_overflowing_layout_reports_instead_of_crashing():
    layout = FeedLayout(slots=(SlotSpec("huge", 2**22, "float32"),))
    findings = check_feed_layout(layout, rows=2**10)
    assert _rules(findings) == ["AL203"]


# ------------------------------------------------------------------- AL204
def test_al204_planner_disagreement():
    findings = check_agreement({"a": ([0, 128], 256), "b": ([0, 256], 384)})
    assert _rules(findings) == ["AL204"]


def test_al204_offset_count_mismatch():
    assert _rules(check_plan([64, 64], [0], 128)) == ["AL204"]


def test_al204_agreeing_planners_are_clean():
    assert check_agreement({"a": ([0, 128], 256), "b": ([0, 128], 256)}) == []


# ------------------------------------------------------------------- AL205
def test_al205_zero_buffers_is_an_error():
    findings = check_ring(None, -1, buffers=0)
    assert [f.rule for f in findings] == ["AL205"]
    assert findings[0].severity == "error"


def test_al205_underprovisioned_ring_warns():
    findings = check_ring(None, -1, buffers=2, queue_capacity=2,
                          donate=False)
    assert _rules(findings) == ["AL205"]
    assert all(f.severity == "warning" for f in findings)


def test_al205_default_queue_bound_is_satisfied():
    # PipelinedRunner's maxsize=max(1, buffers-2) keeps buffers >= 3 clean.
    layout = FeedLayout(slots=(SlotSpec("batch_label", 1, "float32",
                                        rank1=True),))
    assert check_ring(layout, 8, buffers=3) == []


# ------------------------------------------------------------------- AL206
def test_al206_donation_fence_unreachable():
    findings = check_ring(None, -1, buffers=1, queue_capacity=1)
    assert "AL206" in _rules(findings)
    al206 = [f for f in findings if f.rule == "AL206"]
    assert al206[0].severity == "error"


def test_al206_not_raised_without_donation():
    findings = check_ring(None, -1, buffers=1, queue_capacity=1,
                          donate=False)
    assert "AL206" not in _rules(findings)


# ----------------------------------------------------------- clean layouts
@pytest.mark.parametrize("preset", ["ads_ctr", "dlrm", "bst"])
@pytest.mark.parametrize("split", [False, True])
def test_compiled_layouts_pass_the_tri_oracle(preset, split):
    from repro.fe import featureplan, get_spec
    plan = featureplan.compile(get_spec(preset))
    layout = plan.feed_layout(split_sparse_fields=split)
    findings = check_feed_layout(layout, rows=64)
    assert findings == [], "\n".join(f.render() for f in findings)
    assert check_ring(layout, 64, buffers=3) == []


def test_hand_built_layout_tri_oracle_matches_arena_pool():
    layout = FeedLayout(slots=(
        SlotSpec("a", 3, "float32"),
        SlotSpec("b", 1, "int64", rank1=True),
        SlotSpec("c", 17, "int32"),
    ))
    assert check_feed_layout(layout, rows=33) == []


def test_corrupt_plan_offsets_detected_against_oracle():
    layout = FeedLayout(slots=(SlotSpec("a", 4, "float32"),
                               SlotSpec("b", 4, "float32")))
    offsets, total = layout.plan(16)
    bad = np.array(offsets)
    bad[1] = 0  # collide with slot a
    findings = check_plan(layout.sizes(16), list(bad), total,
                          names=layout.slot_names)
    assert "AL201" in _rules(findings)
