"""Runner-equivalence property tests: for random small specs and batches,
``PipelinedRunner`` — with and without the device-feed stage, with
super-layer coalescing on and off, and with the direct-to-arena zero-copy
feed — and ``StagedRunner`` all produce identical final state and
identical per-slot outputs.

The second property extends this through the compiled train-feed boundary
(:mod:`repro.fe.modelfeed`): Pipelined x {feed off/stage/arena} x {dedup
on/off} == Staged, **bit-identical** adapted model batches and losses, on
random specs x tiny arch configs.
"""

import dataclasses

import numpy as np
import pytest
from conftest import recording_step

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import DeviceFeeder, PipelinedRunner, StagedRunner  # noqa: E402
from repro.fe import (  # noqa: E402
    Cross,
    DenseOutput,
    FeatureSpec,
    Hash,
    Join,
    LogNorm,
    Scale,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
    featureplan,
)
from repro.fe.datagen import IMPRESSIONS, USER_PROFILE, gen_views  # noqa: E402

_HASHES = {
    "h_user": Hash("h_user", "user_id"),
    "h_ad": Hash("h_ad", "ad_id", mix=True),
    "x_user_ad": Cross("x_user_ad", "user_id", "ad_id"),
}
_DENSES = {
    "d_dwell": LogNorm("d_dwell", "dwell_time"),
    "d_hour": Scale("d_hour", "hour", 24.0),
}


@st.composite
def _small_specs(draw):
    fields = draw(st.lists(st.sampled_from(sorted(_HASHES)), min_size=1,
                           max_size=3, unique=True))
    dense = draw(st.lists(st.sampled_from(sorted(_DENSES)), max_size=2,
                          unique=True))
    with_seq = draw(st.booleans())
    transforms = [_HASHES[f] for f in fields] + [_DENSES[d] for d in dense]
    sources = [Source("impressions", IMPRESSIONS)]
    joins = []
    outputs = [SparseOutput(tuple(fields))]
    if dense:
        outputs.append(DenseOutput(tuple(dense)))
    if with_seq:
        sources.append(Source("user_profile", USER_PROFILE))
        joins.append(Join("user_profile", key="user_id", prefix="u_"))
        transforms.append(Sequence("s_int", "u_interests", max_len=6))
        outputs.append(SequenceOutput(("s_int",)))
    return FeatureSpec(
        name="prop", base="impressions", sources=tuple(sources),
        joins=tuple(joins), transforms=tuple(transforms),
        outputs=tuple(outputs))


@hypothesis.settings(
    max_examples=8, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow,
                           hypothesis.HealthCheck.data_too_large])
@hypothesis.given(spec=_small_specs(),
                  rows=st.integers(min_value=8, max_value=40),
                  n_batches=st.integers(min_value=1, max_value=3),
                  seed=st.integers(min_value=0, max_value=2**16))
def test_runners_equivalent_on_random_specs(spec, rows, n_batches, seed,
                                            tmp_path_factory):
    from repro.core import compile_layers

    plan = featureplan.compile(spec)
    per_layer = compile_layers(plan.schedule, coalesce=False)
    batches = [gen_views(rows, seed=seed + i) for i in range(n_batches)]

    results = []
    for make in (
        lambda: PipelinedRunner(plan.layers, None, prefetch=2),
        lambda: PipelinedRunner(per_layer, None, prefetch=2),
        lambda: PipelinedRunner(
            plan.layers, None, prefetch=2,
            device_feed=DeviceFeeder(plan.feed_layout(), rows_hint=rows)),
        lambda: PipelinedRunner.from_plan(plan, None, feed="arena",
                                          rows_hint=rows),
        lambda: StagedRunner(
            plan.layers, None,
            workdir=str(tmp_path_factory.mktemp("staged"))),
    ):
        runner = make()
        seen = []
        runner.train_step = recording_step(seen)
        state = runner.run({"batches": 0}, [dict(b) for b in batches])
        results.append((state, seen))

    (s0, o0) = results[0]
    assert s0["batches"] == n_batches
    assert len(o0) == n_batches
    for s, o in results[1:]:
        assert s == s0
        assert len(o) == n_batches
        for a, b in zip(o0, o):
            assert set(a) == set(b)
            for k in a:
                assert a[k].dtype == b[k].dtype
                np.testing.assert_array_equal(a[k], b[k])


# ------------------------------------------- compiled train-feed boundary
@st.composite
def _tiny_archs(draw):
    from repro.models.recsys import RecsysConfig
    kind = draw(st.sampled_from(["dlrm", "dcnv2", "bst"]))
    n_sparse = draw(st.integers(1, 4))
    vocab = tuple(draw(st.lists(st.integers(3, 40), min_size=n_sparse,
                                max_size=n_sparse)))
    return RecsysConfig(
        name="prop", kind=kind, n_sparse=n_sparse, vocab_sizes=vocab,
        n_dense=(0 if kind == "bst" else draw(st.integers(1, 3))),
        embed_dim=4, bot_mlp=(4,), top_mlp=(4, 1) if kind == "dlrm" else (4,),
        seq_len=(draw(st.integers(1, 6)) if kind == "bst" else 0),
        n_blocks=(1 if kind == "bst" else 0),
        n_heads=(2 if kind == "bst" else 0),
        n_cross_layers=(1 if kind == "dcnv2" else 0),
    )


def run_trainfeed_equivalence(spec, cfg, rows, n_batches, seed, workdir):
    """Pipelined x {feed off/stage/arena} x {dedup on/off} == Staged, with
    the spec->arch adaptation traced inside the step's jit (shared by the
    hypothesis property below and a deterministic smoke run)."""
    import jax

    from repro.models import recsys as R

    plan = featureplan.compile(spec)
    batches = [gen_views(rows, seed=seed + i) for i in range(n_batches)]
    feeds = {split: plan.model_feed(cfg, split_sparse_fields=split,
                                    rows_hint=rows)
             for split in (False, True)}
    tuned = feeds[False].config  # dedup capacity sized from the rows hint
    params = R.init_params(tuned, jax.random.PRNGKey(0))
    cfg_on = dataclasses.replace(tuned, dedup_lookup=True)
    cfg_off = dataclasses.replace(tuned, dedup_lookup=False)

    def raw_step(p, opt_state, batch):
        # dedup on/off computed side by side: the working-set lookup must
        # be bit-identical to the plain gather through the full forward
        metrics = {"loss": R.loss_fn(p, cfg_on, batch),
                   "loss_nodedup": R.loss_fn(p, cfg_off, batch)}
        metrics.update({f"adapted_{k}": v for k, v in batch.items()})
        return p, opt_state, metrics

    steps = {split: mf.make_step(raw_step, donate=False)
             for split, mf in feeds.items()}

    def recording(split):
        boundary = steps[split]
        seen = []

        def fn(state, env):
            _, _, m = boundary(params, None, env)
            seen.append({k: np.asarray(v) for k, v in m.items()})
            return {"batches": state["batches"] + 1}
        return fn, seen

    results = []
    for split, make in (
        (False, lambda s: PipelinedRunner(plan.layers, s, prefetch=2)),
        (False, lambda s: PipelinedRunner(
            plan.layers, s, prefetch=2,
            device_feed=DeviceFeeder(plan.feed_layout(), rows_hint=rows))),
        (True, lambda s: PipelinedRunner.from_plan(
            plan, s, feed="arena", split_sparse_fields=True,
            rows_hint=rows)),
        (False, lambda s: StagedRunner(plan.layers, s, workdir=workdir)),
    ):
        fn, seen = recording(split)
        runner = make(fn)
        state = runner.run({"batches": 0}, [dict(b) for b in batches])
        assert state["batches"] == n_batches
        results.append(seen)

    o0 = results[0]
    for a in o0:  # dedup on == dedup off, through the whole forward
        np.testing.assert_array_equal(a["loss"], a["loss_nodedup"])
    for o in results[1:]:
        assert len(o) == len(o0)
        for a, b in zip(o0, o):
            assert set(a) == set(b)
            for k in a:
                assert a[k].dtype == b[k].dtype, k
                np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@hypothesis.settings(
    max_examples=5, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow,
                           hypothesis.HealthCheck.data_too_large])
@hypothesis.given(spec=_small_specs(), cfg=_tiny_archs(),
                  rows=st.integers(min_value=8, max_value=24),
                  seed=st.integers(min_value=0, max_value=2**16))
def test_trainfeed_runners_equivalent_on_random_specs(spec, cfg, rows, seed,
                                                      tmp_path_factory):
    run_trainfeed_equivalence(
        spec, cfg, rows, n_batches=2, seed=seed,
        workdir=str(tmp_path_factory.mktemp("staged_tf")))

def test_trainfeed_equivalence_holds_with_tracing_enabled(tmp_path):
    """Tracing is bit-effect-free: the full runner-equivalence property
    (Pipelined x {feed off/stage/arena} x {dedup on/off} == Staged) holds
    unchanged with an enabled tracer installed (deterministic instance)."""
    from repro.configs import get_arch
    from repro.obs import Tracer, set_tracer

    fields = ("h_user", "h_ad", "x_user_ad")
    spec = FeatureSpec(
        name="traced", base="impressions",
        sources=(Source("impressions", IMPRESSIONS),),
        transforms=tuple(_HASHES[f] for f in fields) + (_DENSES["d_dwell"],),
        outputs=(SparseOutput(fields), DenseOutput(("d_dwell",))))
    cfg = dataclasses.replace(get_arch("dlrm-mlperf").smoke(),
                              dedup_capacity=0)
    tracer = Tracer(enabled=True)
    prev = set_tracer(tracer)
    try:
        run_trainfeed_equivalence(spec, cfg, rows=16, n_batches=2, seed=11,
                                  workdir=str(tmp_path))
    finally:
        set_tracer(prev)
    assert tracer.n_events > 0  # the runs really were traced
