"""Runner-equivalence property test: for random small specs and batches,
``PipelinedRunner`` — with and without the device-feed stage, with
super-layer coalescing on and off, and with the direct-to-arena zero-copy
feed — and ``StagedRunner`` all produce identical final state and
identical per-slot outputs."""

import numpy as np
import pytest
from conftest import recording_step

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import DeviceFeeder, PipelinedRunner, StagedRunner  # noqa: E402
from repro.fe import (  # noqa: E402
    Cross,
    DenseOutput,
    FeatureSpec,
    Hash,
    Join,
    LogNorm,
    Scale,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
    featureplan,
)
from repro.fe.datagen import IMPRESSIONS, USER_PROFILE, gen_views  # noqa: E402

_HASHES = {
    "h_user": Hash("h_user", "user_id"),
    "h_ad": Hash("h_ad", "ad_id", mix=True),
    "x_user_ad": Cross("x_user_ad", "user_id", "ad_id"),
}
_DENSES = {
    "d_dwell": LogNorm("d_dwell", "dwell_time"),
    "d_hour": Scale("d_hour", "hour", 24.0),
}


@st.composite
def _small_specs(draw):
    fields = draw(st.lists(st.sampled_from(sorted(_HASHES)), min_size=1,
                           max_size=3, unique=True))
    dense = draw(st.lists(st.sampled_from(sorted(_DENSES)), max_size=2,
                          unique=True))
    with_seq = draw(st.booleans())
    transforms = [_HASHES[f] for f in fields] + [_DENSES[d] for d in dense]
    sources = [Source("impressions", IMPRESSIONS)]
    joins = []
    outputs = [SparseOutput(tuple(fields))]
    if dense:
        outputs.append(DenseOutput(tuple(dense)))
    if with_seq:
        sources.append(Source("user_profile", USER_PROFILE))
        joins.append(Join("user_profile", key="user_id", prefix="u_"))
        transforms.append(Sequence("s_int", "u_interests", max_len=6))
        outputs.append(SequenceOutput(("s_int",)))
    return FeatureSpec(
        name="prop", base="impressions", sources=tuple(sources),
        joins=tuple(joins), transforms=tuple(transforms),
        outputs=tuple(outputs))


@hypothesis.settings(
    max_examples=8, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow,
                           hypothesis.HealthCheck.data_too_large])
@hypothesis.given(spec=_small_specs(),
                  rows=st.integers(min_value=8, max_value=40),
                  n_batches=st.integers(min_value=1, max_value=3),
                  seed=st.integers(min_value=0, max_value=2**16))
def test_runners_equivalent_on_random_specs(spec, rows, n_batches, seed,
                                            tmp_path_factory):
    from repro.core import compile_layers

    plan = featureplan.compile(spec)
    per_layer = compile_layers(plan.schedule, coalesce=False)
    batches = [gen_views(rows, seed=seed + i) for i in range(n_batches)]

    results = []
    for make in (
        lambda: PipelinedRunner(plan.layers, None, prefetch=2),
        lambda: PipelinedRunner(per_layer, None, prefetch=2),
        lambda: PipelinedRunner(
            plan.layers, None, prefetch=2,
            device_feed=DeviceFeeder(plan.feed_layout(), rows_hint=rows)),
        lambda: PipelinedRunner.from_plan(plan, None, feed="arena",
                                          rows_hint=rows),
        lambda: StagedRunner(
            plan.layers, None,
            workdir=str(tmp_path_factory.mktemp("staged"))),
    ):
        runner = make()
        seen = []
        runner.train_step = recording_step(seen)
        state = runner.run({"batches": 0}, [dict(b) for b in batches])
        results.append((state, seen))

    (s0, o0) = results[0]
    assert s0["batches"] == n_batches
    assert len(o0) == n_batches
    for s, o in results[1:]:
        assert s == s0
        assert len(o) == n_batches
        for a, b in zip(o0, o):
            assert set(a) == set(b)
            for k in a:
                assert a[k].dtype == b[k].dtype
                np.testing.assert_array_equal(a[k], b[k])