"""Property test: lease-server invariants under randomized schedules.

Hypothesis drives arbitrary interleavings of acquire / heartbeat / commit /
fail_worker / reap / issue_backups with synthetic clocks and asserts the
two contracts the loader's exactly-once yield rests on:

* every shard is committed exactly once (first commit wins, later commits
  rejected), and the run always terminates with all shards done;
* ``completed + pending + leased == n_shards`` after every operation (the
  shard-state partition invariant).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.train.fault import ShardServer, StragglerPolicy  # noqa: E402

WORKERS = ("w0", "w1", "w2")

ops = st.lists(
    st.one_of(
        st.tuples(st.just("acquire"), st.sampled_from(WORKERS)),
        st.tuples(st.just("commit"), st.sampled_from(WORKERS)),
        st.tuples(st.just("heartbeat"), st.sampled_from(WORKERS)),
        st.tuples(st.just("fail"), st.sampled_from(WORKERS)),
        st.tuples(st.just("reap"), st.just("")),
        st.tuples(st.just("backups"), st.just("")),
        st.tuples(st.just("tick"), st.floats(min_value=0.0, max_value=5.0,
                                             allow_nan=False)),
    ),
    min_size=1, max_size=120,
)


@hypothesis.settings(max_examples=120, deadline=None)
@hypothesis.given(n_shards=st.integers(min_value=1, max_value=8),
                  schedule=ops,
                  lease_timeout=st.sampled_from([0.5, 2.0, 100.0]))
def test_schedule_preserves_lease_invariants(n_shards, schedule,
                                             lease_timeout):
    srv = ShardServer(n_shards, lease_timeout=lease_timeout,
                      straggler=StragglerPolicy(factor=2.0, min_samples=1))
    now = 0.0
    held = {w: [] for w in WORKERS}  # shards each worker believes it holds
    committed = set()

    def check():
        completed, pending, leased = srv.counts()
        assert completed + pending + leased == n_shards
        assert completed == len(committed) == srv.stats.completed

    for op, arg in schedule:
        now += 0.01  # strictly advancing clock
        if op == "tick":
            now += arg
        elif op == "acquire":
            sid = srv.acquire(arg, now=now)
            if sid is not None:
                assert sid not in committed  # never re-issue a done shard
                held[arg].append(sid)
        elif op == "commit" and held[arg]:
            sid = held[arg].pop(0)
            ok = srv.commit(arg, sid, now=now)
            # first commit accepted, any duplicate rejected — exactly once
            assert ok == (sid not in committed)
            committed.add(sid)
        elif op == "heartbeat":
            for sid in held[arg]:
                srv.heartbeat(arg, sid, now=now)
        elif op == "fail":
            srv.fail_worker(arg)
            held[arg].clear()
        elif op == "reap":
            srv.reap(now=now)
        elif op == "backups":
            srv.issue_backups(now=now)
        check()

    # drain: one surviving worker finishes whatever is left; termination
    # plus exactly-once must hold no matter what the schedule did above
    for _ in range(4 * n_shards + 4):
        if srv.done():
            break
        now += lease_timeout + 1.0  # let stale leases expire
        sid = srv.acquire("w0", now=now)
        if sid is None:
            continue
        assert sid not in committed
        assert srv.commit("w0", sid, now=now)
        committed.add(sid)
        check()
    assert srv.done()
    assert committed == set(range(n_shards))
    assert srv.stats.completed == n_shards
