"""repro.train.fault: lease server invariants, commit-vs-reap races,
first-commit-wins dedup, bounded straggler policy, elastic remesh."""

import numpy as np
import pytest

from repro.train.fault import (
    FaultStats,
    ShardServer,
    StragglerPolicy,
    elastic_remesh,
)


def assert_partition(srv):
    """The lease invariant: done/pending/leased partition the shard space."""
    completed, pending, leased = srv.counts()
    assert completed + pending + leased == srv.n_shards


# ------------------------------------------------------- commit-vs-reap races
def test_acquire_skips_done_after_late_commit():
    """Regression: a shard reaped back into pending and then committed late
    by the original holder must never be handed out again (the seed's
    double-processing bug: acquire did not check the done set)."""
    srv = ShardServer(2, lease_timeout=1.0)
    s0 = srv.acquire("w0", now=0.0)
    assert s0 == 0
    # lease expires; reap returns it to pending
    assert srv.reap(now=5.0) == [s0]
    assert_partition(srv)
    # the original holder was merely slow, not dead: first commit wins
    assert srv.commit("w0", s0, now=6.0)
    # the reissued copy in pending must NOT be acquirable again
    assert srv.acquire("w1", now=7.0) == 1
    assert srv.acquire("w2", now=7.0) is None
    assert srv.commit("w1", 1, now=8.0)
    assert srv.done()
    assert srv.stats.completed == 2
    assert_partition(srv)


def test_commit_vs_reap_race_first_commit_wins():
    """Reap hands the shard to w1; whichever commits first wins, the loser
    is rejected and the shard is completed exactly once."""
    srv = ShardServer(1, lease_timeout=1.0)
    s = srv.acquire("w0", now=0.0)
    s2 = srv.acquire("w1", now=5.0)  # acquire reaps w0's expired lease
    assert s2 == s
    assert srv.stats.reissued == 1 and srv.stats.leases_reaped == 1
    assert srv.commit("w1", s, now=6.0)       # winner
    assert not srv.commit("w0", s, now=6.1)   # loser discards its copy
    assert srv.stats.completed == 1
    assert srv.stats.commits_rejected == 1
    assert srv.done()
    assert_partition(srv)


def test_reap_latency_accounting():
    srv = ShardServer(1, lease_timeout=1.0)
    srv.acquire("w0", now=0.0)
    assert srv.reap(now=3.5) == [0]
    assert srv.stats.leases_reaped == 1
    # expiry was at t=1.0, noticed at t=3.5 -> 2.5s detection lag
    assert srv.stats.reap_latency_seconds == pytest.approx(2.5)
    assert srv.stats.reap_latency_mean == pytest.approx(2.5)


def test_heartbeat_of_committed_or_reaped_shard_is_false():
    srv = ShardServer(2, lease_timeout=1.0)
    s = srv.acquire("w0", now=0.0)
    assert srv.heartbeat("w0", s, now=0.5)
    srv.reap(now=9.0)
    assert not srv.heartbeat("w0", s, now=9.1)  # lease gone
    s2 = srv.acquire("w1", now=9.2)
    assert s2 == s
    srv.commit("w1", s2, now=9.5)
    assert not srv.heartbeat("w1", s2, now=9.6)  # shard done


# ------------------------------------------------------------- backup tasks
def test_straggler_backup_first_commit_wins():
    srv = ShardServer(4, lease_timeout=100.0,
                      straggler=StragglerPolicy(factor=2.0, min_samples=2))
    # two fast shards establish the duration baseline (p50 = 1.0s)
    for _ in range(2):
        sid = srv.acquire("fast", now=0.0)
        assert srv.commit("fast", sid, now=1.0)
    slow = srv.acquire("slow", now=1.0)
    # not yet a straggler at 1.5x p50
    assert srv.issue_backups(now=2.5) == []
    # beyond p50 x factor: duplicate-issued exactly once
    assert srv.issue_backups(now=4.0) == [slow]
    assert srv.issue_backups(now=5.0) == []  # no double backup
    assert srv.stats.backup_issued == 1
    # the slow worker itself cannot pick up its own backup
    assert srv.acquire("slow", now=5.0) == 3  # next pending, not the backup
    backup_sid = srv.acquire("helper", now=5.0)
    assert backup_sid == slow
    assert srv.commit("helper", backup_sid, now=5.5)
    assert srv.stats.backup_wins == 1
    assert not srv.commit("slow", slow, now=6.0)  # original loses
    assert srv.stats.commits_rejected == 1
    assert_partition(srv)


def test_backup_queue_skips_shards_finished_meanwhile():
    srv = ShardServer(2, lease_timeout=100.0,
                      straggler=StragglerPolicy(factor=1.0, min_samples=1))
    s0 = srv.acquire("w0", now=0.0)
    srv.commit("w0", s0, now=0.1)  # baseline p50 = 0.1
    s1 = srv.acquire("w0", now=0.2)
    assert srv.issue_backups(now=10.0) == [s1]
    srv.commit("w0", s1, now=10.5)  # original finishes before backup starts
    # stale backup entry must not be handed out for a done shard
    assert srv.acquire("w1", now=11.0) is None
    assert srv.done()


# -------------------------------------------------------------- fail_worker
def test_fail_worker_returns_all_leases_immediately():
    srv = ShardServer(3, lease_timeout=1000.0)
    a = srv.acquire("w0")
    b = srv.acquire("w0")
    assert srv.fail_worker("w0") == 2
    assert srv.stats.failed_workers == 1
    assert srv.stats.reissued == 2
    got = {srv.acquire("w1"), srv.acquire("w1"), srv.acquire("w1")}
    assert got == {a, b, 2}
    assert_partition(srv)


def test_fail_worker_keeps_other_workers_leases():
    srv = ShardServer(2, lease_timeout=1000.0,
                      straggler=StragglerPolicy(factor=1.0, min_samples=1))
    s0 = srv.acquire("w0", now=0.0)
    srv.commit("w0", s0, now=0.1)
    s1 = srv.acquire("w0", now=0.2)
    srv.issue_backups(now=50.0)
    assert srv.acquire("w1", now=50.0) == s1  # backup lease on same shard
    # the backup worker dies; the original lease survives -> no reissue
    assert srv.fail_worker("w1") == 1
    assert srv.stats.reissued == 0
    assert srv.commit("w0", s1, now=51.0)
    assert srv.done()


# -------------------------------------------------------------- stats tier
def test_fault_stats_as_metrics_flat_numeric():
    srv = ShardServer(1, lease_timeout=1.0)
    srv.acquire("w0", now=0.0)
    srv.reap(now=3.0)
    m = srv.stats.as_metrics()
    assert m["reissued"] == 1 and m["leases_reaped"] == 1
    assert "reap_latency_mean" in m  # derived property harvested
    assert all(isinstance(v, (int, float)) for v in m.values())
    assert isinstance(FaultStats().summary(), str)


def test_record_retry_and_respawn_counters():
    srv = ShardServer(1)
    srv.record_retry()
    srv.record_retry()
    srv.record_respawn()
    assert srv.stats.retries == 2 and srv.stats.respawned == 1


# --------------------------------------------------------- straggler policy
def test_straggler_policy_window_is_bounded():
    p = StragglerPolicy(factor=3.0, min_samples=5, window=64)
    for d in np.random.default_rng(0).uniform(0.1, 2.0, 1000):
        p.record(float(d))
    assert p.n_samples == 64  # rolling window, not full history


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_straggler_policy_p50_matches_numpy_median(seed):
    """The incrementally maintained p50 must equal np.median of the
    window contents after every record (insert + evict correctness)."""
    rng = np.random.default_rng(seed)
    p = StragglerPolicy(factor=3.0, min_samples=1, window=16)
    window = []
    for d in rng.uniform(0.0, 10.0, 200):
        p.record(float(d))
        window.append(float(d))
        window = window[-16:]
        assert p.p50 == pytest.approx(float(np.median(window)))


def test_straggler_policy_should_backup_threshold():
    p = StragglerPolicy(factor=3.0, min_samples=3)
    for d in (1.0, 1.1, 0.9):
        p.record(d)
    assert not p.should_backup(2.0)
    assert p.should_backup(3.5)
    # below min_samples: never trigger
    q = StragglerPolicy(factor=3.0, min_samples=5)
    q.record(0.001)
    assert not q.should_backup(1e9)


def test_straggler_policy_validation():
    with pytest.raises(ValueError):
        StragglerPolicy(factor=0.0)
    with pytest.raises(ValueError):
        StragglerPolicy(min_samples=0)
    with pytest.raises(ValueError):
        StragglerPolicy(min_samples=10, window=5)


# ------------------------------------------------------------ elastic remesh
def test_elastic_remesh_two_axis_and_pods():
    shape, axes, used = elastic_remesh(8, model_parallel=1, pod_size=4)
    assert shape == (2, 4, 1) and axes == ("pod", "data", "model")
    assert used == 8
    shape, axes, used = elastic_remesh(4, model_parallel=1, pod_size=4)
    # one pod's worth is not enough for a pod axis -> flat (data, model)
    assert shape == (4, 1) and axes == ("data", "model") and used == 4


def test_shard_server_validation():
    with pytest.raises(ValueError):
        ShardServer(-1)
    with pytest.raises(ValueError):
        ShardServer(1, lease_timeout=0.0)
    srv = ShardServer(0)
    assert srv.done() and srv.acquire("w") is None
    # out-of-range commit is rejected, not crashed
    srv2 = ShardServer(2)
    assert not srv2.commit("w", 99)
    assert srv2.stats.commits_rejected == 1
