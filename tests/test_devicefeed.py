"""Device-feed stage: arena sizing from OutputLayout, double-buffer rewinds,
bitwise staging, runner integration, and runner equivalence (property)."""

import numpy as np
import pytest
from conftest import pipeline_threads_gone, recording_step

from repro.core import (
    ALIGN,
    DeviceFeeder,
    FeedError,
    PipelinedRunner,
    align_up,
)
from repro.fe import featureplan, get_spec, list_specs
from repro.fe.datagen import gen_views

PRESETS = list_specs()


# ------------------------------------------------------- arena sizing (layout)
@pytest.mark.parametrize("name", PRESETS)
def test_feed_layout_matches_output_layout(name):
    plan = featureplan.compile(get_spec(name))
    lay, fl = plan.layout, plan.feed_layout()
    widths = {s.name: s.width for s in fl.slots}
    assert set(widths) == set(plan.output_slots)
    assert widths["batch_label"] == 1
    if "batch_dense" in widths:
        assert widths["batch_dense"] == lay.n_dense_feats
    else:
        assert lay.n_dense_feats == 0
    assert widths["batch_sparse"] == lay.n_sparse_fields
    assert widths["batch_seq_ids"] == lay.seq_len
    assert widths["batch_seq_mask"] == lay.seq_len

    rows = 96
    # arena capacity == aligned sum of the layout's slot sizes
    expect = align_up(sum(align_up(s.nbytes(rows), ALIGN) for s in fl.slots),
                      ALIGN)
    assert fl.arena_bytes(rows) == expect
    feeder = DeviceFeeder(fl, rows_hint=rows)
    assert feeder.stats.arena_capacity == expect
    assert feeder.pool.capacity == expect


@pytest.mark.parametrize("name", PRESETS)
def test_placement_plan_oracle_agreement(name):
    """jnp prefix-sum plan == Pallas allocator kernel == ArenaPool bump."""
    fl = featureplan.compile(get_spec(name)).feed_layout()
    rows = 64
    off_jnp, total_jnp = fl.plan(rows)
    off_k, total_k = fl.plan(rows, use_kernel=True)
    np.testing.assert_array_equal(off_jnp, off_k)
    assert total_jnp == total_k == fl.arena_bytes(rows)

    feeder = DeviceFeeder(fl, rows_hint=rows)
    feeder.stage(featureplan.compile(get_spec(name)).run(
        gen_views(rows, seed=2)))
    np.testing.assert_array_equal(
        [a.offset for a in feeder.last_allocs], off_jnp)


def test_plan_rejects_int32_overflow():
    """Both oracle paths must refuse what ArenaPool's int64 bookkeeping
    would accept but the kernel's int32 offsets would silently wrap."""
    from repro.core.devicefeed import FeedLayout, SlotSpec
    from repro.kernels.mempool_alloc.ops import plan_block

    with pytest.raises(OverflowError, match="int32"):
        plan_block([2**31], align=ALIGN)
    with pytest.raises(OverflowError, match="int32"):  # per-size ok, sum not
        plan_block([2**30, 2**30, 2**30], align=ALIGN)
    with pytest.raises(ValueError, match="negative"):
        plan_block([4, -1], align=ALIGN)

    fat = FeedLayout(slots=(SlotSpec("batch_huge", width=2**29,
                                     dtype="float32"),))
    with pytest.raises(OverflowError, match="int32"):
        fat.plan(2)                    # jnp prefix-sum path
    with pytest.raises(OverflowError, match="int32"):
        fat.plan(2, use_kernel=True)   # Pallas kernel path


def test_split_sparse_fields_layout_preserves_bytes():
    """Per-field staging (one rank-1 id vector per sparse field) keeps the
    total staged bytes identical to the packed batch_sparse layout."""
    plan = featureplan.compile(get_spec("dlrm"))
    packed = plan.feed_layout()
    split = plan.feed_layout(split_sparse_fields=True)
    n_fields = plan.layout.n_sparse_fields
    fields = [s for s in split.slots if s.name.startswith("batch_field_")]
    assert len(fields) == n_fields == 26
    assert all(s.width == 1 and s.rank1 and s.dtype == "int32"
               for s in fields)
    assert "batch_sparse" not in split.slot_names
    rows = 128
    assert split.bytes_per_batch(rows) == packed.bytes_per_batch(rows)

    # staging the split form is bitwise the packed columns
    env = plan.run(gen_views(rows, seed=11))
    sparse = np.asarray(env["batch_sparse"])
    host = {k: v for k, v in env.items() if k != "batch_sparse"}
    for f in range(n_fields):
        host[f"batch_field_{f:02d}"] = np.ascontiguousarray(sparse[:, f])
    feeder = DeviceFeeder(split, rows_hint=rows)
    staged = feeder.stage(host)
    for f in range(n_fields):
        np.testing.assert_array_equal(
            np.asarray(staged[f"batch_field_{f:02d}"]), sparse[:, f])
    assert feeder.stats.bytes_staged == packed.bytes_per_batch(rows)


# ----------------------------------------------------- double-buffered rewind
def test_double_buffer_rewind_reuses_offsets_bitwise():
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=32, buffers=2)
    host_ids = [id(h) for h in feeder._host]
    offsets = []
    for i in range(4):
        feeder.stage(plan.run(gen_views(32, seed=10 + i)))
        offsets.append([a.offset for a in feeder.last_allocs])
    assert offsets[0] == offsets[1] == offsets[2] == offsets[3]
    assert feeder.pool.n_resets == 4 == feeder.stats.rewinds
    assert feeder.stats.reallocs == 0
    assert [id(h) for h in feeder._host] == host_ids  # O(1) rewind, no
    assert feeder.stats.batches == 4                  # fresh buffers
    assert feeder.stats.bytes_staged == 4 * plan.feed_layout().bytes_per_batch(32)


def test_feeder_grows_arena_for_oversized_batch():
    plan = featureplan.compile(get_spec("bst"))
    fl = plan.feed_layout()
    feeder = DeviceFeeder(fl, rows_hint=16)
    small = feeder.stats.arena_capacity
    feeder.stage(plan.run(gen_views(16, seed=4)))
    env = plan.run(gen_views(64, seed=5))
    staged = feeder.stage(env)
    assert feeder.stats.reallocs == 1
    assert feeder.stats.arena_capacity == fl.arena_bytes(64) > small
    # rewind accounting survives the pool replacement (accumulates)
    assert feeder.stats.rewinds == 2
    for k in plan.output_slots:
        np.testing.assert_array_equal(np.asarray(staged[k]),
                                      np.asarray(env[k]))


# --------------------------------------------------------- bitwise staging
@pytest.mark.parametrize("name", PRESETS)
def test_staged_slots_bit_identical(name):
    plan = featureplan.compile(get_spec(name))
    feeder = DeviceFeeder(plan.feed_layout())
    env = plan.run(gen_views(48, seed=3))
    staged = feeder.stage(env)
    for k in plan.output_slots:
        a, b = np.asarray(env[k]), np.asarray(staged[k])
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    # non-layout slots pass through untouched
    for k in env:
        if k not in plan.output_slots:
            assert staged[k] is env[k]


def test_arena_reuse_never_corrupts_staged_batches():
    """Regression: with buffers=1 every stage() rewrites the same host
    buffer, so a staged array that aliased the arena would show up as
    earlier batches mutating. Reuse must wait for transfer completion and
    transfer sources must never point into the arena — even while the
    consumer keeps every batch alive."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=32, buffers=1)
    host_ids = [id(h) for h in feeder._host]
    staged, snapshots = [], []
    for i in range(3):
        out = feeder.stage(plan.run(gen_views(32, seed=30 + i)))
        kept = {k: out[k] for k in plan.output_slots}
        staged.append(kept)
        snapshots.append({k: np.array(np.asarray(v), copy=True)
                          for k, v in kept.items()})
    for kept, snap in zip(staged, snapshots):
        for k in snap:
            np.testing.assert_array_equal(np.asarray(kept[k]), snap[k])
    # reuse-in-place even under consumer pressure: allocate-once preserved
    assert [id(h) for h in feeder._host] == host_ids


@pytest.mark.parametrize("name", PRESETS)
def test_staged_arrays_never_alias_arena(name):
    """Regression for the async-dispatch corruption: jax's zero-copy
    device_put would hand back arrays whose storage IS the arena bytes,
    which the ring later rewrites while a transfer or train step may still
    be reading them. Every staged array must live outside the host ring."""
    plan = featureplan.compile(get_spec(name))
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=24)
    staged = feeder.stage(plan.run(gen_views(24, seed=9)))
    ranges = [(h.__array_interface__["data"][0], h.nbytes)
              for h in feeder._host]
    for k in plan.output_slots:
        dev = staged[k]
        try:
            ptr = int(dev.unsafe_buffer_pointer())
        except Exception:
            pytest.skip("backend does not expose buffer pointers")
        assert not any(base <= ptr < base + n for base, n in ranges), \
            f"slot {k} aliases the staging arena"


def test_reuse_gate_holds_transfers_until_claim_or_flush():
    """Regression for the weakref liveness gate: the consumer dropping its
    batch references must NOT release the ring — transfers are tracked by
    strong refs until awaited, so flush() can always account for them."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=16, buffers=2)
    feeder.stage(plan.run(gen_views(16, seed=1)))  # output dropped at once
    assert any(feeder._inflight)  # still gated despite dead consumer refs
    feeder.flush()
    assert not any(feeder._inflight) and not feeder._orphans
    # a regrow orphans in-flight work instead of forgetting it
    feeder.stage(plan.run(gen_views(16, seed=2)))
    feeder.stage(plan.run(gen_views(64, seed=3)))
    assert feeder.stats.reallocs == 1
    assert feeder._orphans  # pre-regrow transfers still awaitable
    feeder.flush()
    assert not feeder._orphans


def test_host_buffers_are_layout_aligned():
    """Forced base alignment is what makes the zero-copy probe decisive."""
    plan = featureplan.compile(get_spec("dlrm"))
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=32)
    for h in feeder._host:
        assert h.__array_interface__["data"][0] % feeder.layout.align == 0


def test_stage_rejects_layout_violations():
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout())
    env = plan.run(gen_views(16, seed=0))
    bad = dict(env)
    bad["batch_sparse"] = np.asarray(env["batch_sparse"])[:, :-1]
    with pytest.raises(FeedError, match="shape"):
        feeder.stage(bad)
    bad = dict(env)
    bad["batch_dense"] = np.asarray(env["batch_dense"]).astype(np.float64)
    with pytest.raises(FeedError, match="dtype"):
        feeder.stage(bad)
    with pytest.raises(FeedError, match="missing"):
        feeder.stage({"impressions": None})


# ------------------------------------------------------- runner integration


def test_runner_with_feed_matches_no_feed_bitwise():
    plan = featureplan.compile(get_spec("ads_ctr"))
    batches = [gen_views(40, seed=60 + i) for i in range(4)]

    seen_off, seen_on = [], []
    off = PipelinedRunner(plan.layers, recording_step(seen_off), prefetch=2)
    off.run({"batches": 0}, [dict(b) for b in batches])
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=40)
    on = PipelinedRunner(plan.layers, recording_step(seen_on), prefetch=2,
                         device_feed=feeder)
    on.run({"batches": 0}, [dict(b) for b in batches])

    assert len(seen_off) == len(seen_on) == 4
    for a, b in zip(seen_off, seen_on):
        assert set(a) == set(b)
        for k in a:
            assert a[k].dtype == b[k].dtype
            np.testing.assert_array_equal(a[k], b[k])
    fs = on.stats.feed
    assert fs is feeder.stats
    assert fs.batches == 4
    assert fs.bytes_staged == 4 * plan.feed_layout().bytes_per_batch(40)
    assert off.stats.feed is None  # fallback keeps the two-stage shape


def test_fallback_none_is_bit_identical_to_direct_run():
    """device_feed=None must reproduce today's runner output exactly."""
    plan = featureplan.compile(get_spec("dlrm"))
    batches = [gen_views(24, seed=80 + i) for i in range(3)]
    expect = [plan.outputs(plan.run(dict(b))) for b in batches]

    seen = []
    runner = PipelinedRunner(plan.layers, recording_step(seen), prefetch=2)
    runner.run({"batches": 0}, [dict(b) for b in batches])
    assert len(seen) == 3
    for got, want in zip(seen, expect):
        for k in want:
            np.testing.assert_array_equal(got[k], np.asarray(want[k]))


def test_split_layout_stages_packed_fe_output_in_runner():
    """A split_sparse_fields feeder must work on unmodified FE output: the
    per-field columns are derived from the packed batch_sparse slot."""
    plan = featureplan.compile(get_spec("bst"))
    n_fields = plan.layout.n_sparse_fields
    feeder = DeviceFeeder(plan.feed_layout(split_sparse_fields=True),
                          rows_hint=24)
    seen = []
    runner = PipelinedRunner(plan.layers, recording_step(seen), prefetch=2,
                             device_feed=feeder)
    batches = [gen_views(24, seed=70 + i) for i in range(2)]
    runner.run({"batches": 0}, [dict(b) for b in batches])
    assert len(seen) == 2
    for env, raw in zip(seen, batches):
        packed = np.asarray(plan.run(dict(raw))["batch_sparse"])
        for f in range(n_fields):
            np.testing.assert_array_equal(env[f"batch_field_{f:02d}"],
                                          packed[:, f])


def test_feeder_propagates_worker_exceptions():
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout())
    runner = PipelinedRunner(plan.layers, lambda s, e: s, device_feed=feeder)

    def bad_batches():
        yield gen_views(16, seed=0)
        raise OSError("shard rot")

    with pytest.raises(OSError, match="shard rot"):
        runner.run({}, bad_batches())
    assert pipeline_threads_gone()


def test_feed_train_error_joins_both_workers():
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout(), buffers=2)

    def bad_step(state, env):
        raise ValueError("train blew up")

    runner = PipelinedRunner(plan.layers, bad_step, prefetch=1,
                             device_feed=feeder)
    with pytest.raises(ValueError, match="train blew up"):
        runner.run({}, [gen_views(16, seed=i) for i in range(4)])
    assert pipeline_threads_gone()


# ------------------------------------------------- direct-to-arena staging
def test_claim_views_match_block_plan():
    """claim_views returns typed views laid out exactly where the Alg. 1
    block plan puts them, inside the claimed ring buffer."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    fl = plan.feed_layout()
    feeder = DeviceFeeder(fl, rows_hint=32)
    rows = 32
    claim = feeder.claim_views(rows)
    off_plan, _total = fl.plan(rows)
    base = feeder._host[claim.buffer_index].__array_interface__["data"][0]
    assert set(claim.views) == set(fl.slot_names)
    for spec, alloc, off in zip(fl.slots, claim.allocs, off_plan):
        view = claim.views[spec.name]
        assert view.dtype == np.dtype(spec.dtype)
        assert view.shape == ((rows,) if spec.rank1 else (rows, spec.width))
        assert alloc.offset == off
        vbase = view.__array_interface__["data"][0]
        assert vbase == base + alloc.offset  # view IS the arena bytes
        assert vbase % fl.align == 0


def test_stage_with_claim_elides_arena_resident_slots():
    """A producer that wrote its outputs into claimed views pays no
    env->arena memcpy: stage(env, claim=...) transfers in place."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    feeder = DeviceFeeder(plan.feed_layout(), rows_hint=24)
    env = plan.run(gen_views(24, seed=5))
    claim = feeder.claim_views(24)
    filled = dict(env)
    for name, view in claim.views.items():
        np.copyto(view, np.asarray(env[name]), casting="no")
        filled[name] = view
    staged = feeder.stage(filled, claim=claim)
    assert feeder.stats.copies_elided == len(plan.feed_layout().slots)
    for k in plan.output_slots:
        np.testing.assert_array_equal(np.asarray(staged[k]),
                                      np.asarray(env[k]))


@pytest.mark.parametrize("name", PRESETS)
@pytest.mark.parametrize("split", [False, True])
def test_arena_binding_stage_bit_identical_to_copy_path(name, split):
    """Zero-copy feed == copy path, bitwise: the binding assembles batch_*
    straight into the arena from the sans-final env."""
    from repro.core import run_layers

    plan = featureplan.compile(get_spec(name))
    ab = plan.arena_binding(split_sparse_fields=split)
    rows = 48
    views = gen_views(rows, seed=21)

    want = plan.run(dict(views))  # full layers: reference batch_* values
    copy_feeder = DeviceFeeder(plan.feed_layout(split_sparse_fields=split),
                               rows_hint=rows)
    want_staged = copy_feeder.stage(want)

    env = run_layers(ab.layers, dict(views))
    assert not any(k.startswith("batch_") for k in env)  # final op dropped
    feeder = ab.make_feeder(rows_hint=rows)
    staged = feeder.stage(env)
    assert feeder.stats.copies_elided == len(ab.layout.slots)
    assert copy_feeder.stats.copies_elided == 0
    assert feeder.stats.bytes_staged == copy_feeder.stats.bytes_staged
    for slot in ab.layout.slot_names:
        a, b = np.asarray(staged[slot]), np.asarray(want_staged[slot])
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_arena_binding_ring_rewind_stress_bitwise():
    """buffers=1 direct staging: every batch rewrites the same arena bytes
    through claimed views; earlier staged batches must stay intact."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    ab = plan.arena_binding()
    feeder = ab.make_feeder(rows_hint=16, buffers=1)
    from repro.core import run_layers

    staged, want = [], []
    for i in range(5):
        views = gen_views(16, seed=40 + i)
        want.append(plan.outputs(plan.run(dict(views))))
        staged.append(feeder.stage(run_layers(ab.layers, dict(views))))
    assert feeder.stats.rewinds == 5
    assert feeder.stats.copies_elided == 5 * len(ab.layout.slots)
    for got, exp in zip(staged, want):
        for k in exp:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(exp[k]))


def test_arena_binding_regrow_orphans_preclaim_transfers():
    """A claim taken before a regrow must not file its transfers under the
    fresh ring (indices point at new buffers): they become orphans that
    flush() still awaits."""
    plan = featureplan.compile(get_spec("ads_ctr"))
    ab = plan.arena_binding()
    feeder = ab.make_feeder(rows_hint=16)
    from repro.core import run_layers

    env_small = run_layers(ab.layers, dict(gen_views(16, seed=1)))
    claim = feeder.claim_views(16)  # filled only after the regrow below
    feeder.stage(run_layers(ab.layers, dict(gen_views(64, seed=2))))  # regrow
    assert feeder.stats.reallocs == 1
    ab.binding.write(env_small, claim.views)
    staged = feeder.stage({**env_small, **claim.views}, claim=claim)
    assert feeder._orphans  # pre-regrow transfers tracked as orphans
    feeder.flush()
    assert not feeder._orphans
    want = plan.outputs(plan.run(dict(gen_views(16, seed=1))))
    for k in want:
        np.testing.assert_array_equal(np.asarray(staged[k]),
                                      np.asarray(want[k]))


@pytest.mark.parametrize("split", [False, True])
def test_arena_binding_rejects_shape_violations(split):
    """The zero-copy path must FeedError on wrong-rowed slots like the
    copy path does — np.copyto would otherwise silently broadcast a bad
    producer slot across the whole arena view."""
    from repro.core import run_layers

    plan = featureplan.compile(get_spec("ads_ctr"))
    ab = plan.arena_binding(split_sparse_fields=split)
    env = run_layers(ab.layers, dict(gen_views(16, seed=8)))

    for slot, sliced in (
        ("sparse_ids", lambda a: a[:1]),       # would broadcast rows
        ("dense_feats", lambda a: a[:, :-1]),  # would shrink the concat
        ("interest_ids", lambda a: a[:1]),
    ):
        bad = dict(env)
        bad[slot] = sliced(np.asarray(env[slot]))
        feeder = ab.make_feeder(rows_hint=16)
        with pytest.raises(FeedError, match="shape"):
            feeder.stage(bad)


def test_runner_from_plan_arena_matches_off_bitwise():
    plan = featureplan.compile(get_spec("bst"))
    batches = [gen_views(24, seed=90 + i) for i in range(4)]
    results = {}
    for feed in ("off", "stage", "arena"):
        seen = []
        runner = PipelinedRunner.from_plan(plan, recording_step(seen),
                                           feed=feed, rows_hint=24, buffers=2)
        runner.run({"batches": 0}, [dict(b) for b in batches])
        results[feed] = (seen, runner.stats.feed)
    base, _ = results["off"]
    assert len(base) == 4
    for feed in ("stage", "arena"):
        seen, fs = results[feed]
        assert len(seen) == 4
        for a, b in zip(base, seen):
            assert set(a) == set(b)
            for k in a:
                assert a[k].dtype == b[k].dtype
                np.testing.assert_array_equal(a[k], b[k])
    assert results["arena"][1].copies_elided > 0
    assert results["stage"][1].copies_elided == 0


# ---------------------------------------------------- donation handshake
def _donating_consumer():
    """A jit that takes ownership of its staged inputs (buffer donation)
    and aliases every slot to an output, so the backend actually deletes
    the donated arrays (unusable donations are passed through alive)."""
    import warnings

    import jax

    jitted = jax.jit(lambda b: {k: v + 1 for k, v in b.items()},
                     donate_argnums=(0,))

    def consume(env, slots):
        staged = {k: env[k] for k in slots}
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            return jitted(staged)

    return consume


def test_donated_staged_arrays_reclaim_via_fence():
    from repro.core.devicefeed import FeedLayout, SlotSpec
    layout = FeedLayout(slots=(SlotSpec("batch_label", 1, "float32",
                                        rank1=True),
                               SlotSpec("batch_sparse", 4, "int32")))
    feeder = DeviceFeeder(layout, rows_hint=8, buffers=2)
    env = {"batch_label": np.ones(8, np.float32),
           "batch_sparse": np.arange(32, dtype=np.int32).reshape(8, 4)}
    consume = _donating_consumer()

    out = feeder.stage(dict(env))
    staged = [out[s] for s in layout.slot_names]
    res = consume(out, layout.slot_names)
    assert all(d.is_deleted() for d in staged), "consumer did not donate"
    feeder.donation_fence(res["batch_label"])

    # cycle the 2-slot ring: reclaiming the donated buffer must not raise
    # on the deleted arrays, and must account them
    feeder.stage(dict(env))
    out3 = feeder.stage(dict(env))
    assert feeder.stats.donated == len(layout.slots)
    # the ring still stages bit-identical batches afterwards
    np.testing.assert_array_equal(np.asarray(out3["batch_sparse"]),
                                  env["batch_sparse"])
    feeder.flush()


def test_flush_tolerates_donated_arrays():
    from repro.core.devicefeed import FeedLayout, SlotSpec
    layout = FeedLayout(slots=(SlotSpec("batch_label", 1, "float32",
                                        rank1=True),))
    feeder = DeviceFeeder(layout, rows_hint=4, buffers=2)
    out = feeder.stage({"batch_label": np.ones(4, np.float32)})
    res = _donating_consumer()(out, layout.slot_names)
    feeder.donation_fence(res["batch_label"])
    feeder.flush()  # must not raise on the deleted staged array
    assert feeder.stats.donated == 1


def test_donation_gate_waits_for_the_consuming_steps_fence():
    """Donation deletes staged arrays at consumer *dispatch* — possibly
    before that step's fence is registered. Reclaiming the buffer must
    wait for the fence of the step that consumed it (sequence wait), not
    settle for a stale earlier fence."""
    import threading
    import time

    from repro.core.devicefeed import FeedLayout, SlotSpec
    layout = FeedLayout(slots=(SlotSpec("batch_label", 1, "float32",
                                        rank1=True),))
    feeder = DeviceFeeder(layout, rows_hint=4, buffers=1)
    consume = _donating_consumer()
    env = {"batch_label": np.ones(4, np.float32)}

    out1 = feeder.stage(dict(env))                       # staged seq 1
    res1 = consume(out1, layout.slot_names)
    feeder.donation_fence(res1["batch_label"])           # consumed seq 1
    out2 = feeder.stage(dict(env))                       # staged seq 2
    res2 = consume(out2, layout.slot_names)              # donated, NO fence yet

    done = threading.Event()

    def reclaim():
        feeder.stage(dict(env))  # needs seq-2 fence before rewriting
        done.set()

    t = threading.Thread(target=reclaim, daemon=True)
    t.start()
    time.sleep(0.3)
    assert not done.is_set(), "gate reclaimed a donated buffer before the " \
                              "consuming step registered its fence"
    feeder.donation_fence(res2["batch_label"])           # consumed seq 2
    assert done.wait(5.0)
    t.join(5.0)
    assert feeder.stats.donated >= 2
    assert feeder.stats.stall_seconds >= 0.25
    feeder.flush()


def test_fence_is_optional_for_donated_arrays():
    # Without a registered fence the gate still cannot crash — it counts
    # the donated arrays and proceeds (the driver-side fence is the
    # belt-and-braces completion ordering, not a liveness requirement).
    from repro.core.devicefeed import FeedLayout, SlotSpec
    layout = FeedLayout(slots=(SlotSpec("batch_label", 1, "float32",
                                        rank1=True),))
    feeder = DeviceFeeder(layout, rows_hint=4, buffers=1)
    out = feeder.stage({"batch_label": np.ones(4, np.float32)})
    _donating_consumer()(out, layout.slot_names)
    feeder.stage({"batch_label": np.zeros(4, np.float32)})  # reclaims slot 0
    assert feeder.stats.donated == 1
    feeder.flush()


# The runner-equivalence property test (hypothesis) lives in
# tests/test_runner_equivalence.py — importorskip at module level would
# skip this whole file on hypothesis-free installs.
