"""Hierarchical parameter server: HBM <- host DRAM <- SSD (paper §II-B, [37]).

Three tiers, upper acting as a cache of lower:

* **SSD tier** — the full table as a file-backed ``np.memmap`` (the 10TB+
  production table that fits no single memory).
* **Host tier** — an LRU cache of recently-used rows in host DRAM.
* **Device tier** — the per-batch working set, pulled by ``pull()`` after
  dedup and pushed back by ``push()`` after the optimizer step.

This is deliberately a *host-side software* component: JAX sees only the
dense working-set array, so the training step stays jit/pjit-clean. The
pull/push boundary is exactly the paper's CPU<->GPU H2D/D2H seam.
"""

from __future__ import annotations

import collections
import dataclasses
import os
from typing import Dict, Optional, Tuple

import numpy as np

from repro.embedding.dedup import dedup_np
from repro.obs.metrics import harvest


@dataclasses.dataclass
class TierStats:
    host_hits: int = 0
    ssd_reads: int = 0
    pulls: int = 0
    pushes: int = 0
    pulled_rows: int = 0
    pushed_rows: int = 0
    evictions: int = 0

    @property
    def host_hit_rate(self) -> float:
        """Fraction of working-set row lookups served from host DRAM."""
        return self.host_hits / max(self.host_hits + self.ssd_reads, 1)

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)


class HierarchicalPS:
    """File-backed embedding table with a host LRU row cache."""

    def __init__(
        self,
        path: str,
        *,
        total_rows: int,
        dim: int,
        host_cache_rows: int = 100_000,
        init_scale: Optional[float] = None,
        seed: int = 0,
        create: bool = True,
    ) -> None:
        self.total_rows = total_rows
        self.dim = dim
        self.host_cache_rows = host_cache_rows
        self.path = path
        mode = "r+"
        if create and not os.path.exists(path):
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            mm = np.memmap(path, dtype=np.float32, mode="w+", shape=(total_rows, dim))
            scale = init_scale if init_scale is not None else 1.0 / np.sqrt(dim)
            rng = np.random.default_rng(seed)
            # chunked init so huge tables never materialize in RAM
            step = max(1, (1 << 24) // max(dim, 1))
            for s in range(0, total_rows, step):
                e = min(total_rows, s + step)
                mm[s:e] = rng.uniform(-scale, scale, (e - s, dim)).astype(np.float32)
            mm.flush()
            del mm
        self._ssd = np.memmap(path, dtype=np.float32, mode=mode, shape=(total_rows, dim))
        # host LRU: row id -> row array (most recently used last)
        self._host: "collections.OrderedDict[int, np.ndarray]" = collections.OrderedDict()
        self.stats = TierStats()

    # ------------------------------------------------------------------ pull
    def pull(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fetch the deduped working set for a batch.

        Returns (working_table f32[U, D], unique_ids int64[U], inverse int32[ids.shape]).
        The device trains against ``working_table``; ``inverse`` remaps batch
        slots into it (see ``embedding.dedup``).
        """
        unique, inverse = dedup_np(np.asarray(ids))
        out = np.empty((len(unique), self.dim), np.float32)
        miss_rows = []
        miss_pos = []
        for i, rid in enumerate(unique):
            rid = int(rid)
            row = self._host.get(rid)
            if row is not None:
                self._host.move_to_end(rid)
                out[i] = row
                self.stats.host_hits += 1
            else:
                miss_rows.append(rid)
                miss_pos.append(i)
        if miss_rows:
            # single vectorized SSD read for all misses
            rows = self._ssd[np.asarray(miss_rows)]
            self.stats.ssd_reads += len(miss_rows)
            for pos, rid, row in zip(miss_pos, miss_rows, rows):
                out[pos] = row
                self._cache_row(rid, row.copy())
        self.stats.pulls += 1
        self.stats.pulled_rows += len(unique)
        return out, unique, inverse

    # ------------------------------------------------------------------ push
    def push(self, unique_ids: np.ndarray, rows: np.ndarray) -> None:
        """Write updated working-set rows back (host cache + SSD write-through)."""
        ids = np.asarray(unique_ids)
        rows = np.asarray(rows, np.float32)
        self._ssd[ids] = rows
        for rid, row in zip(ids, rows):
            self._cache_row(int(rid), row.copy())
        self.stats.pushes += 1
        self.stats.pushed_rows += len(ids)

    def flush(self) -> None:
        self._ssd.flush()

    # ------------------------------------------------------------------ util
    def _cache_row(self, rid: int, row: np.ndarray) -> None:
        self._host[rid] = row
        self._host.move_to_end(rid)
        while len(self._host) > self.host_cache_rows:
            self._host.popitem(last=False)  # evict LRU
            self.stats.evictions += 1

    @property
    def host_cache_size(self) -> int:
        return len(self._host)
