"""Hierarchical parameter server: HBM <- host DRAM <- SSD (paper §II-B, [37]).

Three tiers, upper acting as a cache of lower:

* **SSD tier** — the full table as a file-backed ``np.memmap`` (the 10TB+
  production table that fits no single memory).
* **Host tier** — a cache of recently-used rows in host DRAM, evicted in
  approximate-LRU order (recency is stamped per *pull*, not per row — all
  rows touched by one pull share a stamp, so a whole working set ages out
  together). The tier is fully vectorized: one batched id->slot lookup, one
  fancy-indexed read from the slot buffer for hits, one fancy-indexed SSD
  gather for misses — no per-row Python loop on the pull path.
* **Device tier** — the per-batch working set, pulled by ``pull()`` after
  dedup and pushed back by ``push()`` after the optimizer step.

This is deliberately a *host-side software* component: JAX sees only the
dense working-set array, so the training step stays jit/pjit-clean. The
pull/push boundary is exactly the paper's CPU<->GPU H2D/D2H seam.

``HierarchicalPS`` is **not** thread-safe; concurrent pull/push callers
(e.g. :class:`repro.embedding.psfeed.HierarchyFeed`'s prefetch and
write-back threads) must serialize access with their own lock.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.embedding.dedup import dedup_np
from repro.obs.metrics import harvest
from repro.obs.trace import NULL_SPAN, get_tracer


@dataclasses.dataclass
class TierStats:
    host_hits: int = 0
    ssd_reads: int = 0
    pulls: int = 0
    pushes: int = 0
    pulled_rows: int = 0
    pushed_rows: int = 0
    evictions: int = 0

    @property
    def host_hit_rate(self) -> float:
        """Fraction of working-set row lookups served from host DRAM."""
        return self.host_hits / max(self.host_hits + self.ssd_reads, 1)

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)

    def summary(self) -> str:
        return (f"pulls={self.pulls} pushes={self.pushes} "
                f"rows={self.pulled_rows}/{self.pushed_rows} "
                f"host_hit_rate={self.host_hit_rate:.3f} "
                f"evictions={self.evictions}")


class HierarchicalPS:
    """File-backed embedding table with a vectorized host row cache.

    ``init_fn(start, stop, rng) -> f32[stop-start, dim]`` overrides the
    default uniform chunk initializer when creating a new table file (the
    driver uses it to colocate the Adagrad accumulator column).
    """

    def __init__(
        self,
        path: str,
        *,
        total_rows: int,
        dim: int,
        host_cache_rows: int = 100_000,
        init_scale: Optional[float] = None,
        seed: int = 0,
        create: bool = True,
        init_fn: Optional[Callable[[int, int, np.random.Generator],
                                   np.ndarray]] = None,
    ) -> None:
        self.total_rows = total_rows
        self.dim = dim
        self.host_cache_rows = host_cache_rows
        self.path = path
        expected_bytes = total_rows * dim * np.dtype(np.float32).itemsize
        if create and not os.path.exists(path):
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            mm = np.memmap(path, dtype=np.float32, mode="w+", shape=(total_rows, dim))
            scale = init_scale if init_scale is not None else 1.0 / np.sqrt(dim)
            rng = np.random.default_rng(seed)
            # chunked init so huge tables never materialize in RAM
            step = max(1, (1 << 24) // max(dim, 1))
            for s in range(0, total_rows, step):
                e = min(total_rows, s + step)
                if init_fn is not None:
                    mm[s:e] = np.asarray(init_fn(s, e, rng), np.float32)
                else:
                    mm[s:e] = rng.uniform(-scale, scale, (e - s, dim)).astype(np.float32)
            mm.flush()
            del mm
        else:
            # Opening an existing file: a stale or resized table would
            # silently read garbage rows through the memmap — reject any
            # size mismatch up front.
            actual_bytes = os.path.getsize(path)
            if actual_bytes != expected_bytes:
                raise ValueError(
                    f"PS table file {path!r} does not match shape "
                    f"({total_rows}, {dim}) f32: expected {expected_bytes} "
                    f"bytes, found {actual_bytes} bytes — stale or resized "
                    f"table file? Delete it or fix total_rows/dim")
        self._ssd = np.memmap(path, dtype=np.float32, mode="r+",
                              shape=(total_rows, dim))
        # Vectorized host tier: id -> slot map plus parallel slot arrays.
        # The dict is the only per-row structure left; row payloads move
        # through fancy-indexed numpy ops only.
        cap = max(host_cache_rows, 0)
        self._host_map: Dict[int, int] = {}
        self._host_ids = np.full((cap,), -1, np.int64)      # slot -> row id
        self._host_stamp = np.zeros((cap,), np.int64)       # slot -> last use
        self._host_buf: Optional[np.ndarray] = None         # (cap, dim) lazy
        self._free: List[int] = list(range(cap - 1, -1, -1))
        self._tick = 0
        self.stats = TierStats()

    # ------------------------------------------------------------------ pull
    def pull(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Fetch the deduped working set for a batch.

        Returns (working_table f32[U, D], unique_ids int64[U], inverse int32[ids.shape]).
        The device trains against ``working_table``; ``inverse`` remaps batch
        slots into it (see ``embedding.dedup``).
        """
        tracer = get_tracer()
        with (tracer.span("ps.pull") if tracer.enabled else NULL_SPAN):
            unique, inverse = dedup_np(np.asarray(ids))
            out = self.read_rows(unique)
            self.stats.pulls += 1
            self.stats.pulled_rows += len(unique)
        return out, unique, inverse

    def read_rows(self, unique: np.ndarray) -> np.ndarray:
        """Read-through fetch of already-unique row ids (f32[U, D]).

        One batched host-map lookup, one fancy-indexed hit gather from the
        host buffer, one fancy-indexed SSD gather for the misses (which are
        then cached).
        """
        unique = np.asarray(unique)
        n = len(unique)
        out = np.empty((n, self.dim), np.float32)
        if n == 0:
            return out
        if int(unique.max()) >= self.total_rows or int(unique.min()) < 0:
            raise ValueError(
                f"row ids out of range for table with {self.total_rows} "
                f"rows: min={unique.min()} max={unique.max()}")
        get = self._host_map.get
        slots = np.fromiter((get(int(r), -1) for r in unique),
                            np.int64, count=n)
        hit = slots >= 0
        n_hit = int(hit.sum())
        if n_hit:
            hit_slots = slots[hit]
            out[hit] = self._host_buf[hit_slots]
            self._host_stamp[hit_slots] = self._tick
            self.stats.host_hits += n_hit
        if n_hit < n:
            miss = ~hit
            miss_ids = unique[miss]
            rows = self._ssd[miss_ids]  # single fancy-indexed SSD gather
            out[miss] = rows
            self.stats.ssd_reads += n - n_hit
            self._cache_rows(miss_ids, rows)
        self._tick += 1
        return out

    # ------------------------------------------------------------------ push
    def push(self, unique_ids: np.ndarray, rows: np.ndarray) -> None:
        """Write updated working-set rows back (host cache + SSD write-through).

        ``unique_ids`` must be deduplicated (the pull path's ``unique``).
        """
        ids = np.asarray(unique_ids)
        rows = np.asarray(rows, np.float32)
        tracer = get_tracer()
        with (tracer.span("ps.push", rows=len(ids))
              if tracer.enabled else NULL_SPAN):
            self._ssd[ids] = rows
            self._cache_rows(ids, rows)
            self._tick += 1
            self.stats.pushes += 1
            self.stats.pushed_rows += len(ids)

    def flush(self) -> None:
        self._ssd.flush()

    # ------------------------------------------------------------------ util
    def _cache_rows(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Insert/update unique rows in the host tier (vectorized).

        Rows already resident are overwritten in place; new rows take free
        slots first, then evict the least-recently-stamped residents.
        """
        cap = self.host_cache_rows
        if cap <= 0:
            return
        k = len(ids)
        if k > cap:
            # A working set larger than the whole cache: only the tail
            # survives (matches LRU insert order — last inserted wins).
            self.stats.evictions += k - cap
            ids, rows = ids[-cap:], rows[-cap:]
            k = cap
        if self._host_buf is None:
            self._host_buf = np.empty((cap, self.dim), np.float32)
        get = self._host_map.get
        slots = np.fromiter((get(int(r), -1) for r in ids), np.int64, count=k)
        resident = slots >= 0
        if resident.any():
            res_slots = slots[resident]
            self._host_buf[res_slots] = rows[resident]
            self._host_stamp[res_slots] = self._tick
        n_new = k - int(resident.sum())
        if n_new == 0:
            return
        new_mask = ~resident
        take = min(n_new, len(self._free))
        new_slots = np.empty((n_new,), np.int64)
        if take:
            new_slots[:take] = self._free[-take:]
            del self._free[-take:]
        n_evict = n_new - take
        if n_evict:
            # All remaining slots are occupied: evict the n_evict oldest.
            cand = np.flatnonzero(self._host_ids >= 0)
            oldest = np.argpartition(self._host_stamp[cand], n_evict - 1)[:n_evict]
            evict_slots = cand[oldest]
            for rid in self._host_ids[evict_slots]:
                del self._host_map[int(rid)]
            self.stats.evictions += n_evict
            new_slots[take:] = evict_slots
        new_ids = ids[new_mask]
        self._host_ids[new_slots] = new_ids
        self._host_buf[new_slots] = rows[new_mask]
        self._host_stamp[new_slots] = self._tick
        for rid, slot in zip(new_ids, new_slots):
            self._host_map[int(rid)] = int(slot)

    @property
    def host_cache_size(self) -> int:
        return len(self._host_map)
