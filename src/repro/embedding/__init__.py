"""Embedding substrate: dedup working sets, sharded tables, hierarchical PS."""

from repro.embedding.dedup import (
    dedup,
    dedup_np,
    expected_unique,
    scatter_unique_grads,
    undedup,
)
from repro.embedding.hierarchy import HierarchicalPS, TierStats
from repro.embedding.table import (
    MultiTable,
    SparseAdagradState,
    TableSpec,
    bag_lookup_padded,
    bag_lookup_segment,
    init_sparse_adagrad,
    lookup,
    lookup_dedup,
    sparse_grad_update,
)

__all__ = [
    "HierarchicalPS",
    "MultiTable",
    "SparseAdagradState",
    "TableSpec",
    "TierStats",
    "bag_lookup_padded",
    "bag_lookup_segment",
    "dedup",
    "dedup_np",
    "expected_unique",
    "init_sparse_adagrad",
    "lookup",
    "lookup_dedup",
    "scatter_unique_grads",
    "sparse_grad_update",
    "undedup",
]
