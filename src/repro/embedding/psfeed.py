"""Working-set prefetch for the hierarchical PS (the streaming PS tier).

:class:`HierarchyFeed` wires :class:`~repro.embedding.hierarchy.
HierarchicalPS` into the pipelined runner as a fourth stage — *read+extract
-> PS pull -> H2D stage -> train* — so the dedup'd working-set ``pull()``
for batch i+1 overlaps batch i's train step, the same trick the
:class:`~repro.core.devicefeed.DeviceFeeder` plays for H2D transfers
(arXiv 2003.05622's pre-building of the working parameter set).

Consistency protocol (pull-ahead vs write-back)
-----------------------------------------------
``prepare(env)`` (prefetch thread) pulls batch *n*'s working set
*optimistically*, possibly before batch *n-1*'s updated rows were pushed
back. Before releasing the batch it (a) waits until every predecessor's
push has applied, then (b) re-reads exactly the rows that were pushed
after its pull snapshot (the intersection of its unique set with the
recently-pushed id sets). The expensive SSD gather therefore overlaps
training, while the released working set is always identical to a serial
pull-train-push execution — asserted bitwise in ``tests/test_hierarchy.py``.

``complete(meta, ws_rows, ws_accum)`` (train loop) hands the step's updated
rows to a write-back thread, which blocks on the device values (the jit is
async) and pushes them; ``drain()`` is the epoch-end handshake: wait for
every write-back, stop the writer, flush the SSD memmap.

Thread-shared state is annotated for the ``repro.check`` lockset audit
(this file is part of :data:`repro.check.lockset.DEFAULT_FILES`): the
:class:`HierarchicalPS` instance itself is not thread-safe, so *all* PS
access (pull, read_rows, push) happens under ``_cond``'s lock.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.check.annotations import guarded_by, shared_entry, single_writer
from repro.embedding.dedup import MAX_ID, dedup_np
from repro.obs.metrics import harvest

# Env slots prepare() attaches; ModelFeed.make_step(extra_slots=WS_SLOTS)
# forwards them verbatim into the train step's batch.
WS_SLOTS: Tuple[str, ...] = ("_ws_rows", "_ws_accum", "_ws_unique", "_ws_inverse")
# Companion slot holding the host-side PsBatchMeta (never enters the jit).
WS_META = "_ws_meta"

_STOP = object()


class HierarchyFeedError(RuntimeError):
    """The PS feed could not build or write back a working set."""


def collect_gids_np(cfg, sparse: np.ndarray,
                    seq: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
    """Host twin of :func:`repro.models.recsys.collect_gids`.

    Same site keys, layouts, and packed-offset arithmetic, in numpy int64
    (integer math, so values match the device path exactly); site shapes
    agree with :func:`repro.models.recsys.gid_site_shapes` by construction
    (asserted in ``tests/test_hierarchy.py``).
    """
    offsets = cfg.multi_table().offsets  # np.int64 per-field row offsets
    gids: Dict[str, np.ndarray] = {}
    if cfg.kind == "bst":
        if seq is None:
            raise HierarchyFeedError("bst batch is missing the seq block")
        seq_plus = np.concatenate(
            [seq, sparse[:, cfg.item_field][:, None]], axis=1)
        gids["seq"] = seq_plus.astype(np.int64) + int(offsets[cfg.item_field])
        other = np.delete(sparse, cfg.item_field, axis=1)
        other_offs = np.delete(offsets, cfg.item_field)
        gids["other"] = other.astype(np.int64) + other_offs[None, :]
    else:
        gids["sparse"] = sparse.astype(np.int64) + offsets[None, :]
    return gids


@dataclasses.dataclass
class PsFeedStats:
    """The PS-feed tier: where the pull/push seam's time went."""

    batches: int = 0          # working sets prepared
    pull_seconds: float = 0.0  # host time inside ps.pull (overlaps train)
    wait_seconds: float = 0.0  # prepare() blocked on predecessor write-backs
    fixups: int = 0            # batches that re-read rows pushed after pull
    fixup_rows: int = 0        # rows re-read by the consistency fixup
    push_seconds: float = 0.0  # write-back thread time inside ps.push
    completed: int = 0         # steps whose write-back was enqueued

    def as_metrics(self) -> Dict[str, float]:
        return harvest(self)


@guarded_by("_cond", "_applied", "_recent", "_error", "_closed", "stats")
@shared_entry("ps:prepare", "main:complete", "main:drain", "main:close")
@single_writer("_seq", "_drained")
class HierarchyFeed:
    """Pull-ahead / write-back engine between a :class:`HierarchicalPS`
    and the jitted hierarchy train step.

    Call it like a stage: ``env -> env + WS_SLOTS`` (the pipelined runner's
    ``ps_feed`` hook does exactly that on its prefetch thread).
    """

    def __init__(self, ps, model_feed, *, capacity: Optional[int] = None,
                 pad_accum: float = 0.1, max_pending: int = 2,
                 history: int = 16) -> None:
        cfg = model_feed.config
        self.ps = ps
        self.mf = model_feed
        self.cfg = cfg
        self.embed_dim = int(cfg.embed_dim)
        if ps.dim != self.embed_dim + 1:
            raise HierarchyFeedError(
                f"PS table dim {ps.dim} != embed_dim+1 ({self.embed_dim + 1}) "
                f"— the feed colocates the Adagrad accumulator as the last "
                f"column")
        self.capacity = int(capacity or cfg.dedup_capacity)
        if self.capacity <= 0:
            raise HierarchyFeedError(
                "working-set capacity is 0: tune cfg.dedup_capacity (e.g. "
                "via the loader rows hint) before building the feed")
        self.pad_accum = float(pad_accum)
        self.stats = PsFeedStats()
        self._seq = 0                      # prepare() calls issued (ps thread)
        self._cond = threading.Condition()
        self._applied = 0                  # write-backs applied, in step order
        self._recent: "collections.deque" = collections.deque(maxlen=history)
        self._error: Optional[BaseException] = None
        self._closed = False
        self._drained = False
        self._queue: "queue.Queue" = queue.Queue(maxsize=max(1, max_pending))
        self._writer = threading.Thread(
            target=self._writer_loop, daemon=True, name="ps-writer")
        self._writer.start()

    # ----------------------------------------------------------- tier views
    @property
    def tier(self):
        """The PS's :class:`~repro.embedding.hierarchy.TierStats`."""
        return self.ps.stats

    @property
    def pull_seconds(self) -> float:
        return self.stats.pull_seconds

    @property
    def wait_seconds(self) -> float:
        return self.stats.wait_seconds

    @property
    def host_hit_rate(self) -> float:
        return self.tier.host_hit_rate

    @property
    def evictions(self) -> int:
        return self.tier.evictions

    def as_metrics(self) -> Dict[str, float]:
        """Feed counters + the PS tier's stats, one flat dict (the ``ps``
        tier of :class:`repro.obs.MetricsRegistry`)."""
        out = self.tier.as_metrics()
        out.update(harvest(self.stats))
        return out

    def summary(self) -> str:
        s = self.stats
        return (f"{self.tier.summary()} pull={s.pull_seconds:.3f}s "
                f"wait={s.wait_seconds:.3f}s fixups={s.fixups} "
                f"({s.fixup_rows} rows) push={s.push_seconds:.3f}s")

    # -------------------------------------------------------------- prepare
    def __call__(self, env):
        return self.prepare(env)

    def prepare(self, env) -> Dict[str, Any]:
        """Pull batch ``env``'s working set; returns env + ``WS_SLOTS``.

        Runs on the runner's ps-feeder thread: the pull overlaps the
        previous batch's train step, then the consistency wait/fixup makes
        the released rows identical to a serial execution.
        """
        seq = self._seq
        self._seq += 1

        sparse, seq_ids = self.mf.model_ids_np(env)
        gids = collect_gids_np(self.cfg, sparse, seq_ids)
        flat = np.concatenate([gids[s].reshape(-1) for s in sorted(gids)])

        t0 = time.perf_counter()
        with self._cond:
            self._check_live()
            ver0 = self._applied
            rows, unique, inverse = self.ps.pull(flat)
            self.stats.pull_seconds += time.perf_counter() - t0
            self.stats.batches += 1
        n_unique = len(unique)
        if n_unique > self.capacity:
            raise HierarchyFeedError(
                f"working set overflow: {n_unique} unique ids > capacity "
                f"{self.capacity} — raise the rows hint / dedup_capacity")

        t1 = time.perf_counter()
        with self._cond:
            while self._applied < seq:
                self._check_live()
                self._cond.wait(timeout=0.2)
            self._check_live()
            self.stats.wait_seconds += time.perf_counter() - t1
            if ver0 < self._applied:
                # Rows pushed after our pull snapshot are stale in `rows`:
                # re-read exactly those (or everything, if the push history
                # no longer covers the snapshot).
                stale = self._pushed_since(ver0)
                if stale is None:
                    fresh_ids = unique
                    pos = np.arange(n_unique)
                else:
                    fresh_ids, pos, _ = np.intersect1d(
                        unique, stale, assume_unique=True,
                        return_indices=True)
                if len(fresh_ids):
                    rows[pos] = self.ps.read_rows(fresh_ids)
                    self.stats.fixups += 1
                    self.stats.fixup_rows += len(fresh_ids)

        out = dict(env)
        out.update(self._pack(rows, unique, inverse))
        out[WS_META] = (seq, unique)
        return out

    def _pushed_since(self, version: int) -> Optional[np.ndarray]:
        """Union of unique-id sets pushed at step index >= ``version``
        (sorted), or None when the bounded history no longer reaches back
        to ``version`` (caller must then re-read everything). Lock held."""
        if self._recent and self._recent[0][0] > version:
            return None  # history window slid past the snapshot
        sets = [ids for s, ids in self._recent if s >= version]
        if not sets:
            return np.empty((0,), np.int64)
        return np.unique(np.concatenate(sets))

    def _pack(self, rows: np.ndarray, unique: np.ndarray,
              inverse: np.ndarray) -> Dict[str, Any]:
        """FILL-pad the pulled working set to the static capacity and move
        it to device (async H2D on the prefetch thread)."""
        import jax

        cap, d = self.capacity, self.embed_dim
        n = len(unique)
        ws_rows = np.zeros((cap, d), np.float32)
        ws_rows[:n] = rows[:, :d]
        ws_accum = np.full((cap,), self.pad_accum, np.float32)
        ws_accum[:n] = rows[:, d]
        ws_unique = np.full((cap,), MAX_ID, np.int32)
        ws_unique[:n] = unique
        dev = jax.device_put(
            (ws_rows, ws_accum, ws_unique, inverse.astype(np.int32)))
        return dict(zip(WS_SLOTS, dev))

    # ------------------------------------------------------------- complete
    def complete(self, meta: Tuple[int, np.ndarray], ws_rows, ws_accum) -> None:
        """Enqueue step ``meta``'s updated rows for async write-back.

        ``ws_rows``/``ws_accum`` are the train step's device outputs; the
        write-back thread blocks on them (async dispatch) and pushes —
        training continues immediately.
        """
        with self._cond:
            self._check_live()
        seq, unique = meta
        self._queue.put((seq, unique, ws_rows, ws_accum))
        with self._cond:
            self.stats.completed += 1

    def _writer_loop(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is _STOP:
                    return
                seq, unique, ws_rows, ws_accum = item
                with self._cond:
                    if self._error is not None:
                        # A failed write-back poisons the feed; later rows
                        # must not land on top of a hole in step order.
                        continue
                try:
                    n = len(unique)
                    t0 = time.perf_counter()
                    rows = np.asarray(ws_rows)[:n]    # blocks on the device
                    accum = np.asarray(ws_accum)[:n]
                    payload = np.concatenate([rows, accum[:, None]], axis=1)
                    with self._cond:
                        self.ps.push(unique, payload)
                        self._applied = seq + 1
                        self._recent.append((seq, unique))
                        self.stats.push_seconds += time.perf_counter() - t0
                        self._cond.notify_all()
                except BaseException as e:
                    with self._cond:
                        self._error = e
                        self._cond.notify_all()
            finally:
                self._queue.task_done()

    # ---------------------------------------------------------------- drain
    def drain(self) -> PsFeedStats:
        """Epoch-end handshake: wait for every write-back, stop the writer,
        flush the SSD tier. Idempotent; does not raise — write-back errors
        surface through the next ``prepare``/``complete`` (or :attr:`error`)."""
        if not self._drained:
            self._drained = True
            self.close()
            self._queue.join()
            self._queue.put(_STOP)
            self._writer.join(timeout=30.0)
            self.ps.flush()
        return self.stats

    def close(self) -> None:
        """Unblock any prepare() waiting on a write-back that will never
        come (pipeline teardown). Idempotent, never raises — the runner
        calls this duck-typed from its ``finally``."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    @property
    def error(self) -> Optional[BaseException]:
        with self._cond:
            return self._error

    def _check_live(self) -> None:
        """Lock held: raise if the feed was poisoned or torn down."""
        if self._error is not None:
            raise HierarchyFeedError(
                f"hierarchical PS write-back failed: {self._error!r}"
            ) from self._error
        if self._closed:
            raise HierarchyFeedError("hierarchy feed closed during teardown")
