"""Sharded embedding tables + EmbeddingBag (the recsys hot path).

JAX has no native EmbeddingBag or CSR sparse; the lookup substrate is built
from ``jnp.take`` + ``jax.ops.segment_sum`` as first-class system code:

* :class:`TableSpec` / :class:`MultiTable` — many logical tables (one per
  sparse field) packed into ONE physical (sum(vocab), dim) array with field
  offsets. Packing keeps the pjit sharding rule trivial: rows sharded over
  the flattened ('data','model') mesh axes, dim replicated.
* ``lookup`` — one embedding row per (row, field) id: plain sharded gather.
* ``lookup_dedup`` — FeatureBox/[37] working-set path: dedup ids, gather the
  unique rows once (collective traffic ∝ unique count, not batch × fields),
  then expand on-device. This is the paper-faithful optimization measured in
  §Perf.
* ``bag_lookup`` — multi-hot bags (B, L) + weights -> (B, D) via the Pallas
  kernel over a working-set slice, or the segment_sum reference.
* ``sparse_grad_update`` — Adagrad on touched rows only (production CTR
  models update embeddings sparsely; dense updates of a 10TB table per step
  are impossible).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.embedding.dedup import dedup, scatter_unique_grads, undedup


@dataclasses.dataclass(frozen=True)
class TableSpec:
    """One logical embedding table (one sparse field)."""

    name: str
    vocab: int
    dim: int


@dataclasses.dataclass(frozen=True)
class MultiTable:
    """Several logical tables packed into one physical array."""

    specs: Tuple[TableSpec, ...]
    dim: int

    @staticmethod
    def build(specs: Sequence[TableSpec]) -> "MultiTable":
        dims = {s.dim for s in specs}
        if len(dims) != 1:
            raise ValueError(f"all tables must share dim, got {dims}")
        return MultiTable(specs=tuple(specs), dim=dims.pop())

    @property
    def offsets(self) -> np.ndarray:
        """Row offset of each field in the packed array."""
        sizes = np.array([s.vocab for s in self.specs], np.int64)
        return np.concatenate([[0], np.cumsum(sizes)[:-1]])

    @property
    def total_rows(self) -> int:
        return int(sum(s.vocab for s in self.specs))

    def init(self, key: jax.Array, *, dtype=jnp.float32, scale: Optional[float] = None) -> jax.Array:
        """Packed parameter array (V_total, D)."""
        scale = scale if scale is not None else 1.0 / np.sqrt(self.dim)
        return jax.random.uniform(
            key, (self.total_rows, self.dim), dtype=dtype, minval=-scale, maxval=scale
        )

    def global_ids(self, field_ids: jax.Array) -> jax.Array:
        """Per-field local ids (B, F) -> packed global row ids (B, F)."""
        offs = jnp.asarray(self.offsets, jnp.int32)
        return field_ids.astype(jnp.int32) + offs[None, :]

    def lookup_dedup(self, params: jax.Array, field_ids: jax.Array, *,
                     capacity: int) -> jax.Array:
        """Working-set lookup over per-field local ids: (B, F) -> (B, F, D).

        The packed-table form of :func:`lookup_dedup` — per-field ids are
        offset into the packed global row space, deduplicated ONCE across
        all fields (repeats across fields collapse too), gathered, and
        expanded. This is the embedding feed the per-field staged id
        vectors (``split_sparse_fields``) flow into via the compiled
        train-feed boundary (:mod:`repro.fe.modelfeed`).
        """
        return lookup_dedup(params, self.global_ids(field_ids),
                            capacity=capacity)


# ------------------------------------------------------------- row sharding
def row_sharding(mesh, *, axes: Tuple[str, ...] = ("pod", "data")):
    """NamedSharding that splits a packed table's rows over ``axes``.

    The packed (V_total, D) array shards on dim 0 across the flattened
    mesh axes, dim replicated — the trivial rule the packing buys us.
    Padded row counts (``RecsysConfig.row_align``) keep the split even.
    """
    from jax.sharding import NamedSharding, PartitionSpec as _P

    return NamedSharding(mesh, _P(axes, None))


def shard_bounds(total_rows: int, n_shards: int, shard_index: int
                 ) -> Tuple[int, int]:
    """[lo, hi) global row range owned by shard ``shard_index`` under the
    even row split of :func:`row_sharding`. ``total_rows`` must divide by
    ``n_shards`` (guaranteed when it is the row_align-padded count and the
    alignment covers the mesh size)."""
    if total_rows % n_shards:
        raise ValueError(
            f"{total_rows} rows do not shard evenly over {n_shards} devices "
            f"(raise RecsysConfig.row_align)")
    rows = total_rows // n_shards
    return shard_index * rows, (shard_index + 1) * rows


# ------------------------------------------------------------------ lookups
def lookup(params: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain embedding lookup: (..., ) ids -> (..., D) rows (sharded gather)."""
    return jnp.take(params, ids, axis=0)


def lookup_dedup(params: jax.Array, ids: jax.Array, *, capacity: int) -> jax.Array:
    """Working-set lookup: gather unique rows once, expand locally.

    With row-sharded ``params`` the cross-device traffic of the gather is
    proportional to ``capacity`` instead of ``ids.size`` — the measurable
    win of the paper's dedup insight (see EXPERIMENTS.md §Perf).
    """
    unique, inverse, _ = dedup(ids, capacity=capacity)
    safe = jnp.where(unique == jnp.int32(2**31 - 1), 0, unique)
    working = jnp.take(params, safe, axis=0)          # (capacity, D) gather
    return undedup(working, inverse)                   # local expand


def bag_lookup_segment(
    params: jax.Array, flat_ids: jax.Array, segment_ids: jax.Array, n_segments: int
) -> jax.Array:
    """Ragged EmbeddingBag: sum rows of each segment (take + segment_sum)."""
    rows = jnp.take(params, flat_ids, axis=0)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=n_segments)


def bag_lookup_padded(params: jax.Array, ids: jax.Array, mask: jax.Array) -> jax.Array:
    """Padded EmbeddingBag: (B, L) ids + (B, L) mask -> (B, D)."""
    rows = jnp.take(params, ids, axis=0)              # (B, L, D)
    return (rows * mask[..., None].astype(rows.dtype)).sum(axis=1)


# ----------------------------------------------------------- sparse updates
@dataclasses.dataclass
class SparseAdagradState:
    """Per-row accumulator for the embedding table (same shape rows x 1)."""

    accum: jax.Array  # f32[V_total]


def init_sparse_adagrad(total_rows: int, *, init: float = 0.1) -> SparseAdagradState:
    return SparseAdagradState(accum=jnp.full((total_rows,), init, jnp.float32))


def sparse_grad_update(
    params: jax.Array,
    state: SparseAdagradState,
    ids: jax.Array,
    grad_rows: jax.Array,
    *,
    capacity: int,
    lr: float = 0.01,
    eps: float = 1e-10,
) -> Tuple[jax.Array, SparseAdagradState]:
    """Adagrad update touching only the batch's unique rows.

    ``ids``: int[N] global row ids of the batch (may repeat);
    ``grad_rows``: f32[N, D] gradient of each referenced row instance.
    """
    unique, inverse, _ = dedup(ids, capacity=capacity)
    g = scatter_unique_grads(grad_rows, inverse, capacity)       # (cap, D)
    safe = jnp.where(unique == jnp.int32(2**31 - 1), 0, unique)
    valid = (unique != jnp.int32(2**31 - 1)).astype(jnp.float32)[:, None]
    g = g * valid
    gsq = jnp.sum(g * g, axis=-1)                                 # row norm^2
    accum_rows = jnp.take(state.accum, safe) + gsq
    scale = lr / (jnp.sqrt(accum_rows) + eps)
    new_rows = jnp.take(params, safe, axis=0) - scale[:, None] * g
    # Scatter by the raw unique ids with mode="drop": FILL (2**31-1) is out
    # of bounds for any real table, so padded slots write NOTHING. Routing
    # pads through index 0 instead (the old ``safe`` scatter) creates
    # duplicate writes to row 0 that can clobber its real update whenever
    # row 0 is in the batch alongside padding.
    params = params.at[unique].set(new_rows, mode="drop")
    accum = state.accum.at[unique].set(accum_rows, mode="drop")
    return params, SparseAdagradState(accum=accum)
