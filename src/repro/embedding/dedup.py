"""Per-batch working-set construction (dedup of sparse ids).

The hierarchical GPU parameter server's key observation ([37], §II-B): the
number of *referenced* parameters in a mini-batch fits device memory because
inputs are sparse. FeatureBox inherits this — before any table access, a
batch's ids are deduplicated and remapped to a dense local index space.

``dedup`` is jit-traceable (static working-set capacity); ``dedup_np`` is the
host twin used by the hierarchical PS pull path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

compat.install()  # jax.shard_map on older jax

# Sentinel for unused working-set slots (never a valid row id).
FILL = jnp.int32(2**31 - 1)

# Legal id range shared by the device and host dedup paths: ids must be in
# [0, 2**31 - 1). The upper bound is exclusive because FILL == 2**31 - 1 is
# the padding sentinel — an id equal to it would be indistinguishable from
# an unused slot.
MAX_ID = 2**31 - 1


def dedup(ids: jax.Array, *, capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Deduplicate a batch of sparse ids into a fixed-capacity working set.

    Args:
      ids: int[ ... ] arbitrary-shape batch of row ids. **Contract:** every
        id must be in ``[0, MAX_ID)`` (= ``[0, 2**31 - 1)``). This device
        path cannot check that inside the jit: ids >= 2**31 silently wrap
        negative under the ``astype(jnp.int32)`` cast, and an id equal to
        the ``FILL`` sentinel ``2**31 - 1`` would collide with the padding
        of unused working-set slots. Validate on the host before feeding
        (the host twin :func:`dedup_np` enforces the same bounds).
      capacity: static upper bound on unique ids (working-set size). Must be
        >= the true unique count; verify with ``count`` downstream.

    Returns:
      unique:  int32[capacity] unique ids, FILL-padded.
      inverse: int32[ids.shape] position of each id inside ``unique``.
      count:   int32[] true number of unique ids (<= capacity if valid).
    """
    flat = ids.reshape(-1).astype(jnp.int32)
    unique, inverse = jnp.unique(
        flat, return_inverse=True, size=capacity, fill_value=FILL
    )
    count = jnp.sum(unique != FILL).astype(jnp.int32)
    return unique, inverse.reshape(ids.shape).astype(jnp.int32), count


def expected_unique(rows: int, vocab: int) -> float:
    """E[#unique] of ``rows`` uniform draws from a ``vocab``-id space:
    ``v (1 - (1 - 1/v)^n)``. The sizing heuristic for working-set
    capacities (``dedup(..., capacity=...)``) when the worst case
    ``min(rows, vocab)`` is too loose — shared by the dry-run cells'
    ``cap_expected`` variant and the train driver's
    :func:`repro.fe.modelfeed.dedup_capacity_hint`."""
    if rows <= 0 or vocab <= 0:
        return 0.0
    return vocab * (1.0 - (1.0 - 1.0 / vocab) ** rows)


def dedup_np(ids: np.ndarray, *, check_bounds: bool = True
             ) -> Tuple[np.ndarray, np.ndarray]:
    """Host dedup (exact size): returns (unique ids, inverse).

    Enforces the id-range contract the device path (:func:`dedup`) can only
    document: ids must be in ``[0, 2**31 - 1)``. Out-of-range ids would wrap
    negative / collide with ``FILL`` on device, so they are rejected here,
    at the host boundary, with a clear error instead of silent corruption.
    """
    flat = ids.reshape(-1)
    if check_bounds and flat.size:
        lo = int(flat.min())
        hi = int(flat.max())
        if lo < 0 or hi >= MAX_ID:
            raise ValueError(
                f"sparse ids out of range: min={lo} max={hi}, legal range "
                f"is [0, {MAX_ID}) — ids >= 2**31 wrap negative under the "
                f"device path's int32 cast and {MAX_ID} collides with the "
                f"FILL padding sentinel")
    unique, inverse = np.unique(flat, return_inverse=True)
    return unique.astype(np.int64), inverse.reshape(ids.shape).astype(np.int32)


def undedup(rows: jax.Array, inverse: jax.Array) -> jax.Array:
    """Expand working-set rows back to per-slot rows: rows[inverse]."""
    return jnp.take(rows, inverse, axis=0)


def dedup_hierarchical(
    ids: jax.Array, *, capacity: int, mesh, axes, local_capacity: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Two-stage dedup: per-shard local unique, then global unique of the
    pooled local uniques.

    The global distributed sort inside a flat ``jnp.unique`` over B x F ids is
    the measured bound of the recsys train step (EXPERIMENTS.md §Perf pair 1);
    deduping locally first shrinks the globally-sorted pool to
    n_shards x local_capacity (< the raw id count whenever shards see repeated
    ids). Semantics match :func:`dedup` (same unique set, FILL-padded).

    ``ids`` must be sharded over ``axes`` on its leading dim.
    """
    from jax.sharding import PartitionSpec as P

    flat = ids.reshape(-1).astype(jnp.int32)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))

    def local(stage_ids):
        u, inv = jnp.unique(stage_ids.reshape(-1), return_inverse=True,
                            size=local_capacity, fill_value=FILL)
        return u[None], inv[None].astype(jnp.int32)

    local_u, local_inv = jax.shard_map(
        local, mesh=mesh,
        in_specs=P(axes),
        out_specs=(P(axes, None), P(axes, None)),
        check_vma=False,
    )(flat)                                   # (n_shards, cap_loc), (n_shards, B_loc)

    pool = local_u.reshape(-1)                # (n_shards * cap_loc,)
    unique, inv_pool = jnp.unique(pool, return_inverse=True,
                                  size=capacity, fill_value=FILL)
    inv_pool = inv_pool.reshape(n_shards, local_capacity)
    # compose: element e of shard s -> local_inv[s, e] -> inv_pool[s, .]
    final_inv = jnp.take_along_axis(inv_pool, local_inv.astype(jnp.int32),
                                    axis=1).reshape(ids.shape)
    count = jnp.sum(unique != FILL).astype(jnp.int32)
    return unique, final_inv.astype(jnp.int32), count


def dedup_two_stage_local(
    local_ids: jax.Array, *, capacity: int, local_capacity: int,
    gather_axes,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Two-stage dedup *from inside* shard_map (the mesh train step's form).

    :func:`dedup_hierarchical` wraps shard_map around an unsharded caller;
    the mesh train step is already device-local when it needs the working
    set, so this is the per-device body: local unique (stage 1, bounds the
    pooled sort to ``n_devices x local_capacity`` ids) -> all-gather of the
    FILL-padded local uniques over ``gather_axes`` -> global unique of the
    pool (replicated compute, stage 2). The local inverse is recovered by
    ``searchsorted`` against the sorted global unique array — identical to
    ``jnp.unique``'s inverse (position in the sorted uniques), so on a 1x1
    mesh the result is bitwise :func:`dedup`.

    Returns ``(unique, inverse, count, local_count)`` — ``unique``/``count``
    replicated, ``inverse`` for this device's ``local_ids``, ``local_count``
    this device's stage-1 unique count (the pooled-exchange size the comm
    stats report). ``local_capacity`` must bound this shard's true unique
    count or overflow drops the largest local ids (callers size it with
    :func:`repro.fe.modelfeed.dedup_capacity_hint` on the per-device rows).
    """
    flat = local_ids.reshape(-1).astype(jnp.int32)
    local_u = jnp.unique(flat, size=local_capacity, fill_value=FILL)
    pool = jax.lax.all_gather(local_u, gather_axes, axis=0, tiled=True)
    unique = jnp.unique(pool, size=capacity, fill_value=FILL)
    # every local id is present in `unique` (sorted), so searchsorted is
    # exactly jnp.unique's inverse for this device's slice of the batch
    inverse = jnp.searchsorted(unique, flat).astype(jnp.int32)
    count = jnp.sum(unique != FILL).astype(jnp.int32)
    local_count = jnp.sum(local_u != FILL).astype(jnp.int32)
    return unique, inverse.reshape(local_ids.shape), count, local_count


def scatter_unique_grads(
    grad_rows: jax.Array, inverse: jax.Array, capacity: int
) -> jax.Array:
    """Accumulate per-slot gradients onto the working set (transpose of undedup)."""
    flat = grad_rows.reshape(-1, grad_rows.shape[-1])
    seg = inverse.reshape(-1)
    return jax.ops.segment_sum(flat, seg, num_segments=capacity)
