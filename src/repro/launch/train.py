"""End-to-end training driver: ``--arch <id>`` selects any assigned config.

On this CPU container it runs the REDUCED (smoke) config of the chosen
architecture with synthetic data through the full production path: FeatureBox
FE pipeline (recsys archs), jitted train step, async checkpointing, restart.
On a real TPU cluster the same driver runs the full config by passing
``--full`` (the step functions and shardings are the dry-run-validated ones).

Two batch sources:

* default — in-memory ``synthetic_batch`` per step (no disk in the loop);
* ``--data-dir DIR`` (recsys only) — stream ``.fbshard`` raw-log shards
  through a compiled FeatureBox ``FeaturePlan`` with
  ``repro.io.StreamingLoader``: reader threads pull shards off disk
  (decoding only the plan's ``required_columns``), the FE worker extracts
  features for batch i+1 while the device trains on batch i. Pick the
  feature scenario with ``--spec ads_ctr|dlrm|bst``; regenerate shards
  with ``repro.fe.datagen.write_log_shards`` (see ``--gen-shards``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 10
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf \
      --data-dir /tmp/adslog --gen-shards 8 --steps 16 --spec dlrm
"""

from __future__ import annotations

import argparse
import itertools
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import adamw


def synthetic_batch(family: str, cfg, batch: int, step: int) -> Dict[str, Any]:
    rng = np.random.default_rng(step)
    if family == "lm":
        toks = rng.integers(0, cfg.vocab, (batch, 64)).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if family == "recsys":
        b = {
            "sparse": jnp.asarray(np.stack(
                [rng.integers(0, v, batch) for v in cfg.vocab_sizes[:cfg.n_sparse]],
                axis=1).astype(np.int32)),
            "label": jnp.asarray((rng.random(batch) < 0.25).astype(np.float32)),
        }
        if cfg.n_dense:
            b["dense"] = jnp.asarray(
                rng.exponential(1.0, (batch, cfg.n_dense)).astype(np.float32))
        if cfg.kind == "bst":
            b["seq"] = jnp.asarray(
                rng.integers(0, cfg.vocab_sizes[0], (batch, cfg.seq_len)).astype(np.int32))
        return b
    # gnn
    from repro.models.gnn import random_graph
    g = random_graph(200, 800, cfg.d_in, cfg.n_classes, seed=step)
    return {k: jnp.asarray(v) for k, v in g.items()}


# Reference oracle for the compiled boundary (kept importable under the old
# name; repro.fe.modelfeed.compile is the production path).
from repro.fe.modelfeed import fe_env_to_model_batch_ref as fe_env_to_model_batch  # noqa: E402,E501


def run_streaming(args, spec, cfg, state, opt, check_report=None) -> None:
    """Stream raw-log shards from disk through FE into the train step.

    The stage->train boundary is compiled: ``repro.fe.modelfeed`` derives
    the spec->arch adaptation from the plan's ``OutputLayout`` at compile
    time and traces it INSIDE the train step's jit (``--adapt fused``,
    default) — one fused dispatch per step, versus ~10 eager per-step jnp
    ops for the legacy adapter (``--adapt eager``, kept as the measurable
    baseline). The sparse working-set capacity is tuned from the dataset
    manifest's rows hint so the dedup'd embedding path runs by default,
    ``--device-feed arena`` stages per-field id vectors straight into the
    ring arena (``split_sparse_fields``), and the staged batch + params +
    optimizer state are donated through the jit (``--no-donate`` opts out)
    with the feeder's ``donation_fence`` accounting the reuse.
    """
    import dataclasses
    import os

    from repro.core import DeviceFeeder, PipelinedRunner
    from repro.embedding.psfeed import WS_META, WS_SLOTS, HierarchyFeed
    from repro.fe import featureplan, get_spec
    from repro.io.dataset import ShardDataset
    from repro.io.stream import StreamingLoader
    from repro.models import recsys as R

    if spec.family != "recsys":
        raise SystemExit(
            f"--data-dir streaming runs the FeatureBox FE pipeline and is "
            f"only wired for recsys archs (got family={spec.family!r})")

    if args.gen_shards:
        from repro.fe.datagen import write_log_shards
        paths = write_log_shards(args.data_dir, n_shards=args.gen_shards,
                                 rows_per_shard=args.batch, seed=0)
        print(f"wrote {len(paths)} shards to {args.data_dir}")

    ds = ShardDataset(args.data_dir, host_id=args.host_id,
                      n_hosts=args.n_hosts)
    if not len(ds):
        raise SystemExit(
            f"host {args.host_id}/{args.n_hosts} got no shards: the dataset "
            f"has only {len(ds.shards)} shard(s); generate more or use "
            f"fewer hosts")
    plan = featureplan.compile(get_spec(args.spec))
    print(plan.summary())
    epochs = -(-args.steps // len(ds))  # enough passes for --steps
    chaos = None
    if args.chaos:
        if not args.fault_tolerant:
            raise SystemExit(
                "--chaos injects faults into the lease-based reader pool "
                "and needs the ordered fault-tolerant yield contract: "
                "pass --fault-tolerant")
        from repro.io.chaos import ChaosInjector
        chaos = ChaosInjector.from_spec(args.chaos)
        print(f"chaos: {len(chaos.events)} scheduled fault(s) "
              f"({args.chaos})")
    # Projection pushdown: only the columns the spec touches are decoded.
    # Shards are leased from a ShardServer (reap/retry/backup recovery);
    # --fault-tolerant additionally re-sequences completions into plan
    # order so a run with failures yields bit-identical data to one
    # without.
    loader = StreamingLoader(ds, workers=args.stream_workers,
                             prefetch=args.stream_prefetch, epochs=epochs,
                             shuffle=True, seed=0,
                             columns=plan.required_columns,
                             lease_timeout=args.lease_timeout,
                             chaos=chaos, ordered=args.fault_tolerant)
    ckpt = (CheckpointManager(args.checkpoint_dir)
            if args.checkpoint_dir else None)

    # Compile the stage->train boundary: static field remap + vocab modulo
    # + block synthesis, working-set capacity sized from the manifest.
    # Without a manifest rows hint the capacity is left untuned (0): the
    # train step then falls back to its always-safe batch-sized bound —
    # streaming batches are SHARD-sized, so sizing from --batch could
    # silently undersize the working set and drop ids.
    split = args.device_feed == "arena"
    if cfg.dedup_capacity:
        cfg = dataclasses.replace(cfg, dedup_capacity=0)  # re-tune per data
    mf = plan.model_feed(cfg, split_sparse_fields=split,
                         rows_hint=loader.rows_hint)
    cfg = mf.config
    mesh = None
    n_pods = n_data = 1
    if args.mesh:
        from repro.launch.mesh import make_train_mesh, parse_mesh_spec
        if args.mesh == "auto":
            # Elastic topology: size the mesh to whatever devices are
            # healthy right now. With --resume this is the remesh path —
            # checkpoint under one device count, restart under another,
            # and the restored state is re-placed on the new mesh.
            from repro.train.fault import elastic_remesh
            n_healthy = len(jax.devices())
            shape, _axes, n_used = elastic_remesh(
                n_healthy, model_parallel=1, pod_size=args.pod_size)
            n_pods, n_data = ((shape[0], shape[1]) if len(shape) == 3
                              else (1, shape[0]))
            print(f"elastic mesh: {n_healthy} healthy device(s) -> "
                  f"{n_pods}x{n_data} ({n_used} used)")
        else:
            n_pods, n_data = parse_mesh_spec(args.mesh)
        n_mesh_dev = n_pods * n_data
        if n_mesh_dev > 1 and args.device_feed != "off":
            raise SystemExit(
                "--mesh with more than one device requires --device-feed "
                "off: the staging arena is single-device; the mesh jit "
                "splits the host batch across the row shards itself")
        mesh = make_train_mesh(n_pods, n_data)
    comm = None
    if args.embedding == "hierarchy":
        # Embedding rows come from the hierarchical PS (SSD <- host cache
        # <- per-batch working set), pulled a batch ahead on a dedicated
        # pipeline stage; the train step consumes them via WS_SLOTS.
        if not cfg.dedup_capacity:
            raise SystemExit(
                "--embedding hierarchy needs a tuned working-set capacity "
                "and the dataset manifest has no rows hint — regenerate the "
                "shards (repro.fe.datagen writes the manifest)")
        raw_step, _, _ = R.make_hierarchy_train_step(cfg, opt)
        extra_slots = WS_SLOTS
    elif mesh is not None:
        # Data-parallel scale-out: table rows + Adagrad accumulators
        # sharded over the ('pod', 'data') mesh, two-stage dedup, and
        # hierarchical (compressed across pods) gradient reduction. On a
        # 1x1 mesh with --compress off this path is bitwise-identical to
        # the single-device step (tests/test_mesh.py).
        from repro.fe.modelfeed import dedup_capacity_hint
        from repro.train.compression import CommPlan, CommStats
        local_cap = 0
        if n_mesh_dev > 1 and loader.rows_hint:
            # stage-1 capacity: sized like the global working set, but for
            # one device's share of the batch rows
            local_cap = dedup_capacity_hint(
                cfg, max(1, loader.rows_hint // n_mesh_dev))
        raw_step, mesh_init, _ = R.make_mesh_train_step(
            cfg, opt, mesh=mesh, compress=args.compress,
            local_dedup_capacity=local_cap)
        # Rebuild + place the train state per the sharding contract: the
        # generic init in _run lacks the codec's error-feedback residual
        # and the NamedSharding placements.
        state["opt"] = mesh_init(state["params"])
        state["params"], state["opt"] = R.shard_train_state(
            mesh, state["params"], state["opt"])
        rows_dev = max(1, (loader.rows_hint or args.batch) // n_mesh_dev)
        ids_dev = R.batch_id_count(cfg, rows_dev)
        comm = CommStats(plan=CommPlan.for_step(
            n_pods=n_pods, inner=n_data, compress=args.compress,
            hierarchical=True,
            capacity=cfg.dedup_capacity or ids_dev * n_mesh_dev,
            embed_dim=cfg.embed_dim,
            n_dense_elems=R.dense_param_elems(cfg),
            local_capacity=local_cap or ids_dev,
            ids_per_device=ids_dev))
        print(f"comm plan: {comm.summary()}")
        extra_slots = ()
    else:
        raw_step, _, _ = R.make_sparse_train_step(cfg, opt)
        extra_slots = ()

    # Restart-from-latest, possibly across a remesh: the checkpoint holds
    # host arrays (topology-free), so restoring into the *current* state
    # structure and re-placing with shard_train_state adapts it to
    # whatever mesh this run resolved (the elastic_remesh contract).
    start_step = 0
    if args.resume:
        if ckpt is None:
            raise SystemExit("--resume requires --checkpoint-dir")
        restored = ckpt.restore_latest(state)
        if restored is None:
            print("resume: no checkpoint found; starting fresh")
        else:
            step0, state = restored
            prev_mesh = ckpt.latest_meta().get("mesh")
            if mesh is not None:
                state["params"], state["opt"] = R.shard_train_state(
                    mesh, state["params"], state["opt"])
            start_step = step0 + 1
            print(f"resume: restored step {step0} "
                  f"(saved mesh {prev_mesh}, current [{n_pods}, {n_data}])")

    layers = plan.layers
    feeder = None
    if args.device_feed == "arena":
        # Zero-copy feed: FE assembles batch_* outputs straight into
        # claimed arena views (no env->arena memcpy; FeedStats counts the
        # elided copies) — per-field id vectors, so the sparse feed lands
        # in the shape the dedup'd embedding lookup consumes. Arena sized
        # up front from the dataset manifest.
        ab = plan.arena_binding(split_sparse_fields=True)
        layers, feeder = ab.layers, ab.make_feeder(rows_hint=loader.rows_hint)
    elif args.device_feed == "on":
        # Third pipeline stage: batch i+1 is staged through the buffer-ring
        # device arena while batch i trains. Arena sized up front from the
        # dataset manifest via the loader's rows hint.
        feeder = DeviceFeeder(plan.feed_layout(), rows_hint=loader.rows_hint)

    fused = mf.make_step(
        raw_step, fused=(args.adapt == "fused"), donate=not args.no_donate,
        fence_cb=(feeder.donation_fence if feeder is not None else None),
        extra_slots=extra_slots)

    hier = None
    if args.embedding == "hierarchy":
        from repro.embedding.hierarchy import HierarchicalPS
        mt = cfg.multi_table()
        total_rows = int(mt.total_rows)
        dim = cfg.embed_dim + 1  # Adagrad accumulator colocated (last col)
        ps_dir = args.ps_dir or os.path.join(args.data_dir, "_ps")
        ps_path = os.path.join(ps_dir, f"{args.arch}.{args.spec}.ps.f32")
        scale = 1.0 / float(np.sqrt(cfg.embed_dim))

        def ps_init(s, e, rng):
            block = np.empty((e - s, dim), np.float32)
            block[:, :-1] = rng.uniform(-scale, scale, (e - s, cfg.embed_dim))
            block[:, -1] = 0.1  # make_sparse_train_step's embed_accum init
            return block

        ps = HierarchicalPS(ps_path, total_rows=total_rows, dim=dim,
                            host_cache_rows=args.host_cache_rows,
                            init_fn=ps_init)
        hier = HierarchyFeed(ps, mf)
        table_mb = total_rows * dim * 4 / 2**20
        line = (f"ps: table {table_mb:.1f} MiB ({total_rows} rows x {dim} "
                f"f32), host cache {args.host_cache_rows} rows, "
                f"SSD tier {ps_path}")
        if args.device_budget_mb:
            rel = ("EXCEEDS" if table_mb > args.device_budget_mb
                   else "fits in")
            line += (f" — {rel} the simulated device budget "
                     f"{args.device_budget_mb:.1f} MiB")
        print(line)

    losses = []
    cost_args = []  # (params, opt, feed) ShapeDtypeStructs for --metrics
    from repro.obs.trace import get_tracer
    tracer = get_tracer()

    def step_fn(state, env):
        if args.metrics and not cost_args:
            # Shapes only (no data, no transfers): enough to lower the
            # boundary jit for HLO cost analysis after the run.
            from repro.launch.hlo_stats import abstractify
            feed = abstractify(fused.select_feed(env))
            if args.adapt == "eager":
                extras = {k: feed.pop(k) for k in extra_slots}
                feed = dict(jax.eval_shape(mf.apply, feed))
                feed.update(extras)
            p, o = abstractify((state["params"], state["opt"]))
            cost_args.append((p, o, feed))
        w0 = tracer.now_ns() if (tracer.enabled and comm is not None) else 0
        p, o, m = fused(state["params"], state["opt"], env)
        if hier is not None:
            # Async write-back: hand the updated working set to the PS
            # writer thread; the pull for batch i+2 waits on it, not us.
            hier.complete(env[WS_META], m.pop("ws_rows"), m.pop("ws_accum"))
        losses.append(float(m["loss"]))  # blocks until the step lands
        if comm is not None:
            comm.on_step()
            if tracer.enabled:
                # The collectives execute inside the fused XLA step, so
                # their spans cover the step window on dedicated virtual
                # tracks, annotated with the plan's modeled inter-pod
                # bytes (exchange = working set + dedup pool).
                w1 = tracer.now_ns()
                cp = comm.plan
                tracer.complete_on(
                    "comm.exchange", "comm.exchange", w0, w1,
                    interpod_bytes=(cp.exchange_interpod_bytes
                                    + cp.dedup_interpod_bytes))
                tracer.complete_on(
                    "comm.allreduce", "comm.allreduce", w0, w1,
                    interpod_bytes=cp.allreduce_interpod_bytes,
                    codec=cp.codec or "off")
        state = {"params": p, "opt": o}
        if ckpt is not None and len(losses) % args.checkpoint_every == 0:
            ckpt.save_async(start_step + len(losses) - 1, state,
                            meta={"mesh": [n_pods, n_data]})
        return state

    step_fn.feed_stats = mf.stats  # runners adopt the train-feed tier
    step_fn.comm_stats = comm      # runners adopt the comm tier (mesh only)

    runner = PipelinedRunner(layers, step_fn,
                             prefetch=args.stream_prefetch,
                             device_feed=feeder, ps_feed=hier)
    shard_iter = iter(loader)  # kept so the generator can be closed below
    t0 = time.perf_counter()
    try:
        runner.run(state, itertools.islice(shard_iter, args.steps))
    finally:
        # Close the generator explicitly (its finally finalizes the
        # loader's wall-clock stats) before stopping the reader pool —
        # islice abandonment alone leaves that to garbage collection.
        try:
            shard_iter.close()
        except ValueError:  # FE worker still holds it (join timed out)
            pass
        loader.close()
        if hier is not None:
            # Drain/flush handshake: every enqueued write-back lands on the
            # SSD tier before we read stats or exit (idempotent, no-raise).
            hier.drain()
        if ckpt is not None:
            ckpt.wait()
    # islice hides the loader from the runner's duck-typed stats capture
    runner.stats.ingest = loader.stats
    runner.stats.fault = loader.fault_stats
    dt = time.perf_counter() - t0
    s = runner.stats
    if not losses:
        raise SystemExit("streaming run consumed no batches")
    print(f"arch={args.arch} spec={args.spec} mode=streaming steps={s.batches} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({dt:.1f}s, {dt/max(s.batches,1)*1e3:.1f} ms/step; "
          f"fe={s.fe_seconds:.2f}s train={s.train_net_seconds:.2f}s "
          f"adapt={s.adapt_seconds:.3f}s wall={s.wall_seconds:.2f}s)")
    print(f"ingest: {loader.stats.summary()}")
    fs = loader.fault_stats
    if args.fault_tolerant or fs.reissued or fs.retries or fs.failed_workers:
        print(f"fault: {fs.summary()}")
    if chaos is not None:
        fired = {k: v for k, v in chaos.fired.items() if v}
        print(f"chaos: fired {fired or 'nothing'}"
              f"{'' if chaos.exhausted() else ' (schedule NOT exhausted)'}")
    if s.feed is not None:
        print(f"device-feed: {s.feed.summary()}")
    if s.train_feed is not None:
        print(f"train-feed: {s.train_feed.summary()} "
              f"(capacity={cfg.dedup_capacity})")
    if hier is not None:
        print(f"ps: {hier.summary()} ps_stage={s.ps_seconds:.2f}s")
    if comm is not None:
        print(f"comm: {comm.summary()}")
    if args.metrics:
        from repro.launch.hlo_stats import step_cost
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry.from_pipeline(s)
        if check_report is not None:
            reg.register("check", check_report)
        if cost_args:
            tot = step_cost(fused.jitted, *cost_args[0])
            reg.register("hlo", tot)
            _print_hlo_cost(tot)
        print("metrics:")
        print(reg.to_json())


def _print_hlo_cost(tot) -> None:
    """Roofline-style per-step summary from loop-aware HLO analysis."""
    print(f"hlo/step: {tot.flops/1e9:.3f} GFLOP "
          f"hbm={tot.bytes/2**20:.1f}MiB "
          f"(tpu-corrected {tot.bytes_tpu_corrected/2**20:.1f}MiB) "
          f"collective={tot.collective_total/2**20:.1f}MiB "
          f"intensity={tot.flops/max(tot.bytes, 1.0):.2f} flop/byte")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from "
                         "--checkpoint-dir before training (streaming "
                         "mode); with --mesh auto the restored host arrays "
                         "are re-placed on the mesh the current device "
                         "count resolves to — the elastic remesh-resume "
                         "path")
    # fault tolerance (repro.train.fault + repro.io.chaos)
    ap.add_argument("--fault-tolerant", action="store_true",
                    help="ordered fault-tolerant streaming: yield shards "
                         "in plan order through a reorder buffer so a run "
                         "with worker failures is bit-identical to one "
                         "without, and print the fault.* recovery summary "
                         "(lease scheduling itself is always on)")
    ap.add_argument("--lease-timeout", type=float, default=30.0,
                    help="seconds without a heartbeat before the reaper "
                         "returns a shard reader's lease to the queue")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="inject scheduled faults into the reader pool "
                         "(requires --fault-tolerant): comma-separated "
                         "kind@shard[:point][:arg] events, e.g. "
                         "'kill@3,transient@1:read:2,delay@2:read:0.05,"
                         "kill@5:commit' — see repro.io.chaos")
    ap.add_argument("--pod-size", type=int, default=None,
                    help="devices per pod for --mesh auto: lets "
                         "elastic_remesh pick a 3-axis (pod, data, model) "
                         "topology when enough devices are healthy")
    # streaming-ingest mode (repro.io)
    ap.add_argument("--data-dir", default=None,
                    help="stream .fbshard raw-log shards instead of "
                         "in-memory synthetic batches (recsys only)")
    from repro.fe.specs import list_specs
    ap.add_argument("--spec", default="ads_ctr", choices=list_specs(),
                    help="feature spec compiled for --data-dir streaming "
                         "(declarative FE scenario preset)")
    ap.add_argument("--gen-shards", type=int, default=0,
                    help="generate this many shards into --data-dir first")
    ap.add_argument("--device-feed", default="off",
                    choices=["on", "off", "arena"],
                    help="stage batches through a buffer-ring device arena "
                         "on a third pipeline stage (H2D overlaps training); "
                         "'arena' additionally assembles FE outputs directly "
                         "into the arena (zero-copy feed, no env->arena "
                         "memcpy) as per-field id vectors for the dedup'd "
                         "embedding feed")
    ap.add_argument("--mesh", default=None, metavar="PODSxDATA",
                    help="run the streaming train loop data-parallel on a "
                         "('pod', 'data') device mesh, e.g. 2x4, or 'auto' "
                         "to let elastic_remesh size the mesh from the "
                         "healthy device count (see --pod-size, --resume): "
                         "embedding "
                         "rows + Adagrad accumulators sharded over all "
                         "devices, two-stage (local->global) id dedup, "
                         "hierarchical cross-pod gradient reduction; "
                         "simulate devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N "
                         "(streaming --data-dir mode, recsys only)")
    ap.add_argument("--compress", default="off",
                    choices=["bf16", "int8", "off"],
                    help="codec for the inter-pod gradient wire of --mesh "
                         "(error feedback carried in the optimizer state, "
                         "accumulation stays fp32); 'off' keeps the 1x1 "
                         "path bitwise-identical to single-device")
    ap.add_argument("--embedding", default="table",
                    choices=["table", "hierarchy"],
                    help="embedding backend: 'table' keeps the full table "
                         "in device memory; 'hierarchy' serves it from the "
                         "hierarchical PS (SSD memmap <- host LRU cache <- "
                         "per-batch working set) with the pull for batch "
                         "i+1 overlapping batch i's train step — tables "
                         "larger than device memory train end to end "
                         "(streaming --data-dir mode, recsys only)")
    ap.add_argument("--ps-dir", default=None,
                    help="directory for the hierarchical PS table file "
                         "(default: <data-dir>/_ps)")
    ap.add_argument("--host-cache-rows", type=int, default=100_000,
                    help="hierarchical PS host-DRAM cache capacity in rows")
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="simulated device-memory budget: print whether the "
                         "PS table exceeds it (the beyond-HBM demo line)")
    ap.add_argument("--vocab-scale", type=float, default=1.0,
                    help="scale every sparse vocab by this factor (recsys): "
                         "grows the embedding table past any device budget "
                         "without changing the batch shapes")
    ap.add_argument("--adapt", default="fused", choices=["fused", "eager"],
                    help="spec->arch batch adaptation: 'fused' traces the "
                         "compiled ModelFeed plan inside the train step's "
                         "jit (one dispatch per step); 'eager' keeps the "
                         "legacy per-step jnp ops (the measurable baseline)")
    ap.add_argument("--no-donate", action="store_true",
                    help="do not donate params/optimizer/staged batch "
                         "through the jitted train step")
    ap.add_argument("--stream-workers", type=int, default=2)
    ap.add_argument("--stream-prefetch", type=int, default=4)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    # observability (repro.obs)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome trace-event / Perfetto timeline "
                         "of the run to PATH: loader readers, FE worker, "
                         "H2D feeder, and train loop as separate tracks "
                         "(open in ui.perfetto.dev or chrome://tracing)")
    ap.add_argument("--check", action="store_true",
                    help="preflight the run with repro.check (static plan "
                         "verifier, arena aliasing, jaxpr effects, lockset "
                         "audit) and refuse to train on error findings; "
                         "the report lands in the --metrics snapshot under "
                         "'check.*'")
    ap.add_argument("--metrics", action="store_true",
                    help="print the consolidated repro.obs.MetricsRegistry "
                         "snapshot (JSON) plus per-step HLO FLOPs / "
                         "HBM-bytes at exit (the HLO analysis costs one "
                         "extra compile)")
    args = ap.parse_args()

    if args.trace:
        from repro.obs.trace import enable_tracing
        enable_tracing()
    try:
        _run(args)
    finally:
        if args.trace:
            from repro.obs.trace import get_tracer
            tracer = get_tracer()
            out = tracer.export(args.trace)
            print(f"trace: {len(out['traceEvents'])} events on "
                  f"{len(tracer.track_names())} tracks -> {args.trace}")


def _preflight(args, spec):
    """``--check``: run the static analyzers before touching any data.

    Returns the :class:`repro.check.Report` (registered under the
    ``check`` metrics tier) or raises ``SystemExit`` with the report's
    exit code on error findings / analyzer crashes — the 0/1/2 contract
    of ``python -m repro.check``.
    """
    from repro.check import run_check
    if spec.family != "recsys":
        raise SystemExit(
            f"--check verifies the FE feed pipeline, which only recsys "
            f"archs consume (got family={spec.family!r})")
    report = run_check(args.spec, args.arch)
    print(report.render())
    if report.exit_code:
        raise SystemExit(report.exit_code)
    return report


def _run(args) -> None:
    spec = get_arch(args.arch)
    cfg = spec.smoke()
    if args.vocab_scale != 1.0:
        if spec.family != "recsys":
            raise SystemExit("--vocab-scale only applies to recsys archs")
        if args.vocab_scale <= 0:
            raise SystemExit("--vocab-scale must be > 0")
        import dataclasses
        cfg = dataclasses.replace(cfg, vocab_sizes=tuple(
            max(1, int(v * args.vocab_scale)) for v in cfg.vocab_sizes))
    if args.embedding == "hierarchy":
        if spec.family != "recsys":
            raise SystemExit(
                "--embedding hierarchy is a recsys embedding backend "
                f"(got family={spec.family!r})")
        if not args.data_dir:
            raise SystemExit(
                "--embedding hierarchy runs on the streaming pipeline: "
                "pass --data-dir (the PS pull is a pipeline stage)")
        if args.device_feed == "arena":
            raise SystemExit(
                "--embedding hierarchy is incompatible with --device-feed "
                "arena (the zero-copy arena assembles per-field id vectors "
                "for the in-memory dedup'd lookup); use on/off")
    if args.mesh:
        if spec.family != "recsys":
            raise SystemExit(
                "--mesh data-parallel training shards the embedding table "
                f"and is wired for recsys archs (got family={spec.family!r})")
        if not args.data_dir:
            raise SystemExit(
                "--mesh runs the streaming pipeline: pass --data-dir")
        if args.embedding == "hierarchy":
            raise SystemExit(
                "--mesh is incompatible with --embedding hierarchy (the PS "
                "pull path assumes a single device holds the working set); "
                "pick one scale-out axis")
    if (args.resume or args.fault_tolerant or args.chaos) and not args.data_dir:
        raise SystemExit(
            "--resume/--fault-tolerant/--chaos operate on the streaming "
            "ingest tier: pass --data-dir")
    key = jax.random.PRNGKey(0)
    opt = adamw(args.lr)
    check_report = _preflight(args, spec) if args.check else None

    if spec.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(cfg, key)
        train_step = jax.jit(T.make_train_step(cfg, opt))
        opt_state = opt.init(params)
    elif spec.family == "recsys":
        from repro.models import recsys as R
        if args.embedding == "hierarchy":
            # Embedding rows live in the PS file, not in params: dense tree
            # only (same fold_in enumeration, so dense init is bitwise
            # identical to the in-memory backend); the hierarchy train step
            # is compiled in run_streaming with the data-tuned capacity.
            params = R.init_params(cfg, key, include_embed=False)
            train_step = None
            opt_state = {"dense": opt.init(params)}
        else:
            params = R.init_params(cfg, key)
            step_fn, init_st, _ = R.make_sparse_train_step(cfg, opt)
            train_step = jax.jit(step_fn)
            opt_state = init_st(params)
    else:
        from repro.models import gnn as G
        params = G.init_params(cfg, key)
        train_step = jax.jit(G.make_train_step(cfg, opt))
        opt_state = opt.init(params)

    state = {"params": params, "opt": opt_state}

    if args.data_dir:
        # The streaming path builds its own boundary step: the working-set
        # capacity is tuned from the dataset manifest, so the train step
        # is compiled there (same state/optimizer structure).
        run_streaming(args, spec, cfg, state, opt,
                      check_report=check_report)
        return

    def step_wrapper(state, batch):
        p, o, m = train_step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    loop_cfg = LoopConfig(
        n_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )
    t0 = time.perf_counter()
    state, stats = run_training(
        cfg=loop_cfg,
        state=state,
        train_step=step_wrapper,
        batch_source=lambda s: synthetic_batch(spec.family, cfg, args.batch, s),
    )
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} steps={stats.steps} "
          f"loss {stats.losses[0]:.4f} -> {stats.losses[-1]:.4f} "
          f"({dt:.1f}s, {dt/max(stats.steps,1)*1e3:.1f} ms/step)")
    if args.metrics:
        from repro.launch.hlo_stats import step_cost
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        reg.register("loop", stats)
        if check_report is not None:
            reg.register("check", check_report)
        tot = step_cost(train_step, state["params"], state["opt"],
                        synthetic_batch(spec.family, cfg, args.batch, 0))
        reg.register("hlo", tot)
        _print_hlo_cost(tot)
        print("metrics:")
        print(reg.to_json())
    assert stats.losses[-1] < stats.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
