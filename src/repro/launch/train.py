"""End-to-end training driver: ``--arch <id>`` selects any assigned config.

On this CPU container it runs the REDUCED (smoke) config of the chosen
architecture with synthetic data through the full production path: FeatureBox
FE pipeline (recsys archs), jitted train step, async checkpointing, restart.
On a real TPU cluster the same driver runs the full config by passing
``--full`` (the step functions and shardings are the dry-run-validated ones).

Two batch sources:

* default — in-memory ``synthetic_batch`` per step (no disk in the loop);
* ``--data-dir DIR`` (recsys only) — stream ``.fbshard`` raw-log shards
  through a compiled FeatureBox ``FeaturePlan`` with
  ``repro.io.StreamingLoader``: reader threads pull shards off disk
  (decoding only the plan's ``required_columns``), the FE worker extracts
  features for batch i+1 while the device trains on batch i. Pick the
  feature scenario with ``--spec ads_ctr|dlrm|bst``; regenerate shards
  with ``repro.fe.datagen.write_log_shards`` (see ``--gen-shards``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 10
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf \
      --data-dir /tmp/adslog --gen-shards 8 --steps 16 --spec dlrm
"""

from __future__ import annotations

import argparse
import itertools
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import adamw


def synthetic_batch(family: str, cfg, batch: int, step: int) -> Dict[str, Any]:
    rng = np.random.default_rng(step)
    if family == "lm":
        toks = rng.integers(0, cfg.vocab, (batch, 64)).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if family == "recsys":
        b = {
            "sparse": jnp.asarray(np.stack(
                [rng.integers(0, v, batch) for v in cfg.vocab_sizes[:cfg.n_sparse]],
                axis=1).astype(np.int32)),
            "label": jnp.asarray((rng.random(batch) < 0.25).astype(np.float32)),
        }
        if cfg.n_dense:
            b["dense"] = jnp.asarray(
                rng.exponential(1.0, (batch, cfg.n_dense)).astype(np.float32))
        if cfg.kind == "bst":
            b["seq"] = jnp.asarray(
                rng.integers(0, cfg.vocab_sizes[0], (batch, cfg.seq_len)).astype(np.int32))
        return b
    # gnn
    from repro.models.gnn import random_graph
    g = random_graph(200, 800, cfg.d_in, cfg.n_classes, seed=step)
    return {k: jnp.asarray(v) for k, v in g.items()}


def fe_env_to_model_batch(env: Dict[str, Any], cfg) -> Dict[str, Any]:
    """Adapt FE-pipeline outputs to a recsys model batch.

    A compiled ``FeaturePlan`` emits a spec-dependent layout (e.g. ads_ctr:
    9 dense feats, 8 sparse fields, 48 seq positions); the arch config may
    want a different width, so columns are tiled / re-hashed into the
    config's field vocabularies. Specs without a dense block (bst) or
    sequence block (dlrm-as-plain) degrade gracefully: missing blocks are
    synthesized from the sparse fields. Pure jnp so device arrays staged
    by ``--device-feed on`` are adapted where they already live — a host
    round-trip here would put a blocking D2H readback plus a second H2D
    on the training critical path, inverting the flag's whole point.
    """
    sparse = jnp.asarray(env["batch_sparse"])
    idx = np.arange(cfg.n_sparse) % sparse.shape[1]
    vocab = np.asarray(cfg.vocab_sizes[:cfg.n_sparse], np.int32)
    batch: Dict[str, Any] = {
        "sparse": (sparse[:, idx] % vocab).astype(jnp.int32),
        "label": jnp.asarray(env["batch_label"]).astype(jnp.float32),
    }
    if cfg.n_dense:
        if "batch_dense" in env:
            dense = jnp.asarray(env["batch_dense"]).astype(jnp.float32)
        else:  # spec emits no dense block: log-scaled sparse ids stand in
            dense = jnp.log1p(sparse.astype(jnp.float32))
        reps = -(-cfg.n_dense // dense.shape[1])  # ceil
        batch["dense"] = jnp.tile(dense, (1, reps))[:, :cfg.n_dense]
    if cfg.kind == "bst":
        seq = (jnp.asarray(env["batch_seq_ids"])
               if "batch_seq_ids" in env else sparse)
        reps = -(-cfg.seq_len // seq.shape[1])
        batch["seq"] = (jnp.tile(seq, (1, reps))[:, :cfg.seq_len]
                        % cfg.vocab_sizes[0]).astype(jnp.int32)
    return batch


def run_streaming(args, spec, cfg, train_step, state) -> None:
    """Stream raw-log shards from disk through FE into the train step."""
    from repro.core import DeviceFeeder, PipelinedRunner
    from repro.fe import featureplan, get_spec
    from repro.io.dataset import ShardDataset
    from repro.io.stream import StreamingLoader

    if spec.family != "recsys":
        raise SystemExit(
            f"--data-dir streaming runs the FeatureBox FE pipeline and is "
            f"only wired for recsys archs (got family={spec.family!r})")

    if args.gen_shards:
        from repro.fe.datagen import write_log_shards
        paths = write_log_shards(args.data_dir, n_shards=args.gen_shards,
                                 rows_per_shard=args.batch, seed=0)
        print(f"wrote {len(paths)} shards to {args.data_dir}")

    ds = ShardDataset(args.data_dir, host_id=args.host_id,
                      n_hosts=args.n_hosts)
    if not len(ds):
        raise SystemExit(
            f"host {args.host_id}/{args.n_hosts} got no shards: the dataset "
            f"has only {len(ds.shards)} shard(s); generate more or use "
            f"fewer hosts")
    plan = featureplan.compile(get_spec(args.spec))
    print(plan.summary())
    epochs = -(-args.steps // len(ds))  # enough passes for --steps
    # Projection pushdown: only the columns the spec touches are decoded.
    loader = StreamingLoader(ds, workers=args.stream_workers,
                             prefetch=args.stream_prefetch, epochs=epochs,
                             shuffle=True, seed=0,
                             columns=plan.required_columns)
    ckpt = (CheckpointManager(args.checkpoint_dir)
            if args.checkpoint_dir else None)

    losses = []

    def step_fn(state, env):
        batch = fe_env_to_model_batch(env, cfg)
        p, o, m = train_step(state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
        state = {"params": p, "opt": o}
        if ckpt is not None and len(losses) % args.checkpoint_every == 0:
            ckpt.save_async(len(losses) - 1, state)
        return state

    layers = plan.layers
    feeder = None
    if args.device_feed == "arena":
        # Zero-copy feed: FE assembles batch_* outputs straight into
        # claimed arena views (no env->arena memcpy; FeedStats counts the
        # elided copies). Arena sized up front from the dataset manifest.
        ab = plan.arena_binding()
        layers, feeder = ab.layers, ab.make_feeder(rows_hint=loader.rows_hint)
    elif args.device_feed == "on":
        # Third pipeline stage: batch i+1 is staged through the buffer-ring
        # device arena while batch i trains. Arena sized up front from the
        # dataset manifest via the loader's rows hint.
        feeder = DeviceFeeder(plan.feed_layout(), rows_hint=loader.rows_hint)
    runner = PipelinedRunner(layers, step_fn,
                             prefetch=args.stream_prefetch, device_feed=feeder)
    shard_iter = iter(loader)  # kept so the generator can be closed below
    t0 = time.perf_counter()
    try:
        runner.run(state, itertools.islice(shard_iter, args.steps))
    finally:
        # Close the generator explicitly (its finally finalizes the
        # loader's wall-clock stats) before stopping the reader pool —
        # islice abandonment alone leaves that to garbage collection.
        try:
            shard_iter.close()
        except ValueError:  # FE worker still holds it (join timed out)
            pass
        loader.close()
        if ckpt is not None:
            ckpt.wait()
    # islice hides the loader from the runner's duck-typed stats capture
    runner.stats.ingest = loader.stats
    dt = time.perf_counter() - t0
    s = runner.stats
    if not losses:
        raise SystemExit("streaming run consumed no batches")
    print(f"arch={args.arch} spec={args.spec} mode=streaming steps={s.batches} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({dt:.1f}s, {dt/max(s.batches,1)*1e3:.1f} ms/step; "
          f"fe={s.fe_seconds:.2f}s train={s.train_seconds:.2f}s "
          f"wall={s.wall_seconds:.2f}s)")
    print(f"ingest: {loader.stats.summary()}")
    if s.feed is not None:
        print(f"device-feed: {s.feed.summary()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    # streaming-ingest mode (repro.io)
    ap.add_argument("--data-dir", default=None,
                    help="stream .fbshard raw-log shards instead of "
                         "in-memory synthetic batches (recsys only)")
    from repro.fe.specs import list_specs
    ap.add_argument("--spec", default="ads_ctr", choices=list_specs(),
                    help="feature spec compiled for --data-dir streaming "
                         "(declarative FE scenario preset)")
    ap.add_argument("--gen-shards", type=int, default=0,
                    help="generate this many shards into --data-dir first")
    ap.add_argument("--device-feed", default="off",
                    choices=["on", "off", "arena"],
                    help="stage batches through a buffer-ring device arena "
                         "on a third pipeline stage (H2D overlaps training); "
                         "'arena' additionally assembles FE outputs directly "
                         "into the arena (zero-copy feed, no env->arena "
                         "memcpy)")
    ap.add_argument("--stream-workers", type=int, default=2)
    ap.add_argument("--stream-prefetch", type=int, default=4)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--n-hosts", type=int, default=1)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke()
    key = jax.random.PRNGKey(0)
    opt = adamw(args.lr)

    if spec.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(cfg, key)
        train_step = jax.jit(T.make_train_step(cfg, opt))
        opt_state = opt.init(params)
    elif spec.family == "recsys":
        from repro.models import recsys as R
        params = R.init_params(cfg, key)
        step_fn, init_st, _ = R.make_sparse_train_step(cfg, opt)
        train_step = jax.jit(step_fn)
        opt_state = init_st(params)
    else:
        from repro.models import gnn as G
        params = G.init_params(cfg, key)
        train_step = jax.jit(G.make_train_step(cfg, opt))
        opt_state = opt.init(params)

    state = {"params": params, "opt": opt_state}

    if args.data_dir:
        run_streaming(args, spec, cfg, train_step, state)
        return

    def step_wrapper(state, batch):
        p, o, m = train_step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    loop_cfg = LoopConfig(
        n_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )
    t0 = time.perf_counter()
    state, stats = run_training(
        cfg=loop_cfg,
        state=state,
        train_step=step_wrapper,
        batch_source=lambda s: synthetic_batch(spec.family, cfg, args.batch, s),
    )
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} steps={stats.steps} "
          f"loss {stats.losses[0]:.4f} -> {stats.losses[-1]:.4f} "
          f"({dt:.1f}s, {dt/max(stats.steps,1)*1e3:.1f} ms/step)")
    assert stats.losses[-1] < stats.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
