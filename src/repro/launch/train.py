"""End-to-end training driver: ``--arch <id>`` selects any assigned config.

On this CPU container it runs the REDUCED (smoke) config of the chosen
architecture with synthetic data through the full production path: FeatureBox
FE pipeline (recsys archs), jitted train step, async checkpointing, restart.
On a real TPU cluster the same driver runs the full config by passing
``--full`` (the step functions and shardings are the dry-run-validated ones).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 10
  PYTHONPATH=src python -m repro.launch.train --arch pna --steps 20
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.train.checkpoint import CheckpointManager
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import adamw


def synthetic_batch(family: str, cfg, batch: int, step: int) -> Dict[str, Any]:
    rng = np.random.default_rng(step)
    if family == "lm":
        toks = rng.integers(0, cfg.vocab, (batch, 64)).astype(np.int32)
        return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if family == "recsys":
        b = {
            "sparse": jnp.asarray(np.stack(
                [rng.integers(0, v, batch) for v in cfg.vocab_sizes[:cfg.n_sparse]],
                axis=1).astype(np.int32)),
            "label": jnp.asarray((rng.random(batch) < 0.25).astype(np.float32)),
        }
        if cfg.n_dense:
            b["dense"] = jnp.asarray(
                rng.exponential(1.0, (batch, cfg.n_dense)).astype(np.float32))
        if cfg.kind == "bst":
            b["seq"] = jnp.asarray(
                rng.integers(0, cfg.vocab_sizes[0], (batch, cfg.seq_len)).astype(np.int32))
        return b
    # gnn
    from repro.models.gnn import random_graph
    g = random_graph(200, 800, cfg.d_in, cfg.n_classes, seed=step)
    return {k: jnp.asarray(v) for k, v in g.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke()
    key = jax.random.PRNGKey(0)
    opt = adamw(args.lr)

    if spec.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(cfg, key)
        train_step = jax.jit(T.make_train_step(cfg, opt))
        opt_state = opt.init(params)
    elif spec.family == "recsys":
        from repro.models import recsys as R
        params = R.init_params(cfg, key)
        step_fn, init_st, _ = R.make_sparse_train_step(cfg, opt)
        train_step = jax.jit(step_fn)
        opt_state = init_st(params)
    else:
        from repro.models import gnn as G
        params = G.init_params(cfg, key)
        train_step = jax.jit(G.make_train_step(cfg, opt))
        opt_state = opt.init(params)

    state = {"params": params, "opt": opt_state}

    def step_wrapper(state, batch):
        p, o, m = train_step(state["params"], state["opt"], batch)
        return {"params": p, "opt": o}, m

    loop_cfg = LoopConfig(
        n_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
    )
    t0 = time.perf_counter()
    state, stats = run_training(
        cfg=loop_cfg,
        state=state,
        train_step=step_wrapper,
        batch_source=lambda s: synthetic_batch(spec.family, cfg, args.batch, s),
    )
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} steps={stats.steps} "
          f"loss {stats.losses[0]:.4f} -> {stats.losses[-1]:.4f} "
          f"({dt:.1f}s, {dt/max(stats.steps,1)*1e3:.1f} ms/step)")
    assert stats.losses[-1] < stats.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
