"""Loop-aware cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified: an 8-step scan reports 1/8 the FLOPs of its unrolled twin),
which silently undercounts every scanned model (layers, microbatches, loss
chunks) by orders of magnitude. This module re-derives step costs from the
compiled HLO text with loops expanded:

  * computations are parsed into symbol tables (instruction -> shape);
  * ``dot`` FLOPs = 2 * prod(output) * prod(contracted lhs dims);
  * bytes = operand + output bytes per instruction (fusion internals are NOT
    counted — matching XLA's HBM-traffic convention for fused kernels);
  * collective bytes are grouped by op kind;
  * ``while`` totals multiply by ``backend_config.known_trip_count`` (nested
    loops compose); ``conditional`` takes the max branch; ``call`` recurses.

Validated against cost_analysis on unrolled graphs in tests/test_hlo_stats.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from repro.obs.metrics import harvest

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
# "%name = <shape(s)> opcode(operands...)" — shape may be a tuple "(a, b)"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes with no HBM traffic of their own
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id"}


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    Newer JAX returns a dict; older versions return a list with one dict
    per partitioned computation. Merge by summing shared keys so callers
    can index ``["flops"]`` on either.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, dict):
        return ca
    merged: Dict[str, float] = {}
    for entry in ca:
        for k, v in (entry or {}).items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + v
    return merged


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    # bytes attributable to `copy`/`convert` instructions: on the CPU backend
    # these are bf16->f32 promotion and SPMD "involuntary replication"
    # artifacts that native-bf16 TPUs do not execute; bytes - artifact_bytes
    # is the TPU-corrected HBM-traffic estimate (see EXPERIMENTS.md §Roofline)
    artifact_bytes: float = 0.0
    collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", scale: float = 1.0) -> None:
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.artifact_bytes += other.artifact_bytes * scale
        for k, v in other.collective.items():
            self.collective[k] = self.collective.get(k, 0.0) + v * scale

    @property
    def collective_total(self) -> float:
        return sum(self.collective.values())

    @property
    def bytes_tpu_corrected(self) -> float:
        return self.bytes - self.artifact_bytes

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`.

        The per-kind ``collective`` dict is summarized by the
        ``collective_total`` property; kind breakdown stays on the object.
        """
        return harvest(self)


def abstractify(tree):
    """Map a pytree of arrays/scalars to ``ShapeDtypeStruct`` leaves.

    No data is read and no transfers happen — device arrays contribute only
    their (shape, dtype), so this is safe to call on live training state.
    """
    import jax
    import numpy as np

    def _one(x):
        if isinstance(x, jax.ShapeDtypeStruct):
            return x
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            return jax.ShapeDtypeStruct(x.shape, x.dtype)
        a = np.asarray(x)
        return jax.ShapeDtypeStruct(a.shape, a.dtype)

    return jax.tree_util.tree_map(_one, tree)


def step_cost(fn, *args) -> Totals:
    """Loop-aware per-call cost of ``fn`` on arguments shaped like ``args``.

    Lowers on :func:`abstractify`'d arguments (no execution; donation is a
    no-op on abstract values), compiles, and runs :func:`analyze_hlo` on
    the optimized HLO text. ``fn`` may be a ``jax.jit`` wrapper (e.g. the
    ``step.jitted`` attached by :meth:`repro.fe.modelfeed.ModelFeed.
    make_step`) or a plain traceable callable. Costs one extra compile —
    callers should gate it behind an opt-in flag (``--metrics``).
    """
    import jax

    shaped = abstractify(args)
    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    compiled = jitted.lower(*shaped).compile()
    return analyze_hlo(compiled.as_text())


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def analyze_hlo(text: str) -> Totals:
    comps = _split_computations(text)
    entry = None
    for line in text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line)
        if m:
            entry = m.group(1)
    if entry is None:  # fall back to last computation
        entry = list(comps)[-1]

    # computations reachable only as fusion bodies must not be double-counted
    fusion_bodies = set()
    for lines in comps.values():
        for line in lines:
            if " fusion(" in line or line.lstrip().startswith("%fusion"):
                for m in _CALLS_RE.finditer(line):
                    fusion_bodies.add(m.group(1))

    memo: Dict[str, Totals] = {}

    # fusion computations whose body slices/updates a large aliased buffer:
    # their traffic is the slice side, not the whole buffer (XLA aliases
    # in-place DUS; gathers/dynamic-slices read only the addressed rows)
    def _body_has(name: str, needle: str) -> bool:
        return any(needle in line for line in comps.get(name, []))

    def eval_comp(name: str) -> Totals:
        if name in memo:
            return memo[name]
        memo[name] = Totals()  # break cycles defensively
        total = Totals()
        shapes: Dict[str, str] = {}
        lines = comps.get(name, [])
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                shapes[m.group(1)] = m.group(2)
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            out_name, out_shape, opcode = m.groups()
            if opcode == "while":
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                body = _BODY_RE.search(line)
                cond = _COND_RE.search(line)
                if body:
                    total.add(eval_comp(body.group(1)), trip)
                if cond:
                    total.add(eval_comp(cond.group(1)), trip + 1)
                continue
            if opcode == "conditional":
                br = _BRANCHES_RE.search(line)
                if br:
                    branches = _OPERAND_RE.findall(br.group(1))
                    if branches:
                        cand = [eval_comp(b) for b in branches]
                        best = max(cand, key=lambda t: (t.flops, t.bytes))
                        total.add(best)
                continue
            if opcode in ("call", "async-start"):
                ta = _TO_APPLY_RE.search(line)
                if ta:
                    total.add(eval_comp(ta.group(1)))

            # ---- per-instruction direct costs
            if opcode in _FREE_OPS:
                continue
            # operand bytes: look up shapes of referenced values (skip self)
            paren = line[line.index("("):] if "(" in line else ""
            operand_names = [
                n for n in _OPERAND_RE.findall(paren.split("),")[0])
                if n != out_name and n in shapes
            ]
            op_bytes = [_shape_bytes(shapes[n]) for n in operand_names]
            in_bytes = sum(op_bytes)
            out_bytes = _shape_bytes(out_shape)

            # slice-side traffic rules (match XLA cost-model conventions):
            #   gather/dynamic-slice read only the addressed rows;
            #   scatter/dynamic-update-slice write only the update (aliased);
            #   fusions rooted in those ops inherit the rule.
            sliced = False
            if opcode in ("gather", "dynamic-slice"):
                sliced = True
            elif opcode in ("scatter", "dynamic-update-slice"):
                sliced = True
            elif opcode == "fusion" and op_bytes:
                called = _CALLS_RE.search(line)
                big = max(op_bytes + [out_bytes])
                if called and big > 4 * out_bytes and (
                        _body_has(called.group(1), " gather(")
                        or _body_has(called.group(1), " dynamic-slice(")):
                    sliced = True
                elif called and big == out_bytes and (
                        _body_has(called.group(1), " dynamic-update-slice(")
                        or _body_has(called.group(1), " scatter(")):
                    sliced = True
            if sliced:
                # read small operands + write/read the slice-sized side;
                # the largest buffer (source table / aliased accumulator)
                # contributes no whole-buffer traffic
                big = max(op_bytes + [out_bytes])
                traffic = (in_bytes + out_bytes) - big
                total.bytes += 2 * traffic if traffic else out_bytes
            else:
                total.bytes += in_bytes + out_bytes
            if opcode in ("copy", "convert") or "wrapped_convert" in out_name \
                    or (opcode == "fusion" and "convert" in out_name):
                total.artifact_bytes += in_bytes + out_bytes

            base = opcode.replace("-start", "")
            if base in COLLECTIVES:
                total.collective[base] = total.collective.get(base, 0.0) + out_bytes

            if opcode == "dot":
                out_elems = 1
                for d in _shape_dims(out_shape):
                    out_elems *= d
                lc = _LHS_CONTRACT_RE.search(line)
                k = 1
                if lc and operand_names:
                    lhs_dims = _shape_dims(shapes[operand_names[0]])
                    for idx in (int(x) for x in lc.group(1).split(",") if x):
                        if idx < len(lhs_dims):
                            k *= lhs_dims[idx]
                total.flops += 2.0 * out_elems * k
        memo[name] = total
        return total

    return eval_comp(entry)
