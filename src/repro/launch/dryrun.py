import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * ``compiled.memory_analysis()``  — proves the step fits per-device HBM;
  * ``compiled.cost_analysis()``    — per-device HLO FLOPs / bytes accessed;
  * collective bytes parsed from the compiled HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes) — the roofline's collective term.

Results land in ``results/dryrun_<mesh>.json`` for benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch dlrm-mlperf --shape train_batch
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax
import numpy as np

from repro.configs import get_arch, list_archs
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, per op kind.

    Shapes in SPMD-partitioned HLO are per-device shard shapes, so these are
    per-device collective bytes (matching cost_analysis granularity).
    """
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(3)
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(2))
    return out


def run_cell(arch_id: str, shape: str, *, multi_pod: bool = False,
             variant: str = "base", verbose: bool = True) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = get_arch(arch_id)
    cell = spec.build_cell(shape, mesh, variant=variant)
    rec: Dict = {
        "arch": arch_id, "shape": shape, "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "model_flops": cell.model_flops,
    }
    if cell.skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = cell.skip
        if verbose:
            print(f"[SKIP] {arch_id} x {shape}: {cell.skip}")
        return rec

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    from repro.launch.hlo_stats import cost_analysis_dict
    ca = cost_analysis_dict(compiled)  # dict on every JAX version
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)

    # Loop-aware costs: cost_analysis() counts while bodies ONCE (a scanned
    # 60-layer model reads as one layer). hlo_stats re-derives flops/bytes/
    # collective bytes with known_trip_count expansion (see hlo_stats.py and
    # tests/test_hlo_stats.py for validation against unrolled ground truth).
    from repro.launch.hlo_stats import analyze_hlo
    loop_aware = analyze_hlo(hlo)

    # Exact per-device bytes of the model state (params + opt + batch),
    # computed from the declared shardings — NOT subject to the CPU
    # backend's bf16->f32 buffer promotion that inflates memory_analysis()
    # (see EXPERIMENTS.md §Dry-run "CPU-backend inflation").
    def _leaf_bytes(leaf, sharding):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shard = getattr(sharding, "num_devices_sharded_over", None)
        try:
            shard_shape = sharding.shard_shape(leaf.shape)
            n = int(np.prod(shard_shape)) if shard_shape else 1
        except Exception:
            pass
        return n * leaf.dtype.itemsize

    state_bytes = 0
    for arg, sh in zip(cell.args, cell.in_shardings):
        leaves = jax.tree.leaves(arg)
        shardings = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "shard_shape"))
        if len(shardings) == len(leaves):
            state_bytes += sum(_leaf_bytes(l, s) for l, s in zip(leaves, shardings))

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": loop_aware.flops,
        "hlo_bytes_per_device": loop_aware.bytes,
        "collective_bytes_per_device": {k: int(v) for k, v in
                                        loop_aware.collective.items()},
        "collective_total_bytes": int(loop_aware.collective_total),
        "raw_cost_analysis": {            # loop bodies counted once (XLA)
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes_text_scan": int(sum(colls.values())),
        },
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
            "state_bytes_exact": state_bytes,
        },
    })
    if verbose:
        print(f"[OK] {arch_id} x {shape} ({rec['mesh']}, {variant}) "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"     memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"(peak~{rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB/device)")
        print(f"     cost_analysis: flops/dev={rec['hlo_flops_per_device']:.3e} "
              f"bytes/dev={rec['hlo_bytes_per_device']:.3e}")
        print(f"     collectives/dev: { {k: f'{v/2**20:.1f}MiB' for k, v in colls.items()} }")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--all", action="store_true", help="run every arch x shape")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default=None, help="output JSON path")
    args = ap.parse_args()

    if args.all:
        targets = [(a, s) for a in list_archs() for s in get_arch(a).shapes]
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        shapes = [args.shape] if args.shape else list(get_arch(args.arch).shapes)
        targets = [(args.arch, s) for s in shapes]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    failures = 0
    for multi_pod in meshes:
        for arch_id, shape in targets:
            try:
                records.append(run_cell(arch_id, shape, multi_pod=multi_pod,
                                        variant=args.variant))
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                traceback.print_exc()
                records.append({
                    "arch": arch_id, "shape": shape,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "variant": args.variant,
                    "status": "error", "error": f"{type(e).__name__}: {e}",
                })
    out = args.out or (
        f"results/dryrun_{'multi' if args.multi_pod or args.both_meshes else 'single'}"
        f"_{args.variant}.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(records, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    skipped = sum(1 for r in records if r["status"] == "skipped")
    print(f"\n== dry-run summary: {ok} ok, {skipped} skipped, {failures} failed "
          f"-> {out}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
