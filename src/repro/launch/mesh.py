"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and the
dry-run needs to set XLA_FLAGS before that happens.
"""

from __future__ import annotations

import jax

from repro import compat

compat.install()  # axis_types= / AxisType on older jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def parse_mesh_spec(spec: str):
    """``"PODxDATA"`` (the driver's ``--mesh`` flag) -> ``(pods, data)``.

    ``pods`` is the number of pods (inter-pod links are where
    ``--compress`` pays), ``data`` the data-parallel devices per pod
    (the "pod_size" of the byte accounting)."""
    parts = spec.lower().replace("×", "x").split("x")
    try:
        pods, data = (int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"--mesh expects PODSxDATA (e.g. 2x4), got {spec!r}") from None
    if pods < 1 or data < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    return pods, data


def make_train_mesh(pods: int = 1, data: int = 1):
    """('pod', 'data') mesh for the data-parallel streaming train loop.

    Validated on simulated devices: run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes) to get N host devices."""
    n = pods * data
    if n > len(jax.devices()):
        raise ValueError(
            f"mesh {pods}x{data} needs {n} devices but only "
            f"{len(jax.devices())} are visible; simulate with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    return jax.make_mesh(
        (pods, data), ("pod", "data"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over locally-visible devices (tests / examples)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
