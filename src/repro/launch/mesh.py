"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device count is locked at first jax init, and the
dry-run needs to set XLA_FLAGS before that happens.
"""

from __future__ import annotations

import jax

from repro import compat

compat.install()  # axis_types= / AxisType on older jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over locally-visible devices (tests / examples)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
