"""Serving driver: batched CTR scoring with the FeatureBox pipeline.

Runs the smoke config of a recsys arch as an online scorer: requests are
micro-batched, run through the FE schedule (host+device layers), scored with
the jitted serve step, and latency percentiles reported.

  PYTHONPATH=src python -m repro.launch.serve --arch dlrm-mlperf --requests 2000
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.train import synthetic_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family != "recsys":
        raise SystemExit("serve.py scores recsys archs; use train.py for others")
    from repro.models import recsys as R

    cfg = spec.smoke()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(lambda p, b: R.serve_step(p, cfg, b))

    lat = []
    n_batches = args.requests // args.batch
    scores_sum = 0.0
    for i in range(n_batches):
        b = synthetic_batch("recsys", cfg, args.batch, i)
        b.pop("label")
        t0 = time.perf_counter()
        s = serve(params, b)
        s.block_until_ready()
        lat.append(time.perf_counter() - t0)
        scores_sum += float(s.sum())
    lat_ms = np.asarray(lat) * 1e3
    print(f"arch={args.arch} batches={n_batches} batch={args.batch} "
          f"p50={np.percentile(lat_ms,50):.2f}ms p99={np.percentile(lat_ms,99):.2f}ms "
          f"mean_score={scores_sum/(n_batches*args.batch):.4f}")


if __name__ == "__main__":
    main()
