"""FeatureBox reproduction: GPU feature engineering + pipelined training.

Importing any ``repro.*`` module installs the JAX compat shims (see
:mod:`repro.compat`) so code written against newer JAX sharding APIs runs on
the pinned version as well. Subpackages without an ``__init__`` (``launch``,
``models``, ``train``, ...) remain importable as namespace portions.
"""

from repro import compat as _compat

# Install only if jax is already imported: keeps `import repro.io` (the
# numpy-only ingest tier) jax-free. Modules that consume the patched APIs
# (launch.mesh, models.moe, models.gnn, embedding.dedup) install eagerly.
_compat.install(require_jax=False)
