"""Binary record-shard format (``.fbshard``) for raw-log ingestion.

One shard file holds a set of named *tables* (the per-batch views of the ads
pipeline: impressions, user_profile, ...), each a set of named columns of the
three kinds the FE pipeline consumes:

* ``dense``  — fixed-width numeric ndarray (any numeric dtype, any shape
  whose leading axis is the row count),
* ``ragged`` — variable-length int lists per row
  (:class:`~repro.fe.colstore.RaggedColumn`: concatenated values + lengths),
* ``string`` — variable-length UTF-8 strings per row (object ndarray),
  stored as a concatenated byte payload + per-row byte lengths.

File layout::

    +--------------------------------------------------------------+
    | header (24 B): magic "FBSHARD1" | version u32 | flags u32    |
    |                crc32(prev 16 B) u32 | reserved u32           |
    +--------------------------------------------------------------+
    | column payload parts, back to back (raw little-endian bytes) |
    +--------------------------------------------------------------+
    | index: JSON (tables -> columns -> parts{offset,nbytes,crc32})|
    +--------------------------------------------------------------+
    | trailer (24 B): index_offset u64 | index_len u64 |           |
    |                 crc32(index) u32 | magic "FBX1"              |
    +--------------------------------------------------------------+

Every payload part carries a CRC32 (verified on read by default) and the
index itself is checksummed from the trailer, so torn/corrupt shards fail
loudly instead of feeding garbage into training. Writes go to a ``.tmp``
sibling and are renamed into place, so a crashed writer never leaves a
half-shard that readers would pick up.
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.fe.colstore import Columns, RaggedColumn

SHARD_SUFFIX = ".fbshard"

_MAGIC = b"FBSHARD1"
_TRAILER_MAGIC = b"FBX1"
_VERSION = 1
_HEADER = struct.Struct("<8sII")      # magic, version, flags
_HEADER_CRC = struct.Struct("<II")    # crc32(header), reserved
_HEADER_LEN = _HEADER.size + _HEADER_CRC.size          # 24
_TRAILER = struct.Struct("<QQI4s")    # index_offset, index_len, crc32, magic
_TRAILER_LEN = _TRAILER.size                           # 24

KIND_DENSE = "dense"
KIND_RAGGED = "ragged"
KIND_STRING = "string"

_LENGTHS_DTYPE = "<i4"


class ShardFormatError(ValueError):
    """Malformed, truncated, or corrupt shard file."""


# --------------------------------------------------------------------- write
class ShardWriter:
    """Write one shard: ``add_table`` per view, then ``close`` (atomic)."""

    def __init__(self, path: str, *, meta: Optional[Mapping[str, Any]] = None):
        if not path.endswith(SHARD_SUFFIX):
            path += SHARD_SUFFIX
        self.path = path
        self._tmp = path + ".tmp"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f = open(self._tmp, "wb")
        hdr = _HEADER.pack(_MAGIC, _VERSION, 0)
        self._f.write(hdr + _HEADER_CRC.pack(zlib.crc32(hdr), 0))
        self._tables: Dict[str, Dict[str, Any]] = {}
        self._meta = dict(meta or {})
        self._closed = False

    # -- context manager: commit on success, discard the temp file on error
    def __enter__(self) -> "ShardWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:
            self.abort()

    def add_table(self, name: str, columns: Columns) -> None:
        """Add one table (all columns must agree on row count)."""
        if self._closed:
            raise ShardFormatError("writer already closed")
        if name in self._tables:
            raise ShardFormatError(f"duplicate table {name!r}")
        cols: Dict[str, Any] = {}
        n_rows: Optional[int] = None
        for cname, col in columns.items():
            entry, rows = self._write_column(col)
            cols[cname] = entry
            if n_rows is None:
                n_rows = rows
            elif rows != n_rows:
                raise ShardFormatError(
                    f"table {name!r}: column {cname!r} has {rows} rows, "
                    f"expected {n_rows}")
        self._tables[name] = {"n_rows": int(n_rows or 0), "columns": cols}

    def _write_column(self, col: object) -> Tuple[Dict[str, Any], int]:
        if isinstance(col, RaggedColumn):
            values = np.ascontiguousarray(col.values)
            lengths = np.ascontiguousarray(col.lengths, dtype=_LENGTHS_DTYPE)
            if int(lengths.sum()) != values.shape[0]:
                raise ShardFormatError(
                    f"ragged column: sum(lengths)={int(lengths.sum())} != "
                    f"len(values)={values.shape[0]}")
            return {
                "kind": KIND_RAGGED,
                "values_dtype": values.dtype.str,
                "parts": [self._write_part(values), self._write_part(lengths)],
            }, int(lengths.shape[0])
        arr = np.asarray(col)
        if arr.dtype == object:
            for s in arr.reshape(-1):
                if not isinstance(s, str):
                    # str(None)/str(b"x") would roundtrip as their reprs —
                    # silent corruption; refuse at write time instead.
                    raise ShardFormatError(
                        f"string column element has type "
                        f"{type(s).__name__}; only str is supported")
            enc = [s.encode("utf-8") for s in arr.reshape(-1)]
            lengths = np.array([len(b) for b in enc], dtype=_LENGTHS_DTYPE)
            payload = np.frombuffer(b"".join(enc), dtype=np.uint8)
            return {
                "kind": KIND_STRING,
                "shape": list(arr.shape),
                "parts": [self._write_part(payload), self._write_part(lengths)],
            }, int(arr.shape[0]) if arr.ndim else 1
        arr = np.ascontiguousarray(arr)
        return {
            "kind": KIND_DENSE,
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "parts": [self._write_part(arr)],
        }, int(arr.shape[0]) if arr.ndim else 1

    def _write_part(self, arr: np.ndarray) -> Dict[str, int]:
        data = arr.tobytes()
        offset = self._f.tell()
        self._f.write(data)
        return {"offset": offset, "nbytes": len(data), "crc32": zlib.crc32(data)}

    def close(self) -> str:
        """Write index + trailer, fsync, and atomically publish the shard."""
        if self._closed:
            return self.path
        index = json.dumps(
            {"tables": self._tables, "meta": self._meta},
            separators=(",", ":")).encode("utf-8")
        index_offset = self._f.tell()
        self._f.write(index)
        self._f.write(_TRAILER.pack(index_offset, len(index),
                                    zlib.crc32(index), _TRAILER_MAGIC))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        os.replace(self._tmp, self.path)
        self._closed = True
        return self.path

    def abort(self) -> None:
        """Discard the partially-written shard."""
        if self._closed:
            return
        self._f.close()
        if os.path.exists(self._tmp):
            os.remove(self._tmp)
        self._closed = True


# ---------------------------------------------------------------------- read
class ShardReader:
    """Read a shard: header + index parsed eagerly, payloads on demand."""

    def __init__(self, path: str, *, verify: bool = True):
        self.path = path
        self.verify = verify
        self.nbytes = os.path.getsize(path)
        # Decode accounting (projection pushdown observability): payload
        # bytes and column count actually decoded by this reader.
        self.bytes_decoded = 0
        self.columns_decoded = 0
        if self.nbytes < _HEADER_LEN + _TRAILER_LEN:
            raise ShardFormatError(f"{path}: truncated ({self.nbytes} bytes)")
        with open(path, "rb") as f:
            head = f.read(_HEADER_LEN)
            magic, version, _flags = _HEADER.unpack_from(head)
            if magic != _MAGIC:
                raise ShardFormatError(f"{path}: bad magic {magic!r}")
            crc, _ = _HEADER_CRC.unpack_from(head, _HEADER.size)
            if crc != zlib.crc32(head[:_HEADER.size]):
                raise ShardFormatError(f"{path}: header checksum mismatch")
            if version != _VERSION:
                raise ShardFormatError(f"{path}: unsupported version {version}")
            f.seek(self.nbytes - _TRAILER_LEN)
            idx_off, idx_len, idx_crc, tmagic = _TRAILER.unpack(
                f.read(_TRAILER_LEN))
            if tmagic != _TRAILER_MAGIC:
                raise ShardFormatError(f"{path}: bad trailer magic {tmagic!r}")
            if idx_off + idx_len + _TRAILER_LEN != self.nbytes:
                raise ShardFormatError(f"{path}: index extent out of bounds")
            f.seek(idx_off)
            raw = f.read(idx_len)
        if zlib.crc32(raw) != idx_crc:
            raise ShardFormatError(f"{path}: index checksum mismatch")
        index = json.loads(raw.decode("utf-8"))
        self._tables: Dict[str, Dict[str, Any]] = index["tables"]
        self.meta: Dict[str, Any] = index.get("meta", {})

    # ------------------------------------------------------------- metadata
    @property
    def table_names(self) -> List[str]:
        return list(self._tables)

    def n_rows(self, table: str) -> int:
        return int(self._table(table)["n_rows"])

    def column_names(self, table: str) -> List[str]:
        return list(self._table(table)["columns"])

    def _table(self, name: str) -> Dict[str, Any]:
        try:
            return self._tables[name]
        except KeyError:
            raise KeyError(
                f"{self.path}: no table {name!r} (have {self.table_names})"
            ) from None

    # --------------------------------------------------------------- decode
    def read_table(self, table: str,
                   columns: Optional[Sequence[str]] = None) -> Columns:
        """Decode the requested columns of one table (all by default)."""
        with open(self.path, "rb") as f:
            return self._read_table(f, table, columns)

    def read_all(
        self,
        columns: Optional[Mapping[str, Sequence[str]]] = None,
    ) -> Dict[str, Columns]:
        """Decode tables — the env shape the FE runners consume.

        ``columns`` is an optional projection ``{table: [column, ...]}``
        (e.g. a ``FeaturePlan.required_columns``): only the listed tables
        and columns are decoded; everything else stays as undecoded bytes
        on disk. ``None`` decodes every table in full.

        One file handle for the whole shard (hot reader-thread path)."""
        with open(self.path, "rb") as f:
            if columns is None:
                return {t: self._read_table(f, t, None) for t in self._tables}
            return {t: self._read_table(f, t, cols)
                    for t, cols in columns.items()}

    def _read_table(self, f, table: str,
                    columns: Optional[Sequence[str]]) -> Columns:
        tmeta = self._table(table)
        names = list(columns) if columns is not None else list(tmeta["columns"])
        out: Columns = {}
        for name in names:
            cmeta = tmeta["columns"].get(name)
            if cmeta is None:
                raise KeyError(
                    f"{self.path}: table {table!r} has no column {name!r}")
            out[name] = self._read_column(f, cmeta)
            self.columns_decoded += 1
            self.bytes_decoded += sum(p["nbytes"] for p in cmeta["parts"])
        return out

    def _read_column(self, f, cmeta: Mapping[str, Any]) -> object:
        kind = cmeta["kind"]
        if kind == KIND_DENSE:
            arr = self._read_part(f, cmeta["parts"][0], cmeta["dtype"])
            return arr.reshape(cmeta["shape"])
        if kind == KIND_RAGGED:
            values = self._read_part(f, cmeta["parts"][0], cmeta["values_dtype"])
            lengths = self._read_part(f, cmeta["parts"][1], _LENGTHS_DTYPE)
            return RaggedColumn(values=values, lengths=lengths)
        if kind == KIND_STRING:
            payload = self._read_part(f, cmeta["parts"][0], "|u1")
            lengths = self._read_part(f, cmeta["parts"][1], _LENGTHS_DTYPE)
            offs = np.concatenate([[0], np.cumsum(lengths, dtype=np.int64)])
            buf = payload.tobytes()
            arr = np.array(
                [buf[offs[i]: offs[i + 1]].decode("utf-8")
                 for i in range(len(lengths))],
                dtype=object)
            # "shape" absent in shards written before it was recorded: 1-D.
            return arr.reshape(cmeta.get("shape", [len(lengths)]))
        raise ShardFormatError(f"{self.path}: unknown column kind {kind!r}")

    def _read_part(self, f, part: Mapping[str, int], dtype: str) -> np.ndarray:
        f.seek(part["offset"])
        data = f.read(part["nbytes"])
        if len(data) != part["nbytes"]:
            raise ShardFormatError(f"{self.path}: truncated payload part")
        if self.verify and zlib.crc32(data) != part["crc32"]:
            raise ShardFormatError(
                f"{self.path}: payload checksum mismatch at "
                f"offset {part['offset']}")
        return np.frombuffer(data, dtype=np.dtype(dtype)).copy()


# --------------------------------------------------------------- conveniences
def write_shard(path: str, tables: Mapping[str, Columns],
                *, meta: Optional[Mapping[str, Any]] = None) -> str:
    """Write ``{table: columns}`` as one shard; returns the final path."""
    with ShardWriter(path, meta=meta) as w:
        for name, cols in tables.items():
            w.add_table(name, cols)
    return w.path


def read_shard(path: str, *, verify: bool = True) -> Dict[str, Columns]:
    """Read every table of a shard into ``{table: columns}``."""
    return ShardReader(path, verify=verify).read_all()
