"""Streaming shard ingestion (the pipeline's on-disk front end).

FeatureBox's pipeline starts from 15–25 TB of raw ads logs; this package is
the scaled-down stand-in for that ingest tier:

* :mod:`repro.io.shardfmt` — compact binary record-shard format
  (``.fbshard``) with checksummed headers covering the three column kinds
  the FE pipeline uses (dense numeric, ragged int lists, strings).
* :mod:`repro.io.dataset` — shard discovery, manifests, and deterministic
  host-sharded assignment so ingestion composes with ``launch/mesh.py``.
* :mod:`repro.io.stream` — multi-worker prefetching :class:`StreamingLoader`
  with bounded queues, backpressure, ingest statistics, and lease-based
  fault tolerance (``ShardServer`` scheduling, reap/retry/backup recovery).
* :mod:`repro.io.chaos` — deterministic fault injection (kill/delay/
  transient/corrupt schedules) for proving the recovery paths.
* :mod:`repro.io.convert` — bulk conversion from ``fe.datagen`` views and
  ``fe.colstore`` chunks into shards.
"""

from repro.io.chaos import (
    ChaosEvent,
    ChaosInjector,
    ChaosKill,
    ChaosTransientIOError,
    parse_chaos_spec,
    random_schedule,
)
from repro.io.shardfmt import (
    SHARD_SUFFIX,
    ShardFormatError,
    ShardReader,
    ShardWriter,
    read_shard,
    write_shard,
)
from repro.io.dataset import ShardDataset, ShardInfo, assign_shards, write_manifest
from repro.io.stream import IngestStats, StreamingLoader
from repro.io.convert import colstore_to_shards, views_to_shard, write_view_shards

__all__ = [
    "ChaosEvent",
    "ChaosInjector",
    "ChaosKill",
    "ChaosTransientIOError",
    "IngestStats",
    "SHARD_SUFFIX",
    "ShardDataset",
    "ShardFormatError",
    "ShardInfo",
    "ShardReader",
    "ShardWriter",
    "StreamingLoader",
    "assign_shards",
    "colstore_to_shards",
    "parse_chaos_spec",
    "random_schedule",
    "read_shard",
    "views_to_shard",
    "write_manifest",
    "write_shard",
    "write_view_shards",
]
