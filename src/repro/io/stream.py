"""Multi-worker prefetching shard loader with backpressure accounting.

:class:`StreamingLoader` turns a :class:`~repro.io.dataset.ShardDataset`
(or a plain list of shard paths) into an iterator of ``{table: columns}``
environments — exactly the batch shape the FE runners consume — while a
pool of reader threads keeps the disk busy:

    work queue (shard infos) -> N reader threads -> bounded output queue

The output queue bounds memory (backpressure: readers block when the
consumer falls behind) and :class:`IngestStats` records where time went:

* ``read_seconds``          — readers doing disk I/O + decode,
* ``reader_stall_seconds``  — readers blocked on a full queue
  (consumer-bound: the trainer can't keep up),
* ``consumer_stall_seconds``— consumer blocked on an empty queue
  (reader-bound: the disk can't keep up).

Reader-thread exceptions are re-raised in the consumer, so a corrupt shard
fails the training job instead of silently shrinking the epoch.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, Sequence, Union

from repro.check.annotations import guarded_by, single_writer
from repro.io.dataset import ShardDataset, ShardInfo
from repro.io.shardfmt import ShardReader
from repro.obs.metrics import harvest
from repro.obs.trace import get_tracer

_WORKER_DONE = object()


@dataclasses.dataclass
class _ReaderError:
    exc: BaseException
    shard: str


@dataclasses.dataclass
class IngestStats:
    shards: int = 0
    bytes_read: int = 0
    # Projection pushdown accounting: payload bytes / columns actually
    # decoded (== bytes_read's payload when no column projection is set).
    bytes_decoded: int = 0
    columns_decoded: int = 0
    read_seconds: float = 0.0
    reader_stall_seconds: float = 0.0
    consumer_stall_seconds: float = 0.0
    wall_seconds: float = 0.0
    max_queue_depth: int = 0

    @property
    def read_bytes_per_second(self) -> float:
        """Disk+decode throughput of the reader pool (sum over workers)."""
        return self.bytes_read / max(self.read_seconds, 1e-9)

    @property
    def wall_bytes_per_second(self) -> float:
        """End-to-end ingest throughput as the consumer observed it."""
        return self.bytes_read / max(self.wall_seconds, 1e-9)

    def as_metrics(self) -> "dict":
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)

    def summary(self) -> str:
        return (f"shards={self.shards} bytes={self.bytes_read/2**20:.1f}MiB "
                f"decoded={self.bytes_decoded/2**20:.1f}MiB "
                f"({self.columns_decoded} cols) "
                f"read={self.read_seconds:.2f}s "
                f"({self.read_bytes_per_second/2**20:.0f}MiB/s) "
                f"wall={self.wall_seconds:.2f}s "
                f"({self.wall_bytes_per_second/2**20:.0f}MiB/s) "
                f"reader_stall={self.reader_stall_seconds:.2f}s "
                f"consumer_stall={self.consumer_stall_seconds:.2f}s")


# Thread contract (verified by `python -m repro.check` / repro.check.lockset):
# N reader threads and the consuming thread both update IngestStats, so
# every write to `stats` (including the per-pass rebind in __iter__) holds
# _lock; the thread-pool plumbing is only ever touched by the consumer.
@guarded_by("_lock", "stats")
@single_writer("_threads", "_out", "_running")
class StreamingLoader:
    """Iterate shard environments with a prefetching reader pool.

    Parameters
    ----------
    source:
        :class:`ShardDataset`, or a sequence of shard paths /
        :class:`ShardInfo`.
    workers:
        Reader threads. 1 gives deterministic shard order; more overlap
        seeks and decode.
    prefetch:
        Output queue capacity (decoded shards held ahead of the consumer).
    epochs:
        How many passes over the source to enqueue.
    shuffle / seed:
        Per-epoch deterministic shard-order shuffle (datasets only).
    transform:
        Optional ``fn(env, info) -> env`` applied in the reader thread, so
        per-shard host work (filtering, re-batching) overlaps the consumer.
    columns:
        Optional projection ``{table: [column, ...]}`` — typically a
        ``FeaturePlan.required_columns`` — pushed down into
        :meth:`ShardReader.read_all` so untouched tables/columns are never
        decoded from disk. ``IngestStats.bytes_decoded`` /
        ``columns_decoded`` make the saving observable.
    verify:
        Verify payload checksums while decoding (default on).
    """

    def __init__(self, source: Union[ShardDataset, Sequence],
                 *, workers: int = 2, prefetch: int = 4, epochs: int = 1,
                 shuffle: bool = False, seed: int = 0,
                 transform: Optional[Callable[[Dict[str, Any], ShardInfo],
                                              Dict[str, Any]]] = None,
                 columns: Optional[Mapping[str, Sequence[str]]] = None,
                 verify: bool = True):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {prefetch}")
        if epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {epochs}")
        self.source = source
        self.workers = workers
        self.prefetch = prefetch
        self.epochs = epochs
        self.shuffle = shuffle
        self.seed = seed
        self.transform = transform
        self.columns = (None if columns is None
                        else {t: tuple(c) for t, c in columns.items()})
        self.verify = verify
        self.stats = IngestStats()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._out: Optional[queue.Queue] = None
        self._running = False

    @property
    def rows_hint(self) -> Optional[int]:
        """Largest shard row count this loader will emit, if known.

        Pre-sizes downstream staging arenas (``DeviceFeeder(rows_hint=...)``)
        at compile time from the dataset manifest instead of growing on the
        first oversized batch. ``None`` when the source carries no row
        counts (plain path lists).
        """
        if isinstance(self.source, ShardDataset):
            rows = [s.n_rows for s in self.source.local_shards if s.n_rows]
            return max(rows) if rows else None
        rows = [s.n_rows for s in self.source
                if isinstance(s, ShardInfo) and s.n_rows]
        return max(rows) if rows else None

    # ------------------------------------------------------------- plumbing
    def _shard_plan(self) -> List[ShardInfo]:
        plan: List[ShardInfo] = []
        for epoch in range(self.epochs):
            if isinstance(self.source, ShardDataset):
                plan.extend(self.source.epoch_order(
                    epoch, shuffle=self.shuffle, seed=self.seed))
            else:
                items = list(self.source)
                for i, it in enumerate(items):
                    if not isinstance(it, ShardInfo):
                        import os
                        it = ShardInfo(path=str(it),
                                       nbytes=os.path.getsize(str(it)),
                                       n_rows=0, seq=i)
                    plan.append(it)
        return plan

    def _reader(self, work: "queue.Queue", out: "queue.Queue") -> None:
        tracer = get_tracer()
        info: Optional[ShardInfo] = None
        try:
            while not self._stop.is_set():
                try:
                    info = work.get_nowait()
                except queue.Empty:
                    break
                t0 = time.perf_counter()
                with tracer.span("io.read_shard", seq=info.seq):
                    reader = ShardReader(info.path, verify=self.verify)
                    env = reader.read_all(self.columns)
                    if self.transform is not None:
                        env = self.transform(env, info)
                dt = time.perf_counter() - t0
                with self._lock:
                    self.stats.shards += 1
                    self.stats.bytes_read += reader.nbytes
                    self.stats.bytes_decoded += reader.bytes_decoded
                    self.stats.columns_decoded += reader.columns_decoded
                    self.stats.read_seconds += dt
                self._put(out, env)
        except BaseException as e:  # propagate to the consumer
            self._put(out, _ReaderError(e, info.path if info else "?"),
                      force=True)
        finally:
            self._put(out, _WORKER_DONE, force=True)

    def _put(self, out: "queue.Queue", item: Any, *, force: bool = False) -> None:
        """Bounded put that respects close(); stall time is backpressure.

        After close() the consumer is gone, so every put (sentinels
        included) aborts rather than spinning on a full queue.
        """
        tracer = get_tracer()
        w0 = tracer.now_ns() if tracer.enabled else 0
        t0 = time.perf_counter()
        while True:
            try:
                out.put(item, timeout=0.05)
                break
            except queue.Full:
                if self._stop.is_set():
                    return
        stall = time.perf_counter() - t0
        if stall > 1e-4 and not force:
            with self._lock:
                self.stats.reader_stall_seconds += stall
            if tracer.enabled:
                # Reader blocked on a full queue: the consumer (FE/train)
                # is the bottleneck over this window.
                tracer.complete("io.backpressure", w0, tracer.now_ns())

    # ------------------------------------------------------------ iteration
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        if self._running:
            raise RuntimeError("StreamingLoader is already being iterated")
        # Fresh stats per pass: a reused loader must not blend a prior
        # (possibly abandoned) pass into this run's throughput numbers.
        # Under _lock: a prior pass's readers may still be draining.
        with self._lock:
            self.stats = IngestStats()
        plan = self._shard_plan()
        work: "queue.Queue" = queue.Queue()
        for info in plan:
            work.put(info)
        # DONE sentinels flow through the bounded queue too, so capacity
        # must fit them even when every worker finishes at once.
        out: "queue.Queue" = queue.Queue(
            maxsize=max(self.prefetch, self.workers))
        n_workers = min(self.workers, max(1, len(plan)))
        self._stop.clear()
        self._out = out
        self._threads = [
            threading.Thread(target=self._reader, args=(work, out),
                             daemon=True, name=f"shard-reader-{i}")
            for i in range(n_workers)
        ]
        self._running = True
        t_start = time.perf_counter()
        for t in self._threads:
            t.start()
        tracer = get_tracer()
        done = 0
        try:
            while done < n_workers:
                w0 = tracer.now_ns() if tracer.enabled else 0
                t0 = time.perf_counter()
                item = out.get()
                stall = time.perf_counter() - t0
                if stall > 1e-4:
                    # Under _lock: readers concurrently update sibling
                    # IngestStats fields (repro.check rule LK402).
                    with self._lock:
                        self.stats.consumer_stall_seconds += stall
                    if tracer.enabled:
                        # Consumer blocked on an empty queue: the disk /
                        # decode side is the bottleneck over this window.
                        tracer.complete("io.wait_shard", w0, tracer.now_ns())
                with self._lock:
                    self.stats.max_queue_depth = max(
                        self.stats.max_queue_depth, out.qsize() + 1)
                tracer.counter("io.queue_depth", out.qsize() + 1)
                if item is _WORKER_DONE:
                    done += 1
                    continue
                if isinstance(item, _ReaderError):
                    raise RuntimeError(
                        f"shard reader failed on {item.shard}") from item.exc
                yield item
        finally:
            with self._lock:
                self.stats.wall_seconds += time.perf_counter() - t_start
            self.close()

    def close(self) -> None:
        """Stop readers and release queue slots (idempotent).

        Readers may refill the queue between drains (a shard decode was in
        flight), so drain-and-join loops until every thread has exited.
        """
        self._stop.set()
        for t in self._threads:
            while t.is_alive():
                if self._out is not None:
                    try:
                        while True:
                            self._out.get_nowait()
                    except queue.Empty:
                        pass
                t.join(timeout=0.1)
        self._threads = []
        self._running = False
