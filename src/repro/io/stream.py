"""Fault-tolerant multi-worker shard loader with lease-based scheduling.

:class:`StreamingLoader` turns a :class:`~repro.io.dataset.ShardDataset`
(or a plain list of shard paths) into an iterator of ``{table: columns}``
environments — exactly the batch shape the FE runners consume — while a
pool of reader threads keeps the disk busy. Shards are *leased* from a
:class:`~repro.train.fault.ShardServer` rather than drained from a static
queue (ROADMAP item 4):

    ShardServer (leases) <- N reader threads -> bounded output queue
          ^  ^
          |  heartbeat thread (keeps live readers' leases fresh)
          reaper thread (expires dead readers' leases; issues backups)

Recovery story, proven by ``tests/test_chaos.py`` under injected faults
(:mod:`repro.io.chaos`):

* A reader that dies mid-shard stops heartbeating; the reaper returns its
  lease to the queue and another reader re-reads the shard — no data loss.
  The consumer respawns chaos-killed readers (bounded budget) so even a
  single-worker pool survives.
* ``StragglerPolicy`` duplicate-issues shards running slower than
  p50 x factor; commits are strictly first-wins in the server, so every
  shard is yielded downstream **exactly once** (losers discard their copy).
* Transient ``OSError`` reads get bounded retry-with-backoff (``io.retry``
  spans); :class:`~repro.io.shardfmt.ShardFormatError` — checksum/format
  corruption — still fails the job fast, never retried.
* Commit-then-yield ordering: a reader publishes to the consumer only
  after winning the commit, and nothing can kill it between the two
  (chaos kill points are pre-commit by design; threads don't die
  spontaneously between adjacent statements), so the commit log is
  exactly the set of yielded shards.
* ``ordered=True`` re-sequences completions into plan order through a
  small consumer-side reorder buffer, making a chaos run's yielded stream
  *bit-identical* to the failure-free run — at the cost of head-of-line
  blocking on the oldest outstanding shard.

The output queue bounds memory (backpressure: readers block when the
consumer falls behind) and :class:`IngestStats` records where time went:

* ``read_seconds``          — readers doing disk I/O + decode,
* ``reader_stall_seconds``  — readers blocked on a full queue
  (consumer-bound: the trainer can't keep up),
* ``consumer_stall_seconds``— consumer blocked on an empty queue
  (reader-bound: the disk can't keep up).

Only the commit *winner* updates :class:`IngestStats` (``stats.shards``
stays the epoch's shard count under duplicate reads); recovery activity is
a separate tier, :class:`~repro.train.fault.FaultStats`, exposed as
:attr:`StreamingLoader.fault_stats` and registered as the ``fault.*``
metrics tier.

Reader-thread exceptions are re-raised in the consumer, so a corrupt shard
fails the training job instead of silently shrinking the epoch.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import (Any, Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Union)

from repro.check.annotations import guarded_by, single_writer
from repro.io.chaos import ChaosInjector, ChaosKill
from repro.io.dataset import ShardDataset, ShardInfo
from repro.io.shardfmt import ShardReader
from repro.obs.metrics import harvest
from repro.obs.trace import get_tracer
from repro.train.fault import FaultStats, ShardServer, StragglerPolicy


@dataclasses.dataclass
class _ReaderError:
    exc: BaseException
    shard: str


@dataclasses.dataclass
class IngestStats:
    shards: int = 0
    bytes_read: int = 0
    # Projection pushdown accounting: payload bytes / columns actually
    # decoded (== bytes_read's payload when no column projection is set).
    bytes_decoded: int = 0
    columns_decoded: int = 0
    read_seconds: float = 0.0
    reader_stall_seconds: float = 0.0
    consumer_stall_seconds: float = 0.0
    wall_seconds: float = 0.0
    max_queue_depth: int = 0

    @property
    def read_bytes_per_second(self) -> float:
        """Disk+decode throughput of the reader pool (sum over workers)."""
        return self.bytes_read / max(self.read_seconds, 1e-9)

    @property
    def wall_bytes_per_second(self) -> float:
        """End-to-end ingest throughput as the consumer observed it."""
        return self.bytes_read / max(self.wall_seconds, 1e-9)

    def as_metrics(self) -> "dict":
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)

    def summary(self) -> str:
        return (f"shards={self.shards} bytes={self.bytes_read/2**20:.1f}MiB "
                f"decoded={self.bytes_decoded/2**20:.1f}MiB "
                f"({self.columns_decoded} cols) "
                f"read={self.read_seconds:.2f}s "
                f"({self.read_bytes_per_second/2**20:.0f}MiB/s) "
                f"wall={self.wall_seconds:.2f}s "
                f"({self.wall_bytes_per_second/2**20:.0f}MiB/s) "
                f"reader_stall={self.reader_stall_seconds:.2f}s "
                f"consumer_stall={self.consumer_stall_seconds:.2f}s")


# Thread contract (verified by `python -m repro.check` / repro.check.lockset):
# N reader threads and the consuming thread both update IngestStats and the
# active-lease map the heartbeater reads, so every write to `stats` /
# `_active` (including the per-pass rebinds in __iter__) holds _lock. The
# pool plumbing — thread lists, the lease server, the plan, the respawn
# budget — is only ever written by the consumer thread (spawn/respawn/close
# all happen there); readers and the aux threads only read it.
@guarded_by("_lock", "stats", "_active")
@single_writer("_threads", "_aux_threads", "_reader_threads", "_out",
               "_running", "_server", "_plan", "_respawns", "_clean")
class StreamingLoader:
    """Iterate shard environments with a fault-tolerant reader pool.

    Parameters
    ----------
    source:
        :class:`ShardDataset`, or a sequence of shard paths /
        :class:`ShardInfo`.
    workers:
        Reader threads. 1 gives deterministic shard order; more overlap
        seeks and decode.
    prefetch:
        Output queue capacity (decoded shards held ahead of the consumer).
    epochs:
        How many passes over the source to enqueue.
    shuffle / seed:
        Per-epoch deterministic shard-order shuffle (datasets only).
    transform:
        Optional ``fn(env, info) -> env`` applied in the reader thread, so
        per-shard host work (filtering, re-batching) overlaps the consumer.
    columns:
        Optional projection ``{table: [column, ...]}`` — typically a
        ``FeaturePlan.required_columns`` — pushed down into
        :meth:`ShardReader.read_all` so untouched tables/columns are never
        decoded from disk. ``IngestStats.bytes_decoded`` /
        ``columns_decoded`` make the saving observable.
    verify:
        Verify payload checksums while decoding (default on).
    lease_timeout:
        Seconds without a heartbeat before the reaper returns a reader's
        shard to the queue. Small values recover faster but may reap a
        reader that is merely slow (first-commit-wins makes that safe,
        just wasteful).
    retries / retry_backoff:
        Bounded retry for transient ``OSError`` reads: up to ``retries``
        re-reads with exponential backoff starting at ``retry_backoff``
        seconds. Corruption (``ShardFormatError``) is never retried.
    straggler:
        Optional :class:`~repro.train.fault.StragglerPolicy`; by default a
        fresh policy per pass duplicate-issues shards slower than
        p50 x factor.
    chaos:
        Optional :class:`~repro.io.chaos.ChaosInjector` firing scheduled
        faults at the lease lifecycle's injection points (tests/demos).
    ordered:
        Yield in plan order via a consumer-side reorder buffer (makes
        multi-worker and chaos runs bit-identical to ``workers=1``); off
        by default — completion order maximizes pipeline overlap.
    max_respawns:
        Budget for replacing dead readers (default ``2*workers + 2``);
        exhausting it raises instead of looping forever under a
        kill-everything chaos schedule.
    """

    def __init__(self, source: Union[ShardDataset, Sequence],
                 *, workers: int = 2, prefetch: int = 4, epochs: int = 1,
                 shuffle: bool = False, seed: int = 0,
                 transform: Optional[Callable[[Dict[str, Any], ShardInfo],
                                              Dict[str, Any]]] = None,
                 columns: Optional[Mapping[str, Sequence[str]]] = None,
                 verify: bool = True,
                 lease_timeout: float = 30.0,
                 retries: int = 2, retry_backoff: float = 0.05,
                 straggler: Optional[StragglerPolicy] = None,
                 chaos: Optional[ChaosInjector] = None,
                 ordered: bool = False,
                 max_respawns: Optional[int] = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if prefetch < 1:
            raise ValueError(f"prefetch must be >= 1, got {prefetch}")
        if epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {epochs}")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        self.source = source
        self.workers = workers
        self.prefetch = prefetch
        self.epochs = epochs
        self.shuffle = shuffle
        self.seed = seed
        self.transform = transform
        self.columns = (None if columns is None
                        else {t: tuple(c) for t, c in columns.items()})
        self.verify = verify
        self.lease_timeout = lease_timeout
        self.retries = retries
        self.retry_backoff = retry_backoff
        self.straggler = straggler
        self.chaos = chaos
        self.ordered = ordered
        self.max_respawns = max_respawns
        self.stats = IngestStats()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._aux_threads: List[threading.Thread] = []
        self._reader_threads: Dict[str, threading.Thread] = {}
        self._out: Optional[queue.Queue] = None
        self._running = False
        self._server: Optional[ShardServer] = None
        self._plan: List[ShardInfo] = []
        self._active: Dict[str, int] = {}
        self._clean: set = set()
        self._respawns = 0

    @property
    def rows_hint(self) -> Optional[int]:
        """Largest shard row count this loader will emit, if known.

        Pre-sizes downstream staging arenas (``DeviceFeeder(rows_hint=...)``)
        at compile time from the dataset manifest instead of growing on the
        first oversized batch. ``None`` when the source carries no row
        counts (plain path lists).
        """
        if isinstance(self.source, ShardDataset):
            rows = [s.n_rows for s in self.source.local_shards if s.n_rows]
            return max(rows) if rows else None
        rows = [s.n_rows for s in self.source
                if isinstance(s, ShardInfo) and s.n_rows]
        return max(rows) if rows else None

    @property
    def fault_stats(self) -> FaultStats:
        """The current (or last) pass's recovery counters — the ``fault.*``
        metrics tier, owned by the lease server."""
        server = self._server
        return server.stats if server is not None else FaultStats()

    # ------------------------------------------------------------- plumbing
    def _shard_plan(self) -> List[ShardInfo]:
        if isinstance(self.source, ShardDataset):
            return self.source.epoch_plan(self.epochs, shuffle=self.shuffle,
                                          seed=self.seed)
        plan: List[ShardInfo] = []
        for _epoch in range(self.epochs):
            for i, it in enumerate(self.source):
                if not isinstance(it, ShardInfo):
                    import os
                    it = ShardInfo(path=str(it),
                                   nbytes=os.path.getsize(str(it)),
                                   n_rows=0, seq=i)
                plan.append(it)
        return plan

    def _read_with_retry(self, info: ShardInfo, sid: int, worker_id: str):
        """One shard read with bounded transient-error retry.

        Returns ``(reader, env, seconds)``. ``OSError`` (real filesystem
        hiccups and injected :class:`ChaosTransientIOError`) retries up to
        ``self.retries`` times with exponential backoff, heartbeating the
        lease between attempts; :class:`ShardFormatError` (corruption) and
        :class:`ChaosKill` pass straight through.
        """
        tracer = get_tracer()
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                with tracer.span("io.read_shard", seq=info.seq,
                                 attempt=attempt):
                    if self.chaos is not None:
                        self.chaos.trip("read", sid, worker_id)
                    reader = ShardReader(info.path, verify=self.verify)
                    env = reader.read_all(self.columns)
                    if self.transform is not None:
                        env = self.transform(env, info)
                return reader, env, time.perf_counter() - t0
            except OSError as e:
                attempt += 1
                if attempt > self.retries:
                    raise
                server = self._server
                if server is not None:
                    server.record_retry()
                    server.heartbeat(worker_id, sid)
                w0 = tracer.now_ns() if tracer.enabled else 0
                aborted = self._stop.wait(
                    self.retry_backoff * (2 ** (attempt - 1)))
                if tracer.enabled:
                    tracer.complete("io.retry", w0, tracer.now_ns(),
                                    seq=info.seq, attempt=attempt,
                                    error=type(e).__name__)
                if aborted:
                    raise

    def _lease_reader(self, worker_id: str, out: "queue.Queue") -> None:
        """Reader-thread body: acquire -> read (retry) -> commit -> publish.

        Publish strictly follows a *winning* commit, so the server's commit
        log is exactly the multiset of yielded shards; a lost commit race
        (backup or reissued duplicate finished first) discards the copy
        without touching IngestStats.
        """
        tracer = get_tracer()
        server = self._server
        info: Optional[ShardInfo] = None
        try:
            while not self._stop.is_set():
                sid = server.acquire(worker_id)
                if sid is None:
                    if server.done():
                        break
                    # In-flight leases may yet be reaped or backed up.
                    time.sleep(0.005)
                    continue
                info = self._plan[sid]
                with self._lock:
                    self._active[worker_id] = sid
                try:
                    if self.chaos is not None:
                        self.chaos.trip("acquire", sid, worker_id)
                    reader, env, dt = self._read_with_retry(
                        info, sid, worker_id)
                    if self.chaos is not None:
                        # Worst kill point: work done but unacknowledged.
                        self.chaos.trip("commit", sid, worker_id)
                finally:
                    with self._lock:
                        self._active.pop(worker_id, None)
                if server.commit(worker_id, sid):
                    with self._lock:
                        self.stats.shards += 1
                        self.stats.bytes_read += reader.nbytes
                        self.stats.bytes_decoded += reader.bytes_decoded
                        self.stats.columns_decoded += reader.columns_decoded
                        self.stats.read_seconds += dt
                    self._put(out, (sid, env))
        except ChaosKill:
            # Simulated silent death: no fail_worker, no error to the
            # consumer — recovery must come from the lease reaper, exactly
            # as for a SIGKILL'd worker.
            if tracer.enabled:
                tracer.instant("fault.kill", worker=worker_id)
            return
        except BaseException as e:  # propagate to the consumer
            server.fail_worker(worker_id)
            self._put(out, _ReaderError(e, info.path if info else "?"),
                      force=True)
            return
        with self._lock:
            self._clean.add(worker_id)

    def _heartbeat_loop(self) -> None:
        """Refresh every live reader's lease; a dead reader's lease goes
        stale (the thread-alive check is what lets the reaper notice)."""
        server = self._server
        interval = max(min(self.lease_timeout / 4.0, 1.0), 0.01)
        while not self._stop.is_set():
            with self._lock:
                active = dict(self._active)
            threads = dict(self._reader_threads)
            for worker_id, sid in active.items():
                t = threads.get(worker_id)
                if t is not None and t.is_alive():
                    server.heartbeat(worker_id, sid)
            if server.done():
                break
            self._stop.wait(interval)

    def _reaper_loop(self) -> None:
        """Expire dead readers' leases and duplicate-issue stragglers."""
        tracer = get_tracer()
        server = self._server
        interval = max(min(self.lease_timeout / 2.0, 1.0), 0.01)
        while not self._stop.is_set():
            w0 = tracer.now_ns() if tracer.enabled else 0
            reissued = server.reap()
            if reissued and tracer.enabled:
                tracer.complete("fault.reap", w0, tracer.now_ns(),
                                reissued=len(reissued))
            for sid in server.issue_backups():
                if tracer.enabled:
                    tracer.instant("fault.backup", shard=sid)
            if server.done():
                break
            self._stop.wait(interval)

    def _ensure_readers(self, out: "queue.Queue") -> None:
        """Consumer-side pool supervision (runs when the queue goes quiet):
        respawn readers that died without finishing (chaos kills), within
        the respawn budget; raise if the whole pool is gone with shards
        still uncommitted."""
        server = self._server
        if server is None or server.done() or self._stop.is_set():
            return
        with self._lock:
            clean = set(self._clean)
        dead = [wid for wid, t in self._reader_threads.items()
                if not t.is_alive() and wid not in clean]
        if not dead:
            return
        tracer = get_tracer()
        budget = (self.max_respawns if self.max_respawns is not None
                  else 2 * self.workers + 2)
        for wid in dead:
            self._reader_threads.pop(wid, None)
            if self._respawns >= budget:
                raise RuntimeError(
                    f"shard reader pool exhausted: {self._respawns} respawns "
                    f"used and reader {wid!r} died with shards uncommitted")
            self._respawns += 1
            server.record_respawn()
            new_wid = f"reader-r{self._respawns}"
            t = threading.Thread(target=self._lease_reader,
                                 args=(new_wid, out), daemon=True,
                                 name=f"shard-reader-r{self._respawns}")
            self._reader_threads[new_wid] = t
            self._threads.append(t)
            t.start()
            if tracer.enabled:
                tracer.instant("fault.respawn", worker=new_wid,
                               replacing=wid)

    def _put(self, out: "queue.Queue", item: Any, *, force: bool = False) -> None:
        """Bounded put that respects close(); stall time is backpressure.

        After close() the consumer is gone, so every put (errors included)
        aborts rather than spinning on a full queue.
        """
        tracer = get_tracer()
        w0 = tracer.now_ns() if tracer.enabled else 0
        t0 = time.perf_counter()
        while True:
            try:
                out.put(item, timeout=0.05)
                break
            except queue.Full:
                if self._stop.is_set():
                    return
        stall = time.perf_counter() - t0
        if stall > 1e-4 and not force:
            with self._lock:
                self.stats.reader_stall_seconds += stall
            if tracer.enabled:
                # Reader blocked on a full queue: the consumer (FE/train)
                # is the bottleneck over this window.
                tracer.complete("io.backpressure", w0, tracer.now_ns())

    # ------------------------------------------------------------ iteration
    def __iter__(self) -> Iterator[Dict[str, Any]]:
        if self._running:
            raise RuntimeError("StreamingLoader is already being iterated")
        # Fresh stats per pass: a reused loader must not blend a prior
        # (possibly abandoned) pass into this run's throughput numbers.
        # Under _lock: a prior pass's readers may still be draining.
        with self._lock:
            self.stats = IngestStats()
            self._active = {}
        plan = self._shard_plan()
        self._plan = plan
        self._server = ShardServer(
            len(plan), lease_timeout=self.lease_timeout,
            straggler=(self.straggler if self.straggler is not None
                       else StragglerPolicy()))
        out: "queue.Queue" = queue.Queue(
            maxsize=max(self.prefetch, self.workers))
        n_workers = min(self.workers, max(1, len(plan)))
        self._stop.clear()
        self._out = out
        self._clean = set()
        self._respawns = 0
        self._reader_threads = {}
        for i in range(n_workers):
            wid = f"reader-{i}"
            self._reader_threads[wid] = threading.Thread(
                target=self._lease_reader, args=(wid, out),
                daemon=True, name=f"shard-reader-{i}")
        self._threads = list(self._reader_threads.values())
        self._aux_threads = [
            threading.Thread(target=self._heartbeat_loop, daemon=True,
                             name="shard-heartbeat"),
            threading.Thread(target=self._reaper_loop, daemon=True,
                             name="shard-reaper"),
        ]
        self._running = True
        t_start = time.perf_counter()
        for t in self._threads:
            t.start()
        for t in self._aux_threads:
            t.start()
        tracer = get_tracer()
        n_items = len(plan)
        received = 0
        next_out = 0
        hold: Dict[int, Any] = {}  # ordered-mode reorder buffer
        try:
            while received < n_items:
                w0 = tracer.now_ns() if tracer.enabled else 0
                t0 = time.perf_counter()
                item = None
                while item is None:
                    try:
                        item = out.get(timeout=0.05)
                    except queue.Empty:
                        # Quiet queue: check the pool (a chaos-killed
                        # reader is invisible until someone looks).
                        self._ensure_readers(out)
                stall = time.perf_counter() - t0
                if stall > 1e-4:
                    # Under _lock: readers concurrently update sibling
                    # IngestStats fields (repro.check rule LK402).
                    with self._lock:
                        self.stats.consumer_stall_seconds += stall
                    if tracer.enabled:
                        # Consumer blocked on an empty queue: the disk /
                        # decode side is the bottleneck over this window.
                        tracer.complete("io.wait_shard", w0, tracer.now_ns())
                with self._lock:
                    self.stats.max_queue_depth = max(
                        self.stats.max_queue_depth, out.qsize() + 1)
                tracer.counter("io.queue_depth", out.qsize() + 1)
                if isinstance(item, _ReaderError):
                    raise RuntimeError(
                        f"shard reader failed on {item.shard}") from item.exc
                sid, env = item
                received += 1
                if self.ordered:
                    hold[sid] = env
                    while next_out in hold:
                        yield hold.pop(next_out)
                        next_out += 1
                else:
                    yield env
        finally:
            with self._lock:
                self.stats.wall_seconds += time.perf_counter() - t_start
            self.close()

    def close(self) -> None:
        """Stop readers and release queue slots (idempotent).

        Readers may refill the queue between drains (a shard decode was in
        flight), so drain-and-join loops until every thread has exited.
        """
        self._stop.set()
        for t in self._threads + self._aux_threads:
            while t.is_alive():
                if self._out is not None:
                    try:
                        while True:
                            self._out.get_nowait()
                    except queue.Empty:
                        pass
                t.join(timeout=0.1)
        self._threads = []
        self._aux_threads = []
        self._reader_threads = {}
        self._running = False
