"""Deterministic fault injection for the ingest tier.

A :class:`ChaosInjector` is threaded into :class:`repro.io.stream
.StreamingLoader`; reader threads call :meth:`ChaosInjector.trip` at three
well-defined points of the lease lifecycle and the injector decides — from
a schedule that is a pure function of its construction (spec string or
seed) — whether to fail them there. The chaos tests in
``tests/test_chaos.py`` use it to prove the recovery story end to end:
kill a reader mid-epoch and the consumed stream is bit-identical to the
failure-free run.

Injection points (``point`` argument to :meth:`trip`):

``acquire``
    Immediately after a reader leases the shard, before any read — models
    a worker dying with a fresh lease (pure reap/reissue path).
``read``
    Between payload read and commit — models mid-read death, transient
    filesystem errors (``ChaosTransientIOError``, an ``OSError`` the
    loader's bounded retry absorbs), injected latency, and corrupt
    payloads (``ShardFormatError`` — must fail fast, never retry).
``commit``
    After a successful read, immediately *before* ``ShardServer.commit`` —
    the worst kill point: work done but unacknowledged, so the shard is
    reaped and fully re-read elsewhere.

Kills are delivered as :class:`ChaosKill`, a ``BaseException`` subclass so
neither the retry loop's ``except OSError`` nor any blanket ``except
Exception`` in the read path can absorb it — it unwinds the reader like a
real thread death. The loader intentionally does *not* call
``fail_worker`` for it: recovery must come from the lease timeout + reaper,
the path a genuine silent death would take.

Schedules come from :func:`parse_chaos_spec` (the driver's ``--chaos``
flag, e.g. ``"kill@3,transient@1:read:2,delay@2:read:0.05"``) or
:func:`random_schedule` (seeded, for soak-style tests).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.check.annotations import guarded_by, shared_entry
from repro.io.shardfmt import ShardFormatError

KINDS = ("kill", "delay", "transient", "corrupt")
POINTS = ("acquire", "read", "commit")


class ChaosKill(BaseException):
    """Simulated reader-thread death.

    Deliberately *not* an ``Exception``: it must sail through the retry
    loop and the reader's error wrapper so the only observer is the lease
    reaper — exactly like a SIGKILL'd worker process.
    """


class ChaosTransientIOError(OSError):
    """Injected transient read failure (retryable, unlike corruption)."""


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault: fire ``kind`` at (``shard``, ``point``),
    ``count`` times; ``delay_seconds`` only applies to ``kind='delay'``."""

    kind: str
    shard: int
    point: str = "read"
    count: int = 1
    delay_seconds: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r} (want {KINDS})")
        if self.point not in POINTS:
            raise ValueError(
                f"unknown chaos point {self.point!r} (want {POINTS})")
        if self.count < 1:
            raise ValueError(f"count must be >= 1, got {self.count}")
        if self.kind == "delay" and self.delay_seconds <= 0:
            raise ValueError("delay event needs delay_seconds > 0")


# `trip` is called concurrently from every reader thread; the schedule's
# remaining-count bookkeeping and the fired log are the shared state.
@guarded_by("_lock", "_remaining", "fired")
@shared_entry("trip", "exhausted")
class ChaosInjector:
    """Fires scheduled faults when readers pass injection points.

    Deterministic: which (shard, point) pairs fire, what they raise, and
    how many times is fixed at construction. *Which reader thread* trips a
    given shard still depends on runtime scheduling — irrelevant to the
    exactly-once guarantees under test, which quantify over shards.
    """

    def __init__(self, events: Sequence[ChaosEvent] = ()):
        self.events = tuple(events)
        # (shard, point) -> [event, fires_left] in schedule order
        self._remaining: Dict[Tuple[int, str], List[List[object]]] = {}
        for ev in self.events:
            self._remaining.setdefault((ev.shard, ev.point), []).append(
                [ev, ev.count])
        self.fired: Dict[str, int] = {k: 0 for k in KINDS}
        self._lock = threading.Lock()

    @classmethod
    def from_spec(cls, spec: str) -> "ChaosInjector":
        return cls(parse_chaos_spec(spec))

    @classmethod
    def random(cls, seed: int, n_shards: int, *, p_kill: float = 0.05,
               p_transient: float = 0.1, p_delay: float = 0.1,
               max_delay: float = 0.02) -> "ChaosInjector":
        return cls(random_schedule(seed, n_shards, p_kill=p_kill,
                                   p_transient=p_transient, p_delay=p_delay,
                                   max_delay=max_delay))

    def trip(self, point: str, shard: int, worker_id: str = "?") -> None:
        """Fire any scheduled faults for (shard, point).

        Raises :class:`ChaosKill` / :class:`ChaosTransientIOError` /
        :class:`ShardFormatError` per the schedule; delays sleep and
        return. Sleeping/raising happens outside the lock.
        """
        to_fire: List[ChaosEvent] = []
        with self._lock:
            for slot in self._remaining.get((shard, point), ()):
                ev, left = slot
                if left > 0:
                    slot[1] = left - 1
                    self.fired[ev.kind] += 1
                    to_fire.append(ev)
        delay = 0.0
        raising: Optional[ChaosEvent] = None
        for ev in to_fire:
            if ev.kind == "delay":
                delay += ev.delay_seconds
            elif raising is None:
                raising = ev
        if delay:
            time.sleep(delay)
        if raising is None:
            return
        if raising.kind == "transient":
            raise ChaosTransientIOError(
                f"chaos: transient I/O error on shard {shard} at {point} "
                f"(worker {worker_id})")
        if raising.kind == "corrupt":
            raise ShardFormatError(
                f"chaos: corrupt payload on shard {shard} (worker {worker_id})")
        raise ChaosKill(f"chaos: killed {worker_id} at {point} of shard {shard}")

    def exhausted(self) -> bool:
        """True when every scheduled fault has fired."""
        with self._lock:
            return all(slot[1] == 0
                       for slots in self._remaining.values()
                       for slot in slots)


def parse_chaos_spec(spec: str) -> List[ChaosEvent]:
    """Parse the driver's ``--chaos`` mini-language.

    Comma-separated events, each ``kind@shard[:point][:arg]``:

    - ``kill@3`` — kill the reader holding shard 3 mid-read
    - ``kill@3:commit`` — kill it after the read, before the commit
    - ``transient@1:read:2`` — two transient I/O errors on shard 1
    - ``delay@2:read:0.05`` — 50 ms of injected latency on shard 2
    - ``corrupt@5`` — corrupt shard 5's payload (must fail fast)

    The numeric third field means ``count`` for transient/kill and
    ``delay_seconds`` for delay.
    """
    events: List[ChaosEvent] = []
    for raw in spec.split(","):
        item = raw.strip()
        if not item:
            continue
        if "@" not in item:
            raise ValueError(f"bad chaos event {item!r}: expected kind@shard")
        kind, _, rest = item.partition("@")
        parts = rest.split(":")
        if not parts[0]:
            raise ValueError(f"bad chaos event {item!r}: missing shard id")
        try:
            shard = int(parts[0])
        except ValueError:
            raise ValueError(
                f"bad chaos event {item!r}: shard must be an int") from None
        point = parts[1] if len(parts) > 1 and parts[1] else "read"
        count, delay_seconds = 1, 0.0
        if len(parts) > 2 and parts[2]:
            if kind == "delay":
                delay_seconds = float(parts[2])
            else:
                count = int(parts[2])
        elif kind == "delay":
            delay_seconds = 0.01
        if len(parts) > 3:
            raise ValueError(f"bad chaos event {item!r}: too many fields")
        events.append(ChaosEvent(kind=kind, shard=shard, point=point,
                                 count=count, delay_seconds=delay_seconds))
    return events


def random_schedule(seed: int, n_shards: int, *, p_kill: float = 0.05,
                    p_transient: float = 0.1, p_delay: float = 0.1,
                    max_delay: float = 0.02) -> List[ChaosEvent]:
    """Seeded random fault schedule over ``n_shards`` (soak tests).

    Never schedules ``corrupt`` — corruption is unrecoverable by design,
    so random soaks stay completable.
    """
    rng = np.random.default_rng(seed)
    events: List[ChaosEvent] = []
    for sid in range(n_shards):
        if rng.random() < p_kill:
            point = POINTS[int(rng.integers(len(POINTS)))]
            events.append(ChaosEvent("kill", sid, point))
        if rng.random() < p_transient:
            events.append(ChaosEvent("transient", sid, "read",
                                     count=int(rng.integers(1, 3))))
        if rng.random() < p_delay:
            events.append(ChaosEvent(
                "delay", sid, "read",
                delay_seconds=float(rng.uniform(0.001, max_delay))))
    return events
