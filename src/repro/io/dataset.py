"""Shard discovery, manifests, and deterministic host assignment.

A *dataset* is a directory of ``.fbshard`` files plus an optional
``manifest.json`` recording per-shard size/rows (written at conversion time
so discovery never has to open every shard). Assignment to hosts is
round-robin over the manifest order — ``shards[host_id::n_hosts]`` — which
is a disjoint cover, is stable across runs, and composes with the data axis
of ``launch/mesh.py``: host *i* of *n* always streams the same shard subset,
so restarts and stragglers re-read identical data.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence

import numpy as np

from repro.io.shardfmt import SHARD_SUFFIX, ShardFormatError, ShardReader

MANIFEST_NAME = "manifest.json"
_FORMAT = "fbshard.v1"


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    """One shard as discovered from a manifest or directory scan."""

    path: str
    nbytes: int
    n_rows: int      # rows of the primary (instance) table
    seq: int         # position in manifest order; assignment key

    @property
    def name(self) -> str:
        return os.path.basename(self.path)


def write_manifest(data_dir: str, shard_paths: Sequence[str] = (),
                   *, primary: str = "impressions",
                   extra: Optional[Mapping[str, Any]] = None,
                   entries: Optional[List[Dict[str, Any]]] = None) -> str:
    """Write ``manifest.json``.

    Writers that just produced the shards pass prebuilt ``entries``
    (``{file, nbytes, n_rows}``) so nothing is reopened; the
    ``shard_paths`` form reads each shard's index — the repair path for a
    directory of pre-existing shards.
    """
    if entries is None:
        entries = []
        for path in shard_paths:
            r = ShardReader(path)
            table = primary if primary in r.table_names else r.table_names[0]
            entries.append({
                "file": os.path.basename(path),
                "nbytes": r.nbytes,
                "n_rows": r.n_rows(table),
            })
    manifest = {
        "format": _FORMAT,
        "primary": primary,
        "shards": entries,
        **dict(extra or {}),
    }
    path = os.path.join(data_dir, MANIFEST_NAME)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, path)
    return path


def assign_shards(shards: Sequence, host_id: int, n_hosts: int) -> List:
    """Round-robin host assignment: a disjoint cover of ``shards``.

    ``assign_shards(s, i, n) for i in range(n)`` partitions ``s``: every
    shard lands on exactly one host, and hosts differ in size by at most 1.
    """
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    if not 0 <= host_id < n_hosts:
        raise ValueError(f"host_id {host_id} out of range [0, {n_hosts})")
    return list(shards[host_id::n_hosts])


class ShardDataset:
    """Shards of one data directory, filtered to this host's assignment."""

    def __init__(self, data_dir: str, *, host_id: int = 0, n_hosts: int = 1,
                 primary: str = "impressions"):
        self.data_dir = data_dir
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.primary = primary
        self.shards: List[ShardInfo] = self._discover()
        if not self.shards:
            raise FileNotFoundError(
                f"no {SHARD_SUFFIX} shards under {data_dir!r}")
        self.local_shards: List[ShardInfo] = assign_shards(
            self.shards, host_id, n_hosts)

    def _discover(self) -> List[ShardInfo]:
        mpath = os.path.join(self.data_dir, MANIFEST_NAME)
        if os.path.exists(mpath):
            with open(mpath) as f:
                manifest = json.load(f)
            if manifest.get("format") != _FORMAT:
                raise ShardFormatError(
                    f"{mpath}: unknown manifest format "
                    f"{manifest.get('format')!r}")
            return [
                ShardInfo(path=os.path.join(self.data_dir, e["file"]),
                          nbytes=int(e["nbytes"]), n_rows=int(e["n_rows"]),
                          seq=i)
                for i, e in enumerate(manifest["shards"])
            ]
        # No manifest: scan the directory (sorted for determinism) and pull
        # row counts from each shard's index.
        out = []
        for i, path in enumerate(
                sorted(glob.glob(os.path.join(self.data_dir,
                                              "*" + SHARD_SUFFIX)))):
            r = ShardReader(path)
            table = (self.primary if self.primary in r.table_names
                     else r.table_names[0])
            out.append(ShardInfo(path=path, nbytes=r.nbytes,
                                 n_rows=r.n_rows(table), seq=i))
        return out

    # ------------------------------------------------------------ iteration
    def epoch_order(self, epoch: int = 0, *, shuffle: bool = False,
                    seed: int = 0) -> List[ShardInfo]:
        """This host's shards for ``epoch``, optionally shuffled.

        The permutation is a deterministic function of ``(seed, epoch)``, so
        every host reshuffles consistently and restarts replay the same
        order.
        """
        local = self.local_shards
        if not shuffle:
            return list(local)
        perm = np.random.default_rng(
            (seed, epoch)).permutation(len(local))
        return [local[i] for i in perm]

    def epoch_plan(self, epochs: int, *, shuffle: bool = False,
                   seed: int = 0) -> List[ShardInfo]:
        """Concatenated :meth:`epoch_order` over ``epochs`` passes.

        This is the loader's full lease plan: plan index == shard id in
        :class:`~repro.train.fault.ShardServer`, so one ShardInfo appears
        once per epoch under distinct ids and restarts replay identically.
        """
        if epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {epochs}")
        plan: List[ShardInfo] = []
        for epoch in range(epochs):
            plan.extend(self.epoch_order(epoch, shuffle=shuffle, seed=seed))
        return plan

    def __len__(self) -> int:
        return len(self.local_shards)

    def __iter__(self) -> Iterator[ShardInfo]:
        return iter(self.local_shards)

    @property
    def total_bytes(self) -> int:
        return sum(s.nbytes for s in self.local_shards)

    @property
    def total_rows(self) -> int:
        return sum(s.n_rows for s in self.local_shards)
