"""Bulk conversion of in-memory views / column-store chunks into shards.

Two producers feed the shard tier:

* :func:`write_view_shards` — an iterable of per-batch view dicts (what
  ``fe.datagen.gen_views`` yields) becomes one shard per batch, plus a
  manifest. This is how the synthetic "raw log" is laid out on disk.
* :func:`colstore_to_shards` — re-shards an existing
  :class:`~repro.fe.colstore.ColumnStore`: chunk *i* of every view is
  bundled into shard *i* (side views with fewer chunks wrap around, the
  same association ``examples/train_ctr_e2e.py`` uses for shard leases).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence

from repro.fe.colstore import ColumnStore, Columns
from repro.io.dataset import write_manifest
from repro.io.shardfmt import SHARD_SUFFIX, write_shard

_NAME_FMT = "shard_{:05d}" + SHARD_SUFFIX


def views_to_shard(path: str, views: Mapping[str, Columns],
                   *, meta: Optional[Mapping[str, Any]] = None) -> str:
    """Write one batch of views (``{view: columns}``) as a single shard."""
    return write_shard(path, views, meta=meta)


def write_view_shards(data_dir: str,
                      batches: Iterable[Mapping[str, Columns]],
                      *, primary: str = "impressions",
                      manifest: bool = True) -> List[str]:
    """Write one shard per batch of views; returns the shard paths."""
    os.makedirs(data_dir, exist_ok=True)
    paths: List[str] = []
    entries: List[Dict] = []
    for i, views in enumerate(batches):
        path = os.path.join(data_dir, _NAME_FMT.format(i))
        paths.append(views_to_shard(path, views, meta={"seq": i}))
        entries.append(_manifest_entry(paths[-1], views, primary))
    if manifest and paths:
        write_manifest(data_dir, primary=primary, entries=entries)
    return paths


def _manifest_entry(path: str, views: Mapping[str, Columns],
                    primary: str) -> Dict:
    """Manifest entry from in-memory data — no reopening the shard."""
    # Explicit membership test: an empty primary view must count as 0 rows,
    # not silently fall through to another view's row count.
    cols = views[primary] if primary in views else next(iter(views.values()))
    n_rows = 0
    for data in cols.values():
        n_rows = data.n_rows if hasattr(data, "n_rows") else len(data)
        break
    return {"file": os.path.basename(path),
            "nbytes": os.path.getsize(path), "n_rows": int(n_rows)}


def colstore_to_shards(store: ColumnStore, data_dir: str,
                       views: Mapping[str, Sequence[str]],
                       *, primary: str = "impressions",
                       manifest: bool = True) -> List[str]:
    """Re-shard column-store chunks: one shard per chunk of ``primary``.

    ``views`` maps view name -> column names to include. Views with fewer
    chunks than the primary (dimension tables like ``user_profile``) wrap
    around modulo their own chunk count.
    """
    if primary not in views:
        raise ValueError(f"primary view {primary!r} missing from {list(views)}")
    chunk_ids = {v: store.chunks(v) for v in views}
    if not chunk_ids[primary]:
        raise FileNotFoundError(
            f"column store has no chunks for primary view {primary!r}")
    for v, cids in chunk_ids.items():
        if not cids:
            raise FileNotFoundError(f"column store has no chunks for {v!r}")
    os.makedirs(data_dir, exist_ok=True)
    paths: List[str] = []
    entries: List[Dict] = []
    for i, cid in enumerate(chunk_ids[primary]):
        env: Dict[str, Columns] = {}
        for v, cols in views.items():
            # Wrap by loop *position*, not chunk-id value: ids are parsed
            # from directory names and need not be contiguous from 0.
            vcid = cid if v == primary else chunk_ids[v][i % len(chunk_ids[v])]
            env[v] = store.read_columns(v, vcid, list(cols))
        path = os.path.join(data_dir, _NAME_FMT.format(i))
        paths.append(views_to_shard(path, env,
                                    meta={"seq": i, "source_chunk": cid}))
        entries.append(_manifest_entry(paths[-1], env, primary))
    if manifest and paths:
        write_manifest(data_dir, primary=primary, entries=entries)
    return paths
