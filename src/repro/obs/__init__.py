"""``repro.obs`` — pipeline observability: span tracing + metrics registry.

* :mod:`repro.obs.trace` — thread-tracked span tracer with zero-cost
  disabled paths and Chrome trace-event / Perfetto JSON export;
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` consolidating the
  per-tier ``*Stats`` dataclasses behind one ``snapshot()`` protocol,
  plus derived pipeline-level metrics (overlap, stall attribution,
  bytes-and-seconds rollup);
* :mod:`repro.obs.validate` — structural trace validation (also a CLI:
  ``python -m repro.obs.validate trace.json``).

This package intentionally imports nothing from the rest of ``repro`` (no
jax, no numpy): every pipeline tier can depend on it without layering
cycles, and a disabled tracer costs one flag check per span.
"""

from repro.obs.metrics import MetricsRegistry, harvest, pipeline_rollup
from repro.obs.trace import (
    NULL_SPAN,
    Tracer,
    enable_tracing,
    get_tracer,
    set_tracer,
)
from repro.obs.validate import (
    TraceError,
    overlap_seconds,
    span_intervals,
    validate_trace,
)

__all__ = [
    "MetricsRegistry",
    "harvest",
    "pipeline_rollup",
    "NULL_SPAN",
    "Tracer",
    "enable_tracing",
    "get_tracer",
    "set_tracer",
    "TraceError",
    "overlap_seconds",
    "span_intervals",
    "validate_trace",
]
