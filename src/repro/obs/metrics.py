"""Metrics registry: one ``snapshot() -> dict`` over every stats tier.

The pipeline grew seven ad-hoc stats dataclasses (``IngestStats``,
``FeedStats``, ``ExecutionStats``, ``PipelineStats``, ``TrainFeedStats``,
``LoopStats``, ``TierStats``) — each fine alone, none comparable across
runs without hand-written glue. This module consolidates them behind one
protocol without changing any of them behaviorally:

* :func:`harvest` turns any stats object into a flat ``{metric: number}``
  dict — numeric dataclass fields plus numeric ``@property`` values (so
  derived ratios like ``unique_ratio`` or ``overlap_fraction`` come along
  for free). Every stats class gains an ``as_metrics()`` adapter that is
  exactly ``harvest(self)``; existing fields and call sites are untouched.
* :class:`MetricsRegistry` names each tier and flattens the whole run into
  one ``snapshot()`` dict (``"ingest.bytes_read": ...``), plus derived
  pipeline-level metrics (:func:`pipeline_rollup`): overlap fraction,
  per-stage stall attribution, and the disk/H2D/train bytes-and-seconds
  rollup the benchmark rows and the ``--metrics`` driver flag surface.

The registry holds *references* to live stats objects: snapshot late (after
``run()``) and the numbers are final; snapshot mid-run and they are a
consistent-enough progress sample (fields are monotone accumulators).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, Mapping, Optional, Union

Number = Union[int, float]
MetricSource = Union[Mapping[str, Number], Callable[[], Mapping[str, Number]], Any]


def harvest(obj: Any) -> Dict[str, Number]:
    """Flatten a stats object into ``{name: number}``.

    Takes numeric dataclass fields (bools as 0/1) and numeric properties;
    skips nested objects, lists, strings, and properties that raise.
    Works on any object, dataclass or not.
    """
    out: Dict[str, Number] = {}
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclasses.fields(obj):
            v = getattr(obj, f.name, None)
            if isinstance(v, bool):
                out[f.name] = int(v)
            elif isinstance(v, (int, float)):
                out[f.name] = v
    for name in dir(type(obj)):
        if name.startswith("_"):
            continue
        descr = getattr(type(obj), name, None)
        if not isinstance(descr, property):
            continue
        try:
            v = descr.fget(obj)  # type: ignore[misc]
        except Exception:
            continue
        if isinstance(v, bool):
            out[name] = int(v)
        elif isinstance(v, (int, float)):
            out[name] = v
    return out


def _resolve(source: MetricSource) -> Dict[str, Number]:
    if callable(source) and not hasattr(source, "as_metrics"):
        source = source()
    as_metrics = getattr(source, "as_metrics", None)
    if as_metrics is not None:
        return dict(as_metrics())
    if isinstance(source, Mapping):
        return {k: v for k, v in source.items()
                if isinstance(v, (int, float))}
    return harvest(source)


class MetricsRegistry:
    """Named metric tiers, flattened to one ``snapshot()`` dict.

    Sources may be stats objects (anything :func:`harvest` understands,
    preferring an ``as_metrics()`` method when present), plain dicts, or
    zero-arg callables returning dicts (evaluated at snapshot time, so
    derived metrics always reflect the current state).
    """

    def __init__(self) -> None:
        self._sources: Dict[str, MetricSource] = {}
        self._gauges: Dict[str, Number] = {}

    def register(self, name: str, source: MetricSource) -> "MetricsRegistry":
        if not name:
            raise ValueError("metric tier name must be non-empty")
        self._sources[name] = source
        return self

    def gauge(self, name: str, value: Number) -> "MetricsRegistry":
        """Record a single static value (e.g. ``hlo.flops_per_step``)."""
        self._gauges[name] = value
        return self

    @property
    def tiers(self) -> tuple:
        return tuple(self._sources)

    def snapshot(self) -> Dict[str, Number]:
        """Flatten every tier: ``{"<tier>.<metric>": number}``, sorted."""
        out: Dict[str, Number] = dict(self._gauges)
        for tier, source in self._sources.items():
            for k, v in _resolve(source).items():
                out[f"{tier}.{k}"] = v
        return dict(sorted(out.items()))

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    # ------------------------------------------------------------ pipeline
    @classmethod
    def from_pipeline(cls, stats: Any,
                      extra: Optional[Mapping[str, MetricSource]] = None
                      ) -> "MetricsRegistry":
        """Registry over a :class:`~repro.core.pipeline.PipelineStats` and
        every tier attached to it (ingest / feed / train_feed / exec),
        plus the derived :func:`pipeline_rollup` tier."""
        reg = cls()
        reg.register("pipeline", stats)
        exec_stats = getattr(stats, "exec_stats", None)
        if exec_stats is not None:
            reg.register("exec", exec_stats)
        for tier in ("ingest", "feed", "train_feed", "ps", "comm", "fault"):
            obj = getattr(stats, tier, None)
            if obj is not None:
                reg.register(tier, obj)
        reg.register("rollup", lambda: pipeline_rollup(stats))
        for name, source in (extra or {}).items():
            reg.register(name, source)
        return reg


def pipeline_rollup(stats: Any) -> Dict[str, Number]:
    """Derived pipeline-level metrics off a :class:`PipelineStats` tree.

    Bytes-and-seconds per stage (disk -> FE -> H2D -> train) plus stall
    attribution: which stage was waiting, and on whom. All keys are
    present even when a tier is absent (0), so snapshots from different
    configurations stay structurally comparable.
    """
    ingest = getattr(stats, "ingest", None)
    feed = getattr(stats, "feed", None)
    tf = getattr(stats, "train_feed", None)
    ps = getattr(stats, "ps", None)
    wall = float(getattr(stats, "wall_seconds", 0.0))
    out: Dict[str, Number] = {
        "wall_seconds": wall,
        "batches": int(getattr(stats, "batches", 0)),
        "overlap_fraction": float(getattr(stats, "overlap_fraction", 0.0)),
        "overhead_seconds": float(getattr(stats, "overhead_seconds", 0.0)),
        # stage seconds
        "disk_seconds": float(getattr(ingest, "read_seconds", 0.0)) if ingest else 0.0,
        "fe_seconds": float(getattr(stats, "fe_seconds", 0.0)),
        "h2d_seconds": float(getattr(feed, "h2d_seconds", 0.0)) if feed else 0.0,
        "adapt_seconds": float(getattr(stats, "adapt_seconds", 0.0)),
        "train_seconds": float(getattr(stats, "train_net_seconds",
                                       getattr(stats, "train_seconds", 0.0))),
        # stage bytes
        "disk_bytes": int(getattr(ingest, "bytes_read", 0)) if ingest else 0,
        "decoded_bytes": int(getattr(ingest, "bytes_decoded", 0)) if ingest else 0,
        "h2d_bytes": int(getattr(feed, "bytes_staged", 0)) if feed else 0,
        "intermediate_bytes": int(getattr(stats, "intermediate_bytes", 0)),
        # stall attribution: who waited, and for whom
        "stall_loader_backpressure_seconds":
            float(getattr(ingest, "reader_stall_seconds", 0.0)) if ingest else 0.0,
        "stall_waiting_on_disk_seconds":
            float(getattr(ingest, "consumer_stall_seconds", 0.0)) if ingest else 0.0,
        "stall_h2d_reclaim_seconds":
            float(getattr(feed, "stall_seconds", 0.0)) if feed else 0.0,
        "dedup_unique_ratio": float(getattr(tf, "unique_ratio", 0.0)) if tf else 0.0,
        # hierarchical-PS tier (0 when the embedding backend is in-memory)
        "ps_pull_seconds": float(getattr(ps, "pull_seconds", 0.0)) if ps else 0.0,
        "ps_wait_seconds": float(getattr(ps, "wait_seconds", 0.0)) if ps else 0.0,
        "ps_host_hit_rate": float(getattr(ps, "host_hit_rate", 0.0)) if ps else 0.0,
        "ps_evictions": int(getattr(ps, "evictions", 0)) if ps else 0,
    }
    # mesh collectives tier (0 when single-device)
    comm = getattr(stats, "comm", None)
    out["comm_interpod_bytes_total"] = \
        int(getattr(comm, "interpod_bytes_total", 0)) if comm else 0
    plan = getattr(comm, "plan", None)
    out["comm_interpod_reduction"] = \
        float(getattr(plan, "interpod_reduction", 1.0)) if plan else 1.0
    # fault-tolerance tier (0 when the loader saw no failures / is static)
    fault = getattr(stats, "fault", None)
    out["fault_reissued"] = int(getattr(fault, "reissued", 0)) if fault else 0
    out["fault_retries"] = int(getattr(fault, "retries", 0)) if fault else 0
    out["fault_backup_wins"] = \
        int(getattr(fault, "backup_wins", 0)) if fault else 0
    out["fault_failed_workers"] = \
        int(getattr(fault, "failed_workers", 0)) if fault else 0
    if wall > 0:
        for stage in ("disk", "fe", "h2d", "train"):
            out[f"{stage}_busy_fraction"] = \
                min(float(out[f"{stage}_seconds"]) / wall, 1.0)
    return out
