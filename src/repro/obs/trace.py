"""Span tracing with Chrome trace-event / Perfetto JSON export.

The pipeline's "know where every microsecond went" layer: each pipeline
thread (shard readers, the FE worker, the H2D feeder, the train loop)
becomes a *track*, each unit of work a *span* on that track, and the
exported JSON opens directly in https://ui.perfetto.dev (or
``chrome://tracing``), so overlap between stages — the paper's central
claim — is visually inspectable instead of inferred from aggregate
seconds.

Design constraints, in priority order:

* **zero cost when disabled** — the hot paths call
  ``tracer.span("fe.extract", batch=i)`` unconditionally; a disabled
  tracer answers with a shared no-op singleton after one flag check, no
  allocation, no lock (``tests/test_obs.py`` asserts the singleton);
* **bit-effect-free** — tracing records wall-clock only; it never touches
  batch data, so the runner-equivalence property holds with tracing on;
* **thread-safe** — events append under one lock; tracks are assigned per
  thread on first use, named after ``threading.current_thread().name``
  (which the pipeline already names: ``fe-worker``, ``h2d-feeder``,
  ``shard-reader-N``);
* **exceptions don't lose spans** — spans are recorded as separate B/E
  events at ``__enter__``/``__exit__``, so everything recorded before a
  pipeline failure survives to :meth:`Tracer.export`, and the span open
  when an exception unwinds is closed (tagged ``error``) by its context
  manager. Spans a dead thread never closed are end-capped at export.

Typical use::

    from repro.obs import Tracer, set_tracer, get_tracer

    set_tracer(Tracer(enabled=True))
    ...
    with get_tracer().span("fe.extract", batch=3):
        run_layers(...)
    get_tracer().instant("arena.rewind", buffer=0)
    get_tracer().counter("io.queue_depth", 2)
    ...
    get_tracer().export("trace.json")
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

# The single pid all tracks share (one process; tracks are threads).
PID = 1

# Event record layout (tuples keep the hot path allocation-light):
#   (phase, tid, ts_ns, name, args_or_None)
_B, _E, _I, _C = "B", "E", "i", "C"


class _NullSpan:
    """Shared no-op context manager: the disabled tracer's only answer."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _Span:
    """Live span: records a B event on enter, an E event on exit.

    Recording B/E separately (instead of one complete event at exit)
    keeps per-track file order identical to program order — monotone
    timestamps for free — and preserves the B even when the body raises
    and the process dies before ``__exit__`` could run anywhere else.
    """

    __slots__ = ("_tracer", "_name", "_args")

    def __init__(self, tracer: "Tracer", name: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self) -> "_Span":
        self._tracer._record(_B, self._name, self._args)
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        args = None
        if exc_type is not None:
            args = {"error": exc_type.__name__}
        self._tracer._record(_E, self._name, args)
        return False


class Tracer:
    """Thread-safe span/instant/counter recorder with Perfetto export.

    One instance is installed process-wide via :func:`set_tracer`; the
    pipeline hot paths fetch it with :func:`get_tracer` and call
    :meth:`span` unconditionally — when ``enabled`` is False every
    recording entry point returns immediately after the flag check.
    """

    def __init__(self, *, enabled: bool = True) -> None:
        self.enabled = enabled
        self._lock = threading.Lock()
        self._events: List[Tuple[str, int, int, str, Optional[Dict]]] = []
        # thread ident (int) or "virtual:<name>" (str) -> (tid, track name)
        self._tracks: Dict[Any, Tuple[int, str]] = {}
        self._epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------ recording
    def span(self, name: str, **args: Any) -> Any:
        """Context manager timing one unit of work on this thread's track.

        Disabled tracers return the shared :data:`NULL_SPAN` singleton —
        the no-allocation guarantee the hot paths rely on. (Keyword args
        are only materialized by the caller when tracing is on; callers
        on the hottest paths pass none.)
        """
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args or None)

    def instant(self, name: str, **args: Any) -> None:
        """Mark a point event (arena rewind, donation fence, stall)."""
        if not self.enabled:
            return
        self._record(_I, name, args or None)

    def counter(self, name: str, value: float) -> None:
        """Sample a counter series (queue depth, bytes in flight)."""
        if not self.enabled:
            return
        self._record(_C, name, {name: value})

    def complete(self, name: str, t0_ns: int, t1_ns: int, **args: Any) -> None:
        """Record a span retroactively from explicit perf_counter_ns stamps.

        For conditional spans (e.g. a queue stall only worth recording
        when it exceeded a threshold). Safe for per-track monotonicity as
        long as the calling thread recorded nothing between ``t0_ns`` and
        now — true for a thread that was blocked for that whole window.
        """
        if not self.enabled:
            return
        a = args or None
        with self._lock:
            tid = self._track_locked()
            self._events.append((_B, tid, t0_ns, name, a))
            self._events.append((_E, tid, t1_ns, name, None))

    def complete_on(self, track: str, name: str, t0_ns: int, t1_ns: int,
                    **args: Any) -> None:
        """Record a retroactive span on a named *virtual* track.

        :meth:`complete` reuses the calling thread's track, which is only
        monotonicity-safe when that thread recorded nothing inside the
        window. Work that happens *inside* another span — e.g. the
        collective phases of a fused train step, which execute within the
        step's own ``train.step`` span — would interleave non-monotone
        B/E pairs on the thread track. A virtual track (one per ``track``
        name, lazily allocated, keyed separately from thread idents)
        gives each such series its own monotone timeline in the exported
        timeline — the ``comm.*`` spans of the mesh train loop live here.
        """
        if not self.enabled:
            return
        a = args or None
        with self._lock:
            key = f"virtual:{track}"
            entry = self._tracks.get(key)
            if entry is None:
                entry = (len(self._tracks), track)
                self._tracks[key] = entry
            tid = entry[0]
            self._events.append((_B, tid, t0_ns, name, a))
            self._events.append((_E, tid, t1_ns, name, None))

    def now_ns(self) -> int:
        """Monotonic stamp compatible with :meth:`complete` (cheap enough
        to call even when disabled; callers gate on ``enabled``)."""
        return time.perf_counter_ns()

    def _record(self, phase: str, name: str,
                args: Optional[Dict[str, Any]]) -> None:
        ts = time.perf_counter_ns()
        with self._lock:
            self._events.append((phase, self._track_locked(), ts, name, args))

    def _track_locked(self) -> int:
        ident = threading.get_ident()
        entry = self._tracks.get(ident)
        if entry is None:
            entry = (len(self._tracks), threading.current_thread().name)
            self._tracks[ident] = entry
        return entry[0]

    # ------------------------------------------------------------- querying
    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    def track_names(self) -> Dict[int, str]:
        """tid -> thread name for every track that recorded an event."""
        with self._lock:
            return {tid: name for tid, name in self._tracks.values()}

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._tracks.clear()
            self._epoch_ns = time.perf_counter_ns()

    # -------------------------------------------------------------- export
    def to_dict(self) -> Dict[str, Any]:
        """The trace as a Chrome trace-event object (``traceEvents`` list).

        Timestamps are microseconds relative to the tracer's epoch. Spans
        left open by a thread that died mid-span are end-capped at the
        trace's last timestamp so every B has a matching E.
        """
        with self._lock:
            events = list(self._events)
            tracks = dict(self._tracks)
        out: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": PID, "tid": 0,
            "args": {"name": "featurebox-pipeline"},
        }]
        for tid, name in sorted(tracks.values()):
            out.append({"ph": "M", "name": "thread_name", "pid": PID,
                        "tid": tid, "args": {"name": name}})
        open_stacks: Dict[int, List[str]] = {}
        last_ts: Dict[int, int] = {}
        for phase, tid, ts_ns, name, args in events:
            ev: Dict[str, Any] = {
                "ph": phase, "name": name, "pid": PID, "tid": tid,
                "ts": (ts_ns - self._epoch_ns) / 1e3,
            }
            if phase == _I:
                ev["s"] = "t"  # thread-scoped instant
            if args:
                ev["args"] = args
            out.append(ev)
            last_ts[tid] = ts_ns
            if phase == _B:
                open_stacks.setdefault(tid, []).append(name)
            elif phase == _E and open_stacks.get(tid):
                open_stacks[tid].pop()
        for tid, stack in open_stacks.items():
            for name in reversed(stack):  # end-cap spans a dead thread left open
                out.append({"ph": _E, "name": name, "pid": PID, "tid": tid,
                            "ts": (last_ts[tid] - self._epoch_ns) / 1e3,
                            "args": {"capped": True}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> Dict[str, Any]:
        """Write the Chrome trace-event JSON to ``path`` (returns the dict).

        Open the file in https://ui.perfetto.dev — loader / FE / H2D /
        train appear as separate named tracks.
        """
        trace = self.to_dict()
        with open(path, "w") as f:
            json.dump(trace, f)
            f.write("\n")
        return trace


# -------------------------------------------------------- process-wide tracer
_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The installed process-wide tracer (a disabled one by default)."""
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` process-wide; returns the previous tracer so
    callers (tests, drivers) can restore it."""
    global _tracer
    prev = _tracer
    _tracer = tracer
    return prev


def enable_tracing() -> Tracer:
    """Install and return a fresh enabled tracer (driver ``--trace``)."""
    tracer = Tracer(enabled=True)
    set_tracer(tracer)
    return tracer
