"""Structural validation of exported Chrome trace-event JSON.

Shared by the trace-export tests and the CI trace-smoke step (``python -m
repro.obs.validate trace.json --require-tracks 4``): a trace the tooling
would silently mis-render (unmatched B/E, time running backwards inside a
track, events missing required keys) fails loudly here instead.

Checks:

* the file is valid JSON with a ``traceEvents`` list;
* every event carries ``ph``/``pid``/``tid`` (+ ``ts``/``name`` for
  non-metadata events) with numeric timestamps;
* per track (pid, tid), timestamps are monotone non-decreasing in file
  order (the exporter writes events in program order per thread);
* B/E events form matched, properly nested pairs per track (same name on
  push and pop, empty stack at end of trace).

:func:`span_intervals` and :func:`overlap_seconds` additionally turn the
validated B/E pairs back into intervals so tests can assert the pipeline
property the trace exists to show: spans on different tracks *overlap*.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Mapping, Sequence, Tuple, Union


class TraceError(ValueError):
    """The trace violates the Chrome trace-event structural contract."""


def load_trace(obj: Union[str, Mapping[str, Any]]) -> Dict[str, Any]:
    """Accept a path or an already-parsed trace dict."""
    if isinstance(obj, str):
        with open(obj) as f:
            try:
                obj = json.load(f)
            except json.JSONDecodeError as e:
                raise TraceError(f"not valid JSON: {e}") from e
    if not isinstance(obj, Mapping) or "traceEvents" not in obj:
        raise TraceError("trace must be an object with a 'traceEvents' list")
    if not isinstance(obj["traceEvents"], list):
        raise TraceError("'traceEvents' must be a list")
    return dict(obj)


def validate_trace(obj: Union[str, Mapping[str, Any]]) -> Dict[str, Any]:
    """Validate; return a summary dict (raises :class:`TraceError`).

    Summary: ``n_events``, ``n_spans``, ``n_instants``, ``n_counters``,
    ``tracks`` ({tid: thread name}), ``span_names`` (sorted).
    """
    trace = load_trace(obj)
    events = trace["traceEvents"]
    tracks: Dict[int, str] = {}
    last_ts: Dict[Tuple[int, int], float] = {}
    stacks: Dict[Tuple[int, int], List[str]] = {}
    n_spans = n_instants = n_counters = 0
    span_names = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, Mapping):
            raise TraceError(f"event {i} is not an object: {ev!r}")
        ph = ev.get("ph")
        if ph is None or "pid" not in ev or "tid" not in ev:
            raise TraceError(f"event {i} missing ph/pid/tid: {ev!r}")
        if ph == "M":
            if ev.get("name") == "thread_name":
                tracks[ev["tid"]] = ev.get("args", {}).get("name", "")
            continue
        if "ts" not in ev or not isinstance(ev["ts"], (int, float)):
            raise TraceError(f"event {i} has no numeric ts: {ev!r}")
        if not ev.get("name"):
            raise TraceError(f"event {i} has no name: {ev!r}")
        key = (ev["pid"], ev["tid"])
        prev = last_ts.get(key)
        if prev is not None and ev["ts"] < prev:
            raise TraceError(
                f"event {i} ({ev['name']!r}): ts {ev['ts']} < {prev} — "
                f"time ran backwards on track {key}")
        last_ts[key] = ev["ts"]
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
            span_names.add(ev["name"])
            n_spans += 1
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise TraceError(
                    f"event {i}: E {ev['name']!r} with no open B on "
                    f"track {key}")
            top = stack.pop()
            if top != ev["name"]:
                raise TraceError(
                    f"event {i}: E {ev['name']!r} closes B {top!r} on "
                    f"track {key} (improper nesting)")
        elif ph == "i":
            n_instants += 1
        elif ph == "C":
            n_counters += 1
        elif ph not in ("X", "M"):
            raise TraceError(f"event {i}: unsupported phase {ph!r}")
    unclosed = {k: s for k, s in stacks.items() if s}
    if unclosed:
        raise TraceError(f"unmatched B events at end of trace: {unclosed}")
    return {
        "n_events": len(events),
        "n_spans": n_spans,
        "n_instants": n_instants,
        "n_counters": n_counters,
        "tracks": tracks,
        "span_names": sorted(span_names),
    }


def span_intervals(obj: Union[str, Mapping[str, Any]],
                   name_prefix: str = "") -> List[Tuple[float, float, str, int]]:
    """Matched (start_us, end_us, name, tid) intervals, optionally
    filtered to span names starting with ``name_prefix``."""
    trace = load_trace(obj)
    stacks: Dict[Tuple[int, int], List[Tuple[str, float]]] = {}
    out: List[Tuple[float, float, str, int]] = []
    for ev in trace["traceEvents"]:
        ph = ev.get("ph")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            stacks.setdefault(key, []).append((ev["name"], ev["ts"]))
        elif ph == "E" and stacks.get(key):
            name, t0 = stacks[key].pop()
            if name.startswith(name_prefix):
                out.append((t0, ev["ts"], name, ev["tid"]))
    return out


def _merge(intervals: Sequence[Tuple[float, float]]) -> List[Tuple[float, float]]:
    merged: List[Tuple[float, float]] = []
    for t0, t1 in sorted(intervals):
        if merged and t0 <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
        else:
            merged.append((t0, t1))
    return merged


def overlap_seconds(obj: Union[str, Mapping[str, Any]],
                    prefix_a: str, prefix_b: str) -> float:
    """Total wall-clock during which a span named ``prefix_a*`` and a span
    named ``prefix_b*`` were simultaneously open — the pipelining the
    trace exists to make visible (e.g. ``overlap_seconds(t, "fe.",
    "train.") > 0`` means FE genuinely hid behind training)."""
    trace = load_trace(obj)
    a = _merge([(t0, t1) for t0, t1, _, _ in span_intervals(trace, prefix_a)])
    b = _merge([(t0, t1) for t0, t1, _, _ in span_intervals(trace, prefix_b)])
    total_us = 0.0
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total_us += hi - lo
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return total_us / 1e6


def main(argv: Sequence[str] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate an exported Chrome trace-event JSON file")
    ap.add_argument("trace", help="path to the trace JSON")
    ap.add_argument("--require-tracks", type=int, default=0, metavar="N",
                    help="fail unless at least N named tracks recorded spans")
    ap.add_argument("--require-overlap", nargs=2, metavar=("A", "B"),
                    default=None,
                    help="fail unless spans with these two name prefixes "
                         "overlap in time (e.g. fe. train.)")
    args = ap.parse_args(argv)
    try:
        summary = validate_trace(args.trace)
    except TraceError as e:
        print(f"INVALID trace: {e}", file=sys.stderr)
        return 1
    print(f"{args.trace}: {summary['n_events']} events, "
          f"{summary['n_spans']} spans, {summary['n_instants']} instants, "
          f"{summary['n_counters']} counter samples")
    for tid, name in sorted(summary["tracks"].items()):
        print(f"  track {tid}: {name}")
    print(f"  span names: {', '.join(summary['span_names'])}")
    if args.require_tracks and len(summary["tracks"]) < args.require_tracks:
        print(f"FAIL: {len(summary['tracks'])} tracks < required "
              f"{args.require_tracks}", file=sys.stderr)
        return 1
    if args.require_overlap:
        a, b = args.require_overlap
        ov = overlap_seconds(args.trace, a, b)
        print(f"  overlap({a}*, {b}*) = {ov * 1e3:.1f} ms")
        if ov <= 0:
            print(f"FAIL: no overlap between {a}* and {b}* spans",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
