"""Operator DAG for FeatureBox feature-extraction pipelines.

Implements the paper's Fig. 4(a)->(b) transformation: coarse operators that
*call* shared functions are expanded into fine-granularity operators (one per
function call), producing a DAG whose nodes can be scheduled layer-by-layer
(see ``scheduler.py``).

An :class:`Operator` is a named unit of work with:
  * ``fn`` — the callable. Device ops take/return dicts of jnp arrays and must
    be jit-traceable; host ops may do arbitrary python (string parsing, disk
    reads, huge dictionary lookups).
  * ``inputs`` / ``outputs`` — named column/tensor slots. Dependencies are
    derived from producer->consumer slot matching, so graph wiring is by data,
    not by hand-maintained edge lists.
  * ``device`` — placement hint (``AUTO`` lets the scheduler decide using the
    paper's heuristic: GPU/TPU unless the op's memory footprint is too large).
  * ``cost`` — optional static estimate (bytes touched, flops) used by the
    placement heuristic and the memory-pool planner.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple


class Device(enum.Enum):
    AUTO = "auto"
    HOST = "host"      # CPU worker (paper: memory-intensive ops)
    DEVICE = "device"  # TPU/GPU (paper: compute-intensive ops)


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Static cost estimate for placement + arena planning."""

    bytes_touched: int = 0     # working set (dictionary sizes, table sizes)
    flops: int = 0             # arithmetic volume
    out_bytes_per_row: int = 8  # dynamic-allocation need per instance (Alg. 1)


@dataclasses.dataclass
class Operator:
    name: str
    fn: Callable[..., Mapping[str, Any]]
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    device: Device = Device.AUTO
    cost: OpCost = dataclasses.field(default_factory=OpCost)
    # Function-call expansion metadata (Fig 4a): names of shared functions
    # this operator invokes, split into pre-processing and post-processing
    # calls. ``expand_calls`` turns each into its own Operator.
    pre_calls: Tuple[str, ...] = ()
    post_calls: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        self.inputs = tuple(self.inputs)
        self.outputs = tuple(self.outputs)
        if not self.outputs:
            raise ValueError(f"operator {self.name!r} must produce at least one output")

    def __call__(self, **kwargs: Any) -> Mapping[str, Any]:
        return self.fn(**kwargs)


@dataclasses.dataclass(frozen=True)
class FuncDef:
    """A shared function referenced by operators' pre/post calls (Fig 4a)."""

    name: str
    fn: Callable[..., Mapping[str, Any]]
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    device: Device = Device.AUTO
    cost: OpCost = OpCost()


class OpGraph:
    """A DAG of operators with data-slot based dependency wiring."""

    def __init__(self) -> None:
        self._ops: Dict[str, Operator] = {}
        self._funcs: Dict[str, FuncDef] = {}
        self._external_inputs: set[str] = set()

    # ------------------------------------------------------------------ build
    def add(self, op: Operator) -> Operator:
        if op.name in self._ops:
            raise ValueError(f"duplicate operator name {op.name!r}")
        for out in op.outputs:
            producer = self.producer_of(out)
            if producer is not None:
                raise ValueError(
                    f"slot {out!r} already produced by {producer.name!r}"
                )
        self._ops[op.name] = op
        return op

    def add_func(self, func: FuncDef) -> FuncDef:
        if func.name in self._funcs:
            raise ValueError(f"duplicate function name {func.name!r}")
        self._funcs[func.name] = func
        return func

    def mark_external(self, *slots: str) -> None:
        """Declare slots provided from outside the graph (pipeline inputs)."""
        self._external_inputs.update(slots)

    # ---------------------------------------------------------------- queries
    @property
    def ops(self) -> Dict[str, Operator]:
        return dict(self._ops)

    @property
    def external_inputs(self) -> set:
        return set(self._external_inputs)

    def producer_of(self, slot: str) -> Optional[Operator]:
        for op in self._ops.values():
            if slot in op.outputs:
                return op
        return None

    def dependencies(self, op: Operator) -> List[Operator]:
        deps: List[Operator] = []
        seen = set()
        for slot in op.inputs:
            producer = self.producer_of(slot)
            if producer is None:
                if slot not in self._external_inputs:
                    raise KeyError(
                        f"operator {op.name!r} consumes slot {slot!r} which is "
                        "neither produced by another operator nor marked external"
                    )
                continue
            if producer.name not in seen:
                seen.add(producer.name)
                deps.append(producer)
        return deps

    def edges(self) -> List[Tuple[str, str]]:
        out = []
        for op in self._ops.values():
            for dep in self.dependencies(op):
                out.append((dep.name, op.name))
        return out

    # ------------------------------------------------- Fig 4(a)->(b) expansion
    def expand_calls(self) -> "OpGraph":
        """Expand operators' function calls into fine-granularity operators.

        Mirrors the paper's example: Op2 calling Func1 (pre) and Func3 (post)
        becomes three operators: ``Func1@Op2`` -> ``Op2`` -> ``Func3@Op2``.
        Pre-calls run before the operator body (their outputs become extra
        operator inputs); post-calls run after (consuming the operator's
        outputs). Each call site is its *own* operator — Func3 called from
        three operators yields three nodes, as in Fig. 4(b).
        """

        g = OpGraph()
        g._external_inputs = set(self._external_inputs)
        g._funcs = dict(self._funcs)
        for op in self._ops.values():
            body_inputs = list(op.inputs)
            for fname in op.pre_calls:
                func = self._require_func(fname, op)
                call_name = f"{fname}@{op.name}"
                outs = tuple(f"{o}@{op.name}" for o in func.outputs)
                g.add(
                    Operator(
                        name=call_name,
                        fn=_rename_outputs(func.fn, func.outputs, outs),
                        inputs=func.inputs,
                        outputs=outs,
                        device=func.device,
                        cost=func.cost,
                    )
                )
                body_inputs.extend(outs)
            if op.post_calls:
                body_outs = tuple(f"{o}~body" for o in op.outputs)
                g.add(
                    Operator(
                        name=op.name,
                        fn=_rename_outputs(op.fn, op.outputs, body_outs),
                        inputs=tuple(body_inputs),
                        outputs=body_outs,
                        device=op.device,
                        cost=op.cost,
                    )
                )
                prev_outs = body_outs
                for i, fname in enumerate(op.post_calls):
                    func = self._require_func(fname, op)
                    call_name = f"{fname}@{op.name}"
                    last = i == len(op.post_calls) - 1
                    outs = (
                        op.outputs
                        if last
                        else tuple(f"{o}~post{i}" for o in op.outputs)
                    )
                    # Post-call contract: the function receives the operator's
                    # outputs under their ORIGINAL names and returns the same
                    # names (it is a per-output post-processing pass, like the
                    # paper's Func3 applied to each caller's result).
                    g.add(
                        Operator(
                            name=call_name,
                            fn=_rename_io(func.fn, op.outputs, prev_outs, op.outputs, outs),
                            inputs=prev_outs,
                            outputs=outs,
                            device=func.device,
                            cost=func.cost,
                        )
                    )
                    prev_outs = outs
            else:
                g.add(
                    Operator(
                        name=op.name,
                        fn=op.fn,
                        inputs=tuple(body_inputs),
                        outputs=op.outputs,
                        device=op.device,
                        cost=op.cost,
                    )
                )
        return g

    def _require_func(self, fname: str, op: Operator) -> FuncDef:
        if fname not in self._funcs:
            raise KeyError(f"operator {op.name!r} calls unknown function {fname!r}")
        return self._funcs[fname]

    # ------------------------------------------------------------- validation
    def validate(self) -> None:
        """Check the graph is a DAG and every input slot resolves."""
        for op in self._ops.values():
            self.dependencies(op)  # raises on unresolved slots
        # cycle check via DFS colouring
        WHITE, GREY, BLACK = 0, 1, 2
        colour = {name: WHITE for name in self._ops}

        def visit(name: str, stack: List[str]) -> None:
            colour[name] = GREY
            for dep in self.dependencies(self._ops[name]):
                if colour[dep.name] == GREY:
                    cyc = " -> ".join(stack + [name, dep.name])
                    raise ValueError(f"operator graph has a cycle: {cyc}")
                if colour[dep.name] == WHITE:
                    visit(dep.name, stack + [name])
            colour[name] = BLACK

        for name in self._ops:
            if colour[name] == WHITE:
                visit(name, [])


def _rename_outputs(fn, old: Sequence[str], new: Sequence[str]):
    mapping = dict(zip(old, new))

    def wrapped(**kwargs):
        res = fn(**kwargs)
        return {mapping.get(k, k): v for k, v in res.items()}

    return wrapped


def _rename_io(fn, old_in: Sequence[str], new_in: Sequence[str],
               old_out: Sequence[str], new_out: Sequence[str]):
    in_map = dict(zip(new_in, old_in))
    out_map = dict(zip(old_out, new_out))

    def wrapped(**kwargs):
        remapped = {in_map.get(k, k): v for k, v in kwargs.items()}
        res = fn(**remapped)
        return {out_map.get(k, k): v for k, v in res.items()}

    return wrapped
