"""Layer-wise heterogeneous operator scheduling (paper §IV, Fig. 4(c)).

Given an :class:`~repro.core.opgraph.OpGraph`, produce an execution
:class:`Schedule`:

1. Topologically sort the DAG and assign each operator to the layer equal to
   its depth from the root operators (ASAP levels). Operators in the same
   layer have no mutual dependencies, so the whole layer is issued together
   with one synchronization barrier at layer end — exactly Fig. 4(c).

2. Assign each ``AUTO`` operator to DEVICE unless its static memory footprint
   exceeds the device budget (the paper's heuristic: "prefer to execute
   operators on GPUs unless an operator requires a significant memory
   footprint" — e.g. the word-embedding dictionary lookup goes to CPU with an
   explicit H2D move of its results).

The schedule is computed once before training and stays fixed (paper:
"we determine the operator execution order before the actual training phase
and keep the scheduling fixed"), which is what lets ``metakernel.py`` build
one fused executable per layer ahead of time.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Tuple

from repro.core.opgraph import Device, Operator, OpGraph

# Paper setting: GPU ops must fit alongside the training working set. We use a
# conservative default device budget; callers override per deployment.
DEFAULT_DEVICE_BYTES_BUDGET = 2 * 1024**3


@dataclasses.dataclass(frozen=True)
class PlacedOp:
    op: Operator
    device: Device  # resolved HOST or DEVICE


@dataclasses.dataclass(frozen=True)
class Layer:
    index: int
    host_ops: Tuple[PlacedOp, ...]
    device_ops: Tuple[PlacedOp, ...]

    @property
    def ops(self) -> Tuple[PlacedOp, ...]:
        return self.host_ops + self.device_ops


@dataclasses.dataclass(frozen=True)
class SuperLayer:
    """A maximal run of consecutive layers with no interleaving host ops.

    Only the first member layer may carry host ops (any later host op would
    have started a new super-layer), so execution is: host prologue -> one
    fused device dispatch covering every member layer's device ops. This is
    the true analogue of the paper's one-launch-per-layer meta-kernel once
    XLA is the launcher: a dispatch is only *required* where a host barrier
    interrupts device work, so per batch the device pays
    ``n_host_barriers + 1`` dispatches instead of one per layer.
    """

    index: int
    layers: Tuple[Layer, ...]

    @property
    def layer_indices(self) -> Tuple[int, ...]:
        return tuple(layer.index for layer in self.layers)

    @property
    def host_ops(self) -> Tuple[PlacedOp, ...]:
        return tuple(p for layer in self.layers for p in layer.host_ops)

    @property
    def device_ops(self) -> Tuple[PlacedOp, ...]:
        """Member device ops in layer order (dependency-safe trace order)."""
        return tuple(p for layer in self.layers for p in layer.device_ops)

    @property
    def ops(self) -> Tuple[PlacedOp, ...]:
        return self.host_ops + self.device_ops


def coalesce_layers(layers: Tuple[Layer, ...]) -> Tuple[SuperLayer, ...]:
    """Group layers into super-layers, breaking before every host-op layer.

    A layer with host ops must start a new group: its host ops impose a
    host barrier (device results of earlier layers must be visible before
    the host code runs), so its device ops cannot join the previous fused
    dispatch. Layers with no host ops extend the current group.
    """
    groups: List[List[Layer]] = []
    for layer in layers:
        if layer.host_ops or not groups:
            groups.append([layer])
        else:
            groups[-1].append(layer)
    return tuple(SuperLayer(index=i, layers=tuple(g))
                 for i, g in enumerate(groups))


@dataclasses.dataclass(frozen=True)
class Schedule:
    layers: Tuple[Layer, ...]
    depth_of: Dict[str, int]

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_device_dispatches(self) -> int:
        """One fused dispatch per layer that has any device op (meta-kernel)."""
        return sum(1 for layer in self.layers if layer.device_ops)

    @property
    def n_unfused_dispatches(self) -> int:
        """What a naive per-op launcher would pay (Table I comparison)."""
        return sum(len(layer.device_ops) for layer in self.layers)

    @property
    def superlayers(self) -> Tuple[SuperLayer, ...]:
        """Maximal host-barrier-free layer runs (see :func:`coalesce_layers`)."""
        return coalesce_layers(self.layers)

    @property
    def n_host_barriers(self) -> int:
        """Host stages that interrupt device work (split the device run).

        Host stages *before* the first device op (clean/join/extract) don't
        count: they delay the first dispatch but don't force an extra one.
        Consecutive host-only layers collapse into one barrier (their
        super-layers carry no device ops, so they force no extra dispatch),
        which is why this is counted over the coalesced structure: it is
        the number of device-op-bearing super-layers beyond the first —
        exactly the dispatches a host interruption costs.
        """
        return max(0, self.n_coalesced_dispatches - 1)

    @property
    def n_coalesced_dispatches(self) -> int:
        """Fused dispatches per batch after super-layer coalescing
        (``n_host_barriers + 1`` whenever the schedule has device ops)."""
        return sum(1 for sl in self.superlayers if sl.device_ops)


def assign_device(op: Operator, device_bytes_budget: int) -> Device:
    """The paper's placement heuristic for AUTO ops."""
    if op.device is not Device.AUTO:
        return op.device
    if op.cost.bytes_touched > device_bytes_budget:
        return Device.HOST
    return Device.DEVICE


def build_schedule(
    graph: OpGraph,
    *,
    device_bytes_budget: int = DEFAULT_DEVICE_BYTES_BUDGET,
    expand: bool = True,
) -> Schedule:
    """Expand call sites, layer the DAG, and place every operator."""

    if expand:
        graph = graph.expand_calls()
    graph.validate()

    ops = graph.ops
    depth: Dict[str, int] = {}

    # Kahn-style longest-path layering: depth(op) = 1 + max(depth(deps)).
    indeg: Dict[str, int] = {}
    dependents: Dict[str, List[str]] = {name: [] for name in ops}
    for name, op in ops.items():
        deps = graph.dependencies(op)
        indeg[name] = len(deps)
        for d in deps:
            dependents[d.name].append(name)

    frontier = sorted(name for name, deg in indeg.items() if deg == 0)
    for name in frontier:
        depth[name] = 0
    queue = collections.deque(frontier)
    processed = 0
    while queue:
        name = queue.popleft()
        processed += 1
        for child in dependents[name]:
            depth[child] = max(depth.get(child, 0), depth[name] + 1)
            indeg[child] -= 1
            if indeg[child] == 0:
                queue.append(child)
    if processed != len(ops):
        raise ValueError("operator graph has a cycle (topological sort failed)")

    n_layers = 1 + max(depth.values(), default=-1)
    layers: List[Layer] = []
    for i in range(n_layers):
        host_ops: List[PlacedOp] = []
        device_ops: List[PlacedOp] = []
        for name in sorted(n for n, d in depth.items() if d == i):
            op = ops[name]
            placed = PlacedOp(op=op, device=assign_device(op, device_bytes_budget))
            (device_ops if placed.device is Device.DEVICE else host_ops).append(placed)
        layers.append(Layer(index=i, host_ops=tuple(host_ops), device_ops=tuple(device_ops)))
    return Schedule(layers=tuple(layers), depth_of=depth)


def validate_schedule(graph: OpGraph, schedule: Schedule, *, expanded: bool = True) -> None:
    """Invariants used by the property tests:

    * every operator appears exactly once;
    * no operator is in the same or an earlier layer than any dependency;
    * layer indices are contiguous from 0.
    """
    g = graph.expand_calls() if expanded else graph
    seen: Dict[str, int] = {}
    for layer in schedule.layers:
        for placed in layer.ops:
            if placed.op.name in seen:
                raise AssertionError(f"{placed.op.name} scheduled twice")
            seen[placed.op.name] = layer.index
    if set(seen) != set(g.ops):
        missing = set(g.ops) - set(seen)
        extra = set(seen) - set(g.ops)
        raise AssertionError(f"schedule mismatch: missing={missing} extra={extra}")
    for name, op in g.ops.items():
        for dep in g.dependencies(op):
            if seen[dep.name] >= seen[name]:
                raise AssertionError(
                    f"dependency violated: {dep.name} (layer {seen[dep.name]}) "
                    f"must precede {name} (layer {seen[name]})"
                )
