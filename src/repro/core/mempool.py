"""Block-level memory pool with prefix-sum allocation (paper §V, Alg. 1).

The paper's mechanism: each GPU thread computes its required size; a parallel
prefix sum over the block yields per-thread offsets; one thread bumps a global
``idle_memory_head`` with ``atomic_add``; the pool is reset (O(1) pointer
rewind) after every meta-kernel, because layer-wise scheduling makes all
allocations of a layer dead once the layer's barrier passes.

TPU adaptation (see DESIGN.md §2): the allocator is expressed as

* :func:`plan_offsets` — jit-traceable prefix-sum offset planning used by the
  variable-length feature ops (ragged string pieces, split results, ...).
  Alignment is 128 *elements* (TPU lane width) instead of 128 bytes.
* :class:`ArenaPool` — the host-side pool object that owns a flat buffer,
  hands out layer-scoped arenas, and implements the O(1) reset between
  meta-kernels. The bump pointer is ordinary Python state because layer
  execution on one host is sequential (the TPU analogue of the single
  ``atomic_add`` owner); the *device side* of Alg. 1 lives in
  ``repro.kernels.mempool_alloc`` as a Pallas kernel with a sequential-grid
  SMEM carry.

Both paths are oracle-checked against each other in the tests.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ALIGN = 128  # TPU lane width; paper uses 128-byte cache alignment.


def align_up(x, align: int = ALIGN):
    """Round ``x`` up to a multiple of ``align`` (works on ints and arrays)."""
    return (x + align - 1) // align * align


def plan_offsets(sizes: jax.Array, *, align: int = ALIGN) -> Tuple[jax.Array, jax.Array]:
    """Alg. 1 lines 1–4 as a pure function.

    Args:
      sizes: int32[N] requested element counts per "thread" (per instance).
      align: alignment granularity in elements.

    Returns:
      offsets: int32[N] start offset of each request in the arena.
      total:   int32[]  total arena elements consumed (aligned).
    """
    aligned = align_up(sizes.astype(jnp.int32), align)
    # exclusive prefix sum == paper's prefix_i - prefix_1 with prefix from an
    # inclusive scan; jnp.cumsum + shift keeps it O(N log N) on the VPU.
    inclusive = jnp.cumsum(aligned)
    offsets = inclusive - aligned
    total = inclusive[-1] if sizes.shape[0] > 0 else jnp.int32(0)
    return offsets, total


@dataclasses.dataclass
class Allocation:
    offset: int
    size: int


class ArenaPool:
    """Pre-allocated flat pool with bump allocation and O(1) reset.

    Mirrors Fig. 5: ``idle_memory_head`` advances by the block's total
    (prefix_N); ``reset()`` rewinds it to the start after each meta-kernel.
    """

    def __init__(self, capacity: int, *, align: int = ALIGN):
        if capacity % align:
            raise ValueError(f"capacity must be {align}-aligned, got {capacity}")
        self.capacity = int(capacity)
        self.align = align
        self._head = 0
        self._high_water = 0
        self.n_resets = 0
        self.n_allocs = 0

    @property
    def head(self) -> int:
        return self._head

    @property
    def high_water(self) -> int:
        """Peak usage across resets — sizing feedback for deployments."""
        return self._high_water

    def alloc_block(self, sizes: Sequence[int]) -> List[Allocation]:
        """Allocate for a whole block of requests at once (Alg. 1).

        One prefix sum + one head bump, regardless of len(sizes) — the
        paper's point is that per-request allocation cost collapses to a
        scan plus a single atomic.
        """
        sizes_arr = np.asarray(sizes, dtype=np.int64)
        if sizes_arr.size == 0:
            return []
        if (sizes_arr < 0).any():
            raise ValueError("negative allocation size")
        aligned = (sizes_arr + self.align - 1) // self.align * self.align
        prefix = np.cumsum(aligned)
        total = int(prefix[-1])
        base = self._head  # "atomic_add(idle_memory_head, prefix_N)"
        if base + total > self.capacity:
            raise MemoryError(
                f"arena exhausted: head={base} request={total} capacity={self.capacity}"
            )
        self._head = base + total
        self._high_water = max(self._high_water, self._head)
        self.n_allocs += 1
        offsets = prefix - aligned  # exclusive scan
        return [Allocation(offset=base + int(o), size=int(s))
                for o, s in zip(offsets, sizes_arr)]

    def reset(self) -> None:
        """O(1) batch free after a meta-kernel (paper §V 'Reset')."""
        self._head = 0
        self.n_resets += 1


def required_capacity(layer_sizes: Sequence[Sequence[int]], *, align: int = ALIGN) -> int:
    """Size a pool so every layer's total allocation fits (reset between layers).

    The paper assumes "the total required memory for dynamic allocations
    [per layer] fits the GPU memory"; this helper computes that bound from
    the schedule's static cost model so the assumption is checked, not hoped.
    """
    worst = 0
    for sizes in layer_sizes:
        arr = np.asarray(list(sizes), dtype=np.int64)
        if arr.size == 0:
            continue
        aligned = (arr + align - 1) // align * align
        worst = max(worst, int(aligned.sum()))
    return int(align_up(worst, align))
