"""Per-layer fused meta-kernels (paper §IV "Inner-GPU operator launching").

The paper amortizes CUDA launch overhead (~3.5 µs/launch, Table I) by fusing
all same-layer operators into one meta-kernel that invokes each operator as a
device function, so each layer costs exactly one launch.

XLA/TPU analogue implemented here:

* every layer's DEVICE operators are traced together into **one** ``jax.jit``
  computation (`LayerExecutable`). XLA then fuses the bodies; at runtime each
  layer is a single dispatch — the direct counterpart of one kernel launch
  per layer. HOST operators run as Python callables before the device
  dispatch, and their outputs are moved with an explicit ``device_put``
  (the paper's H2D copy).
* by default, compilation goes one step further than the paper's per-layer
  fusion: maximal runs of consecutive layers with no interleaving host ops
  (``Schedule.superlayers``) are traced as a **single** jit computation, so
  per batch the device pays ``n_host_barriers + 1`` dispatches instead of
  one per layer — a dispatch is only *required* where host code interrupts
  device work. ``compile_layers(..., coalesce=False)`` keeps the per-layer
  structure (the Fig. 4(c) baseline the coalescing benchmark compares to).
* compilation happens once, ahead of training (`compile_layers`), because the
  schedule is fixed — the paper's "runtime-compilation manner ... only need to
  create this meta-kernel for each layer once as a pre-processing".

For hash/cross-style elementwise FE ops there is additionally a *true*
single-kernel path: ``repro.kernels.feature_hash`` executes a whole layer of
such ops inside one ``pallas_call`` over a shared VMEM tile. The scheduler
stays agnostic; ops that advertise a pallas device function are routed there
by ``fuse_pallas_ops``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, MutableMapping, Optional, Tuple

import jax

from repro.core.scheduler import PlacedOp, Schedule
from repro.obs.metrics import harvest
from repro.obs.trace import NULL_SPAN, get_tracer


@dataclasses.dataclass
class LayerExecutable:
    """One (super-)layer of the schedule, ready to run with one dispatch."""

    index: int
    host_ops: Tuple[PlacedOp, ...]
    device_ops: Tuple[PlacedOp, ...]
    fused_fn: Optional[Callable[..., Dict[str, Any]]]  # jitted; None if no device ops
    # slots the fused fn consumes from the environment, in order
    device_input_slots: Tuple[str, ...] = ()
    # schedule layers folded into this executable (coalescing accounting)
    layer_indices: Tuple[int, ...] = ()
    # per-op jitted wrappers, built once at compile time so the unfused
    # baseline (run_unfused) measures dispatch overhead, not retraces
    op_jits: Tuple[Callable[..., Dict[str, Any]], ...] = ()

    @property
    def n_dispatches(self) -> int:
        return 1 if self.fused_fn is not None else 0

    @property
    def n_source_layers(self) -> int:
        return len(self.layer_indices) if self.layer_indices else 1


def _build_fused_fn(device_ops: Tuple[PlacedOp, ...]) -> Tuple[Callable, Tuple[str, ...]]:
    """Trace all device ops of a (super-)layer as one function env->outputs.

    Ops are traced in schedule order, which is dependency-safe: within one
    layer ops are independent (scheduler invariant), and across coalesced
    layers every producer precedes its consumers. Slots produced inside the
    body are fed forward through the trace instead of the environment, so
    the fused computation's only inputs are externally-produced slots.
    """
    input_slots: List[str] = []
    seen = set()
    produced = set()
    for placed in device_ops:
        for slot in placed.op.inputs:
            if slot not in seen and slot not in produced:
                seen.add(slot)
                input_slots.append(slot)
        produced.update(placed.op.outputs)
    input_slots_t = tuple(input_slots)

    def fused(env: Dict[str, Any]) -> Dict[str, Any]:
        scope = dict(env)
        out: Dict[str, Any] = {}
        for placed in device_ops:
            kwargs = {s: scope[s] for s in placed.op.inputs}
            res = placed.op.fn(**kwargs)
            for slot in placed.op.outputs:
                scope[slot] = res[slot]
                out[slot] = res[slot]
        return out

    return jax.jit(fused), input_slots_t


def compile_layers(schedule: Schedule, *, coalesce: bool = True,
                   drop: Tuple[str, ...] = ()) -> List[LayerExecutable]:
    """Ahead-of-time build of every (super-)layer's fused executable.

    ``coalesce=True`` (default) groups maximal host-barrier-free layer runs
    into one executable each (``Schedule.superlayers``): dispatches per
    batch drop from one per device layer to ``n_host_barriers + 1``.
    ``coalesce=False`` keeps the paper's per-layer fusion for comparison.
    ``drop`` removes named operators from the build (used by the
    direct-to-arena staging path, which replaces the device ``final_batch``
    assembly with a host binding that writes straight into the arena).
    """
    groups = (schedule.superlayers if coalesce
              else tuple((layer,) for layer in schedule.layers))
    layers: List[LayerExecutable] = []
    dropped = set(drop)
    for i, group in enumerate(groups):
        members = group.layers if coalesce else group
        host_ops = tuple(p for layer in members for p in layer.host_ops
                         if p.op.name not in dropped)
        device_ops = tuple(p for layer in members for p in layer.device_ops
                           if p.op.name not in dropped)
        fused_fn, slots = (None, ())
        if device_ops:
            fused_fn, slots = _build_fused_fn(device_ops)
        layers.append(
            LayerExecutable(
                index=i,
                host_ops=host_ops,
                device_ops=device_ops,
                fused_fn=fused_fn,
                device_input_slots=slots,
                layer_indices=tuple(layer.index for layer in members),
                op_jits=tuple(jax.jit(p.op.fn) for p in device_ops),
            )
        )
    return layers


@dataclasses.dataclass
class ExecutionStats:
    n_layers: int = 0             # executables run (super-layers when coalesced)
    n_source_layers: int = 0      # schedule layers they cover (coalescing gain)
    n_device_dispatches: int = 0
    n_host_ops: int = 0
    host_seconds: float = 0.0
    device_seconds: float = 0.0

    @property
    def n_layers_coalesced(self) -> int:
        """Schedule layers folded into an already-dispatched super-layer."""
        return self.n_source_layers - self.n_layers

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)


def run_layers(
    layers: List[LayerExecutable],
    env: MutableMapping[str, Any],
    *,
    device: Optional[jax.Device] = None,
    stats: Optional[ExecutionStats] = None,
) -> MutableMapping[str, Any]:
    """Execute a compiled schedule over an environment of named slots.

    Layer order gives the barrier semantics of Fig. 4(c): host ops of layer i
    run, their outputs are device_put (H2D), then the single fused device
    dispatch for layer i runs; only then does layer i+1 start.
    """
    tracer = get_tracer()
    for layer in layers:
        # Span args are only materialized when tracing is on, keeping the
        # disabled hot path at one flag check per layer.
        span = (tracer.span("fe.layer", layer=layer.index,
                            host_ops=len(layer.host_ops),
                            dispatches=layer.n_dispatches)
                if tracer.enabled else NULL_SPAN)
        with span:
            t0 = time.perf_counter()
            for placed in layer.host_ops:
                kwargs = {s: env[s] for s in placed.op.inputs}
                res = placed.op.fn(**kwargs)
                for slot in placed.op.outputs:
                    val = res[slot]
                    # Explicit H2D move of host-op results (paper: CPU op
                    # output copied to GPU as a host-to-device CUDA call).
                    if device is not None and hasattr(val, "shape"):
                        val = jax.device_put(val, device)
                    env[slot] = val
            t1 = time.perf_counter()
            if layer.fused_fn is not None:
                out = layer.fused_fn(
                    {s: env[s] for s in layer.device_input_slots})
                env.update(out)
            t2 = time.perf_counter()
        if stats is not None:
            stats.n_layers += 1
            stats.n_source_layers += layer.n_source_layers
            stats.n_host_ops += len(layer.host_ops)
            stats.n_device_dispatches += layer.n_dispatches
            stats.host_seconds += t1 - t0
            stats.device_seconds += t2 - t1
    return env


def run_unfused(
    layers: List[LayerExecutable],
    env: MutableMapping[str, Any],
    *,
    stats: Optional[ExecutionStats] = None,
) -> MutableMapping[str, Any]:
    """Baseline executor: one dispatch per operator (no meta-kernel).

    This is the Table I comparison point — identical results, but every
    device op pays its own dispatch. Used by the launch-overhead benchmark.
    Per-op jitted wrappers come from compile time (``LayerExecutable.
    op_jits``) so the baseline measures dispatch overhead, not the retrace
    a fresh ``jax.jit`` wrapper per batch would cost.
    """
    for layer in layers:
        t0 = time.perf_counter()
        for placed in layer.host_ops:
            kwargs = {s: env[s] for s in placed.op.inputs}
            res = placed.op.fn(**kwargs)
            env.update({slot: res[slot] for slot in placed.op.outputs})
        t1 = time.perf_counter()
        # fallback for hand-built executables that predate op_jits
        fns = layer.op_jits or tuple(jax.jit(p.op.fn)
                                     for p in layer.device_ops)
        for placed, fn in zip(layer.device_ops, fns):
            kwargs = {s: env[s] for s in placed.op.inputs}
            res = fn(**kwargs)
            for slot in placed.op.outputs:
                env[slot] = res[slot]
            if stats is not None:
                stats.n_device_dispatches += 1
        t2 = time.perf_counter()
        if stats is not None:
            stats.n_layers += 1
            stats.n_source_layers += layer.n_source_layers
            stats.n_host_ops += len(layer.host_ops)
            stats.host_seconds += t1 - t0
            stats.device_seconds += t2 - t1
    return env
