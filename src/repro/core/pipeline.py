"""End-to-end pipelined execution (paper Fig. 1 lower / Fig. 3).

FeatureBox's headline mechanism: feature extraction and training share the
same servers and run as a mini-batch pipeline, so extracted features are fed
directly into the trainer without materializing intermediates.

Two executors are provided so the benchmarks can reproduce Table II:

* :class:`PipelinedRunner` — FeatureBox mode. A host prefetch thread runs the
  FE schedule for batch i+1 while the device trains on batch i (double
  buffering). JAX's async dispatch provides the device-side overlap; the
  bounded queue provides backpressure. With ``device_feed`` set to a
  :class:`~repro.core.devicefeed.DeviceFeeder`, a third stage is inserted —
  *read+extract -> H2D stage -> train* — where a dedicated thread stages
  batch i+1 through a buffer-ring staging arena (block-planned async
  transfers) while batch i trains, so host->device transfer leaves the training
  critical path too. ``device_feed=None`` keeps the two-stage behavior.
* :class:`StagedRunner` — the MapReduce-style baseline: stage after stage,
  each stage writes its full output to disk (the "intermediate files" of
  Fig. 1 upper) and the next stage reads it back. Tracks intermediate bytes
  so the I/O-elimination claim is measurable.

Both runners take any iterable of raw batches — in particular a
``repro.io.StreamingLoader``, in which case the pipelined runner's FE worker
overlaps *disk read + extract* with training and the loader's
``IngestStats`` are attached to :attr:`PipelineStats.ingest` after the run.
"""

from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
from typing import Any, Callable, Dict, Iterable, Iterator, List, Mapping, Optional

import numpy as np

from repro.check.annotations import single_writer
from repro.core.devicefeed import DeviceFeeder
from repro.core.metakernel import ExecutionStats, LayerExecutable, run_layers
from repro.obs.metrics import harvest
from repro.obs.trace import get_tracer

# Sentinel for end-of-stream in the prefetch queue.
_DONE = object()


@dataclasses.dataclass
class PipelineStats:
    batches: int = 0
    fe_seconds: float = 0.0
    train_seconds: float = 0.0
    # PS-feeder stage only (hierarchical embedding backend): thread time
    # spent pulling working sets + waiting on write-back consistency.
    ps_seconds: float = 0.0
    # StagedRunner only: time draining the batch source up front (disk reads
    # with no compute overlap). Accounted so wall == fe + train + drain +
    # small overhead instead of misreading the gap as overhead.
    drain_seconds: float = 0.0
    wall_seconds: float = 0.0
    intermediate_bytes: int = 0  # bytes written to disk between stages
    exec_stats: ExecutionStats = dataclasses.field(default_factory=ExecutionStats)
    # When the batch source is a repro.io.StreamingLoader, its IngestStats
    # (disk bytes/s, queue stalls) are attached here after run().
    ingest: Optional[Any] = None
    # When a DeviceFeeder staged the batches, its FeedStats (h2d bytes/s,
    # arena rewinds, buffer stalls) are attached here after run().
    feed: Optional[Any] = None
    # When the train step is a compiled boundary step (repro.fe.modelfeed
    # make_step), its TrainFeedStats (adapt time/dispatches, dedup unique
    # ratio) are attached here after run(), splitting "adapt" out of the
    # train bucket.
    train_feed: Optional[Any] = None
    # When a HierarchyFeed pulled working sets (ps_feed stage), its
    # PsFeedStats + the PS TierStats are attached here after run().
    ps: Optional[Any] = None
    # When the train step runs on a device mesh, its CommStats (static
    # collective-byte plan x steps) are attached here after run().
    comm: Optional[Any] = None
    # When the batch source is a lease-based StreamingLoader, its
    # FaultStats (reissued leases, retries, backup wins, reap latency)
    # are attached here after run() — the recovery story of the run.
    fault: Optional[Any] = None

    @property
    def adapt_seconds(self) -> float:
        """Host time spent adapting staged batches to the model's layout
        (0 when the train step carries no train-feed stats)."""
        return (self.train_feed.adapt_seconds
                if self.train_feed is not None else 0.0)

    @property
    def train_net_seconds(self) -> float:
        """train_seconds with the measurable adapt share split out."""
        return max(self.train_seconds - self.adapt_seconds, 0.0)

    # ------------------------------------------------- derived accounting
    # The accounting identity both runners satisfy (asserted in
    # tests/test_pipeline.py):
    #     wall <= fe + train_net + adapt + drain + overhead
    # with equality for the serial (Staged) runner, overhead >= 0 always,
    # and the pipelined runner's surplus busy time showing up as overlap.

    @property
    def busy_seconds(self) -> float:
        """Stage time summed across threads: fe + ps + train + drain.
        Exceeds wall exactly when pipelining hid stage time behind another
        stage."""
        return (self.fe_seconds + self.ps_seconds + self.train_seconds
                + self.drain_seconds)

    @property
    def overhead_seconds(self) -> float:
        """Wall time no stage accounts for (queue waits, thread startup,
        end-of-stream drain). Never negative: when stages overlap, busy
        time can exceed wall and the residual is overlap, not overhead."""
        return max(self.wall_seconds - self.busy_seconds, 0.0)

    @property
    def overlap_seconds(self) -> float:
        """Stage seconds hidden by pipelining (busy time beyond wall)."""
        return max(self.busy_seconds - self.wall_seconds, 0.0)

    @property
    def overlap_fraction(self) -> float:
        """How much of the smaller stage (FE vs train) was hidden behind
        the other, in [0, 1]. 0 = fully serial; 1 = the cheaper stage ran
        entirely in the other's shadow — the paper's pipelining claim as
        one number."""
        denom = min(self.fe_seconds, self.train_seconds)
        if denom <= 0.0:
            return 0.0
        return min(self.overlap_seconds / denom, 1.0)

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot (fields + derived properties) for the
        :class:`repro.obs.MetricsRegistry`; nested tiers register
        themselves separately."""
        return harvest(self)


def _capture_ingest(stats: PipelineStats, batches: Any) -> None:
    """Adopt ingest stats from a StreamingLoader-like batch source.

    Duck-typed so core stays import-independent of :mod:`repro.io`.
    """
    src_stats = getattr(batches, "stats", None)
    if src_stats is not None and hasattr(src_stats, "bytes_read"):
        stats.ingest = src_stats


def _capture_fault(stats: PipelineStats, batches: Any) -> None:
    """Adopt recovery stats from a lease-based StreamingLoader source.

    Duck-typed off ``fault_stats`` so core stays import-independent of
    :mod:`repro.io` / :mod:`repro.train`.
    """
    fs = getattr(batches, "fault_stats", None)
    if fs is not None and hasattr(fs, "reissued"):
        stats.fault = fs


def _capture_train_feed(stats: PipelineStats, train_step: Any) -> None:
    """Adopt train-feed stats from a modelfeed-compiled boundary step.

    Duck-typed off the step's ``feed_stats`` attribute so core stays
    import-independent of :mod:`repro.fe`.
    """
    fs = getattr(train_step, "feed_stats", None)
    if fs is not None and hasattr(fs, "adapt_seconds"):
        stats.train_feed = fs


def _capture_comm(stats: PipelineStats, train_step: Any) -> None:
    """Adopt mesh collective stats from the train step's ``comm_stats``.

    Duck-typed off :class:`repro.train.compression.CommStats` so core stays
    import-independent of :mod:`repro.train`.
    """
    cs = getattr(train_step, "comm_stats", None)
    if cs is not None and hasattr(cs, "interpod_bytes_total"):
        stats.comm = cs


# Thread contract (verified by `python -m repro.check` / repro.check.lockset):
# PipelineStats is shared without a lock because every field has exactly one
# writing thread — the fe-worker owns fe_seconds, the main train loop owns
# the rest (it only reads them after joining the workers). Any new field
# written from more than one thread must move to a @guarded_by lock.
@single_writer("stats.fe_seconds",                       # fe-worker thread
               "stats.ps_seconds",                       # ps-feeder thread
               "stats.train_seconds", "stats.batches",   # main train loop
               "stats.wall_seconds", "stats.feed", "stats.ps")
class PipelinedRunner:
    """FeatureBox: FE for batch i+1 overlaps training on batch i.

    With ``device_feed`` set, an H2D staging thread is inserted between the
    FE worker and the train loop (three-stage pipeline); ``None`` keeps the
    two-stage path and hands host environments straight to ``train_step``.

    With ``ps_feed`` set (a :class:`repro.embedding.psfeed.HierarchyFeed`),
    a PS-pull stage runs between the FE worker and the H2D/train stages:
    batch i+1's dedup'd working set is pulled from the hierarchical
    parameter server while batch i trains — the paper's pre-built working
    parameter set, as a pipeline stage.
    """

    def __init__(
        self,
        layers: List[LayerExecutable],
        train_step: Callable[[Any, Mapping[str, Any]], Any],
        *,
        prefetch: int = 2,
        device=None,
        device_feed: Optional[DeviceFeeder] = None,
        ps_feed: Optional[Callable[[Mapping[str, Any]], Dict[str, Any]]] = None,
    ) -> None:
        self.layers = layers
        self.train_step = train_step
        self.prefetch = prefetch
        self.device = device
        self.device_feed = device_feed
        self.ps_feed = ps_feed
        self.stats = PipelineStats()

    @classmethod
    def from_plan(cls, plan: Any, train_step: Callable[[Any, Mapping[str, Any]], Any],
                  *, prefetch: int = 2, device=None, feed: str = "off",
                  split_sparse_fields: bool = False,
                  rows_hint: Optional[int] = None,
                  buffers: int = 3) -> "PipelinedRunner":
        """Wire a compiled ``repro.fe.featureplan.FeaturePlan`` into a runner.

        ``feed`` selects the H2D tier:

        * ``"off"``   — two-stage pipeline; the train step receives host
          arrays (per-tensor transfer on the training critical path);
        * ``"stage"`` — three-stage: a :class:`DeviceFeeder` memcpys each
          batch's outputs into the block-planned staging arena and
          transfers them asynchronously (PR 3 behavior);
        * ``"arena"`` — zero-copy feed: FE assembles the ``batch_*``
          outputs **directly into claimed arena views**
          (``plan.arena_binding()``), eliminating the per-batch
          env->arena memcpy (``FeedStats.copies_elided``).

        Duck-typed on the plan (``layers`` / ``feed_layout`` /
        ``arena_binding``) so core stays import-independent of repro.fe.
        """
        if feed == "off":
            return cls(plan.layers, train_step, prefetch=prefetch,
                       device=device)
        if feed == "stage":
            feeder = DeviceFeeder(
                plan.feed_layout(split_sparse_fields=split_sparse_fields),
                rows_hint=rows_hint, buffers=buffers, device=device)
            return cls(plan.layers, train_step, prefetch=prefetch,
                       device=device, device_feed=feeder)
        if feed == "arena":
            ab = plan.arena_binding(split_sparse_fields=split_sparse_fields)
            feeder = ab.make_feeder(rows_hint=rows_hint, buffers=buffers,
                                    device=device)
            return cls(ab.layers, train_step, prefetch=prefetch,
                       device=device, device_feed=feeder)
        raise ValueError(
            f"feed must be 'off', 'stage', or 'arena', got {feed!r}")

    def _fe_worker(self, batches: Iterator[Mapping[str, Any]],
                   q: "queue.Queue", stop: threading.Event) -> None:
        tracer = get_tracer()
        try:
            for bi, raw in enumerate(batches):
                if stop.is_set():  # consumer died: don't extract the rest
                    break
                t0 = time.perf_counter()
                with tracer.span("fe.extract", batch=bi):
                    env = dict(raw)
                    run_layers(self.layers, env, device=self.device,
                               stats=self.stats.exec_stats)
                self.stats.fe_seconds += time.perf_counter() - t0
                self._put(q, env, stop)
        except BaseException as e:  # surface worker failures to the consumer
            tracer.instant("fe.error", kind=type(e).__name__)
            self._put(q, e, stop)
        finally:
            self._put(q, _DONE, stop)

    @staticmethod
    def _put(q: "queue.Queue", item: Any, stop: threading.Event) -> None:
        """Backpressured put that gives up once the consumer is gone, so a
        failed train_step can't leave the FE worker blocked forever."""
        while True:
            try:
                q.put(item, timeout=0.1)
                return
            except queue.Full:
                if stop.is_set():
                    return

    def _feed_worker(self, q: "queue.Queue", out: "queue.Queue",
                     stop: threading.Event) -> None:
        """H2D stage: pull extracted envs, stage batch i+1 while i trains.

        Sentinels and FE-worker exceptions pass through unchanged so the
        consumer sees the original failure, not a feed artifact.
        """
        try:
            while True:
                try:
                    item = q.get(timeout=0.1)
                except queue.Empty:
                    if stop.is_set():
                        return
                    continue
                if item is _DONE:
                    self._put(out, _DONE, stop)
                    return
                if isinstance(item, BaseException):
                    self._put(out, item, stop)
                    continue  # _DONE follows from the FE worker
                self._put(out, self.device_feed.stage(item), stop)
        except BaseException as e:  # staging failure: surface + terminate
            self._put(out, e, stop)
            self._put(out, _DONE, stop)

    def _ps_worker(self, q: "queue.Queue", out: "queue.Queue",
                   stop: threading.Event) -> None:
        """PS stage: pull batch i+1's working set while batch i trains.

        Same pass-through contract as :meth:`_feed_worker` — sentinels and
        upstream exceptions flow downstream unchanged.
        """
        try:
            while True:
                try:
                    item = q.get(timeout=0.1)
                except queue.Empty:
                    if stop.is_set():
                        return
                    continue
                if item is _DONE:
                    self._put(out, _DONE, stop)
                    return
                if isinstance(item, BaseException):
                    self._put(out, item, stop)
                    continue  # _DONE follows from the FE worker
                t0 = time.perf_counter()
                prepared = self.ps_feed(item)
                self.stats.ps_seconds += time.perf_counter() - t0
                self._put(out, prepared, stop)
        except BaseException as e:  # pull/consistency failure: surface it
            self._put(out, e, stop)
            self._put(out, _DONE, stop)

    def run(self, state: Any, batches: Iterable[Mapping[str, Any]]) -> Any:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()
        t_start = time.perf_counter()
        worker = threading.Thread(
            target=self._fe_worker, args=(iter(batches), q, stop),
            daemon=True, name="fe-worker",
        )
        threads = [worker]
        queues = [q]
        out_q = q
        if self.ps_feed is not None:
            # Working sets hold device buffers: keep at most one prepared
            # batch queued ahead of the train loop (single-batch pull-ahead;
            # the consistency protocol in HierarchyFeed assumes it).
            ps_q: "queue.Queue" = queue.Queue(maxsize=1)
            ps_feeder = threading.Thread(
                target=self._ps_worker, args=(out_q, ps_q, stop),
                daemon=True, name="ps-feeder",
            )
            threads.append(ps_feeder)
            queues.append(ps_q)
            out_q = ps_q
        if self.device_feed is not None:
            # Bounded by the buffer ring: with one batch held by the train
            # loop and one being staged, at most buffers-2 more fit in the
            # queue before the feeder would block reclaiming a ring slot.
            feed_q: "queue.Queue" = queue.Queue(
                maxsize=max(1, self.device_feed.buffers - 2))
            feeder = threading.Thread(
                target=self._feed_worker, args=(out_q, feed_q, stop),
                daemon=True, name="h2d-feeder",
            )
            threads.append(feeder)
            queues.append(feed_q)
            out_q = feed_q
        for t in threads:
            t.start()
        tracer = get_tracer()
        try:
            while True:
                if tracer.enabled:
                    # Record the wait for the next extracted/staged batch
                    # only when it actually stalled the train loop: the
                    # gap is the pipeline's backpressure signal.
                    w0 = tracer.now_ns()
                    item = out_q.get()
                    w1 = tracer.now_ns()
                    if w1 - w0 > 100_000:  # >0.1 ms
                        tracer.complete("train.wait_batch", w0, w1)
                else:
                    item = out_q.get()
                if item is _DONE:
                    break
                if isinstance(item, BaseException):
                    raise item
                t0 = time.perf_counter()
                with tracer.span("train.step", batch=self.stats.batches):
                    state = self.train_step(state, item)
                self.stats.train_seconds += time.perf_counter() - t0
                self.stats.batches += 1
                # Release the env before blocking on the next get so batch
                # memory is reclaimed as soon as the device is done with it.
                del item
        finally:
            stop.set()
            if self.ps_feed is not None:
                # Unblock a prepare() waiting on a write-back that will
                # never arrive (duck-typed; HierarchyFeed.close never
                # raises). Drain/flush is the driver's job, not teardown's.
                close = getattr(self.ps_feed, "close", None)
                if close is not None:
                    close()
            for qq in queues:  # release workers blocked on a full queue
                try:
                    while True:
                        qq.get_nowait()
                except queue.Empty:
                    pass
            for t in threads:
                t.join(timeout=5.0)
            if self.device_feed is not None:
                # Drain in-flight transfers so wall time covers them and
                # FeedStats.stall_seconds reflects the end-of-stream wait —
                # but only once the h2d feeder is confirmed dead: join can
                # time out with the thread still inside stage(), and flush
                # must not race the ring it is draining.
                if not any(t.is_alive() for t in threads):
                    self.device_feed.flush()
                self.stats.feed = self.device_feed.stats
            if self.ps_feed is not None and hasattr(self.ps_feed, "as_metrics"):
                self.stats.ps = self.ps_feed
            self.stats.wall_seconds = time.perf_counter() - t_start
            _capture_ingest(self.stats, batches)
            _capture_fault(self.stats, batches)
            _capture_train_feed(self.stats, self.train_step)
            _capture_comm(self.stats, self.train_step)
        return state


class StagedRunner:
    """Baseline: materialize every stage's output before the next stage runs.

    Mirrors the paper's Fig. 1 (upper): MapReduce jobs write intermediate
    files to the DFS; the trainer then streams the final features back. Here
    each scheduled layer plays the role of one MapReduce job and writes its
    produced slots to ``workdir`` as .npy files.
    """

    def __init__(
        self,
        layers: List[LayerExecutable],
        train_step: Callable[[Any, Mapping[str, Any]], Any],
        *,
        workdir: str,
        device=None,
    ) -> None:
        self.layers = layers
        self.train_step = train_step
        self.workdir = workdir
        self.device = device
        self.stats = PipelineStats()
        os.makedirs(workdir, exist_ok=True)

    def _materialize(self, env: Dict[str, Any], stage: int, batch: int) -> Dict[str, Any]:
        """Write every slot to disk and read it back (stage boundary).

        Slots may be arrays, dicts of columns (views), or ragged columns —
        each is written like the MapReduce intermediates it stands in for.
        """
        out: Dict[str, Any] = {}
        for slot, val in env.items():
            out[slot] = self._roundtrip(val, f"b{batch}_s{stage}_{_safe(slot)}")
        return out

    def _roundtrip(self, val: Any, stem: str) -> Any:
        if isinstance(val, dict):
            return {k: self._roundtrip(v, f"{stem}__{_safe(str(k))}")
                    for k, v in val.items()}
        if hasattr(val, "values") and hasattr(val, "lengths"):  # RaggedColumn
            vals = self._roundtrip(np.asarray(val.values), stem + "__values")
            lens = self._roundtrip(np.asarray(val.lengths), stem + "__lengths")
            return type(val)(values=vals, lengths=lens)
        arr = np.asarray(val)
        path = os.path.join(self.workdir, stem + ".npy")
        np.save(path, arr, allow_pickle=True)  # string columns are object arrays
        # Count the on-disk size: for object (string) columns arr.nbytes is
        # just 8-byte pointers, which would undercount the I/O eliminated.
        self.stats.intermediate_bytes += os.path.getsize(path)
        return np.load(path, allow_pickle=True)

    def run(self, state: Any, batches: Iterable[Mapping[str, Any]]) -> Any:
        tracer = get_tracer()
        t_start = time.perf_counter()
        # A StreamingLoader source is drained up front: the staged baseline
        # by definition has no read/compute overlap. That read time is its
        # own accounting bucket (drain_seconds), not fe/train overhead.
        with tracer.span("staged.drain"):
            all_batches = list(batches)
        self.stats.drain_seconds = time.perf_counter() - t_start
        _capture_ingest(self.stats, batches)
        _capture_fault(self.stats, batches)
        # Stage-after-stage: run *every* batch through layer k, materialize,
        # then move to layer k+1 — the defining property of the baseline.
        envs: List[Dict[str, Any]] = [dict(b) for b in all_batches]
        for li, layer in enumerate(self.layers):
            t0 = time.perf_counter()
            with tracer.span("fe.stage", layer=li, batches=len(envs)):
                for bi, env in enumerate(envs):
                    run_layers([layer], env, device=self.device,
                               stats=self.stats.exec_stats)
                    envs[bi] = self._materialize(env, li, bi)
            self.stats.fe_seconds += time.perf_counter() - t0
        for bi, env in enumerate(envs):
            t0 = time.perf_counter()
            with tracer.span("train.step", batch=bi):
                state = self.train_step(state, env)
            self.stats.train_seconds += time.perf_counter() - t0
            self.stats.batches += 1
        self.stats.wall_seconds = time.perf_counter() - t_start
        _capture_train_feed(self.stats, self.train_step)
        _capture_comm(self.stats, self.train_step)
        return state


def _safe(slot: str) -> str:
    return "".join(c if c.isalnum() or c in "-_" else "_" for c in slot)
