"""Async device-feed stage: arena-staged double-buffered H2D transfers.

The third pipeline stage (read+extract -> **H2D stage** -> train). The FE
worker hands host feature environments to a :class:`DeviceFeeder`, which
stages each batch's ``batch_*`` output slots through a pre-allocated flat
byte arena (paper §V, Alg. 1: one prefix-sum placement plan + one head bump
per batch, O(1) pointer rewind between batches) and issues **one**
``jax.device_put`` per batch — the arena is the unit of transfer, so the
host->device hop for batch i+1 overlaps training on batch i instead of
sitting on the training critical path.

Staging layout is static: :class:`FeedLayout` (derived at compile time from
a plan's :class:`~repro.fe.compiler.OutputLayout` via
``FeaturePlan.feed_layout()``) fixes per-slot row widths and dtypes, so the
arena is sized once and per-batch placement is a cached plan, not a fresh
allocation. Each slot is transferred with its own ``jax.device_put`` from
an aligned typed view of the arena — pure transfers, deliberately **not**
a jitted repack: transfers bypass the device execution queue, so staging
never serializes behind the in-flight train step (a jitted unpack would).

Buffer reuse is gated on *use-completion*, not Python liveness: the ring
holds strong references to every array staged from a buffer and rewrites
the buffer only after each of those arrays is ready (transfer confirmed
complete). Python liveness is not a safe gate — jax dispatch is async, so
the consumer can drop its references while the H2D transfer (or a train
step reading a zero-copy alias) is still in flight; the in-flight
execution keeps the host memory *alive* but not *immutable*.

Readiness alone is only a safe gate if the staged arrays never alias the
arena. ``jax.device_put`` may zero-copy a well-aligned host view
(backend- and alignment-dependent — the CPU backend does for 128-byte-
aligned sources), and a zero-copied array aliases the staged bytes for
its whole lifetime: no amount of waiting makes rewriting safe. The
feeder therefore forces its host buffers to 128-byte-aligned bases (so
the backend's behavior is deterministic, not malloc luck), probes the
first transfer, and — where ``device_put`` zero-copies — transfers each
slot from a private copy of its staged bytes, owned by the device array.
Staged arrays thus never point into the arena: copying backends
(discrete-device H2D) pay no extra copy and overlap the real transfer;
zero-copy backends pay one host memcpy per slot — the price of reusing
the arena without a consumer completion protocol, on backends where
there is no transfer to overlap anyway.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.check.annotations import guarded_by, shared_entry, single_writer
from repro.core.mempool import ALIGN, Allocation, ArenaPool, align_up, plan_offsets
from repro.obs.metrics import harvest
from repro.obs.trace import get_tracer


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    """One staged output slot: fixed per-row width and element dtype."""

    name: str
    width: int          # elements per row ([rows, width]; rank1 -> [rows])
    dtype: str          # numpy dtype name (itemsize divides the alignment)
    rank1: bool = False

    @property
    def itemsize(self) -> int:
        return np.dtype(self.dtype).itemsize

    def nbytes(self, rows: int) -> int:
        return int(rows) * self.width * self.itemsize


@dataclasses.dataclass(frozen=True)
class FeedLayout:
    """Static staging layout: the compile-time contract of the feed stage.

    Sizes depend only on the batch row count, so arena capacity and slot
    placement are known before the first batch arrives.
    """

    slots: Tuple[SlotSpec, ...]
    align: int = ALIGN  # byte alignment of slot starts inside the arena

    def __post_init__(self) -> None:
        if not self.slots:
            raise ValueError("FeedLayout needs at least one slot")
        names = [s.name for s in self.slots]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate slot names: {names}")

    @property
    def slot_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.slots)

    def sizes(self, rows: int) -> List[int]:
        """Per-slot byte sizes for a batch of ``rows`` instances."""
        return [s.nbytes(rows) for s in self.slots]

    def bytes_per_batch(self, rows: int) -> int:
        """Payload bytes staged per batch (before arena alignment)."""
        return sum(self.sizes(rows))

    def arena_bytes(self, rows: int) -> int:
        """Aligned arena capacity one batch of ``rows`` instances needs."""
        return int(align_up(sum(align_up(n, self.align)
                                for n in self.sizes(rows)), self.align))

    def plan(self, rows: int, *, use_kernel: bool = False
             ) -> Tuple[np.ndarray, int]:
        """Alg. 1 placement plan: per-slot arena offsets + total bytes.

        ``use_kernel=False`` runs :func:`repro.core.mempool.plan_offsets`
        (the jit-traceable prefix-sum path); ``use_kernel=True`` routes
        through the Pallas allocator kernel
        (:func:`repro.kernels.mempool_alloc.ops.plan_allocation`). Both are
        oracle-checked against :class:`ArenaPool` in the tests.
        """
        if use_kernel:
            from repro.kernels.mempool_alloc.ops import plan_block
            return plan_block(self.sizes(rows), align=self.align)
        sizes = self.sizes(rows)
        need = sum(align_up(n, self.align) for n in sizes)
        if need > np.iinfo(np.int32).max:
            raise OverflowError(
                f"feed layout needs {need} aligned bytes for rows={rows}, "
                f"which overflows the planner's int32 offsets; split the "
                f"batch")
        offsets, total = plan_offsets(
            jnp.asarray(sizes, jnp.int32), align=self.align)
        return np.asarray(offsets), int(total)


@dataclasses.dataclass
class FeedStats:
    """Where the feed tier's time and bytes went."""

    batches: int = 0
    bytes_staged: int = 0       # payload bytes copied host->device
    h2d_seconds: float = 0.0    # staging copy + transfer dispatch
    stall_seconds: float = 0.0  # waiting on in-flight transfers (ring reclaim + flush)
    arena_capacity: int = 0     # bytes per host buffer
    buffers: int = 0
    rewinds: int = 0            # O(1) arena resets (one per staged batch)
    reallocs: int = 0           # capacity regrows (batch exceeded the hint)
    copies_elided: int = 0      # slots staged without an env->arena memcpy
    #   (zero-copy feed: the producer wrote the slot straight into a
    #   claimed arena view, so stage() had nothing to copy)
    donated: int = 0            # staged arrays reclaimed via consumer donation
    #   (deleted by a jit that took ownership of the staged batch; the
    #   completion gate awaits the donation fence instead of the array)

    @property
    def h2d_bytes_per_second(self) -> float:
        return self.bytes_staged / max(self.h2d_seconds, 1e-9)

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)

    def summary(self) -> str:
        return (f"batches={self.batches} "
                f"staged={self.bytes_staged / 2**20:.1f}MiB "
                f"h2d={self.h2d_seconds:.2f}s "
                f"({self.h2d_bytes_per_second / 2**20:.0f}MiB/s) "
                f"stall={self.stall_seconds:.2f}s "
                f"arena={self.arena_capacity / 2**10:.0f}KiB x{self.buffers} "
                f"rewinds={self.rewinds} reallocs={self.reallocs} "
                f"elided={self.copies_elided} donated={self.donated}")


class FeedError(RuntimeError):
    """A batch violated the feed layout's static shape contract."""


@dataclasses.dataclass
class ArenaClaim:
    """One batch's claimed ring slot: typed arena views awaiting the payload.

    Returned by :meth:`DeviceFeeder.claim_views`; producers write each
    slot's rows directly into ``views[name]`` (zero-copy feed), then hand
    the claim back to :meth:`DeviceFeeder.stage`, which issues the
    transfers without re-copying arena-resident slots.
    """

    buffer_index: int
    rows: int
    views: Dict[str, np.ndarray]
    allocs: List[Allocation]
    epoch: int  # arena generation; a regrow orphans older claims' transfers


# Thread contract (verified by `python -m repro.check` / repro.check.lockset):
# the h2d-feeder thread drives stage()/claim_views(); the main train loop
# calls flush() and donation_fence(). Ring state is guarded by _lock, the
# donation handshake by _fence_cond; the remaining stats fields and arena
# plumbing are written only from the feeder thread.
@guarded_by("_lock", "_inflight", "_orphans", "_host", "_next", "_seq",
            "_inflight_seq", "_epoch", "stats.donated", "stats.stall_seconds")
@guarded_by("_fence_cond", "_fence", "_consumed_seq")
@shared_entry("feeder:stage", "feeder:claim_views",
              "main:flush", "main:donation_fence")
@single_writer("pool", "last_allocs", "_zero_copy_put", "_rewinds_prior",
               "stats.batches", "stats.bytes_staged", "stats.h2d_seconds",
               "stats.copies_elided", "stats.rewinds", "stats.reallocs",
               "stats.arena_capacity")
class DeviceFeeder:
    """Stage feature batches into device memory through a double-buffered arena.

    Used standalone (``env = feeder.stage(env)``) or as the middle stage of
    :class:`~repro.core.pipeline.PipelinedRunner` (``device_feed=feeder``),
    where a dedicated thread stages batch i+1 while batch i trains.

    Parameters
    ----------
    layout:
        The static :class:`FeedLayout` (``FeaturePlan.feed_layout()``).
    rows_hint:
        Expected batch row count; pre-sizes the arenas at construction
        (compile time). Larger batches still work — the arena regrows and
        ``FeedStats.reallocs`` counts the event.
    buffers:
        Staging arenas cycling round-robin. The default 3 matches the
        three-stage pipeline's steady state — one buffer being written,
        one whose transfer is in flight, one whose batch the consumer
        holds — so reclaiming a ring slot rarely has to wait.
    device:
        Target device for ``jax.device_put`` (default backend if None).
    binding:
        Optional output binding (``FeaturePlan.arena_binding().binding``):
        a producer-side assembler with ``ready(env)`` / ``rows_of(env)`` /
        ``write(env, views)``. When set and a batch arrives in pre-assembly
        form, :meth:`stage` claims ring views and has the binding write the
        ``batch_*`` outputs **directly into the arena** — the zero-copy
        feed: no fresh output arrays, no env->arena memcpy
        (``FeedStats.copies_elided`` counts the slots that skipped it).
    """

    def __init__(self, layout: FeedLayout, *, rows_hint: Optional[int] = None,
                 buffers: int = 3, device=None, binding=None) -> None:
        if buffers < 1:
            raise ValueError(f"buffers must be >= 1, got {buffers}")
        self.layout = layout
        self.buffers = buffers
        self.device = device
        self.binding = binding
        self.stats = FeedStats(buffers=buffers)
        self.pool: Optional[ArenaPool] = None
        self.last_allocs: List[Allocation] = []  # placement of the last batch
        self._rewinds_prior = 0  # resets of pools replaced by a regrow
        self._host: List[Optional[np.ndarray]] = [None] * buffers
        # Strong refs to the arrays staged from each buffer: the reuse gate.
        # Cleared only after block_until_ready (claim/flush), never by the
        # consumer dropping its references (jax dispatch is async — Python
        # liveness says nothing about whether a transfer finished).
        self._inflight: List[List[jax.Array]] = [[] for _ in range(buffers)]
        # Transfers orphaned by an arena regrow, still awaited by flush().
        self._orphans: List[jax.Array] = []
        # Guards _inflight/_orphans/_host/_next against flush() racing stage().
        self._lock = threading.Lock()
        # None until the first transfer probes whether device_put zero-copies
        # 128-byte-aligned host views on this backend (see _put).
        self._zero_copy_put: Optional[bool] = None
        # Donation-fence protocol state (see donation_fence): the latest
        # consumer output, and how many consumer steps have registered one.
        # Staged batches are consumed in order, so a buffer whose batch was
        # the n-th staged is covered once _consumed_seq >= n.
        self._fence_cond = threading.Condition()
        self._fence: Optional[jax.Array] = None
        self._consumed_seq = 0
        self._seq = 0                                  # batches staged
        self._inflight_seq: List[int] = [0] * buffers  # stage seq per buffer
        self._next = 0
        # Arena generation: bumped by every regrow so transfers issued from
        # a pre-regrow ArenaClaim are tracked as orphans, not misfiled
        # under a fresh buffer's index.
        self._epoch = 0
        if rows_hint is not None:
            self._ensure_capacity(int(rows_hint))

    # ------------------------------------------------------------ arena mgmt
    def _aligned_zeros(self, nbytes: int) -> np.ndarray:
        """Zeroed host buffer whose base is layout-aligned. numpy gives no
        alignment guarantee beyond ~16 bytes, and zero-copy eligibility in
        ``jax.device_put`` depends on source alignment — forcing the base
        makes the backend's copy-vs-alias behavior deterministic, so the
        one-time probe in :meth:`_put` generalizes to every buffer."""
        a = self.layout.align
        raw = np.zeros(nbytes + a, dtype=np.uint8)
        off = (-raw.__array_interface__["data"][0]) % a
        return raw[off:off + nbytes]

    def _ensure_capacity(self, rows: int) -> None:
        need = self.layout.arena_bytes(rows)
        if self.pool is not None:
            if need <= self.pool.capacity:
                return
            self.stats.reallocs += 1
            self._rewinds_prior += self.pool.n_resets
            get_tracer().instant("arena.regrow", old=self.pool.capacity,
                                 new=need)
        self.pool = ArenaPool(need, align=self.layout.align)
        with self._lock:
            # Transfers from the old buffers may still be in flight; jax
            # keeps the source memory alive and we never rewrite a dropped
            # buffer, but flush() must still be able to await the work.
            self._orphans.extend(d for devs in self._inflight for d in devs)
            self._host = [self._aligned_zeros(need)
                          for _ in range(self.buffers)]
            self._inflight = [[] for _ in range(self.buffers)]
            self._inflight_seq = [0] * self.buffers
            self._next = 0
            self._epoch += 1
        self.stats.arena_capacity = need

    def _claim_buffer(self) -> int:
        """Next ring slot, gated on *use-completion*: every array staged
        from the buffer is awaited (transfer confirmed complete) before the
        buffer may be rewritten. Staged arrays never alias the arena (see
        :meth:`_put`), so readiness is a sufficient gate — the consumer
        dropping or keeping its batch references is irrelevant."""
        with self._lock:
            b = self._next
            self._next = (self._next + 1) % self.buffers
            pending, self._inflight[b] = self._inflight[b], []
            seq = self._inflight_seq[b]
        self._await_completion(pending, seq)
        return b

    # Ceiling on waiting for a consumer that donated staged arrays but
    # whose fence registration never arrives (mis-wired protocol, dead
    # consumer): proceed best-effort after this, counting the stall.
    DONATION_FENCE_TIMEOUT = 10.0

    def _await_completion(self, pending: List[jax.Array],
                          seq: int = 0) -> None:
        """Block until every array in ``pending`` is done with its staging
        buffer. An array a consumer jit *donated* (``make_step(donate=
        True)`` in :mod:`repro.fe.modelfeed`) is deleted and cannot be
        awaited; instead the gate waits for the :meth:`donation_fence` of
        the step that consumed the buffer's batch — batches are consumed
        in stage order, so that is the ``seq``-th registered fence — and
        awaits it. The fence is an output of the consuming step, and a
        step cannot execute before its inputs' transfers complete, so the
        fence's readiness implies the donated transfers finished. Deletion
        happens at consumer *dispatch*, i.e. possibly before that step's
        fence is registered; the sequence wait (not just "latest fence")
        closes that window."""
        tracer = get_tracer()
        w0 = tracer.now_ns() if tracer.enabled else 0
        donated = 0
        t0 = time.perf_counter()
        for dev in pending:
            if _deleted(dev):
                donated += 1
                continue
            try:
                dev.block_until_ready()
            except RuntimeError:
                # Deleted between the check and the await (the consumer
                # thread donates concurrently with ring reclaim).
                if not _deleted(dev):
                    raise
                donated += 1
        # Stats updates take _lock: this method runs on BOTH the feeder
        # thread (ring reclaim via _claim_buffer, which released the lock
        # before calling here) and the main thread (flush) — unsynchronized
        # `+=` on the shared FeedStats would lose increments (repro.check
        # rule LK402 regression).
        if donated:
            with self._lock:
                self.stats.donated += donated
            fence = self._await_donation_fence(seq)
            if fence is not None and not _deleted(fence):
                fence.block_until_ready()
        with self._lock:
            self.stats.stall_seconds += time.perf_counter() - t0
        if tracer.enabled:
            w1 = tracer.now_ns()
            if w1 - w0 > 100_000:  # record real waits only (>0.1 ms):
                # the ring slot could not be rewritten until its in-flight
                # transfers (or the donating consumer's fence) completed
                tracer.complete("h2d.reclaim_stall", w0, w1,
                                pending=len(pending), donated=donated)

    def _await_donation_fence(self, seq: int) -> Optional[jax.Array]:
        """Wait until the consumer of the ``seq``-th staged batch has
        registered its fence; returns the fence to await (None when no
        consumer ever joined the fence protocol — then donation safety
        rests on deletion implying the consumer dispatched, which orders
        after the transfers were enqueued)."""
        with self._fence_cond:
            if self._consumed_seq == 0 and self._fence is None:
                return None
            deadline = time.monotonic() + self.DONATION_FENCE_TIMEOUT
            while self._consumed_seq < seq:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break  # mis-wired/dead consumer: best effort
                self._fence_cond.wait(remaining)
            return self._fence

    def donation_fence(self, fence: Optional[jax.Array]) -> None:
        """Consumer handshake for donated staged batches.

        A train step that takes ownership of the staged batch (buffer
        donation) deletes the staged arrays, breaking the ring's
        await-the-array completion gate. The driver registers one of the
        step's *outputs* here after **every** step call, in consumption
        order; the gate awaits the fence of the step that consumed a
        donated buffer in place of its deleted arrays (see
        :meth:`_await_completion`).
        """
        with self._fence_cond:
            self._fence = fence
            self._consumed_seq += 1
            self._fence_cond.notify_all()
        get_tracer().instant("h2d.donation_fence", seq=self._consumed_seq)

    # --------------------------------------------------------------- staging
    def _rows(self, env: Mapping[str, Any]) -> int:
        name = self.layout.slots[0].name
        try:
            return int(np.asarray(env[name]).shape[0])
        except KeyError:
            raise FeedError(
                f"batch is missing staged slot {name!r} "
                f"(layout slots: {self.layout.slot_names})") from None

    @staticmethod
    def _slot_host(env: Mapping[str, Any], spec: SlotSpec) -> np.ndarray:
        """Fetch a slot's host array, deriving per-field ``batch_field_NN``
        columns from a packed ``batch_sparse`` when the env carries the
        packed form (split layouts work with unmodified FE output)."""
        if spec.name in env:
            return np.ascontiguousarray(np.asarray(env[spec.name]))
        if spec.name.startswith("batch_field_") and "batch_sparse" in env:
            idx = int(spec.name[len("batch_field_"):])
            sparse = np.asarray(env["batch_sparse"])
            if idx < sparse.shape[1]:
                return np.ascontiguousarray(sparse[:, idx])
        raise FeedError(
            f"batch is missing staged slot {spec.name!r} "
            f"(batch slots: {sorted(k for k in env if k.startswith('batch_'))})")

    def _put(self, view: np.ndarray) -> jax.Array:
        """Transfer one staged slot, guaranteeing the device array never
        aliases the arena. The first transfer probes the backend: if
        ``device_put`` copies (discrete-device H2D), arena views transfer
        directly and :meth:`_claim_buffer`'s readiness gate covers the
        async read; if it zero-copies, the source becomes a private copy
        of the staged bytes, owned by the device array, so the arena is
        free the moment ``stage`` returns. Host buffer bases are forced to
        the layout alignment, so one probe decides for every buffer."""
        if self._zero_copy_put is None:
            dev = jax.device_put(view, self.device)
            self._zero_copy_put = _aliases_host(dev, view)
            if not self._zero_copy_put:
                return dev
        if self._zero_copy_put:
            return jax.device_put(view.copy(), self.device)
        return jax.device_put(view, self.device)

    def claim_views(self, rows: int) -> ArenaClaim:
        """Claim the next ring slot and return typed views of its arena.

        This is the zero-copy feed's producer contract: Alg. 1 runs here
        (O(1) rewind + one block allocation), and the returned
        :class:`ArenaClaim` holds one aligned typed view per layout slot.
        The producer writes each batch output straight into its view —
        never building a fresh array — then hands the claim to
        :meth:`stage`, which skips the env->arena memcpy for every slot
        that is already arena-resident.

        Claiming blocks until every transfer previously issued from the
        slot's buffer has completed (the use-completion gate), exactly as
        the copying path does.
        """
        rows = int(rows)
        if rows < 0:
            raise FeedError(f"rows must be >= 0, got {rows}")
        self._ensure_capacity(rows)
        assert self.pool is not None
        b = self._claim_buffer()
        # Alg. 1 per meta-batch: O(1) rewind, then one block allocation.
        self.pool.reset()
        get_tracer().instant("arena.rewind", buffer=b)
        allocs = self.pool.alloc_block(self.layout.sizes(rows))
        self.last_allocs = allocs
        buf = self._host[b]
        views: Dict[str, np.ndarray] = {}
        for spec, alloc in zip(self.layout.slots, allocs):
            shape = (rows,) if spec.rank1 else (rows, spec.width)
            views[spec.name] = (buf[alloc.offset:alloc.offset + spec.nbytes(rows)]
                                .view(spec.dtype).reshape(shape))
        self.stats.rewinds = self._rewinds_prior + self.pool.n_resets
        with self._lock:
            epoch = self._epoch
        return ArenaClaim(buffer_index=b, rows=rows, views=views,
                          allocs=allocs, epoch=epoch)

    def stage(self, env: Mapping[str, Any], *,
              claim: Optional[ArenaClaim] = None) -> Dict[str, Any]:
        """Stage one batch: plan -> (copy into arena) -> async H2D of the views.

        Three entry forms, one transfer tail:

        * plain ``stage(env)`` — the fallback copy path: every layout slot
          is validated, memcpy'd into a freshly claimed arena buffer, and
          transferred;
        * ``stage(env, claim=...)`` — the producer already wrote some/all
          slots into ``claim``'s views (:meth:`claim_views`); arena-resident
          slots skip the memcpy (``FeedStats.copies_elided``);
        * with a ``binding`` attached and a pre-assembly batch — the
          binding assembles the ``batch_*`` outputs directly into claimed
          views (zero-copy feed), then everything transfers.

        Returns the environment with the layout's slots replaced by device
        arrays (bitwise-equal values); all other slots pass through.
        """
        with get_tracer().span("h2d.stage", batch=self.stats.batches):
            return self._stage(env, claim)

    def _stage(self, env: Mapping[str, Any],
               claim: Optional[ArenaClaim]) -> Dict[str, Any]:
        if claim is None and self.binding is not None \
                and self.binding.ready(env):
            return self._stage_direct(env)
        rows = claim.rows if claim is not None else self._rows(env)
        # Validate the whole batch against the layout BEFORE claiming a
        # buffer or issuing any transfer: a FeedError mid-batch must not
        # leave half-issued transfers outside the reuse/flush gates.
        arrs: List[Optional[np.ndarray]] = []
        for spec in self.layout.slots:
            if claim is not None:
                view = claim.views[spec.name]
                got = env.get(spec.name)
                if got is not None and isinstance(got, np.ndarray) \
                        and np.shares_memory(got, view):
                    arrs.append(None)  # already arena-resident: no memcpy
                    continue
            arr = self._slot_host(env, spec)
            if arr.dtype != np.dtype(spec.dtype):
                raise FeedError(
                    f"slot {spec.name!r}: dtype {arr.dtype} != layout "
                    f"{spec.dtype} (pass a custom FeedLayout)")
            want = (rows,) if spec.rank1 else (rows, spec.width)
            if arr.shape != want:
                raise FeedError(
                    f"slot {spec.name!r}: shape {arr.shape} != layout {want}")
            arrs.append(arr)
        if claim is None:
            claim = self.claim_views(rows)
        t0 = time.perf_counter()
        for spec, arr in zip(self.layout.slots, arrs):
            if arr is None:
                self.stats.copies_elided += 1
            else:
                np.copyto(claim.views[spec.name], arr, casting="no")
        return self._transfer(env, claim, t0)

    def _stage_direct(self, env: Mapping[str, Any]) -> Dict[str, Any]:
        """Zero-copy feed: assemble ``batch_*`` outputs straight into the
        arena via the attached binding — the env->arena memcpy (and the
        fresh output arrays the copy path reads from) never exist."""
        claim = self.claim_views(self.binding.rows_of(env))
        t0 = time.perf_counter()
        self.binding.write(env, claim.views)
        self.stats.copies_elided += len(self.layout.slots)
        return self._transfer(env, claim, t0)

    def _transfer(self, env: Mapping[str, Any], claim: ArenaClaim,
                  t0: float) -> Dict[str, Any]:
        """Issue the async H2D transfers for a claimed, filled arena slot."""
        payload = 0
        devs: List[jax.Array] = []
        try:
            for spec in self.layout.slots:
                devs.append(self._put(claim.views[spec.name]))
                payload += spec.nbytes(claim.rows)
        finally:
            # Whatever was issued stays tracked, even if a transfer raised.
            # Transfers from a pre-regrow claim can't be filed under the
            # fresh ring (indices refer to new buffers): they join the
            # orphans flush() awaits.
            with self._lock:
                self._seq += 1
                if claim.epoch == self._epoch:
                    self._inflight[claim.buffer_index] = devs
                    self._inflight_seq[claim.buffer_index] = self._seq
                else:
                    self._orphans.extend(devs)

        out = dict(env)
        out.update({spec.name: dev
                    for spec, dev in zip(self.layout.slots, devs)})
        self.stats.h2d_seconds += time.perf_counter() - t0
        self.stats.batches += 1
        self.stats.bytes_staged += payload
        return out

    def flush(self) -> None:
        """Block until every staged transfer has completed.

        The ring holds strong refs until claim/flush, so no transfer can
        escape the wait — including ones whose consumer references already
        died and ones orphaned by an arena regrow.
        """
        with self._lock:
            groups = [(devs, seq) for devs, seq
                      in zip(self._inflight, self._inflight_seq) if devs]
            # Orphans predate the current ring (regrow): no per-buffer seq;
            # awaited with the no-wait fallback (seq 0 is always covered).
            orphans = self._orphans
            self._inflight = [[] for _ in range(self.buffers)]
            self._inflight_seq = [0] * self.buffers
            self._orphans = []
        for devs, seq in groups:
            self._await_completion(devs, seq)
        self._await_completion(orphans)


def _deleted(dev: jax.Array) -> bool:
    """True if ``dev`` was deleted (donated into a consumer computation)."""
    fn = getattr(dev, "is_deleted", None)
    if fn is None:
        return False
    try:
        return bool(fn())
    except Exception:
        return False


def _aliases_host(dev: jax.Array, view: np.ndarray) -> bool:
    """True unless ``dev`` provably does NOT share memory with ``view``.

    Unknown means True: a needless private copy is safe, a missed alias is
    silent batch corruption.
    """
    try:
        dev.block_until_ready()
        ptr = int(dev.unsafe_buffer_pointer())
    except Exception:
        return True
    base = view.__array_interface__["data"][0]
    return base <= ptr < base + max(view.nbytes, 1)
