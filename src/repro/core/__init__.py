"""FeatureBox core: operator DAG, layer-wise scheduling, meta-kernels,
memory pool, and the pipelined FE<->train executor (the paper's contribution).
"""

from repro.core.opgraph import Device, FuncDef, OpCost, Operator, OpGraph
from repro.core.scheduler import (
    Layer,
    PlacedOp,
    Schedule,
    SuperLayer,
    build_schedule,
    coalesce_layers,
    validate_schedule,
)
from repro.core.metakernel import (
    ExecutionStats,
    LayerExecutable,
    compile_layers,
    run_layers,
    run_unfused,
)
from repro.core.mempool import ALIGN, Allocation, ArenaPool, align_up, plan_offsets, required_capacity
from repro.core.devicefeed import (
    ArenaClaim,
    DeviceFeeder,
    FeedError,
    FeedLayout,
    FeedStats,
    SlotSpec,
)
from repro.core.pipeline import PipelinedRunner, PipelineStats, StagedRunner

__all__ = [
    "ALIGN",
    "Allocation",
    "ArenaClaim",
    "ArenaPool",
    "Device",
    "DeviceFeeder",
    "FeedError",
    "FeedLayout",
    "FeedStats",
    "SlotSpec",
    "ExecutionStats",
    "FuncDef",
    "Layer",
    "LayerExecutable",
    "OpCost",
    "OpGraph",
    "Operator",
    "PipelinedRunner",
    "PipelineStats",
    "PlacedOp",
    "Schedule",
    "StagedRunner",
    "SuperLayer",
    "align_up",
    "build_schedule",
    "coalesce_layers",
    "compile_layers",
    "plan_offsets",
    "required_capacity",
    "run_layers",
    "run_unfused",
    "validate_schedule",
]
