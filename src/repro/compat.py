"""Feature-detection shims for the installed JAX version.

The production code (``launch/mesh.py``) and the sharding tests construct
meshes with ``jax.make_mesh(..., axis_types=(jax.sharding.AxisType.Auto,))``.
``AxisType`` and the ``axis_types=`` kwarg only exist in newer JAX; on the
pinned older version the attribute is missing and ``make_mesh`` rejects the
kwarg. Rather than forking every call site, :func:`install` feature-detects
and backfills both:

* ``jax.sharding.AxisType`` — a stand-in enum with the same member names.
  ``Auto`` was the only pre-existing behaviour, so ignoring the value is
  semantically a no-op on old JAX.
* ``jax.make_mesh`` — wrapped to accept and drop ``axis_types`` when the
  underlying signature does not take it.
* ``jax.shard_map`` — aliased from ``jax.experimental.shard_map.shard_map``
  (mapping the renamed ``check_vma`` kwarg back to ``check_rep``) where the
  top-level name does not exist yet.

On a JAX that already provides all of these, :func:`install` does nothing.
"""

from __future__ import annotations

import enum
import functools
import inspect
import sys


def install(*, require_jax: bool = True) -> None:
    """Idempotently backfill newer JAX sharding APIs on older versions.

    With ``require_jax=False`` this is a no-op unless jax is already
    imported — the package ``__init__`` uses that so jax-free consumers
    (the numpy-only ingest tier) don't pay for a jax import; modules that
    actually use the patched APIs call ``install()`` unconditionally.
    """
    if not require_jax and "jax" not in sys.modules:
        return
    import jax

    _install_shard_map(jax)

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    orig = getattr(jax, "make_mesh", None)
    if orig is None or getattr(orig, "_repro_compat", False):
        return  # pre-make_mesh jax: nothing to wrap
    try:
        params = inspect.signature(orig).parameters
    except (TypeError, ValueError):  # pragma: no cover — exotic callables
        return
    if "axis_types" in params:
        return

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        del axis_types  # old JAX: Auto is the only (implicit) behaviour
        return orig(axis_shapes, axis_names, **kwargs)

    make_mesh._repro_compat = True
    jax.make_mesh = make_mesh


def _install_shard_map(jax) -> None:
    try:
        if jax.shard_map is not None:  # newer JAX: nothing to do
            return
    except AttributeError:
        pass
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    @functools.wraps(_exp_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
        if check_vma is not None:  # renamed from check_rep
            kwargs.setdefault("check_rep", check_vma)
        return _exp_shard_map(f, mesh, in_specs, out_specs, **kwargs)

    shard_map._repro_compat = True
    jax.shard_map = shard_map
