"""Jitted public wrapper for the dot-interaction kernel."""

from __future__ import annotations

import jax

from repro.kernels.interaction_dot.kernel import dot_interaction
from repro.kernels.interaction_dot.ref import dot_interaction_ref


def pairwise_dots(x: jax.Array, *, use_kernel: bool = True) -> jax.Array:
    """DLRM feature interaction: all <x_i, x_j>, i<j, per batch row."""
    if x.ndim != 3:
        raise ValueError(f"expected (B, F, D), got {x.shape}")
    if x.shape[1] < 2:
        raise ValueError("need at least 2 fields to interact")
    if not use_kernel:
        return dot_interaction_ref(x)
    interpret = jax.default_backend() != "tpu"
    return dot_interaction(x, interpret=interpret)
