"""interaction_dot kernel package."""
from repro.kernels.interaction_dot.ops import *  # noqa: F401,F403
