"""Pallas TPU kernel: DLRM pairwise-dot feature interaction.

The dot-interaction op (DLRM [arXiv:1906.00091]) takes the stacked field
embeddings ``x: (B, F, D)`` (bottom-MLP output + one embedding per sparse
field) and emits all distinct pairwise dots ``<x_i, x_j>, i<j`` — the
feature-combination hot spot of the CTR models FeatureBox trains.

Kernel layout: grid over batch tiles; per tile the (F, D) block computes
``x @ x^T`` on the MXU, and the strictly-lower-triangular entries are
compacted with a static gather (indices fixed at trace time). F is padded to
the sublane multiple; D is expected 128-aligned (embed_dim in these archs is
16..128 — ops.py pads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BATCH_TILE = 128


def _tril_indices(f: int) -> np.ndarray:
    rows, cols = np.tril_indices(f, k=-1)
    return (rows * f + cols).astype(np.int32)


def _interaction_kernel(idx_ref, x_ref, out_ref, *, f: int):
    x = x_ref[...]                                    # (Bt, F, D)
    bt = x.shape[0]
    scores = jnp.einsum(
        "bfd,bgd->bfg", x, x, preferred_element_type=jnp.float32
    )                                                 # MXU batched matmul
    flat = scores.reshape(bt, f * f)
    out_ref[...] = jnp.take(flat, idx_ref[...], axis=1)  # triangle compaction


@functools.partial(jax.jit, static_argnames=("interpret",))
def dot_interaction(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """All pairwise dots of field embeddings.

    Args:
      x: f32[B, F, D] stacked per-field embeddings.
    Returns:
      f32[B, F*(F-1)/2] strictly-lower-triangle of x @ x^T per row.
    """
    b, f, d = x.shape
    n_pairs = f * (f - 1) // 2
    b_pad = (b + BATCH_TILE - 1) // BATCH_TILE * BATCH_TILE
    if b_pad != b:
        x = jnp.pad(x, ((0, b_pad - b), (0, 0), (0, 0)))
    flat_idx = jnp.asarray(_tril_indices(f))
    grid = (b_pad // BATCH_TILE,)
    out = pl.pallas_call(
        functools.partial(_interaction_kernel, f=f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pairs,), lambda i: (0,)),
            pl.BlockSpec((BATCH_TILE, f, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, n_pairs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, n_pairs), jnp.float32),
        interpret=interpret,
    )(flat_idx, x.astype(jnp.float32))
    return out[:b]
