"""Pure-jnp oracle for the DLRM dot-interaction kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dot_interaction_ref(x: jax.Array) -> jax.Array:
    """Strictly-lower-triangle pairwise dots: f32[B, F*(F-1)/2]."""
    b, f, d = x.shape
    scores = jnp.einsum("bfd,bgd->bfg", x.astype(jnp.float32), x.astype(jnp.float32))
    rows, cols = np.tril_indices(f, k=-1)
    return scores[:, rows, cols]
