"""Jitted public wrapper for the EmbeddingBag kernel.

The kernel path expects a *working set* table (post-dedup); callers with a
full sharded table go through ``repro.embedding.table`` which performs dedup
+ device gather first, then calls this on the dense slice.
"""

from __future__ import annotations

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def bag_lookup(ids: jax.Array, weights: jax.Array, table: jax.Array,
               *, use_kernel: bool = True) -> jax.Array:
    """Weighted EmbeddingBag over a working-set table."""
    if ids.ndim != 2 or weights.shape != ids.shape or table.ndim != 2:
        raise ValueError(f"bad shapes ids={ids.shape} w={weights.shape} table={table.shape}")
    if not use_kernel:
        return embedding_bag_ref(ids, weights, table)
    interpret = jax.default_backend() != "tpu"
    return embedding_bag(ids, weights, table, interpret=interpret)
