"""Pure-jnp oracle for the EmbeddingBag kernel: take + weighted sum."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(ids: jax.Array, weights: jax.Array, table: jax.Array) -> jax.Array:
    """out[b] = sum_l weights[b,l] * table[ids[b,l]] via gather."""
    rows = jnp.take(table, ids, axis=0)                  # (B, L, D)
    return (rows * weights[..., None].astype(table.dtype)).sum(axis=1)


def embedding_bag_segment_ref(flat_ids: jax.Array, segment_ids: jax.Array,
                              table: jax.Array, n_segments: int) -> jax.Array:
    """Ragged-form oracle (flat ids + segment ids), unweighted sum."""
    rows = jnp.take(table, flat_ids, axis=0)
    return jax.ops.segment_sum(rows, segment_ids, num_segments=n_segments)
