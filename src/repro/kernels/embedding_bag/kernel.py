"""Pallas TPU kernel: fused EmbeddingBag (gather + weighted segment reduce).

JAX has no native ``EmbeddingBag``; the substrate builds it from ``jnp.take``
+ ``segment_sum`` (see ``ref.py`` and ``repro.embedding.table``). This kernel
is the TPU-native hot path for the *working set* lookup of the hierarchical
parameter server: after per-batch dedup (FeatureBox/[37]: "the number of
referenced parameters in a mini-batch fits the GPU memory"), the deduped
table slice ``table[U, D]`` lives in fast memory and every bag id is already
remapped to ``[0, U)``.

TPU adaptation (DESIGN.md §2): instead of a row-gather (poor fit for the MXU
and for VMEM DMA granularity) the lookup is computed as a **blocked one-hot
matmul**: for each vocab block ``V_b`` the kernel forms the one-hot matrix of
the bag ids that fall inside the block and contracts it with the block's rows
on the MXU, accumulating into the output:

    out[b, :] += sum_l  w[b,l] * onehot(ids[b,l] - v0, V_b) @ table[v0:v0+V_b]

Grid = (batch tiles, vocab blocks); vocab is the minor (fastest) axis so each
output tile stays resident in VMEM while table blocks stream through.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_TILE = 256   # bags per grid step
VOCAB_BLOCK = 512  # table rows per grid step (MXU-aligned multiple of 128)


def _bag_kernel(ids_ref, w_ref, table_ref, out_ref, *, vocab_block: int):
    vstep = pl.program_id(1)

    @pl.when(vstep == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]            # (Bt, L) int32, already working-set-local
    w = w_ref[...]                # (Bt, L) f32 weights (0 for padding)
    table = table_ref[...]        # (Vb, D) f32

    v0 = vstep * vocab_block
    local = ids - v0              # position within this vocab block
    in_block = (local >= 0) & (local < vocab_block)
    # one-hot over the block, masked by weight and membership -> (Bt*L, Vb)
    bt, l = ids.shape
    onehot = (
        local.reshape(bt * l, 1)
        == jax.lax.broadcasted_iota(jnp.int32, (bt * l, vocab_block), 1)
    )
    wflat = (w * in_block.astype(w.dtype)).reshape(bt * l, 1)
    contrib = (onehot.astype(table.dtype) * wflat) @ table      # MXU matmul
    out_ref[...] += contrib.reshape(bt, l, -1).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(ids: jax.Array, weights: jax.Array, table: jax.Array,
                  *, interpret: bool = True) -> jax.Array:
    """Weighted-sum EmbeddingBag: out[b] = sum_l weights[b,l] * table[ids[b,l]].

    Args:
      ids:     int32[B, L] working-set-local ids (0 <= id < U).
      weights: f32[B, L] per-slot weights (0 disables a slot — padding).
      table:   f32[U, D] working-set embedding rows.
    Returns:
      f32[B, D].
    """
    b, l = ids.shape
    u, d = table.shape
    b_pad = (b + BATCH_TILE - 1) // BATCH_TILE * BATCH_TILE
    u_pad = (u + VOCAB_BLOCK - 1) // VOCAB_BLOCK * VOCAB_BLOCK
    if b_pad != b:
        ids = jnp.pad(ids, ((0, b_pad - b), (0, 0)))
        weights = jnp.pad(weights, ((0, b_pad - b), (0, 0)))
    if u_pad != u:
        table = jnp.pad(table, ((0, u_pad - u), (0, 0)))
    grid = (b_pad // BATCH_TILE, u_pad // VOCAB_BLOCK)
    out = pl.pallas_call(
        functools.partial(_bag_kernel, vocab_block=VOCAB_BLOCK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BATCH_TILE, l), lambda i, j: (i, 0)),
            pl.BlockSpec((BATCH_TILE, l), lambda i, j: (i, 0)),
            pl.BlockSpec((VOCAB_BLOCK, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BATCH_TILE, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), weights.astype(table.dtype), table)
    return out[:b]
