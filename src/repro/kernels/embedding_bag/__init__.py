"""embedding_bag kernel package."""
from repro.kernels.embedding_bag.ops import *  # noqa: F401,F403
