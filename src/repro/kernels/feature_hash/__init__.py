"""feature_hash kernel package."""
from repro.kernels.feature_hash.ops import *  # noqa: F401,F403
