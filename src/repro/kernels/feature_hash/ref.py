"""Pure-jnp oracle for the feature-hash meta-kernel (shares repro.fe.ops)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.fe.ops import fmix32, hash_combine


def hash_layer_ref(cols: jax.Array, *, program) -> jax.Array:
    outs = []
    for kind, a_idx, b_idx, field_size in program:
        a = cols[a_idx]
        if kind == "cross":
            h = hash_combine(a, cols[b_idx])
        elif kind == "hash":
            h = fmix32(a.astype(jnp.uint32))
        elif kind == "mod":
            h = a.astype(jnp.uint32)
        else:
            raise ValueError(kind)
        outs.append((h % np.uint32(field_size)).astype(jnp.int32))
    return jnp.stack(outs, axis=0)
