"""Pallas TPU meta-kernel for a layer of hash/cross feature-extraction ops.

This is the paper's §IV meta-kernel made concrete: the scheduler fixes the
set of same-layer FE operators ahead of training; here each hash/cross op of
the layer becomes a *device function* (a traced Python function), and ONE
``pallas_call`` executes all of them over a shared VMEM tile of the input
columns — one launch per layer instead of one per operator (Table I).

The op program is a static tuple of ``(kind, a_col, b_col, field_size)``:

* ``("cross", a, b, m)``  -> fmix32(a*GOLDEN + fmix32(b)) % m   (feature cross)
* ``("hash", a, _, m)``   -> fmix32(a) % m                      (single-column hash)
* ``("mod",  a, _, m)``   -> a % m                              (id passthrough)

All arithmetic is uint32 (TPU-native), matching ``repro.fe.ops`` bit-for-bit.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)

ROW_TILE = 1024

OpProgram = Tuple[Tuple[str, int, int, int], ...]


def _fmix32(x):
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


def _hash_layer_kernel(cols_ref, out_ref, *, program: OpProgram):
    cols = cols_ref[...].astype(jnp.uint32)  # (K, T)
    outs = []
    # The schedule is fixed ahead of time, so the program unrolls at trace
    # time — the XLA analogue of the paper's runtime-compiled meta-kernel.
    for kind, a_idx, b_idx, field_size in program:
        a = cols[a_idx]
        if kind == "cross":
            h = _fmix32(a * _GOLDEN + _fmix32(cols[b_idx]))
        elif kind == "hash":
            h = _fmix32(a)
        elif kind == "mod":
            h = a
        else:  # pragma: no cover - validated in ops.py
            raise ValueError(f"unknown op kind {kind!r}")
        outs.append((h % np.uint32(field_size)).astype(jnp.int32))
    out_ref[...] = jnp.stack(outs, axis=0)  # (n_ops, T)


@functools.partial(jax.jit, static_argnames=("program", "interpret"))
def hash_layer(cols: jax.Array, *, program: OpProgram, interpret: bool = True) -> jax.Array:
    """Execute a layer of hash/cross ops in one kernel.

    Args:
      cols: int32[K, N] stacked input id columns.
      program: static op tuple (see module docstring).
    Returns:
      int32[n_ops, N] — one output column per op.
    """
    k, n = cols.shape
    n_ops = len(program)
    n_pad = (n + ROW_TILE - 1) // ROW_TILE * ROW_TILE
    if n_pad != n:
        cols = jnp.pad(cols, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // ROW_TILE,)
    out = pl.pallas_call(
        functools.partial(_hash_layer_kernel, program=program),
        grid=grid,
        in_specs=[pl.BlockSpec((k, ROW_TILE), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n_ops, ROW_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_ops, n_pad), jnp.int32),
        interpret=interpret,
    )(cols.astype(jnp.int32))
    return out[:, :n]
