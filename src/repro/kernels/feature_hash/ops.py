"""Jitted public wrapper for the feature-hash meta-kernel."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax

from repro.kernels.feature_hash.kernel import OpProgram, hash_layer
from repro.kernels.feature_hash.ref import hash_layer_ref

_KINDS = ("cross", "hash", "mod")


def validate_program(program: Sequence[Tuple[str, int, int, int]], n_cols: int) -> OpProgram:
    prog = tuple(tuple(op) for op in program)
    for kind, a, b, m in prog:
        if kind not in _KINDS:
            raise ValueError(f"unknown op kind {kind!r}")
        if not (0 <= a < n_cols) or (kind == "cross" and not (0 <= b < n_cols)):
            raise ValueError(f"column index out of range in {(kind, a, b, m)}")
        if m <= 0:
            raise ValueError(f"field_size must be positive in {(kind, a, b, m)}")
    return prog  # type: ignore[return-value]


def run_hash_layer(cols: jax.Array, program: Sequence[Tuple[str, int, int, int]],
                   *, use_kernel: bool = True) -> jax.Array:
    """Run a fixed layer of hash/cross FE ops over stacked id columns."""
    prog = validate_program(program, cols.shape[0])
    if not use_kernel:
        return hash_layer_ref(cols, program=prog)
    interpret = jax.default_backend() != "tpu"
    return hash_layer(cols, program=prog, interpret=interpret)
