"""mempool_alloc kernel package."""
from repro.kernels.mempool_alloc.ops import *  # noqa: F401,F403
