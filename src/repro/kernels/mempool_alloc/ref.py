"""Pure-jnp oracle for the Alg. 1 allocator kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mempool import ALIGN


def alloc_offsets_ref(sizes: jax.Array, *, align: int = ALIGN):
    """Reference allocator: exclusive scan of lane-aligned sizes.

    Must agree elementwise with the Pallas kernel AND with the host
    ``ArenaPool.alloc_block`` offsets (for a fresh pool).
    """
    sizes = sizes.astype(jnp.int32)
    aligned = (sizes + (align - 1)) // align * align
    inclusive = jnp.cumsum(aligned)
    offsets = inclusive - aligned
    head = inclusive[-1:] if sizes.shape[0] else jnp.zeros((1,), jnp.int32)
    return offsets, head
