"""Jitted public wrapper for the mempool allocator kernel.

Selects the Pallas kernel on TPU (compiled) and interpret mode elsewhere;
falls back to the jnp reference for shapes the kernel doesn't support.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mempool import ALIGN, align_up
from repro.kernels.mempool_alloc.kernel import alloc_offsets
from repro.kernels.mempool_alloc.ref import alloc_offsets_ref

_INT32_MAX = np.iinfo(np.int32).max


def plan_allocation(sizes: jax.Array, *, align: int = ALIGN, use_kernel: bool = True):
    """Plan arena offsets for a block of allocation requests.

    Returns (offsets int32[N], head int32[1]). ``head`` is the post-bump
    ``idle_memory_head``; callers compare it against pool capacity before
    launching the consuming meta-kernel.
    """
    if sizes.ndim != 1:
        raise ValueError(f"sizes must be rank-1, got {sizes.shape}")
    if sizes.shape[0] == 0 or not use_kernel:
        return alloc_offsets_ref(sizes, align=align)
    interpret = jax.default_backend() != "tpu"
    return alloc_offsets(sizes, align=align, interpret=interpret)


def plan_block(sizes: Sequence[int], *, align: int = ALIGN,
               use_kernel: bool = True) -> Tuple[np.ndarray, int]:
    """Host-side sizing entry: plan a block of requests from plain ints.

    The bridge the device-feed tier uses to plan static arena placement at
    compile time: takes ordinary Python sizes, runs the allocator kernel
    (or its reference), and returns ``(offsets int64[N], total)`` ready for
    host bookkeeping. Oracle-equivalent to
    :meth:`repro.core.mempool.ArenaPool.alloc_block` — including on inputs
    the kernel's int32 offsets cannot represent: the pool raises there
    (ValueError on negative sizes, int64 capacity check), so this path
    raises too instead of silently wrapping at 2 GiB.
    """
    reqs = np.asarray(list(sizes), dtype=np.int64)
    if reqs.ndim != 1:
        raise ValueError(f"sizes must be rank-1, got {reqs.shape}")
    if (reqs < 0).any():
        raise ValueError("negative allocation size")
    head_bound = sum(int(align_up(s, align)) for s in reqs)
    if head_bound > _INT32_MAX:
        raise OverflowError(
            f"allocation block needs {head_bound} aligned bytes, which "
            f"overflows the kernel's int32 offsets (max {_INT32_MAX}); "
            f"split the block or plan with ArenaPool.alloc_block (int64)")
    arr = jnp.asarray(reqs, jnp.int32)
    offsets, head = plan_allocation(arr, align=align, use_kernel=use_kernel)
    total = int(np.asarray(head).reshape(-1)[0]) if arr.shape[0] else 0
    return np.asarray(offsets, dtype=np.int64), total
