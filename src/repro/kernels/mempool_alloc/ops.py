"""Jitted public wrapper for the mempool allocator kernel.

Selects the Pallas kernel on TPU (compiled) and interpret mode elsewhere;
falls back to the jnp reference for shapes the kernel doesn't support.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mempool import ALIGN
from repro.kernels.mempool_alloc.kernel import alloc_offsets
from repro.kernels.mempool_alloc.ref import alloc_offsets_ref


def plan_allocation(sizes: jax.Array, *, align: int = ALIGN, use_kernel: bool = True):
    """Plan arena offsets for a block of allocation requests.

    Returns (offsets int32[N], head int32[1]). ``head`` is the post-bump
    ``idle_memory_head``; callers compare it against pool capacity before
    launching the consuming meta-kernel.
    """
    if sizes.ndim != 1:
        raise ValueError(f"sizes must be rank-1, got {sizes.shape}")
    if sizes.shape[0] == 0 or not use_kernel:
        return alloc_offsets_ref(sizes, align=align)
    interpret = jax.default_backend() != "tpu"
    return alloc_offsets(sizes, align=align, interpret=interpret)
