"""Pallas TPU kernel for Alg. 1 (in-kernel dynamic memory allocation).

Paper semantics (per block of N threads):
  1. ``prefix = parallel_prefix_sum(sizes)``             (in-block scan)
  2. ``address = atomic_add(idle_memory_head, prefix_N)`` (one bump per block)
  3. ``offsets_i = address + prefix_i - prefix_1``         (per-thread offsets)

TPU adaptation: a Pallas grid step plays the role of a CUDA block. TPU grid
steps execute **sequentially** on a core, so the global bump pointer is a
scalar carried in SMEM scratch across steps — the deterministic equivalent of
the atomic add (DESIGN.md §2). The in-block scan is a ``jnp.cumsum`` on the
VPU over the whole tile. Sizes are aligned up to the 128-element lane width
(the paper's 128-byte cache alignment, in TPU units).

Out-of-range tail lanes (N not a multiple of the tile) are masked to size 0,
so they consume no arena space and their offsets are harmless.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.mempool import ALIGN

# One grid step scans this many allocation requests (a "block" in the paper).
BLOCK = 1024


def _alloc_kernel(sizes_ref, offsets_ref, head_ref, carry_ref, *, n: int, align: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        carry_ref[0] = 0

    base = carry_ref[0]  # "idle_memory_head" before this block's bump

    sizes = sizes_ref[...].astype(jnp.int32)
    # mask tail lanes beyond n
    lane = jax.lax.broadcasted_iota(jnp.int32, sizes.shape, 0)
    valid = (step * BLOCK + lane) < n
    sizes = jnp.where(valid, sizes, 0)

    aligned = (sizes + (align - 1)) // align * align
    inclusive = jnp.cumsum(aligned)
    exclusive = inclusive - aligned          # prefix_i - prefix_1
    offsets_ref[...] = base + exclusive      # address + (prefix_i - prefix_1)

    total = inclusive[-1]                    # prefix_N
    carry_ref[0] = base + total              # atomic_add(head, prefix_N)
    head_ref[0] = base + total               # exposed head after this block


@functools.partial(jax.jit, static_argnames=("align", "interpret"))
def alloc_offsets(sizes: jax.Array, *, align: int = ALIGN, interpret: bool = True):
    """Run Alg. 1 over ``sizes`` (int32[N]); returns (offsets int32[N], head int32[1]).

    ``head[0]`` is the final ``idle_memory_head`` — total arena elements
    consumed. Resetting the pool (paper §V) is the caller dropping this value.
    """
    n = sizes.shape[0]
    n_pad = (n + BLOCK - 1) // BLOCK * BLOCK
    if n_pad != n:
        sizes = jnp.pad(sizes, (0, n_pad - n))
    grid = (n_pad // BLOCK,)
    offsets, head = pl.pallas_call(
        functools.partial(_alloc_kernel, n=n, align=align),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(sizes.astype(jnp.int32))
    return offsets[:n], head
