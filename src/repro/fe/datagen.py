"""Synthetic raw ads-log generator (stand-in for the paper's 15–25 TB logs).

Generates the three view sources of a typical ads pipeline plus the
materialized *basic features* table, with realistic messiness: null
sentinels, JSON context payloads, ragged interest lists, free-text titles.
Scaled down (10^4–10^6 instances) but structurally identical, so every
pipeline stage (read -> clean -> join -> extract -> merge) is exercised.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.fe.colstore import ColumnStore, Columns, RaggedColumn
from repro.fe.schema import ColType, Column, ViewSchema

_NULL_INT = np.iinfo(np.int64).min
_NULL_FLOAT = np.nan

WORDS = (
    "cheap flights hotel deals shoes running phone case laptop gaming credit "
    "card insurance auto home loan pizza delivery coffee near me best price"
).split()


IMPRESSIONS = ViewSchema(
    name="impressions",
    key="instance_id",
    columns=(
        Column("instance_id", ColType.INT, nullable=False),
        Column("user_id", ColType.INT, nullable=False),
        Column("ad_id", ColType.INT, nullable=False),
        Column("label", ColType.INT, nullable=False),
        Column("hour", ColType.INT),
        Column("dwell_time", ColType.FLOAT),
        Column("context_json", ColType.STRING),
    ),
)

USER_PROFILE = ViewSchema(
    name="user_profile",
    key="user_id",
    columns=(
        Column("user_id", ColType.INT, nullable=False),
        Column("age_bucket", ColType.INT),
        Column("gender", ColType.INT),
        Column("interests", ColType.INT_LIST),
        Column("query_text", ColType.STRING),
    ),
)

AD_INVENTORY = ViewSchema(
    name="ad_inventory",
    key="ad_id",
    columns=(
        Column("ad_id", ColType.INT, nullable=False),
        Column("advertiser_id", ColType.INT),
        Column("campaign_id", ColType.INT),
        Column("bid_price", ColType.FLOAT),
        Column("title_text", ColType.STRING),
    ),
)

BASIC_FEATURES = ViewSchema(
    name="basic_features",
    key="instance_id",
    columns=(
        Column("instance_id", ColType.INT, nullable=False),
        Column("ctr_7d", ColType.FLOAT),
        Column("user_click_cnt", ColType.FLOAT),
        Column("ad_show_cnt", ColType.FLOAT),
    ),
)


def _text(rng: np.random.Generator, n_words: int) -> str:
    return " ".join(rng.choice(WORDS, size=n_words))


def gen_views(
    n_instances: int,
    *,
    n_users: Optional[int] = None,
    n_ads: Optional[int] = None,
    null_rate: float = 0.05,
    seed: int = 0,
) -> Dict[str, Columns]:
    """Generate the raw views + basic features for ``n_instances`` logs."""
    rng = np.random.default_rng(seed)
    n_users = n_users or max(4, n_instances // 4)
    n_ads = n_ads or max(4, n_instances // 8)

    def nullify_int(col):
        mask = rng.random(col.shape) < null_rate
        return np.where(mask, _NULL_INT, col)

    def nullify_float(col):
        mask = rng.random(col.shape) < null_rate
        return np.where(mask, _NULL_FLOAT, col).astype(np.float32)

    user_ids = rng.integers(0, n_users, n_instances)
    ad_ids = rng.integers(0, n_ads, n_instances)
    ctx = np.array(
        [
            json.dumps({"slot": int(rng.integers(0, 16)),
                        "device": int(rng.integers(0, 4)),
                        "geo": int(rng.integers(0, 512))})
            if rng.random() > null_rate else ""
            for _ in range(n_instances)
        ],
        dtype=object,
    )
    impressions: Columns = {
        "instance_id": np.arange(n_instances, dtype=np.int64),
        "user_id": user_ids.astype(np.int64),
        "ad_id": ad_ids.astype(np.int64),
        "label": (rng.random(n_instances) < 0.05).astype(np.int64),
        "hour": nullify_int(rng.integers(0, 24, n_instances).astype(np.int64)),
        "dwell_time": nullify_float(rng.exponential(3.0, n_instances)),
        "context_json": ctx,
    }

    lengths = rng.integers(0, 8, n_users).astype(np.int32)
    interests = RaggedColumn(
        values=rng.integers(0, 10_000, int(lengths.sum())).astype(np.int64),
        lengths=lengths,
    )
    user_profile: Columns = {
        "user_id": np.arange(n_users, dtype=np.int64),
        "age_bucket": nullify_int(rng.integers(0, 10, n_users).astype(np.int64)),
        "gender": nullify_int(rng.integers(0, 3, n_users).astype(np.int64)),
        "interests": interests,
        "query_text": np.array([_text(rng, int(rng.integers(1, 6))) for _ in range(n_users)],
                               dtype=object),
    }

    ad_inventory: Columns = {
        "ad_id": np.arange(n_ads, dtype=np.int64),
        "advertiser_id": rng.integers(0, max(2, n_ads // 4), n_ads).astype(np.int64),
        "campaign_id": nullify_int(rng.integers(0, max(2, n_ads // 2), n_ads).astype(np.int64)),
        "bid_price": nullify_float(rng.gamma(2.0, 0.5, n_ads)),
        "title_text": np.array([_text(rng, int(rng.integers(2, 8))) for _ in range(n_ads)],
                               dtype=object),
    }

    basic: Columns = {
        "instance_id": np.arange(n_instances, dtype=np.int64),
        "ctr_7d": rng.beta(1, 20, n_instances).astype(np.float32),
        "user_click_cnt": rng.poisson(5, n_instances).astype(np.float32),
        "ad_show_cnt": rng.poisson(50, n_instances).astype(np.float32),
    }
    return {
        "impressions": impressions,
        "user_profile": user_profile,
        "ad_inventory": ad_inventory,
        "basic_features": basic,
    }


def write_views(store: ColumnStore, views: Dict[str, Columns], *, chunk_rows: int = 4096) -> None:
    """Materialize views into the column store in chunks."""
    for vname, cols in views.items():
        n = None
        for data in cols.values():
            n = data.n_rows if isinstance(data, RaggedColumn) else len(data)
            break
        assert n is not None
        cid = 0
        for start in range(0, n, chunk_rows):
            idx = np.arange(start, min(start + chunk_rows, n))
            chunk: Columns = {}
            for name, data in cols.items():
                chunk[name] = data.take(idx) if isinstance(data, RaggedColumn) else data[idx]
            store.write_chunk(vname, cid, chunk)
            cid += 1


def write_log_shards(
    data_dir: str,
    *,
    n_shards: int = 8,
    rows_per_shard: int = 2048,
    seed: int = 0,
    null_rate: float = 0.05,
) -> List[str]:
    """Materialize the synthetic raw log as on-disk ``.fbshard`` files.

    Each shard is one independently-generated batch of the four views
    (deterministic per ``(seed, shard)``), plus a dataset manifest — the
    scaled-down stand-in for the paper's 15–25 TB sharded log store that
    ``repro.io.StreamingLoader`` ingests.
    """
    from repro.io.convert import write_view_shards  # avoid import cycle

    return write_view_shards(
        data_dir,
        (gen_views(rows_per_shard, seed=seed + i, null_rate=null_rate)
         for i in range(n_shards)),
    )


def gen_criteo_batch(
    batch: int,
    *,
    n_dense: int = 13,
    n_sparse: int = 26,
    vocab_sizes: Optional[List[int]] = None,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Criteo-like direct training batch for the recsys models."""
    rng = np.random.default_rng(seed)
    vocab_sizes = vocab_sizes or [1000] * n_sparse
    sparse = np.stack(
        [rng.integers(0, v, batch).astype(np.int32) for v in vocab_sizes[:n_sparse]],
        axis=1,
    )
    return {
        "dense": rng.exponential(1.0, (batch, n_dense)).astype(np.float32),
        "sparse": sparse,
        "label": (rng.random(batch) < 0.25).astype(np.float32),
    }
