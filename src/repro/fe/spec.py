"""Declarative feature definitions (the FeatureBox front end).

The paper's premise is that practitioners retrain CTR models constantly to
test new engineered features, so defining a feature must be cheap. This
module is the declarative surface for that: users describe *what* to compute
— sources, joins, transforms, outputs — as plain data, and
:mod:`repro.fe.compiler` lowers the description into the existing
:class:`~repro.core.opgraph.OpGraph` with correct placements, cost hints,
and sparse-field offsets.

A :class:`FeatureSpec` is a pure value: hashable pieces, no callables except
the :class:`Custom` escape hatch. The bundled scenario presets live in
:mod:`repro.fe.specs`.

Naming: transforms and outputs reference columns of the *joined* table by
name — base-view columns keep their names, joined columns carry the join's
prefix (``u_age_bucket``), JSON-extracted fields appear under their field
name. Transform results are referenced by the transform's ``name``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Tuple

from repro.core.opgraph import Device, OpCost
from repro.fe.schema import ColType, ViewSchema

# Default feature-space layout (mirrors the legacy hand-wired ads pipeline).
DEFAULT_FIELD_SIZE = 1 << 20


# ------------------------------------------------------------------- sources
@dataclasses.dataclass(frozen=True)
class JsonExtract:
    """Parse fields out of a JSON string column during the clean stage."""

    column: str                          # JSON source column on the view
    fields: Tuple[Tuple[str, ColType], ...]  # (field name, type) pairs

    def __post_init__(self) -> None:
        object.__setattr__(self, "fields", tuple(self.fields))


@dataclasses.dataclass(frozen=True)
class Source:
    """One raw view consumed by the pipeline.

    ``json`` lists semi-structured payloads to flatten while cleaning;
    extracted fields become ordinary columns of the view (null-filled with
    their type defaults, same as schema columns).
    """

    view: str
    schema: ViewSchema
    json: Tuple[JsonExtract, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "json", tuple(self.json))


@dataclasses.dataclass(frozen=True)
class Join:
    """Left-join a source view onto the base table (host dictionary lookup)."""

    view: str
    key: str                 # shared key column (user_id, ad_id, ...)
    prefix: str = ""         # prefix for the joined columns


@dataclasses.dataclass(frozen=True)
class Merge:
    """Merge a materialized feature table on the instance key (paper §III).

    The named float columns are appended to the dense output, after all
    :class:`DenseOutput` features, in merge declaration order.
    """

    view: str
    columns: Tuple[str, ...]
    key: str = "instance_id"
    prefix: str = "basic_"
    bytes_touched: int = 4 * 1024**3   # dictionary working set (placement hint)

    def __post_init__(self) -> None:
        object.__setattr__(self, "columns", tuple(self.columns))


# ---------------------------------------------------------------- transforms
@dataclasses.dataclass(frozen=True)
class Hash:
    """A categorical column as one sparse field: ``id % field_size``.

    ``mix=True`` additionally avalanche-mixes the id (fmix32) before the
    modulo — use it when raw ids are correlated with the field size.
    """

    name: str
    column: str
    mix: bool = False


@dataclasses.dataclass(frozen=True)
class Cross:
    """Feature combination: hash two categorical columns into one field."""

    name: str
    a: str
    b: str


@dataclasses.dataclass(frozen=True)
class Bucketize:
    """Discretize a float column into right-open buckets (dense feature)."""

    name: str
    column: str
    boundaries: Tuple[float, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "boundaries", tuple(self.boundaries))


@dataclasses.dataclass(frozen=True)
class LogNorm:
    """``log(1+x)`` transform for heavy-tailed counters (dense feature)."""

    name: str
    column: str


@dataclasses.dataclass(frozen=True)
class Scale:
    """``x / denom`` as float32 (dense feature, e.g. ``hour / 24``)."""

    name: str
    column: str
    denom: float


@dataclasses.dataclass(frozen=True)
class Sequence:
    """A padded id sequence + mask from a ragged or string column.

    * INT_LIST columns are padded/truncated to ``max_len``;
    * STRING columns are tokenized (whitespace + ``ngrams``-gram hashing)
      on the host first — the paper's "extract keywords" stand-in.
    """

    name: str
    column: str
    max_len: int
    ngrams: int = 2


@dataclasses.dataclass(frozen=True)
class Custom:
    """Escape hatch: a user operator inserted into the graph as-is.

    ``fn`` takes the declared input slots as keyword arguments and returns
    ``{output: array}``. Device ops must be jit-traceable; host ops may run
    arbitrary Python. ``cost`` feeds the scheduler's placement heuristic for
    ``Device.AUTO`` ops.
    """

    name: str
    fn: Callable[..., Mapping[str, Any]]
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    device: Device = Device.AUTO
    cost: OpCost = dataclasses.field(default_factory=OpCost)

    def __post_init__(self) -> None:
        object.__setattr__(self, "inputs", tuple(self.inputs))
        object.__setattr__(self, "outputs", tuple(self.outputs))


DENSE_TRANSFORMS = (Bucketize, LogNorm, Scale)
SPARSE_TRANSFORMS = (Hash, Cross)
Transform = Any  # union of the dataclasses above (kept loose for Custom)


# ------------------------------------------------------------------- outputs
@dataclasses.dataclass(frozen=True)
class SparseOutput:
    """``batch_sparse`` [B, n_fields] int32: one global sparse id per field.

    ``fields`` reference :class:`Hash`/:class:`Cross` transforms (or a
    :class:`Custom` output slot holding per-field hashes); declaration order
    is field order, and field *i* occupies ``[i*field_size, (i+1)*field_size)``
    in the global id space.
    """

    fields: Tuple[str, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "fields", tuple(self.fields))


@dataclasses.dataclass(frozen=True)
class DenseOutput:
    """``batch_dense`` [B, n] float32 in declaration order.

    Columns contributed by :class:`Merge` tables are appended after these
    features, in merge declaration order.
    """

    features: Tuple[str, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "features", tuple(self.features))


@dataclasses.dataclass(frozen=True)
class SequenceOutput:
    """``batch_seq_ids``/``batch_seq_mask`` [B, sum(max_len)]: the named
    :class:`Sequence` transforms concatenated along the length axis."""

    sequences: Tuple[str, ...]

    def __post_init__(self) -> None:
        object.__setattr__(self, "sequences", tuple(self.sequences))


Output = Any  # union of the three output dataclasses


# ---------------------------------------------------------------------- spec
@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    """A full feature-engineering scenario as data.

    ``base`` names the instance-grain view; every :class:`Join` left-joins
    another source onto it, every :class:`Merge` joins a materialized table
    on the instance key. ``label`` is a base-view column emitted as
    ``batch_label``.
    """

    name: str
    base: str
    sources: Tuple[Source, ...]
    outputs: Tuple[Output, ...]
    joins: Tuple[Join, ...] = ()
    merges: Tuple[Merge, ...] = ()
    transforms: Tuple[Transform, ...] = ()
    label: str = "label"
    join_bytes_touched: int = 8 * 1024**3

    def __post_init__(self) -> None:
        object.__setattr__(self, "sources", tuple(self.sources))
        object.__setattr__(self, "outputs", tuple(self.outputs))
        object.__setattr__(self, "joins", tuple(self.joins))
        object.__setattr__(self, "merges", tuple(self.merges))
        object.__setattr__(self, "transforms", tuple(self.transforms))
        views = [s.view for s in self.sources]
        if len(set(views)) != len(views):
            raise ValueError(f"spec {self.name!r}: duplicate source views")
        if self.base not in views:
            raise ValueError(
                f"spec {self.name!r}: base view {self.base!r} is not a source")
        known = set(views)
        for j in self.joins:
            if j.view not in known:
                raise ValueError(
                    f"spec {self.name!r}: join references unknown view {j.view!r}")
        for m in self.merges:
            if m.view not in known:
                raise ValueError(
                    f"spec {self.name!r}: merge references unknown view {m.view!r}")
        names = [t.name for t in self.transforms]
        if len(set(names)) != len(names):
            raise ValueError(f"spec {self.name!r}: duplicate transform names")

    def source(self, view: str) -> Source:
        for s in self.sources:
            if s.view == view:
                return s
        raise KeyError(f"spec {self.name!r} has no source {view!r}")

    def transform(self, name: str) -> Transform:
        for t in self.transforms:
            if t.name == name:
                return t
        raise KeyError(f"spec {self.name!r} has no transform {name!r}")
