"""View cleaning (paper §III "Clean views").

Views arrive with null values and semi-structured payloads (JSON). Cleaning
fills nulls, extracts required fields from semi-structured columns, and
applies application-specific instance filters, producing a structured table
where every column has a non-empty simple type.

These are HOST operators in the schedule (string/JSON work), exactly as the
paper assigns them; their numeric outputs flow to the device.
"""

from __future__ import annotations

import json
from typing import Dict, List, Mapping, Optional

import numpy as np

from repro.fe.colstore import Columns, RaggedColumn
from repro.fe.schema import ColType, Column, ViewSchema

# Null sentinels used by the raw log generator / real logs.
_NULL_INT = np.iinfo(np.int64).min
_NULL_FLOAT = np.nan


def fill_nulls(
    columns: Columns,
    schema: ViewSchema,
    *,
    extracted: Optional[Mapping[str, ColType]] = None,
) -> Columns:
    """Replace null sentinels with each column's fill value.

    ``extracted`` names columns that are not part of ``schema`` (typically
    produced by :func:`extract_json_fields`) but should be null-filled with
    their type's default as well, so callers never hand-roll a second
    sentinel pass.
    """
    extra_cols = tuple(Column(name, ctype) for name, ctype in (extracted or {}).items())
    for col in extra_cols:
        if col.name in {c.name for c in schema.columns}:
            raise ValueError(
                f"extracted column {col.name!r} shadows a schema column of "
                f"view {schema.name!r}")
    out: Columns = {}
    for col in schema.columns + extra_cols:
        if col.name not in columns:
            continue
        data = columns[col.name]
        if isinstance(data, RaggedColumn):
            values = np.where(data.values == _NULL_INT,
                              np.int64(col.default_fill()), data.values)
            out[col.name] = RaggedColumn(values=values, lengths=data.lengths)
        elif col.ctype is ColType.INT:
            out[col.name] = np.where(data == _NULL_INT, np.int64(col.default_fill()), data)
        elif col.ctype is ColType.FLOAT:
            out[col.name] = np.where(np.isnan(data), np.float32(col.default_fill()),
                                     data).astype(np.float32)
        elif col.ctype is ColType.STRING:
            fill = str(col.default_fill())
            out[col.name] = np.array([fill if (s is None or s == "") else s for s in data],
                                     dtype=object)
        else:
            out[col.name] = data
    # carry through any extra columns untouched
    for name, data in columns.items():
        out.setdefault(name, data)
    return out


def extract_json_fields(
    columns: Columns, source_col: str, fields: Mapping[str, ColType]
) -> Columns:
    """Parse a JSON string column into simple-typed columns (host op).

    Missing/unparseable fields become null sentinels so ``fill_nulls`` can
    handle them uniformly.
    """
    raw = columns[source_col]
    parsed: List[Dict] = []
    for s in raw:
        try:
            parsed.append(json.loads(s) if s else {})
        except (json.JSONDecodeError, TypeError):
            parsed.append({})
    out = dict(columns)
    for fname, ctype in fields.items():
        if ctype is ColType.INT:
            out[fname] = np.array(
                [int(p[fname]) if fname in p and p[fname] is not None else _NULL_INT
                 for p in parsed], np.int64)
        elif ctype is ColType.FLOAT:
            out[fname] = np.array(
                [float(p[fname]) if fname in p and p[fname] is not None else _NULL_FLOAT
                 for p in parsed], np.float32)
        elif ctype is ColType.STRING:
            out[fname] = np.array(
                [str(p.get(fname, "")) for p in parsed], dtype=object)
        else:
            raise ValueError(f"cannot extract {ctype} from JSON")
    return out


def filter_rows(columns: Columns, mask: np.ndarray) -> Columns:
    """Apply an application filter (paper: 'custom filter ... unrelated
    instances'), keeping rows where mask is True."""
    idx = np.nonzero(mask)[0]
    out: Columns = {}
    for name, data in columns.items():
        if isinstance(data, RaggedColumn):
            out[name] = data.take(idx)
        else:
            out[name] = data[idx]
    return out


def n_rows(columns: Columns) -> int:
    for data in columns.values():
        if isinstance(data, RaggedColumn):
            return data.n_rows
        return int(np.asarray(data).shape[0])
    return 0
