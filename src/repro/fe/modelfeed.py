"""Compiled spec->arch batch adaptation: the stage->train boundary, jitted.

A compiled :class:`~repro.fe.featureplan.FeaturePlan` emits a spec-dependent
``batch_*`` layout (e.g. ads_ctr: 8 sparse fields, 9 dense feats, 48 seq
positions); an arch config usually wants a different width, so fields are
remapped / re-hashed into the config's vocabularies and missing blocks are
synthesized. The legacy adapter (kept verbatim below as
:func:`fe_env_to_model_batch_ref`, the reference oracle) did this with ~10
eager jnp dispatches per step — every one of them on the training critical
path, *after* the device-feed stage had already paid to put the batch on
device.

:func:`compile` moves all of that to compile time. It derives a
:class:`ModelFeed` plan from the plan's :class:`~repro.fe.compiler.
OutputLayout` + the arch config: which spec field feeds which model field
(static remap indices), the per-field vocab modulo vector, and how to
synthesize dense / behavior-sequence blocks when the spec has none. The
plan's :meth:`ModelFeed.apply` is pure jnp over static constants, so
:meth:`ModelFeed.make_step` traces it **inside** the train step's jit — the
whole stage->train boundary is ONE fused dispatch per step (the train step
itself), with zero eager adaptation ops. Outputs are asserted bit-identical
to the oracle in ``tests/test_modelfeed.py``.

The plan also closes the two remaining gaps on this boundary:

* **per-field dedup'd embedding feed** — with ``split_sparse_fields=True``
  the plan consumes the arena binding's per-field ``batch_field_NN`` id
  vectors directly (no packed intermediate on the host), and
  :func:`dedup_capacity_hint` sizes the working set of the sparse train
  step (``MultiTable.lookup_dedup`` / ``make_sparse_train_step``) from the
  loader's ``rows_hint`` — so the streaming driver runs the
  FeatureBox/[37] working-set path by default, with dedup saturation
  surfaced in :attr:`TrainFeedStats.overflows`;
* **donated staged buffers** — ``make_step(donate=True)`` donates the
  staged batch (and params/optimizer) through the jit, so arena-fed device
  slots are reused in place; the consumer side of the
  :meth:`~repro.core.devicefeed.DeviceFeeder.donation_fence` handshake.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.embedding.dedup import expected_unique
from repro.fe.compiler import OutputLayout, field_slot, field_slots
from repro.obs.metrics import harvest
from repro.obs.trace import get_tracer

_DONATE_MSG = "Some donated buffers were not usable"


class ModelFeedError(ValueError):
    """A batch (or config) violates the compiled adaptation contract."""


# ---------------------------------------------------------------- oracle
def fe_env_to_model_batch_ref(env: Mapping[str, Any], cfg) -> Dict[str, Any]:
    """Reference adapter: FE-pipeline outputs -> recsys model batch.

    This is the pre-compilation implementation, kept verbatim as the
    oracle :meth:`ModelFeed.apply` is asserted bit-identical against
    (``tests/test_modelfeed.py``). Columns are tiled / re-hashed into the
    config's field vocabularies; specs without a dense block (bst) or
    sequence block (dlrm-as-plain) degrade gracefully: missing blocks are
    synthesized from the sparse fields. Pure jnp, but every op here is an
    eager per-step dispatch — the cost the compiled path removes.
    """
    sparse = jnp.asarray(env["batch_sparse"])
    idx = np.arange(cfg.n_sparse) % sparse.shape[1]
    vocab = np.asarray(cfg.vocab_sizes[:cfg.n_sparse], np.int32)
    batch: Dict[str, Any] = {
        "sparse": (sparse[:, idx] % vocab).astype(jnp.int32),
        "label": jnp.asarray(env["batch_label"]).astype(jnp.float32),
    }
    if cfg.n_dense:
        if "batch_dense" in env:
            dense = jnp.asarray(env["batch_dense"]).astype(jnp.float32)
        else:  # spec emits no dense block: log-scaled sparse ids stand in
            dense = jnp.log1p(sparse.astype(jnp.float32))
        reps = -(-cfg.n_dense // dense.shape[1])  # ceil
        batch["dense"] = jnp.tile(dense, (1, reps))[:, :cfg.n_dense]
    if cfg.kind == "bst":
        seq = (jnp.asarray(env["batch_seq_ids"])
               if "batch_seq_ids" in env else sparse)
        reps = -(-cfg.seq_len // seq.shape[1])
        batch["seq"] = (jnp.tile(seq, (1, reps))[:, :cfg.seq_len]
                        % cfg.vocab_sizes[0]).astype(jnp.int32)
    return batch


# ------------------------------------------------------- capacity heuristic
def dedup_capacity_hint(cfg, rows: int, *, mode: str = "worst",
                        safety: float = 1.15, multiple: int = 64) -> int:
    """Working-set capacity for a batch of ``rows`` instances.

    ``mode="worst"`` (default) is the exact upper bound on unique packed
    ids — ``sum_f min(rows, vocab_f)`` plus the behavior-sequence field for
    bst — so dedup can never overflow as long as batches respect the rows
    hint. ``mode="expected"`` uses the uniform-draw expectation
    ``E[unique] = v(1 - (1 - 1/v)^n`` (x ``safety``), capped at the worst
    case — tighter at scale, but a skewed batch can saturate it
    (surfaced as :attr:`TrainFeedStats.overflows`). The result is rounded
    up to ``multiple`` so the working set shards evenly.
    """
    rows = int(rows)
    if rows <= 0:
        raise ModelFeedError(f"rows must be > 0, got {rows}")
    vocabs = cfg.vocab_sizes[:cfg.n_sparse]
    seq_rows = rows * (cfg.seq_len + 1) if cfg.kind == "bst" else 0
    worst = sum(min(rows, v) for v in vocabs)
    # Behavior-sequence ids are produced modulo vocab_sizes[0] (see the
    # reference adapter), NOT the item field's vocab — bound with the
    # id space they actually range over.
    if seq_rows:
        worst += min(seq_rows, cfg.vocab_sizes[0])
    if mode == "worst":
        cap = worst
    elif mode == "expected":
        exp = sum(expected_unique(rows, v) for v in vocabs)
        if seq_rows:
            exp += expected_unique(seq_rows, cfg.vocab_sizes[0])
        cap = min(worst, int(exp * safety) + 1)
    else:
        raise ModelFeedError(f"mode must be 'worst' or 'expected', got {mode!r}")
    return max(multiple, -(-cap // multiple) * multiple)


# ------------------------------------------------------------------- stats
@dataclasses.dataclass
class TrainFeedStats:
    """The train-feed tier: where the stage->train boundary's time went.

    Attached to :class:`~repro.core.pipeline.PipelineStats.train_feed` by
    the runners (duck-typed off the train step's ``feed_stats`` attribute)
    so "adapt" is measurable separately from "train".
    """

    steps: int = 0
    fused_steps: int = 0        # steps whose adaptation ran inside the train jit
    adapt_seconds: float = 0.0  # host time preparing the feed (select + eager apply)
    adapt_dispatches: int = 0   # eager device dispatches spent adapting (0 when fused)
    unique_ids: int = 0         # sum over steps of the dedup'd working-set count
    total_ids: int = 0          # sum over steps of ids referenced (batch x fields)
    overflows: int = 0          # steps whose unique count saturated the capacity
    # mesh two-stage dedup only: sum over steps of stage-1 (per-device)
    # unique counts — the pooled-exchange volume before the global unique
    local_unique_ids: int = 0

    @property
    def adapt_dispatches_per_step(self) -> float:
        return self.adapt_dispatches / max(self.steps, 1)

    @property
    def dispatches_per_step(self) -> float:
        """Total stage->train boundary dispatches per step: the eager
        adaptation ops plus the single train-jit call. 1.0 means the whole
        boundary is one fused dispatch."""
        return (self.adapt_dispatches + self.steps) / max(self.steps, 1)

    @property
    def pool_ratio(self) -> float:
        """stage-1 unique ids / referenced ids — how much the local dedup
        shrinks the cross-device id pool before the global unique (0 when
        the step reports no stage-1 counts, i.e. single-device)."""
        return self.local_unique_ids / max(self.total_ids, 1)

    @property
    def unique_ratio(self) -> float:
        """unique ids / referenced ids — the dedup win ([37]: collective
        traffic is proportional to this, not to batch x fields)."""
        return self.unique_ids / max(self.total_ids, 1)

    def as_metrics(self) -> Dict[str, float]:
        """Flat numeric snapshot for :class:`repro.obs.MetricsRegistry`."""
        return harvest(self)

    def summary(self) -> str:
        return (f"steps={self.steps} (fused={self.fused_steps}) "
                f"adapt={self.adapt_seconds:.3f}s "
                f"dispatches/step={self.dispatches_per_step:.1f} "
                f"unique_ratio={self.unique_ratio:.3f} "
                f"overflows={self.overflows}")


# --------------------------------------------------------------- the plan
@dataclasses.dataclass
class ModelFeed:
    """Compile-time spec->arch adaptation plan (build via :func:`compile`).

    All remap indices, modulo vectors, and synthesis/tile plans are static
    numpy/python constants, so :meth:`apply` is traceable: the fused step
    from :meth:`make_step` runs the whole adaptation inside the train jit.
    """

    config: Any                       # arch config, dedup capacity tuned
    slots: Tuple[str, ...]            # env slots apply() consumes
    split: bool                       # consume per-field batch_field_NN vectors
    n_spec_fields: int
    field_sources: np.ndarray         # (n_model_fields,) spec field per model field
    vocab: np.ndarray                 # (n_model_fields,) int32 modulo vector
    dense_from: Optional[str]         # "batch_dense" | "sparse" | None
    seq_from: Optional[str]           # "batch_seq_ids" | "sparse" | None
    dedup_capacity: int
    stats: TrainFeedStats = dataclasses.field(default_factory=TrainFeedStats)
    _eager_ops: Optional[int] = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------- select
    def select(self, env: Mapping[str, Any]) -> Dict[str, Any]:
        """Filter an environment down to the slots :meth:`apply` consumes.

        Host-side dict work only (no dispatches); validates the static
        shape contract so a mis-wired env fails loudly instead of tracing
        garbage into the jit.
        """
        try:
            feed = {s: env[s] for s in self.slots}
        except KeyError as e:
            raise ModelFeedError(
                f"batch is missing adapted slot {e.args[0]!r} (feed slots: "
                f"{self.slots}; batch slots: "
                f"{sorted(k for k in env if k.startswith('batch_'))})"
            ) from None
        width = (feed[field_slot(0)].ndim if self.split
                 else feed["batch_sparse"].shape[1])
        want = 1 if self.split else self.n_spec_fields
        if width != want:
            raise ModelFeedError(
                f"sparse feed shape mismatch: got width {width}, compiled "
                f"for {want} ({'split' if self.split else 'packed'} layout)")
        return feed

    # -------------------------------------------------------------- apply
    def apply(self, feed: Mapping[str, Any]) -> Dict[str, Any]:
        """Adapt one feed (see :meth:`select`) to a model batch.

        Pure jnp over compile-time constants — call it eagerly (the
        benchmark baseline) or let :meth:`make_step` trace it inside the
        train jit. Bit-identical to :func:`fe_env_to_model_batch_ref`.
        """
        cfg = self.config
        if self.split:
            fields = [jnp.asarray(feed[field_slot(i)])
                      for i in range(self.n_spec_fields)]
            sel = jnp.stack([fields[i] for i in self.field_sources], axis=1)
            packed = (jnp.stack(fields, axis=1)
                      if "sparse" in (self.dense_from, self.seq_from) else None)
        else:
            packed = jnp.asarray(feed["batch_sparse"])
            sel = packed[:, self.field_sources]
        vocab = jnp.asarray(self.vocab)
        batch: Dict[str, Any] = {
            "sparse": (sel % vocab).astype(jnp.int32),
            "label": jnp.asarray(feed["batch_label"]).astype(jnp.float32),
        }
        if self.dense_from is not None:
            if self.dense_from == "batch_dense":
                dense = jnp.asarray(feed["batch_dense"]).astype(jnp.float32)
            else:
                dense = jnp.log1p(packed.astype(jnp.float32))
            reps = -(-cfg.n_dense // dense.shape[1])  # ceil
            batch["dense"] = jnp.tile(dense, (1, reps))[:, :cfg.n_dense]
        if self.seq_from is not None:
            seq = (jnp.asarray(feed["batch_seq_ids"])
                   if self.seq_from == "batch_seq_ids" else packed)
            reps = -(-cfg.seq_len // seq.shape[1])
            batch["seq"] = (jnp.tile(seq, (1, reps))[:, :cfg.seq_len]
                            % cfg.vocab_sizes[0]).astype(jnp.int32)
        return batch

    def model_ids_np(self, env: Mapping[str, Any]
                     ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Host twin of :meth:`apply`'s *id* arithmetic: the model batch's
        ``sparse`` (and bst ``seq``) blocks, as numpy, straight from a
        pre-staging env.

        Integer remap + modulo only, so the values are bitwise-identical to
        the device path — the hierarchical-PS prefetch stage
        (:class:`repro.embedding.psfeed.HierarchyFeed`) uses this to build
        the working set *before* the batch reaches the device.
        """
        cfg = self.config
        if self.split:
            fields = [np.asarray(env[field_slot(i)])
                      for i in range(self.n_spec_fields)]
            sel = np.stack([fields[i] for i in self.field_sources], axis=1)
            packed = (np.stack(fields, axis=1)
                      if self.seq_from == "sparse" else None)
        else:
            packed = np.asarray(env["batch_sparse"])
            sel = packed[:, self.field_sources]
        sparse = (sel % self.vocab).astype(np.int32)
        seq = None
        if self.seq_from is not None:
            src = (np.asarray(env["batch_seq_ids"])
                   if self.seq_from == "batch_seq_ids" else packed)
            reps = -(-cfg.seq_len // src.shape[1])
            seq = (np.tile(src, (1, reps))[:, :cfg.seq_len]
                   % cfg.vocab_sizes[0]).astype(np.int32)
        return sparse, seq

    def eager_adapt_ops(self, feed: Mapping[str, Any]) -> int:
        """Device dispatches one eager :meth:`apply` costs (jaxpr op count,
        cached — the feed's static shape contract makes it batch-invariant)."""
        if self._eager_ops is None:
            jaxpr = jax.make_jaxpr(self.apply)(
                {k: np.asarray(v) for k, v in feed.items()})
            self._eager_ops = len(jaxpr.jaxpr.eqns)
        return self._eager_ops

    # --------------------------------------------------------------- step
    def make_step(self, train_step: Callable, *, fused: bool = True,
                  donate: bool = True,
                  fence_cb: Optional[Callable[[Any], None]] = None,
                  extra_slots: Tuple[str, ...] = ()):
        """Wrap an unjitted ``(params, opt_state, batch) -> (params,
        opt_state, metrics)`` train step into the compiled boundary step
        ``(params, opt_state, env) -> (params, opt_state, metrics)``.

        ``fused=True`` traces :meth:`apply` inside the train jit (one
        dispatch covers adapt + train); ``fused=False`` keeps the eager
        adaptation (the measurable before). ``donate=True`` donates params,
        optimizer state, AND the staged batch through the jit, so
        arena-staged device slots are reused in place — pair with
        :meth:`~repro.core.devicefeed.DeviceFeeder.donation_fence` via
        ``fence_cb`` (called with a step output after every call) so the
        feeder's completion gate can account the donated buffers.

        ``extra_slots`` names env slots forwarded *verbatim* into the train
        step's batch, bypassing :meth:`apply` — the hierarchical-PS backend
        rides its pulled working-set arrays (``_ws_rows``/``_ws_unique``/...)
        through the boundary this way. They are part of the donated batch
        argument, so working-set buffers are donated into the jit like any
        staged slot.

        The returned callable carries ``feed_stats`` (this plan's
        :class:`TrainFeedStats`), which the pipeline runners adopt into
        ``PipelineStats.train_feed``.
        """
        donate_args = (0, 1, 2) if donate else ()
        extra_slots = tuple(extra_slots)
        if fused:
            def _boundary(params, opt_state, feed):
                batch = self.apply(feed)
                batch.update({k: feed[k] for k in extra_slots})
                return train_step(params, opt_state, batch)
            jitted = jax.jit(_boundary, donate_argnums=donate_args)
        else:
            jitted = jax.jit(train_step, donate_argnums=donate_args)
        stats = self.stats

        def _select_with_extras(env):
            feed = self.select(env)
            try:
                feed.update({k: env[k] for k in extra_slots})
            except KeyError as e:
                raise ModelFeedError(
                    f"batch is missing extra slot {e.args[0]!r} (extra "
                    f"slots: {extra_slots}) — is the working-set prefetch "
                    f"stage wired in?") from None
            return feed

        def step(params, opt_state, env):
            tracer = get_tracer()
            w0 = tracer.now_ns() if tracer.enabled else 0
            t0 = time.perf_counter()
            feed = _select_with_extras(env)
            if fused:
                stats.fused_steps += 1
            else:
                stats.adapt_dispatches += self.eager_adapt_ops(self.select(env))
                extras = {k: feed[k] for k in extra_slots}
                feed = self.apply(feed)  # eager: each op its own dispatch
                feed.update(extras)
            stats.adapt_seconds += time.perf_counter() - t0
            if tracer.enabled:
                tracer.complete("train.adapt", w0, tracer.now_ns(),
                                fused=fused)
            with warnings.catch_warnings():
                if donate:
                    # The staged batch rarely aliases an output shape; the
                    # donation is still wanted (params/opt DO alias, and
                    # the feeder accounts batch donation via the fence).
                    warnings.filterwarnings("ignore", message=_DONATE_MSG)
                new_params, new_opt, metrics = jitted(params, opt_state, feed)
            stats.steps += 1
            # Register the fence BEFORE touching metric values: _record
            # blocks on the step's results, and the feeder may already be
            # waiting on this step's fence to reclaim a donated buffer.
            if fence_cb is not None:
                fence = metrics.get("loss")
                if fence is None and metrics:
                    fence = next(iter(metrics.values()))
                fence_cb(fence)
            self._record(metrics)
            return new_params, new_opt, metrics

        step.feed_stats = stats
        # Expose the underlying jit so drivers/benchmarks can lower it for
        # HLO cost analysis (repro.launch.hlo_stats.step_cost) without
        # re-deriving the boundary function; select_feed builds the exact
        # feed argument the jit expects (extra slots included).
        step.jitted = jitted
        step.select_feed = _select_with_extras
        return step

    def _record(self, metrics: Mapping[str, Any]) -> None:
        u = metrics.get("unique")
        if u is None:
            return  # non-working-set step (e.g. the dense nodedup baseline)
        u = int(u)
        self.stats.unique_ids += u
        n = metrics.get("n_ids")
        if n is not None:
            self.stats.total_ids += int(n)
        lu = metrics.get("local_unique")
        if lu is not None:
            self.stats.local_unique_ids += int(lu)
        if self.dedup_capacity and u >= self.dedup_capacity:
            if self.stats.overflows == 0:
                warnings.warn(
                    f"dedup working set saturated (unique={u} >= capacity="
                    f"{self.dedup_capacity}): ids beyond the capacity are "
                    f"silently dropped from the working set — raise the "
                    f"rows hint / dedup_capacity", RuntimeWarning,
                    stacklevel=2)
            self.stats.overflows += 1


# ----------------------------------------------------------------- compile
def compile(plan, cfg, *, split_sparse_fields: bool = False,
            rows_hint: Optional[int] = None, capacity_mode: str = "worst",
            safety: float = 1.15) -> ModelFeed:
    """Derive the :class:`ModelFeed` adaptation plan for ``plan`` x ``cfg``.

    ``plan`` is a compiled :class:`~repro.fe.featureplan.FeaturePlan` (or a
    bare :class:`~repro.fe.compiler.OutputLayout`). ``split_sparse_fields``
    selects the per-field ``batch_field_NN`` feed form the arena binding
    stages (one id vector per spec field, no packed host intermediate).
    When ``cfg.dedup_capacity`` is 0 and ``rows_hint`` is given, the
    returned plan's :attr:`ModelFeed.config` carries a
    :func:`dedup_capacity_hint`-tuned capacity, so building the sparse
    train step from it runs the working-set path by default.
    """
    layout: OutputLayout = getattr(plan, "layout", plan)
    emitted = set(getattr(plan, "output_slots", ())
                  or (name for name, *_ in layout.feed_slots()))
    if layout.n_sparse_fields <= 0 or "batch_sparse" not in emitted:
        raise ModelFeedError(
            f"model feed needs a sparse block; layout emits {sorted(emitted)}")
    if getattr(cfg, "n_sparse", 0) <= 0:
        raise ModelFeedError("arch config has no sparse fields")

    n_spec = layout.n_sparse_fields
    field_sources = np.arange(cfg.n_sparse) % n_spec
    vocab = np.asarray(cfg.vocab_sizes[:cfg.n_sparse], np.int32)
    dense_from = None
    if cfg.n_dense:
        dense_from = ("batch_dense" if "batch_dense" in emitted else "sparse")
    seq_from = None
    if cfg.kind == "bst":
        seq_from = ("batch_seq_ids" if "batch_seq_ids" in emitted
                    else "sparse")

    slots = ["batch_label"]
    slots.extend(field_slots(n_spec) if split_sparse_fields
                 else ("batch_sparse",))
    if dense_from == "batch_dense":
        slots.append("batch_dense")
    if seq_from == "batch_seq_ids":
        slots.append("batch_seq_ids")

    if getattr(cfg, "dedup_capacity", 0) == 0 and rows_hint:
        cfg = dataclasses.replace(
            cfg, dedup_capacity=dedup_capacity_hint(
                cfg, rows_hint, mode=capacity_mode, safety=safety))

    return ModelFeed(
        config=cfg,
        slots=tuple(slots),
        split=split_sparse_fields,
        n_spec_fields=n_spec,
        field_sources=field_sources,
        vocab=vocab,
        dense_from=dense_from,
        seq_from=seq_from,
        dedup_capacity=int(getattr(cfg, "dedup_capacity", 0)),
    )
