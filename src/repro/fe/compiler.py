"""Lower a declarative :class:`~repro.fe.spec.FeatureSpec` into an OpGraph.

The compiler emits the same staged shape the hand-wired ads pipeline used,
so schedules (layers, placements, fused dispatch counts) are identical for
equivalent definitions:

* ``clean_<view>``   — HOST, one per base/joined source (JSON extraction +
  null fill, both driven by the view schema);
* ``join_views``     — HOST, the chained dictionary-lookup left joins
  (cost-hinted: "large table joins" stay off the device);
* ``extract_text``   — HOST, every :class:`Sequence` transform (tokenize +
  pad) in one operator;
* ``to_device``      — HOST, gathers exactly the numeric columns the device
  stage consumes (the H2D boundary);
* ``cross_features`` / ``dense_features`` — DEVICE, grouped elementwise
  transforms (fused into the layer's meta-kernel);
* ``merge_<view>``   — HOST, instance-key merges of materialized tables;
* ``sparse_ids``     — DEVICE, per-field hashes packed into the global
  sparse id space (field i occupies [i*field_size, (i+1)*field_size));
* ``final_batch``    — DEVICE, assembles ``batch_dense`` / ``batch_sparse``
  / ``batch_seq_ids`` / ``batch_seq_mask`` / ``batch_label``.

:class:`Custom` transforms are inserted verbatim; their placement follows
their declared device/cost through the scheduler's heuristic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import Device, OpCost, Operator, OpGraph
from repro.fe import ops as F
from repro.fe.colstore import Columns
from repro.fe.join import hash_join
from repro.fe.schema import ColType
from repro.fe.views import extract_json_fields, fill_nulls
from repro.fe.spec import (
    DEFAULT_FIELD_SIZE,
    Bucketize,
    Cross,
    Custom,
    DenseOutput,
    FeatureSpec,
    Hash,
    LogNorm,
    Scale,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
)


@dataclasses.dataclass(frozen=True)
class OutputLayout:
    """Shape contract of a compiled spec's ``batch_*`` outputs."""

    n_sparse_fields: int
    n_dense_feats: int
    seq_len: int            # total width of the concatenated sequence block
    field_size: int

    @property
    def sparse_id_space(self) -> int:
        return self.n_sparse_fields * self.field_size

    def feed_slots(self) -> Tuple[Tuple[str, int, str, bool], ...]:
        """Static H2D staging contract: (slot, row width, dtype, rank1).

        The per-row element widths and dtypes of every ``batch_*`` output a
        spec with this layout emits — what the device-feed tier needs to
        size its arenas at compile time (``FeaturePlan.feed_layout()``
        wraps these into :class:`repro.core.devicefeed.SlotSpec`).
        """
        slots: List[Tuple[str, int, str, bool]] = [
            ("batch_label", 1, "float32", True)]
        if self.n_dense_feats:
            slots.append(("batch_dense", self.n_dense_feats, "float32", False))
        if self.n_sparse_fields:
            slots.append(("batch_sparse", self.n_sparse_fields, "int32", False))
        if self.seq_len:
            slots.append(("batch_seq_ids", self.seq_len, "int32", False))
            slots.append(("batch_seq_mask", self.seq_len, "float32", False))
        return tuple(slots)


def field_slot(i: int) -> str:
    """Staged slot name of sparse field ``i``'s per-field id vector (the
    ``split_sparse_fields`` feed form; mirrored by the core device feeder's
    ``batch_field_``-prefix derivation, which must stay fe-independent)."""
    return f"batch_field_{i:02d}"


def field_slots(n: int) -> Tuple[str, ...]:
    """All per-field staged slot names of an ``n``-field sparse block."""
    return tuple(field_slot(i) for i in range(n))


class SpecError(ValueError):
    """A FeatureSpec that cannot be lowered (bad reference, type mismatch)."""


@dataclasses.dataclass(frozen=True)
class _FinalAssembly:
    """Shape of the ``final_batch`` assembly, shared by the device op and
    the host output binding so the two can never diverge."""

    has_dense: bool
    merge_slots: Tuple[str, ...]
    has_sparse: bool
    n_sparse_fields: int
    seq_names: Tuple[str, ...]
    label_slot: str


def _final_assembly(spec: FeatureSpec) -> _FinalAssembly:
    dense_out = _single(spec, DenseOutput)
    sparse_out = _single(spec, SparseOutput)
    seq_out = _single(spec, SequenceOutput)
    return _FinalAssembly(
        has_dense=bool(dense_out and dense_out.features),
        merge_slots=tuple(f"{m.prefix}dense" for m in spec.merges),
        has_sparse=bool(sparse_out and sparse_out.fields),
        n_sparse_fields=len(sparse_out.fields) if sparse_out else 0,
        seq_names=tuple(seq_out.sequences) if seq_out else (),
        label_slot=f"{spec.label}_col",
    )


class OutputBinding:
    """Host twin of the device ``final_batch`` op for the zero-copy feed.

    Assembles a spec's ``batch_*`` outputs from the pre-final slots
    (``dense_feats`` / ``sparse_ids`` / ``<seq>_ids`` / merge slots /
    label) **directly into caller-provided arrays** — the typed arena
    views a :class:`~repro.core.devicefeed.DeviceFeeder` claims per batch
    (``claim_views``). No fresh output arrays are built and no env->arena
    memcpy happens afterwards; values are bit-identical to the device
    assembly (the ops are pure copies/concatenations, and the int64->int32
    sequence-id narrowing matches ``jnp.asarray`` under disabled x64).

    Duck-typed contract consumed by ``DeviceFeeder``: :meth:`ready`,
    :meth:`rows_of`, :meth:`write`.
    """

    final_op = "final_batch"

    def __init__(self, assembly: _FinalAssembly,
                 *, split_sparse_fields: bool = False) -> None:
        self._asm = assembly
        self.split_sparse_fields = split_sparse_fields
        inputs: List[str] = []
        if assembly.has_dense:
            inputs.append("dense_feats")
        inputs.extend(assembly.merge_slots)
        if assembly.has_sparse:
            inputs.append("sparse_ids")
        for n in assembly.seq_names:
            inputs.extend([f"{n}_ids", f"{n}_mask"])
        inputs.append(assembly.label_slot)
        self.input_slots: Tuple[str, ...] = tuple(dict.fromkeys(inputs))
        self.rows_slot = assembly.label_slot

    def ready(self, env: Mapping[str, object]) -> bool:
        """True when ``env`` carries the pre-assembly slots this binding
        consumes (i.e. the FE ran the sans-final layer build)."""
        return all(s in env for s in self.input_slots)

    def rows_of(self, env: Mapping[str, object]) -> int:
        return int(np.asarray(env[self.rows_slot]).shape[0])

    def write(self, env: Mapping[str, object],
              views: Mapping[str, np.ndarray]) -> None:
        """Assemble every ``batch_*`` output straight into ``views``.

        Shape-validates every source against its destination view first
        (``np.copyto`` would silently broadcast a wrong-rowed slot into
        the arena — the zero-copy twin of the copy path's FeedError).
        """
        asm = self._asm
        _copy_into(views["batch_label"], np.asarray(env[asm.label_slot]),
                   "batch_label")
        if asm.has_dense or asm.merge_slots:
            parts = ([np.asarray(env["dense_feats"])] if asm.has_dense else [])
            parts += [np.asarray(env[s]) for s in asm.merge_slots]
            _concat_into(views["batch_dense"], parts, "batch_dense")
        if asm.has_sparse:
            ids = np.asarray(env["sparse_ids"])
            if self.split_sparse_fields:
                want = (views[field_slot(0)].shape[0],
                        asm.n_sparse_fields)
                if ids.shape != want:
                    raise _shape_error("sparse_ids", ids.shape, want)
                for i in range(asm.n_sparse_fields):
                    np.copyto(views[field_slot(i)], ids[:, i],
                              casting="same_kind")
            else:
                _copy_into(views["batch_sparse"], ids, "batch_sparse")
        if asm.seq_names:
            _concat_into(views["batch_seq_ids"],
                         [np.asarray(env[f"{n}_ids"])
                          for n in asm.seq_names], "batch_seq_ids")
            _concat_into(views["batch_seq_mask"],
                         [np.asarray(env[f"{n}_mask"])
                          for n in asm.seq_names], "batch_seq_mask")


def _shape_error(slot: str, got, want) -> Exception:
    from repro.core.devicefeed import FeedError
    return FeedError(f"slot {slot!r}: shape {tuple(got)} != layout "
                     f"{tuple(want)}")


def _copy_into(out: np.ndarray, src: np.ndarray, slot: str) -> None:
    if src.shape != out.shape:
        raise _shape_error(slot, src.shape, out.shape)
    np.copyto(out, src, casting="same_kind")


def _concat_into(out: np.ndarray, parts: List[np.ndarray],
                 slot: str) -> None:
    """Axis-1 concatenation straight into ``out`` (no intermediate)."""
    if len(parts) == 1:
        _copy_into(out, parts[0], slot)
        return
    rows = out.shape[0]
    widths = 0
    for p in parts:
        if p.ndim != 2 or p.shape[0] != rows:
            raise _shape_error(slot, p.shape, (rows, "*"))
        widths += p.shape[1]
    if widths != out.shape[1]:
        raise _shape_error(slot, (rows, widths), out.shape)
    np.concatenate(parts, axis=1, out=out)


def output_binding(spec: FeatureSpec, *,
                   split_sparse_fields: bool = False) -> OutputBinding:
    """Compile ``spec``'s output-binding (see :class:`OutputBinding`)."""
    return OutputBinding(_final_assembly(spec),
                         split_sparse_fields=split_sparse_fields)


# ------------------------------------------------------------ name resolution
@dataclasses.dataclass(frozen=True)
class _ResolvedCol:
    view: str        # source view name
    column: str      # column name on that view
    ctype: ColType
    extracted: bool  # produced by a JsonExtract, not stored on disk


def _column_table(spec: FeatureSpec) -> Dict[str, _ResolvedCol]:
    """Map joined-table column names -> their origin (view, column, type)."""
    table: Dict[str, _ResolvedCol] = {}

    def register(source: Source, prefix: str) -> None:
        for col in source.schema.columns:
            table[f"{prefix}{col.name}"] = _ResolvedCol(
                source.view, col.name, col.ctype, extracted=False)
        for je in source.json:
            for fname, ctype in je.fields:
                table[f"{prefix}{fname}"] = _ResolvedCol(
                    source.view, fname, ctype, extracted=True)

    register(spec.source(spec.base), "")
    for join in spec.joins:
        register(spec.source(join.view), join.prefix)
    return table


def _resolve(spec: FeatureSpec, table: Dict[str, _ResolvedCol],
             name: str, *, context: str) -> _ResolvedCol:
    try:
        return table[name]
    except KeyError:
        raise SpecError(
            f"spec {spec.name!r}: {context} references unknown column "
            f"{name!r} (known: {sorted(table)})") from None


# ----------------------------------------------------------------- main entry
def lower(spec: FeatureSpec, *, field_size: int = DEFAULT_FIELD_SIZE) -> OpGraph:
    """Compile ``spec`` into an :class:`OpGraph` (see module docstring)."""
    table = _column_table(spec)
    g = OpGraph()

    joined_views = [spec.base] + [j.view for j in spec.joins]
    external = list(dict.fromkeys(joined_views + [m.view for m in spec.merges]))
    g.mark_external(*external)

    # ---------------------------------------------------------- clean (HOST)
    clean_slots: Dict[str, str] = {}
    for view in joined_views:
        source = spec.source(view)
        slot = f"{view}_clean"
        clean_slots[view] = slot
        g.add(Operator(f"clean_{view}", _make_clean_fn(source, slot),
                       (view,), (slot,), device=Device.HOST))

    # ----------------------------------------------------------- join (HOST)
    if spec.joins:
        join_inputs = tuple(clean_slots[v] for v in joined_views)
        g.add(Operator(
            "join_views",
            _make_join_fn(spec, [clean_slots[v] for v in joined_views]),
            join_inputs, ("joined",), device=Device.HOST,
            cost=OpCost(bytes_touched=spec.join_bytes_touched)))
        joined_slot = "joined"
    else:
        joined_slot = clean_slots[spec.base]

    # -------------------------------------------- transform groups, by kind
    sequences = [t for t in spec.transforms if isinstance(t, Sequence)]
    crosses = [t for t in spec.transforms if isinstance(t, Cross)]
    customs = [t for t in spec.transforms if isinstance(t, Custom)]
    by_name = {t.name: t for t in spec.transforms if not isinstance(t, Custom)}

    dense_out = _single(spec, DenseOutput)
    sparse_out = _single(spec, SparseOutput)
    seq_out = _single(spec, SequenceOutput)

    dense_feats: List = []
    if dense_out is not None:
        for ref in dense_out.features:
            t = by_name.get(ref)
            if t is not None and not isinstance(t, (Bucketize, LogNorm, Scale)):
                raise SpecError(
                    f"spec {spec.name!r}: dense feature {ref!r} is a "
                    f"{type(t).__name__}, not a dense transform")
            dense_feats.append(t if t is not None else ref)

    sparse_fields: List = []
    if sparse_out is not None:
        for ref in sparse_out.fields:
            t = by_name.get(ref)
            if t is not None and not isinstance(t, (Hash, Cross)):
                raise SpecError(
                    f"spec {spec.name!r}: sparse field {ref!r} is a "
                    f"{type(t).__name__}, not Hash/Cross")
            sparse_fields.append(t if t is not None else ref)

    # ----------------------------------- host string/sequence extraction
    seq_plans: List[Tuple[Sequence, ColType]] = []
    for t in sequences:
        rc = _resolve(spec, table, t.column, context=f"Sequence {t.name!r}")
        if rc.ctype not in (ColType.STRING, ColType.INT_LIST):
            raise SpecError(
                f"spec {spec.name!r}: Sequence {t.name!r} needs a STRING or "
                f"INT_LIST column, got {rc.ctype} ({t.column!r})")
        seq_plans.append((t, rc.ctype))
    if seq_plans:
        outs = tuple(s for t, _ in seq_plans
                     for s in (f"{t.name}_ids", f"{t.name}_mask"))
        g.add(Operator("extract_text",
                       _make_extract_text_fn(seq_plans, field_size, joined_slot),
                       (joined_slot,), outs, device=Device.HOST))

    # ------------------------------- numeric columns to device (H2D stage)
    device_cols: List[str] = []

    def device_col(name: str, context: str, allowed, kind_desc: str) -> None:
        rc = _resolve(spec, table, name, context=context)
        if rc.ctype not in allowed:
            raise SpecError(
                f"spec {spec.name!r}: {context} needs a {kind_desc} column, "
                f"got {rc.ctype} ({name!r})")
        device_cols.append(name)

    for t in crosses:
        for c in (t.a, t.b):
            device_col(c, f"Cross {t.name!r}", (ColType.INT,),
                       "categorical INT")
    for t in sparse_fields:
        if isinstance(t, Hash):
            device_col(t.column, f"Hash {t.name!r}", (ColType.INT,),
                       "categorical INT")
    for t in dense_feats:
        if isinstance(t, (Bucketize, LogNorm, Scale)):
            device_col(t.column, f"{type(t).__name__} {t.name!r}",
                       (ColType.INT, ColType.FLOAT), "numeric")
    device_cols = list(dict.fromkeys(device_cols))
    label_rc = _resolve(spec, table, spec.label, context="label")
    if label_rc.ctype not in (ColType.INT, ColType.FLOAT):
        raise SpecError(
            f"spec {spec.name!r}: label {spec.label!r} must be a numeric "
            f"column, got {label_rc.ctype}")
    merge_keys = list(dict.fromkeys(m.key for m in spec.merges))
    for key in merge_keys:
        _resolve(spec, table, key, context="merge key")

    col_slot = {name: f"{name}_col" for name in device_cols}
    label_slot = f"{spec.label}_col"
    key_slots = {key: f"{key}_col" for key in merge_keys}
    to_device_outs = (tuple(col_slot[c] for c in device_cols)
                      + tuple(s for s in (label_slot,) if s not in col_slot.values())
                      + tuple(s for k, s in key_slots.items()
                              if s != label_slot and s not in col_slot.values()))
    g.add(Operator(
        "to_device",
        _make_to_device_fn(spec, table, device_cols, col_slot,
                           label_slot, key_slots, joined_slot),
        (joined_slot,), to_device_outs, device=Device.HOST))

    # ------------------------------------------------- extract (DEVICE, jnp)
    if crosses:
        g.add(Operator(
            "cross_features",
            _make_cross_fn(crosses, col_slot, field_size),
            tuple(dict.fromkeys(col_slot[c] for t in crosses
                                for c in (t.a, t.b))),
            tuple(t.name for t in crosses), device=Device.DEVICE))

    if dense_feats:
        ins: List[str] = []
        for t in dense_feats:
            ins.append(col_slot[t.column]
                       if isinstance(t, (Bucketize, LogNorm, Scale)) else t)
        g.add(Operator(
            "dense_features",
            _make_dense_fn(dense_feats, col_slot),
            tuple(dict.fromkeys(ins)), ("dense_feats",), device=Device.DEVICE))

    for t in customs:
        g.add(Operator(t.name, t.fn, t.inputs, t.outputs,
                       device=t.device, cost=t.cost))

    # ------------------------------------------------------ merge (HOST)
    merge_slots: List[str] = []
    for m in spec.merges:
        slot = f"{m.prefix}dense"
        merge_slots.append(slot)
        g.add(Operator(
            f"merge_{m.view}",
            _make_merge_fn(m, key_slots[m.key], slot),
            (m.view, key_slots[m.key]), (slot,), device=Device.HOST,
            cost=OpCost(bytes_touched=m.bytes_touched)))

    # ------------------------------------------------- sparse pack (DEVICE)
    if sparse_fields:
        ins = []
        for t in sparse_fields:
            ins.append(col_slot[t.column] if isinstance(t, Hash)
                       else (t.name if isinstance(t, Cross) else t))
        g.add(Operator(
            "sparse_ids",
            _make_sparse_pack_fn(sparse_fields, col_slot, field_size),
            tuple(dict.fromkeys(ins)), ("sparse_ids",), device=Device.DEVICE))

    # ------------------------------------------------- assemble (DEVICE)
    final_inputs: List[str] = []
    if dense_feats:
        final_inputs.append("dense_feats")
    final_inputs.extend(merge_slots)
    if sparse_fields:
        final_inputs.append("sparse_ids")
    seq_names = []
    if seq_out is not None:
        seq_by_name = {t.name: t for t in sequences}
        for ref in seq_out.sequences:
            if ref not in seq_by_name:
                raise SpecError(
                    f"spec {spec.name!r}: SequenceOutput references "
                    f"{ref!r}, which is not a Sequence transform")
            seq_names.append(ref)
            final_inputs.extend([f"{ref}_ids", f"{ref}_mask"])
    final_inputs.append(label_slot)

    final_outputs = ["batch_label"]
    if dense_feats or merge_slots:
        final_outputs.append("batch_dense")
    if sparse_fields:
        final_outputs.append("batch_sparse")
    if seq_names:
        final_outputs.extend(["batch_seq_ids", "batch_seq_mask"])

    g.add(Operator(
        "final_batch",
        _make_final_fn(bool(dense_feats), tuple(merge_slots),
                       bool(sparse_fields), tuple(seq_names), label_slot),
        tuple(dict.fromkeys(final_inputs)), tuple(final_outputs),
        device=Device.DEVICE))

    g.validate()
    return g


def output_layout(spec: FeatureSpec,
                  *, field_size: int = DEFAULT_FIELD_SIZE) -> OutputLayout:
    """Static ``batch_*`` shape contract of ``spec`` (no compilation)."""
    sparse_out = _single(spec, SparseOutput)
    dense_out = _single(spec, DenseOutput)
    seq_out = _single(spec, SequenceOutput)
    seq_len = 0
    if seq_out is not None:
        by_name = {t.name: t for t in spec.transforms if isinstance(t, Sequence)}
        seq_len = sum(by_name[r].max_len for r in seq_out.sequences
                      if r in by_name)
    return OutputLayout(
        n_sparse_fields=len(sparse_out.fields) if sparse_out else 0,
        n_dense_feats=((len(dense_out.features) if dense_out else 0)
                       + sum(len(m.columns) for m in spec.merges)),
        seq_len=seq_len,
        field_size=field_size,
    )


def required_columns(spec: FeatureSpec) -> Dict[str, Tuple[str, ...]]:
    """Per-view columns the compiled pipeline actually reads.

    This is the loader projection: feeding it to ``StreamingLoader`` (or a
    column store) means untouched columns are never decoded from disk.
    Specs containing :class:`Custom` transforms fall back to *all* columns
    of every source — the compiler cannot see inside user callables.
    """
    table = _column_table(spec)
    needed: Dict[str, set] = {}

    def need(view: str, column: str) -> None:
        needed.setdefault(view, set()).add(column)

    if any(isinstance(t, Custom) for t in spec.transforms):
        out: Dict[str, Tuple[str, ...]] = {}
        for s in spec.sources:
            cols = set(s.schema.column_names)
            for m in spec.merges:
                if m.view == s.view:
                    cols.update(m.columns + (m.key,))
            out[s.view] = tuple(sorted(cols))
        return out

    def need_ref(name: str, context: str) -> None:
        rc = _resolve(spec, table, name, context=context)
        if rc.extracted:
            source = spec.source(rc.view)
            for je in source.json:
                if any(f == rc.column for f, _ in je.fields):
                    need(rc.view, je.column)
        else:
            need(rc.view, rc.column)

    def need_view_col(view: str, column: str, context: str) -> None:
        """A column read directly from one view (join build side): an
        on-disk schema column, or the JSON source of an extracted field."""
        source = spec.source(view)
        if column in source.schema.column_names:
            need(view, column)
            return
        for je in source.json:
            if any(f == column for f, _ in je.fields):
                need(view, je.column)
                return
        raise SpecError(
            f"spec {spec.name!r}: {context} references {column!r}, which is "
            f"neither a column nor an extracted field of view {view!r}")

    for join in spec.joins:
        # probe side resolves in the joined namespace (may be extracted)
        need_ref(join.key, f"join on {join.view!r}")
        need_view_col(join.view, join.key, f"join on {join.view!r}")
    for m in spec.merges:
        need_ref(m.key, f"merge on {m.view!r}")
        # merge views are consumed raw (no clean stage), so the key and
        # payload must be on-disk schema columns
        schema_cols = spec.source(m.view).schema.column_names
        for c in (m.key,) + m.columns:
            if c not in schema_cols:
                raise SpecError(
                    f"spec {spec.name!r}: merge on {m.view!r} references "
                    f"{c!r}, which is not a column of that view")
            need(m.view, c)
    need_ref(spec.label, "label")
    for t in spec.transforms:
        ctx = f"transform {t.name!r}"
        if isinstance(t, Cross):
            need_ref(t.a, ctx)
            need_ref(t.b, ctx)
        elif isinstance(t, (Hash, Bucketize, LogNorm, Scale, Sequence)):
            need_ref(t.column, ctx)
    return {view: tuple(sorted(cols)) for view, cols in needed.items()}


# ----------------------------------------------------------- op constructors
# Each factory closes over resolved spec pieces only (no late binding).
def _single(spec: FeatureSpec, kind):
    found = [o for o in spec.outputs if isinstance(o, kind)]
    if len(found) > 1:
        raise SpecError(
            f"spec {spec.name!r}: at most one {kind.__name__} allowed")
    return found[0] if found else None


def _make_clean_fn(source: Source, out_slot: str):
    schema = source.schema
    json_extracts = source.json

    def clean(**kwargs) -> Dict[str, Columns]:
        cols = kwargs[source.view]
        extracted: Dict[str, ColType] = {}
        for je in json_extracts:
            cols = extract_json_fields(cols, je.column, dict(je.fields))
            extracted.update(dict(je.fields))
        return {out_slot: fill_nulls(cols, schema, extracted=extracted)}

    return clean


def _make_join_fn(spec: FeatureSpec, clean_order: List[str]):
    joins = spec.joins
    base_slot = clean_order[0]
    right_slots = clean_order[1:]

    def join_all(**kwargs) -> Dict[str, Columns]:
        t = kwargs[base_slot]
        for join, slot in zip(joins, right_slots):
            t = hash_join(t, kwargs[slot], key=join.key,
                          right_prefix=join.prefix)
        return {"joined": t}

    return join_all


def _make_extract_text_fn(seq_plans, field_size: int, joined_slot: str):
    def extract_text(**kwargs) -> Dict[str, object]:
        joined = kwargs[joined_slot]
        out: Dict[str, object] = {}
        for t, ctype in seq_plans:
            col = joined[t.column]
            if ctype is ColType.STRING:
                col = F.tokenize_hash(col, field_size=field_size,
                                      ngrams=t.ngrams)
            ids, mask = F.ragged_to_padded(col, max_len=t.max_len)
            out[f"{t.name}_ids"] = ids
            out[f"{t.name}_mask"] = mask
        return out

    return extract_text


def _make_to_device_fn(spec, table, device_cols, col_slot,
                       label_slot, key_slots, joined_slot: str):
    plans: List[Tuple[str, str, np.dtype]] = []
    for name in device_cols:
        rc = table[name]
        dtype = np.float32 if rc.ctype is ColType.FLOAT else np.int64
        plans.append((col_slot[name], name, dtype))
    # label is always emitted as float32 (training target)
    if label_slot not in {s for s, _, _ in plans}:
        plans.append((label_slot, spec.label, np.float32))
    else:
        plans = [(s, n, np.float32 if s == label_slot else d)
                 for s, n, d in plans]
    for key, slot in key_slots.items():
        if slot not in {s for s, _, _ in plans}:
            plans.append((slot, key, np.int64))

    def to_device(**kwargs) -> Dict[str, np.ndarray]:
        joined = kwargs[joined_slot]
        return {slot: np.asarray(joined[name], dtype)
                for slot, name, dtype in plans}

    return to_device


def _make_cross_fn(crosses, col_slot, field_size: int):
    plans = [(t.name, col_slot[t.a], col_slot[t.b]) for t in crosses]

    def cross_features(**kwargs):
        return {name: F.cross_feature(kwargs[a], kwargs[b],
                                      field_size=field_size)
                for name, a, b in plans}

    return cross_features


def _make_dense_fn(dense_feats, col_slot):
    plans = []
    for t in dense_feats:
        if isinstance(t, LogNorm):
            plans.append(("log", col_slot[t.column], None))
        elif isinstance(t, Scale):
            plans.append(("scale", col_slot[t.column], t.denom))
        elif isinstance(t, Bucketize):
            plans.append(("bucket", col_slot[t.column], t.boundaries))
        else:  # precomputed [B] float slot (e.g. a Custom output)
            plans.append(("slot", t, None))

    def dense_features(**kwargs):
        feats = []
        for kind, src, param in plans:
            x = kwargs[src]
            if kind == "log":
                feats.append(F.log_norm(x))
            elif kind == "scale":
                feats.append(jnp.asarray(x, jnp.float32) / param)
            elif kind == "bucket":
                feats.append(F.bucketize(x, param).astype(jnp.float32))
            else:
                feats.append(jnp.asarray(x, jnp.float32))
        return {"dense_feats": jnp.stack(feats, axis=1)}

    return dense_features


def _make_merge_fn(merge, key_slot: str, out_slot: str):
    def merge_fn(**kwargs) -> Dict[str, np.ndarray]:
        probe: Columns = {merge.key: np.asarray(kwargs[key_slot])}
        merged = hash_join(probe, kwargs[merge.view], key=merge.key,
                           right_prefix=merge.prefix)
        return {out_slot: np.stack(
            [merged[f"{merge.prefix}{c}"] for c in merge.columns],
            axis=1).astype(np.float32)}

    return merge_fn


def _make_sparse_pack_fn(sparse_fields, col_slot, field_size: int):
    plans = []
    for t in sparse_fields:
        if isinstance(t, Hash):
            plans.append(("mix" if t.mix else "mod", col_slot[t.column]))
        elif isinstance(t, Cross):
            plans.append(("slot", t.name))
        else:  # precomputed [B] int field hash slot
            plans.append(("mod", t))

    def sparse_ids(**kwargs):
        fields = []
        for kind, src in plans:
            x = kwargs[src]
            if kind == "mix":
                x = F.fmix32(x) % np.uint32(field_size)
            elif kind == "mod":
                x = jnp.asarray(x % field_size, jnp.int32)
            fields.append(x)
        # global sparse id space: field i occupies [i*fs, (i+1)*fs)
        ids = jnp.stack(
            [f.astype(jnp.int32) + i * field_size
             for i, f in enumerate(fields)], axis=1)
        return {"sparse_ids": ids}

    return sparse_ids


def _make_final_fn(has_dense: bool, merge_slots: Tuple[str, ...],
                   has_sparse: bool, seq_names: Tuple[str, ...],
                   label_slot: str):
    def final_batch(**kwargs):
        out: Dict[str, object] = {"batch_label": jnp.asarray(kwargs[label_slot])}
        dense_parts = ([kwargs["dense_feats"]] if has_dense else [])
        dense_parts += [jnp.asarray(kwargs[s]) for s in merge_slots]
        if dense_parts:
            out["batch_dense"] = jnp.concatenate(dense_parts, axis=1)
        if has_sparse:
            out["batch_sparse"] = kwargs["sparse_ids"]
        if seq_names:
            out["batch_seq_ids"] = jnp.concatenate(
                [jnp.asarray(kwargs[f"{n}_ids"]) for n in seq_names], axis=1)
            out["batch_seq_mask"] = jnp.concatenate(
                [jnp.asarray(kwargs[f"{n}_mask"]) for n in seq_names], axis=1)
        return out

    return final_batch
