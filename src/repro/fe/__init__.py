"""Feature-extraction substrate: declarative specs + compiler, column store,
views, joins, FE ops, datagen.

Defining features is declarative: describe sources/transforms/outputs with
:mod:`repro.fe.spec`, then ``featureplan.compile(spec)`` returns a
:class:`~repro.fe.featureplan.FeaturePlan` bundling the lowered OpGraph,
fixed schedule, fused layer executables, output layout, and the per-view
column projection (``required_columns``) for the ingest tier.
"""

from repro.fe.colstore import ColumnStore, Columns, RaggedColumn
from repro.fe.schema import ColType, Column, ViewSchema
from repro.fe.spec import (
    Bucketize,
    Cross,
    Custom,
    DenseOutput,
    FeatureSpec,
    Hash,
    Join,
    JsonExtract,
    LogNorm,
    Merge,
    Scale,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
)
from repro.fe.compiler import OutputLayout, SpecError
from repro.fe.featureplan import FeaturePlan
from repro.fe.specs import get_spec, list_specs

__all__ = [
    "Bucketize",
    "ColType",
    "Column",
    "ColumnStore",
    "Columns",
    "Cross",
    "Custom",
    "DenseOutput",
    "FeaturePlan",
    "FeatureSpec",
    "Hash",
    "Join",
    "JsonExtract",
    "LogNorm",
    "Merge",
    "OutputLayout",
    "RaggedColumn",
    "Scale",
    "Sequence",
    "SequenceOutput",
    "Source",
    "SparseOutput",
    "SpecError",
    "ViewSchema",
    "get_spec",
    "list_specs",
]
