"""Feature-extraction substrate: column store, views, joins, FE ops, datagen."""

from repro.fe.colstore import ColumnStore, Columns, RaggedColumn
from repro.fe.schema import ColType, Column, ViewSchema

__all__ = [
    "ColType",
    "Column",
    "ColumnStore",
    "Columns",
    "RaggedColumn",
    "ViewSchema",
]
