"""Feature-extraction operator library (paper §III "Extract features").

Every new engineered feature is an operator over the joined structured table.
Device ops are pure jnp (traceable, fusable into per-layer meta-kernels);
host ops handle strings. The integer mixing hash is shared with the Pallas
``feature_hash`` kernel and its oracle, so all three agree bit-for-bit.

All hashes land in a fixed feature space of ``2**bits`` slots per field; the
sparse id convention is ``field_offset + (hash % field_size)`` — the classic
"~10^12-dimensional one/multi-hot encoding" of production CTR models.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fe.colstore import Columns, RaggedColumn

# ----------------------------------------------------------------- hashing
# Finalizer of MurmurHash3 (fmix32) — good avalanche, cheap on the VPU
# (mul/xor/shift only). 32-bit arithmetic is used everywhere (jnp default has
# x64 disabled; TPU integer units are 32-bit) so the jnp, numpy, and Pallas
# implementations agree bit-for-bit.
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def fmix32(x: jax.Array) -> jax.Array:
    """MurmurHash3 32-bit finalizer on uint32 arrays (jnp, jittable)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


def fmix32_np(x: np.ndarray) -> np.ndarray:
    """Numpy twin of :func:`fmix32` (host ops + kernel oracle)."""
    with np.errstate(over="ignore"):
        x = np.asarray(x).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
        x = x * _C1
        x = x ^ (x >> np.uint32(13))
        x = x * _C2
        x = x ^ (x >> np.uint32(16))
        return x


def hash_combine(a: jax.Array, b: jax.Array) -> jax.Array:
    """Order-sensitive combine of two id columns (jnp)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    return fmix32(a * _GOLDEN + fmix32(b))


def hash_combine_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        a = np.asarray(a).astype(np.uint32)
        b = np.asarray(b).astype(np.uint32)
        return fmix32_np(a * _GOLDEN + fmix32_np(b))


# Backwards-compatible aliases (64-bit names kept for callers/tests).
fmix64 = fmix32
fmix64_np = fmix32_np


# ----------------------------------------------------------- device FE ops
def cross_feature(a: jax.Array, b: jax.Array, *, field_size: int) -> jax.Array:
    """Feature combination: cross two categorical columns into one id."""
    return (hash_combine(a, b) % np.uint32(field_size)).astype(jnp.int32)


def bucketize(x: jax.Array, boundaries: Sequence[float]) -> jax.Array:
    """Discretize a float column into integer buckets (right-open)."""
    b = jnp.asarray(list(boundaries), dtype=jnp.float32)
    return jnp.searchsorted(b, x.astype(jnp.float32), side="right").astype(jnp.int32)


def log_norm(x: jax.Array) -> jax.Array:
    """log(1+x) transform used for Criteo-style dense counters."""
    return jnp.log1p(jnp.maximum(x.astype(jnp.float32), 0.0))


def sparse_id(hashed: jax.Array, *, field_index: int, field_size: int) -> jax.Array:
    """Map a per-field hash into the global sparse id space (int32-exact)."""
    return (hashed.astype(jnp.int32) % field_size) + field_index * field_size


def clip_seq(ids: jax.Array, *, max_len: int, pad_id: int = 0) -> jax.Array:
    """Truncate/pad a dense [B, L] id matrix to max_len (behavior sequences)."""
    b, l = ids.shape
    if l >= max_len:
        return ids[:, :max_len]
    pad = jnp.full((b, max_len - l), pad_id, ids.dtype)
    return jnp.concatenate([ids, pad], axis=1)


# ------------------------------------------------------------- host FE ops
def tokenize_hash(strings: np.ndarray, *, field_size: int, ngrams: int = 1) -> RaggedColumn:
    """Keyword extraction: split on whitespace, hash (n-gram) tokens.

    This is the paper's "extract keywords with language models" stand-in: a
    host (string) op producing a ragged int column whose per-row lengths vary
    — the workload class Alg. 1's allocator exists for.
    """
    values: List[int] = []
    lengths: List[int] = []
    for s in strings:
        toks = str(s).split()
        grams = [
            " ".join(toks[i: i + n])
            for n in range(1, ngrams + 1)
            for i in range(len(toks) - n + 1)
        ]
        ids = [
            int(fmix32_np(np.uint32(hash(g) & 0xFFFFFFFF)) % np.uint32(field_size))
            for g in grams
        ]
        values.extend(ids)
        lengths.append(len(ids))
    return RaggedColumn(
        values=np.asarray(values, np.int64), lengths=np.asarray(lengths, np.int32)
    )


def ragged_to_padded(col: RaggedColumn, *, max_len: int, pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Densify a ragged column into [B, max_len] + mask for device consumption."""
    b = col.n_rows
    out = np.full((b, max_len), pad_id, np.int64)
    mask = np.zeros((b, max_len), np.float32)
    offs = col.offsets()
    for i in range(b):
        n = min(int(col.lengths[i]), max_len)
        out[i, :n] = col.values[offs[i]: offs[i] + n]
        mask[i, :n] = 1.0
    return out, mask


def ragged_to_bag(col: RaggedColumn) -> Tuple[np.ndarray, np.ndarray]:
    """Ragged column -> (flat ids, segment ids) for EmbeddingBag lookup."""
    segs = np.repeat(np.arange(col.n_rows, dtype=np.int32), col.lengths)
    return col.values.astype(np.int64), segs
