"""Feature-extraction operator library (paper §III "Extract features").

Every new engineered feature is an operator over the joined structured table.
Device ops are pure jnp (traceable, fusable into per-layer meta-kernels);
host ops handle strings. The integer mixing hash is shared with the Pallas
``feature_hash`` kernel and its oracle, so all three agree bit-for-bit.

All hashes land in a fixed feature space of ``2**bits`` slots per field; the
sparse id convention is ``field_offset + (hash % field_size)`` — the classic
"~10^12-dimensional one/multi-hot encoding" of production CTR models.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fe.colstore import RaggedColumn

# ----------------------------------------------------------------- hashing
# Finalizer of MurmurHash3 (fmix32) — good avalanche, cheap on the VPU
# (mul/xor/shift only). 32-bit arithmetic is used everywhere (jnp default has
# x64 disabled; TPU integer units are 32-bit) so the jnp, numpy, and Pallas
# implementations agree bit-for-bit.
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)


def fmix32(x: jax.Array) -> jax.Array:
    """MurmurHash3 32-bit finalizer on uint32 arrays (jnp, jittable)."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


def fmix32_np(x: np.ndarray) -> np.ndarray:
    """Numpy twin of :func:`fmix32` (host ops + kernel oracle)."""
    with np.errstate(over="ignore"):
        x = np.asarray(x).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
        x = x * _C1
        x = x ^ (x >> np.uint32(13))
        x = x * _C2
        x = x ^ (x >> np.uint32(16))
        return x


def hash_combine(a: jax.Array, b: jax.Array) -> jax.Array:
    """Order-sensitive combine of two id columns (jnp)."""
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    return fmix32(a * _GOLDEN + fmix32(b))


def hash_combine_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):
        a = np.asarray(a).astype(np.uint32)
        b = np.asarray(b).astype(np.uint32)
        return fmix32_np(a * _GOLDEN + fmix32_np(b))


# Backwards-compatible aliases (64-bit names kept for callers/tests).
fmix64 = fmix32
fmix64_np = fmix32_np


# ----------------------------------------------------------- device FE ops
def cross_feature(a: jax.Array, b: jax.Array, *, field_size: int) -> jax.Array:
    """Feature combination: cross two categorical columns into one id."""
    return (hash_combine(a, b) % np.uint32(field_size)).astype(jnp.int32)


def bucketize(x: jax.Array, boundaries: Sequence[float]) -> jax.Array:
    """Discretize a float column into integer buckets (right-open)."""
    b = jnp.asarray(list(boundaries), dtype=jnp.float32)
    return jnp.searchsorted(b, x.astype(jnp.float32), side="right").astype(jnp.int32)


def log_norm(x: jax.Array) -> jax.Array:
    """log(1+x) transform used for Criteo-style dense counters."""
    return jnp.log1p(jnp.maximum(x.astype(jnp.float32), 0.0))


def sparse_id(hashed: jax.Array, *, field_index: int, field_size: int) -> jax.Array:
    """Map a per-field hash into the global sparse id space (int32-exact)."""
    return (hashed.astype(jnp.int32) % field_size) + field_index * field_size


def clip_seq(ids: jax.Array, *, max_len: int, pad_id: int = 0) -> jax.Array:
    """Truncate/pad a dense [B, L] id matrix to max_len (behavior sequences)."""
    b, l = ids.shape
    if l >= max_len:
        return ids[:, :max_len]
    pad = jnp.full((b, max_len - l), pad_id, ids.dtype)
    return jnp.concatenate([ids, pad], axis=1)


# ------------------------------------------------------------- host FE ops
#
# Host string ops are the FE hot path's CPU tax: they run once per batch on
# the critical path of the read+extract stage. Both ops below are
# numpy-vectorized single-pass implementations; the per-row loop versions
# are kept as ``*_ref`` oracles (the semantic spec, exercised bit-for-bit
# by hypothesis tests).
#
# Token hashing is deterministic across processes and hosts: token ids are
# derived ONLY from token bytes via :func:`fmix32_np` chains (the builtin
# ``hash()`` is salted per process by PYTHONHASHSEED, so two hosts of one
# training job would disagree on every feature id). The hash spec:
#
# * token hash: ``h = uint32(n_codepoints)``, then for each codepoint
#   ``cp`` (one uint32 word of the token's UTF-32-LE bytes)
#   ``h = fmix32(h * GOLDEN + cp)``;
# * n-gram id: ``g = uint32(n)``, then for each member token hash ``th``
#   (left to right) ``g = fmix32(g * GOLDEN + th)``; id = ``g % field_size``.
#
# Tokenization splits on Unicode whitespace exactly like ``str.split()``;
# NUL (U+0000) is additionally treated as a separator so the fixed-width
# numpy codepoint matrix (NUL-padded) and Python strings agree.

# The codepoints ``str.split()`` treats as whitespace (CPython's
# Py_UNICODE_ISSPACE table: Unicode White_Space plus the 0x1C-0x1F file/
# group/record/unit separators). Verified against ``chr(c).isspace()``
# over the full codepoint range in tests/test_hostops.py.
_WHITESPACE_CODEPOINTS = np.asarray(
    [0x09, 0x0A, 0x0B, 0x0C, 0x0D, 0x1C, 0x1D, 0x1E, 0x1F, 0x20, 0x85,
     0xA0, 0x1680, 0x2000, 0x2001, 0x2002, 0x2003, 0x2004, 0x2005, 0x2006,
     0x2007, 0x2008, 0x2009, 0x200A, 0x2028, 0x2029, 0x202F, 0x205F,
     0x3000],
    np.uint32,
)


def _token_hash_ref(token: str) -> int:
    """Oracle token hash: fmix32 chain over the token's UTF-32-LE words."""
    cps = np.frombuffer(token.encode("utf-32-le"), "<u4").astype(np.uint32)
    with np.errstate(over="ignore"):
        h = np.uint32(len(cps))
        for cp in cps:
            h = fmix32_np(h * _GOLDEN + cp)
    return int(h)


def _gram_hash_ref(token_hashes: Sequence[int], n: int) -> int:
    """Oracle n-gram hash: fmix32 chain over the member token hashes."""
    with np.errstate(over="ignore"):
        g = np.uint32(n)
        for th in token_hashes:
            g = fmix32_np(g * _GOLDEN + np.uint32(th))
    return int(g)


def tokenize_hash_ref(strings: np.ndarray, *, field_size: int,
                      ngrams: int = 1) -> RaggedColumn:
    """Per-row loop reference for :func:`tokenize_hash` (the semantic spec).

    Kept as the oracle the vectorized implementation is property-tested
    against, and as the baseline the host-op benchmark measures speedup
    over.
    """
    values: List[int] = []
    lengths: List[int] = []
    for s in strings:
        toks = str(s).replace("\x00", " ").split()
        tok_hashes = [_token_hash_ref(t) for t in toks]
        ids = [
            _gram_hash_ref(tok_hashes[i: i + n], n) % field_size
            for n in range(1, ngrams + 1)
            for i in range(len(toks) - n + 1)
        ]
        values.extend(ids)
        lengths.append(len(ids))
    return RaggedColumn(
        values=np.asarray(values, np.int64), lengths=np.asarray(lengths, np.int32)
    )


def _token_spans(codes: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Token (start, length, row) triples from a [B, L+1] codepoint matrix.

    The matrix's trailing column must be a separator (0) so token runs never
    cross row boundaries. One vectorized pass: separator mask -> run starts/
    ends via shifted comparisons.
    """
    b, lp1 = codes.shape
    sep = np.isin(codes, _WHITESPACE_CODEPOINTS) | (codes == np.uint32(0))
    tok = ~sep.ravel()
    prev = np.empty_like(tok)
    prev[0] = False
    prev[1:] = tok[:-1]
    starts = np.flatnonzero(tok & ~prev)
    nxt = np.empty_like(tok)
    nxt[-1] = False
    nxt[:-1] = tok[1:]
    ends = np.flatnonzero(tok & ~nxt)
    lens = ends - starts + 1
    rows = starts // lp1
    return starts, lens, rows


def _hash_tokens(flat_codes: np.ndarray, starts: np.ndarray,
                 lens: np.ndarray) -> np.ndarray:
    """Vectorized fmix32 chain over every token's codepoints.

    Column-at-a-time over the longest token: iteration j advances the hash
    of every token still longer than j positions — O(max_token_len) passes
    of bulk vector work instead of a Python loop per token.
    """
    with np.errstate(over="ignore"):
        h = lens.astype(np.uint32)
        alive = np.arange(starts.shape[0])
        for j in range(int(lens.max()) if lens.size else 0):
            alive = alive[lens[alive] > j]
            if not alive.size:
                break
            cps = flat_codes[starts[alive] + j]
            h[alive] = fmix32_np(h[alive] * _GOLDEN + cps)
    return h


def tokenize_hash(strings: np.ndarray, *, field_size: int, ngrams: int = 1) -> RaggedColumn:
    """Keyword extraction: split on whitespace, hash (n-gram) tokens.

    This is the paper's "extract keywords with language models" stand-in: a
    host (string) op producing a ragged int column whose per-row lengths vary
    — the workload class Alg. 1's allocator exists for.

    Vectorized: strings are bulk-converted to a fixed-width codepoint
    matrix, tokenized with one separator-mask pass, hashed column-at-a-time
    (fmix32 chains), and n-gram ids scattered into the output with fancy
    indexing — no per-row Python loop. Bit-identical to
    :func:`tokenize_hash_ref`.
    """
    arr = np.asarray(strings)
    b = int(arr.shape[0])
    empty = RaggedColumn(values=np.zeros((0,), np.int64),
                         lengths=np.zeros((b,), np.int32))
    if b == 0:
        return empty
    if arr.dtype.kind == "U":
        u = arr
    elif arr.dtype.kind in "OS":
        # exact ref semantics: every row through ``str()`` (bytes rows give
        # their "b'...'" repr). numpy's astype(np.str_) would DECODE bytes
        # instead. This normalization is the only per-row Python step; the
        # tokenizer and hashing below stay fully vectorized.
        u = np.asarray([str(x) for x in arr.tolist()], np.str_)
    else:
        u = arr.astype(np.str_)
    width = u.dtype.itemsize // 4
    if width == 0:  # every row is the empty string
        return empty
    # [B, L+1] codepoint matrix; the appended 0 column terminates row runs.
    codes = np.zeros((b, width + 1), np.uint32)
    codes[:, :width] = np.ascontiguousarray(u).view(np.uint32).reshape(b, width)
    starts, tok_lens, tok_rows = _token_spans(codes)
    flat = codes.ravel()
    tok_hashes = _hash_tokens(flat, starts, tok_lens)

    n_tokens = np.bincount(tok_rows, minlength=b)           # tokens per row
    tok_row_start = np.concatenate([[0], np.cumsum(n_tokens)[:-1]])
    # Output ordering (matches the ref): per row, all 1-grams, then all
    # 2-grams, ... Per-row gram counts c_n = max(n_tokens - n + 1, 0).
    gram_counts = [np.maximum(n_tokens - n + 1, 0)
                   for n in range(1, ngrams + 1)]
    lengths = np.sum(gram_counts, axis=0).astype(np.int32)
    row_out_start = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.int64)
    values = np.zeros((int(lengths.sum()),), np.int64)

    t = starts.shape[0]
    block_start = row_out_start.copy()  # start of the current n-gram block
    with np.errstate(over="ignore"):
        for n in range(1, ngrams + 1):
            w = t - n + 1
            if w > 0:
                g = np.full((w,), np.uint32(n))
                for k in range(n):
                    g = fmix32_np(g * _GOLDEN + tok_hashes[k: k + w])
                # window [i, i+n) is a gram iff it stays within one row
                valid = tok_rows[:w] == tok_rows[n - 1: n - 1 + w]
                idx = np.flatnonzero(valid)
                rows = tok_rows[idx]
                pos_in_row = idx - tok_row_start[rows]
                values[block_start[rows] + pos_in_row] = \
                    (g[idx] % np.uint32(field_size)).astype(np.int64)
            block_start += gram_counts[n - 1]
    return RaggedColumn(values=values, lengths=lengths)


def ragged_to_padded_ref(col: RaggedColumn, *, max_len: int,
                         pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row loop reference for :func:`ragged_to_padded` (the oracle)."""
    b = col.n_rows
    out = np.full((b, max_len), pad_id, np.int64)
    mask = np.zeros((b, max_len), np.float32)
    offs = col.offsets()
    for i in range(b):
        n = min(int(col.lengths[i]), max_len)
        out[i, :n] = col.values[offs[i]: offs[i] + n]
        mask[i, :n] = 1.0
    return out, mask


def ragged_to_padded(col: RaggedColumn, *, max_len: int, pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Densify a ragged column into [B, max_len] + mask for device consumption.

    Vectorized single-pass scatter: row/column/source indices for every kept
    element come from ``offsets()`` + prefix sums, then one fancy-indexed
    assignment fills ids and mask. Bit-identical to
    :func:`ragged_to_padded_ref`.
    """
    b = col.n_rows
    out = np.full((b, max_len), pad_id, np.int64)
    mask = np.zeros((b, max_len), np.float32)
    if b == 0 or max_len == 0:
        return out, mask
    keep = np.minimum(col.lengths.astype(np.int64), max_len)
    total = int(keep.sum())
    if total == 0:
        return out, mask
    rows = np.repeat(np.arange(b), keep)
    within = np.arange(total) - np.repeat(np.cumsum(keep) - keep, keep)
    src = np.repeat(col.offsets(), keep) + within
    out[rows, within] = col.values[src]
    mask[rows, within] = 1.0
    return out, mask


def ragged_to_bag(col: RaggedColumn) -> Tuple[np.ndarray, np.ndarray]:
    """Ragged column -> (flat ids, segment ids) for EmbeddingBag lookup."""
    segs = np.repeat(np.arange(col.n_rows, dtype=np.int32), col.lengths)
    return col.values.astype(np.int64), segs
