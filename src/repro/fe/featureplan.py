"""The one-call front door: FeatureSpec -> ready-to-run FeaturePlan.

``compile(spec)`` bundles everything the ten call sites used to wire by
hand — ``build_fe_graph() -> build_schedule() -> compile_layers()`` plus the
output-layout constants — into a single object:

    plan = featureplan.compile(get_spec("ads_ctr"))
    env = plan.run(raw_views)                  # one batch through the FE
    runner = PipelinedRunner(plan.layers, train_step)   # or the full loop
    loader = StreamingLoader(ds, columns=plan.required_columns)  # pushdown

``plan.required_columns`` is the per-view column projection derived from
the spec, fed to ``StreamingLoader``/``ShardReader``/``ColumnStore`` so
columns no transform touches are never decoded from disk.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, MutableMapping, Tuple

from repro.core.metakernel import LayerExecutable, compile_layers, run_layers
from repro.core.opgraph import OpGraph
from repro.core.scheduler import (
    DEFAULT_DEVICE_BYTES_BUDGET,
    Schedule,
    build_schedule,
)
from repro.fe import compiler
from repro.fe.compiler import OutputLayout
from repro.fe.spec import DEFAULT_FIELD_SIZE, FeatureSpec


@dataclasses.dataclass
class ArenaBinding:
    """Zero-copy feed bundle for one plan: everything a runner needs to
    have FE write its ``batch_*`` outputs straight into the staging arena.

    * :attr:`layers` — the plan's executables with the device
      ``final_batch`` assembly dropped (its work moves into the binding);
    * :attr:`binding` — the host assembler targeting claimed arena views
      (:class:`repro.fe.compiler.OutputBinding`);
    * :attr:`layout` — the matching :class:`~repro.core.devicefeed.FeedLayout`.

    Typical wiring (or just ``PipelinedRunner.from_plan(..., feed="arena")``)::

        ab = plan.arena_binding(split_sparse_fields=True)
        runner = PipelinedRunner(ab.layers, step,
                                 device_feed=ab.make_feeder(rows_hint=rows))
    """

    layers: List[LayerExecutable]
    binding: compiler.OutputBinding
    layout: Any  # repro.core.devicefeed.FeedLayout

    def make_feeder(self, *, rows_hint=None, buffers: int = 3, device=None):
        from repro.core.devicefeed import DeviceFeeder
        return DeviceFeeder(self.layout, rows_hint=rows_hint, buffers=buffers,
                            device=device, binding=self.binding)


@dataclasses.dataclass
class FeaturePlan:
    """A compiled feature pipeline: graph + schedule + layers + layout."""

    spec: FeatureSpec
    graph: OpGraph
    schedule: Schedule
    layers: List[LayerExecutable]
    layout: OutputLayout
    required_columns: Dict[str, Tuple[str, ...]]
    device_budget: int

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def output_slots(self) -> Tuple[str, ...]:
        """The ``batch_*`` slots this plan produces, in a stable order."""
        final = self.graph.ops["final_batch"]
        return tuple(sorted(final.outputs))

    def run(self, batch: Mapping[str, Any], *, device=None,
            stats=None) -> Dict[str, Any]:
        """Run one raw batch ``{view: columns}`` through the compiled layers.

        Returns the full slot environment (inputs, intermediates, and the
        ``batch_*`` outputs); use :meth:`outputs` for just the batch dict.
        """
        env: MutableMapping[str, Any] = dict(batch)
        run_layers(self.layers, env, device=device, stats=stats)
        return dict(env)

    def outputs(self, env: Mapping[str, Any]) -> Dict[str, Any]:
        """Filter an environment down to this plan's ``batch_*`` outputs."""
        return {k: env[k] for k in self.output_slots}

    def feed_layout(self, *, split_sparse_fields: bool = False):
        """Static H2D staging layout for this plan's ``batch_*`` outputs.

        Derived from :attr:`layout` at compile time, so a
        :class:`~repro.core.devicefeed.DeviceFeeder` can size its staging
        arenas before the first batch arrives:

            feeder = DeviceFeeder(plan.feed_layout(), rows_hint=batch_rows)
            runner = PipelinedRunner(plan.layers, step, device_feed=feeder)

        ``split_sparse_fields=True`` replaces the packed ``batch_sparse``
        slot with one rank-1 ``batch_field_NN`` id vector per sparse field —
        the shape per-table embedding consumers feed — so the arena's block
        allocation (Alg. 1) coalesces the many per-field transfers into one
        planned staging pass. Total staged bytes are unchanged, and the
        feeder derives the field columns from a packed ``batch_sparse``
        automatically, so the split layout works on unmodified FE output.
        """
        from repro.core.devicefeed import FeedLayout, SlotSpec
        emitted = set(self.output_slots)
        slots = []
        for name, width, dtype, rank1 in self.layout.feed_slots():
            if name not in emitted:
                continue
            if name == "batch_sparse" and split_sparse_fields:
                slots.extend(SlotSpec(compiler.field_slot(i), 1, dtype,
                                      rank1=True)
                             for i in range(width))
            else:
                slots.append(SlotSpec(name, width, dtype, rank1=rank1))
        return FeedLayout(slots=tuple(slots))

    def arena_binding(self, *, split_sparse_fields: bool = False,
                      coalesce: bool = True) -> ArenaBinding:
        """Compile this plan's zero-copy feed form (see :class:`ArenaBinding`).

        The returned bundle's layers run everything up to (and excluding)
        the device ``final_batch`` assembly; the binding assembles the
        ``batch_*`` outputs host-side **directly into arena views** a
        :class:`~repro.core.devicefeed.DeviceFeeder` claims per batch, so
        the per-batch env->arena memcpy of the copy path disappears
        (``FeedStats.copies_elided`` counts it). Outputs are bit-identical
        to :attr:`layers` + ``feeder.stage(env)``.
        """
        binding = compiler.output_binding(
            self.spec, split_sparse_fields=split_sparse_fields)
        return ArenaBinding(
            layers=compile_layers(self.schedule, coalesce=coalesce,
                                  drop=(binding.final_op,)),
            binding=binding,
            layout=self.feed_layout(split_sparse_fields=split_sparse_fields),
        )

    def model_feed(self, cfg, *, split_sparse_fields: bool = False,
                   rows_hint=None, **kw):
        """Compile the stage->train adaptation plan for this plan x ``cfg``
        (see :mod:`repro.fe.modelfeed`): a :class:`~repro.fe.modelfeed.
        ModelFeed` whose ``apply`` is traced inside the train step's jit,
        with the sparse working-set capacity tuned from ``rows_hint``."""
        from repro.fe import modelfeed
        return modelfeed.compile(self, cfg,
                                 split_sparse_fields=split_sparse_fields,
                                 rows_hint=rows_hint, **kw)

    def summary(self) -> str:
        s = self.schedule
        lay = self.layout
        return (f"plan {self.spec.name!r}: {s.n_layers} layers "
                f"({len(s.superlayers)} super-layers), "
                f"{s.n_coalesced_dispatches} coalesced device dispatches "
                f"(vs {s.n_device_dispatches} per-layer, "
                f"{s.n_unfused_dispatches} unfused); "
                f"outputs: {lay.n_sparse_fields} sparse fields x "
                f"{lay.field_size} slots, {lay.n_dense_feats} dense, "
                f"seq_len {lay.seq_len}")


def compile(spec: FeatureSpec, *,
            device_budget: int = DEFAULT_DEVICE_BYTES_BUDGET,
            field_size: int = DEFAULT_FIELD_SIZE) -> FeaturePlan:
    """Lower ``spec`` and build its fixed schedule + fused layer executables."""
    graph = compiler.lower(spec, field_size=field_size)
    schedule = build_schedule(graph, device_bytes_budget=device_budget)
    return FeaturePlan(
        spec=spec,
        graph=graph,
        schedule=schedule,
        layers=compile_layers(schedule),
        layout=compiler.output_layout(spec, field_size=field_size),
        required_columns=compiler.required_columns(spec),
        device_budget=device_budget,
    )
