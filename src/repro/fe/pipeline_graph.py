"""The standard ads FE pipeline as an operator graph (paper Fig. 3).

DEPRECATED surface: the pipeline is now *defined* declaratively in
``repro.fe.specs.ads_ctr`` and lowered by ``repro.fe.compiler``;
:func:`build_fe_graph` is kept as a thin compat wrapper that compiles that
spec. Prefer the one-call front door::

    from repro.fe import featureplan
    from repro.fe.specs import get_spec
    plan = featureplan.compile(get_spec("ads_ctr"))

:func:`build_fe_graph_legacy` is the original hand-wired builder, retained
so ``tests/test_spec.py`` can assert the compiled spec is
schedule-equivalent (same layers, placements, outputs).

Placements match the paper either way:

* clean / json-extract / tokenize / join — HOST (string + dictionary work),
* hash-cross / bucketize / lognorm / sparse-id mapping — DEVICE, fused into
  per-layer meta-kernels by the scheduler.

The graph's external inputs are the per-batch raw view slices, so the same
graph runs under both the pipelined and the staged executor.
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import Device, OpCost, Operator, OpGraph
from repro.fe import ops as F
from repro.fe.colstore import Columns
from repro.fe.datagen import AD_INVENTORY, IMPRESSIONS, USER_PROFILE
from repro.fe.join import hash_join, merge_on_instance
from repro.fe.schema import ColType
from repro.fe.views import extract_json_fields, fill_nulls

# Feature space layout: per-field hash sizes (scaled-down production layout).
FIELD_SIZE = 1 << 20
N_CROSS = 4          # engineered cross features
SEQ_LEN = 16         # padded interest-sequence length
DENSE_DIM = 6        # dense features after extraction


def build_fe_graph(*, field_size: int = FIELD_SIZE) -> OpGraph:
    """Compat wrapper: compile the declarative ``ads_ctr`` spec."""
    from repro.fe import compiler
    from repro.fe.specs import ads_ctr

    return compiler.lower(ads_ctr.build_spec(), field_size=field_size)


def build_fe_graph_legacy(*, field_size: int = FIELD_SIZE) -> OpGraph:
    """The original hand-wired graph (reference for equivalence tests)."""
    g = OpGraph()
    g.mark_external("impressions", "user_profile", "ad_inventory", "basic_features")

    # ---------------------------------------------------------- clean (HOST)
    def clean_impressions(impressions: Columns) -> Dict[str, Columns]:
        ctx_fields = {"slot": ColType.INT, "device": ColType.INT,
                      "geo": ColType.INT}
        cols = extract_json_fields(impressions, "context_json", ctx_fields)
        return {"imp_clean": fill_nulls(cols, IMPRESSIONS,
                                        extracted=ctx_fields)}

    g.add(Operator("clean_impressions", clean_impressions,
                   ("impressions",), ("imp_clean",), device=Device.HOST))

    def clean_user(user_profile: Columns) -> Dict[str, Columns]:
        return {"user_clean": fill_nulls(user_profile, USER_PROFILE)}

    g.add(Operator("clean_user", clean_user, ("user_profile",), ("user_clean",),
                   device=Device.HOST))

    def clean_ads(ad_inventory: Columns) -> Dict[str, Columns]:
        return {"ads_clean": fill_nulls(ad_inventory, AD_INVENTORY)}

    g.add(Operator("clean_ads", clean_ads, ("ad_inventory",), ("ads_clean",),
                   device=Device.HOST))

    # ----------------------------------------------------------- join (HOST)
    # "large table joins (which corresponds to a large dictionary lookup)"
    def join_all(imp_clean: Columns, user_clean: Columns, ads_clean: Columns) -> Dict[str, Columns]:
        t = hash_join(imp_clean, user_clean, key="user_id", right_prefix="u_")
        t = hash_join(t, ads_clean, key="ad_id", right_prefix="a_")
        return {"joined": t}

    g.add(Operator("join_views", join_all,
                   ("imp_clean", "user_clean", "ads_clean"), ("joined",),
                   device=Device.HOST,
                   cost=OpCost(bytes_touched=8 * 1024**3)))

    # ------------------------------------------- host-side string extraction
    def extract_text(joined: Columns) -> Dict[str, object]:
        q = F.tokenize_hash(joined["u_query_text"], field_size=FIELD_SIZE, ngrams=2)
        t = F.tokenize_hash(joined["a_title_text"], field_size=FIELD_SIZE, ngrams=2)
        q_ids, q_mask = F.ragged_to_padded(q, max_len=SEQ_LEN)
        t_ids, t_mask = F.ragged_to_padded(t, max_len=SEQ_LEN)
        iv, im = F.ragged_to_padded(joined["u_interests"], max_len=SEQ_LEN)
        return {
            "query_ids": q_ids, "query_mask": q_mask,
            "title_ids": t_ids, "title_mask": t_mask,
            "interest_ids": iv, "interest_mask": im,
        }

    g.add(Operator("extract_text", extract_text, ("joined",),
                   ("query_ids", "query_mask", "title_ids", "title_mask",
                    "interest_ids", "interest_mask"),
                   device=Device.HOST))

    # --------------------------------- numeric columns to device (H2D stage)
    def to_device_cols(joined: Columns) -> Dict[str, np.ndarray]:
        return {
            "user_id_col": np.asarray(joined["user_id"], np.int64),
            "ad_id_col": np.asarray(joined["ad_id"], np.int64),
            "advertiser_col": np.asarray(joined["a_advertiser_id"], np.int64),
            "slot_col": np.asarray(joined["slot"], np.int64),
            "geo_col": np.asarray(joined["geo"], np.int64),
            "age_col": np.asarray(joined["u_age_bucket"], np.int64),
            "hour_col": np.asarray(joined["hour"], np.int64),
            "dwell_col": np.asarray(joined["dwell_time"], np.float32),
            "bid_col": np.asarray(joined["a_bid_price"], np.float32),
            "label_col": np.asarray(joined["label"], np.float32),
            "instance_col": np.asarray(joined["instance_id"], np.int64),
        }

    g.add(Operator("to_device", to_device_cols, ("joined",),
                   ("user_id_col", "ad_id_col", "advertiser_col", "slot_col",
                    "geo_col", "age_col", "hour_col", "dwell_col", "bid_col",
                    "label_col", "instance_col"),
                   device=Device.HOST))

    # ------------------------------------------------- extract (DEVICE, jnp)
    def cross_features(user_id_col, ad_id_col, advertiser_col, slot_col, geo_col):
        return {
            "x_user_ad": F.cross_feature(user_id_col, ad_id_col, field_size=field_size),
            "x_user_adv": F.cross_feature(user_id_col, advertiser_col, field_size=field_size),
            "x_slot_geo": F.cross_feature(slot_col, geo_col, field_size=field_size),
            "x_ad_slot": F.cross_feature(ad_id_col, slot_col, field_size=field_size),
        }

    g.add(Operator("cross_features", cross_features,
                   ("user_id_col", "ad_id_col", "advertiser_col", "slot_col", "geo_col"),
                   ("x_user_ad", "x_user_adv", "x_slot_geo", "x_ad_slot"),
                   device=Device.DEVICE))

    def dense_features(dwell_col, bid_col, hour_col, age_col):
        return {
            "dense_feats": jnp.stack(
                [
                    F.log_norm(dwell_col),
                    F.log_norm(bid_col),
                    jnp.asarray(hour_col, jnp.float32) / 24.0,
                    jnp.asarray(age_col, jnp.float32) / 10.0,
                    F.bucketize(dwell_col, (0.5, 1, 2, 4, 8, 16)).astype(jnp.float32),
                    F.bucketize(bid_col, (0.1, 0.3, 1, 3)).astype(jnp.float32),
                ],
                axis=1,
            )
        }

    g.add(Operator("dense_features", dense_features,
                   ("dwell_col", "bid_col", "hour_col", "age_col"),
                   ("dense_feats",), device=Device.DEVICE))

    def sparse_ids(x_user_ad, x_user_adv, x_slot_geo, x_ad_slot,
                   user_id_col, ad_id_col, slot_col, geo_col):
        fields = [x_user_ad, x_user_adv, x_slot_geo, x_ad_slot,
                  jnp.asarray(user_id_col % field_size, jnp.int32),
                  jnp.asarray(ad_id_col % field_size, jnp.int32),
                  jnp.asarray(slot_col % field_size, jnp.int32),
                  jnp.asarray(geo_col % field_size, jnp.int32)]
        # global sparse id space: field i occupies [i*field_size, (i+1)*field_size)
        # (8 fields x 2^20 slots < 2^31, so int32 ids are exact)
        ids = jnp.stack(
            [f.astype(jnp.int32) + i * field_size for i, f in enumerate(fields)], axis=1
        )
        return {"sparse_ids": ids}

    g.add(Operator("sparse_ids", sparse_ids,
                   ("x_user_ad", "x_user_adv", "x_slot_geo", "x_ad_slot",
                    "user_id_col", "ad_id_col", "slot_col", "geo_col"),
                   ("sparse_ids",), device=Device.DEVICE))

    # ------------------------------------------------------ merge (HOST+DEV)
    def merge_basic(basic_features: Columns, instance_col) -> Dict[str, np.ndarray]:
        # join basic features on instance id (paper: "join operation on the
        # instance id"); basic table is already instance-aligned per chunk but
        # we do the real dictionary join for faithfulness.
        probe: Columns = {"instance_id": np.asarray(instance_col)}
        merged = merge_on_instance(probe, basic_features)
        return {
            "basic_dense": np.stack(
                [merged["basic_ctr_7d"], merged["basic_user_click_cnt"],
                 merged["basic_ad_show_cnt"]], axis=1
            ).astype(np.float32)
        }

    g.add(Operator("merge_basic", merge_basic, ("basic_features", "instance_col"),
                   ("basic_dense",), device=Device.HOST,
                   cost=OpCost(bytes_touched=4 * 1024**3)))

    def final_batch(dense_feats, basic_dense, sparse_ids, interest_ids, interest_mask,
                    query_ids, query_mask, title_ids, title_mask, label_col):
        return {
            "batch_dense": jnp.concatenate(
                [dense_feats, jnp.asarray(basic_dense)], axis=1),
            "batch_sparse": sparse_ids,
            "batch_seq_ids": jnp.concatenate(
                [jnp.asarray(interest_ids), jnp.asarray(query_ids), jnp.asarray(title_ids)],
                axis=1),
            "batch_seq_mask": jnp.concatenate(
                [jnp.asarray(interest_mask), jnp.asarray(query_mask), jnp.asarray(title_mask)],
                axis=1),
            "batch_label": jnp.asarray(label_col),
        }

    g.add(Operator("final_batch", final_batch,
                   ("dense_feats", "basic_dense", "sparse_ids",
                    "interest_ids", "interest_mask", "query_ids", "query_mask",
                    "title_ids", "title_mask", "label_col"),
                   ("batch_dense", "batch_sparse", "batch_seq_ids",
                    "batch_seq_mask", "batch_label"),
                   device=Device.DEVICE))
    return g


N_SPARSE_FIELDS = 8
N_DENSE_FEATS = DENSE_DIM + 3  # extracted + basic
