"""Column schemas for views and basic features (paper §III).

A *view* is a collection of raw data logs from one source (user purchase
history, query logs, ad inventory...). After the cleaning stage every column
has a non-empty, simple type: integer, float, or string (paper §III "Clean
views"). Strings never reach the device — the host stage hashes/parses them;
device columns are always numeric.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Tuple

import numpy as np


class ColType(enum.Enum):
    INT = "int"        # int64 ids/keys
    FLOAT = "float"    # float32 measures
    STRING = "string"  # host-only; object ndarray of str
    # Ragged int list (e.g. multi-hot feature ids, tokenized query); stored as
    # (values, row_lengths) pair of columns — the variable-length case that
    # motivates Alg. 1.
    INT_LIST = "int_list"

    @property
    def np_dtype(self):
        return {
            ColType.INT: np.int64,
            ColType.FLOAT: np.float32,
            ColType.STRING: object,
            ColType.INT_LIST: np.int64,
        }[self]


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    ctype: ColType
    nullable: bool = True
    # Fill used by the cleaning stage for nulls (paper: "fill the null values").
    null_fill: object = None

    def default_fill(self):
        if self.null_fill is not None:
            return self.null_fill
        return {
            ColType.INT: np.int64(0),
            ColType.FLOAT: np.float32(0.0),
            ColType.STRING: "",
            ColType.INT_LIST: np.int64(0),
        }[self.ctype]


@dataclasses.dataclass(frozen=True)
class ViewSchema:
    name: str
    key: str                     # join key column (user_id, ad_id, ...)
    columns: Tuple[Column, ...]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate columns in view {self.name!r}")
        if self.key not in names:
            raise ValueError(f"join key {self.key!r} not a column of view {self.name!r}")

    def column(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"view {self.name!r} has no column {name!r}")

    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.columns)
