"""Columnar chunk store (paper §III "Read views and basic features").

The paper cuts network I/O by (a) materializing frequently-used features as
*basic features* for reuse and (b) storing logs column-wise so a job reads
only the columns it needs. This module is that column store: each chunk of a
view is a directory with one ``.npy`` file per column plus a tiny manifest,
so ``read_columns`` touches exactly the requested columns' bytes.

Ragged INT_LIST columns are stored as two files (``<col>.values.npy`` and
``<col>.lengths.npy``).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Mapping, Sequence

import numpy as np


MANIFEST = "manifest.json"


@dataclasses.dataclass
class RaggedColumn:
    """Host-side ragged column: values concatenated, per-row lengths."""

    values: np.ndarray   # int64[sum(lengths)]
    lengths: np.ndarray  # int32[n_rows]

    @property
    def n_rows(self) -> int:
        return int(self.lengths.shape[0])

    def offsets(self) -> np.ndarray:
        """Exclusive prefix sum of lengths (row start offsets) — Alg. 1 shape."""
        return np.concatenate([[0], np.cumsum(self.lengths)[:-1]]).astype(np.int64)

    def row(self, i: int) -> np.ndarray:
        off = self.offsets()
        return self.values[off[i]: off[i] + self.lengths[i]]

    def take(self, idx: np.ndarray) -> "RaggedColumn":
        off = self.offsets()
        parts = [self.values[off[i]: off[i] + self.lengths[i]] for i in idx]
        lengths = self.lengths[idx]
        values = np.concatenate(parts) if parts else np.zeros((0,), np.int64)
        return RaggedColumn(values=values, lengths=lengths)


Columns = Dict[str, object]  # str -> np.ndarray | RaggedColumn


class ColumnStore:
    """Chunked column-wise storage rooted at a directory."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # ----------------------------------------------------------------- write
    def write_chunk(self, view: str, chunk_id: int, columns: Mapping[str, object]) -> str:
        cdir = self._chunk_dir(view, chunk_id)
        os.makedirs(cdir, exist_ok=True)
        manifest: Dict[str, Dict] = {}
        n_rows = None
        for name, col in columns.items():
            if isinstance(col, RaggedColumn):
                np.save(os.path.join(cdir, f"{name}.values.npy"), col.values)
                np.save(os.path.join(cdir, f"{name}.lengths.npy"), col.lengths)
                manifest[name] = {"kind": "ragged", "rows": col.n_rows}
                rows = col.n_rows
            else:
                arr = np.asarray(col)
                if arr.dtype == object:
                    # Strings: store as encoded bytes with per-row lengths
                    # (host-only column).
                    enc = [str(s).encode("utf-8") for s in arr]
                    lengths = np.array([len(b) for b in enc], np.int32)
                    values = np.frombuffer(b"".join(enc), dtype=np.uint8).copy()
                    np.save(os.path.join(cdir, f"{name}.values.npy"), values)
                    np.save(os.path.join(cdir, f"{name}.lengths.npy"), lengths)
                    manifest[name] = {"kind": "string", "rows": int(arr.shape[0])}
                    rows = int(arr.shape[0])
                else:
                    np.save(os.path.join(cdir, f"{name}.npy"), arr)
                    manifest[name] = {"kind": "dense", "rows": int(arr.shape[0])}
                    rows = int(arr.shape[0])
            if n_rows is None:
                n_rows = rows
            elif n_rows != rows:
                raise ValueError(f"column {name!r} row count {rows} != {n_rows}")
        with open(os.path.join(cdir, MANIFEST), "w") as f:
            json.dump({"columns": manifest, "n_rows": n_rows}, f)
        return cdir

    # ------------------------------------------------------------------ read
    def chunks(self, view: str) -> List[int]:
        vdir = os.path.join(self.root, view)
        if not os.path.isdir(vdir):
            return []
        out = []
        for d in os.listdir(vdir):
            if d.startswith("chunk_"):
                out.append(int(d.split("_", 1)[1]))
        return sorted(out)

    def read_columns(self, view: str, chunk_id: int, names: Sequence[str]) -> Columns:
        """Read ONLY the requested columns (the column-store I/O saving)."""
        cdir = self._chunk_dir(view, chunk_id)
        with open(os.path.join(cdir, MANIFEST)) as f:
            manifest = json.load(f)["columns"]
        out: Columns = {}
        for name in names:
            meta = manifest.get(name)
            if meta is None:
                raise KeyError(f"view {view!r} chunk {chunk_id} has no column {name!r}")
            if meta["kind"] == "dense":
                out[name] = np.load(os.path.join(cdir, f"{name}.npy"))
            elif meta["kind"] == "ragged":
                out[name] = RaggedColumn(
                    values=np.load(os.path.join(cdir, f"{name}.values.npy")),
                    lengths=np.load(os.path.join(cdir, f"{name}.lengths.npy")),
                )
            elif meta["kind"] == "string":
                values = np.load(os.path.join(cdir, f"{name}.values.npy"))
                lengths = np.load(os.path.join(cdir, f"{name}.lengths.npy"))
                offs = np.concatenate([[0], np.cumsum(lengths)])
                buf = values.tobytes()
                out[name] = np.array(
                    [buf[offs[i]: offs[i + 1]].decode("utf-8") for i in range(len(lengths))],
                    dtype=object,
                )
            else:  # pragma: no cover
                raise ValueError(f"unknown column kind {meta['kind']!r}")
        return out

    def column_bytes(self, view: str, chunk_id: int, names: Sequence[str]) -> int:
        """Bytes that reading these columns costs (for the I/O accounting)."""
        cdir = self._chunk_dir(view, chunk_id)
        total = 0
        for name in names:
            for suffix in (".npy", ".values.npy", ".lengths.npy"):
                p = os.path.join(cdir, f"{name}{suffix}")
                if os.path.exists(p):
                    total += os.path.getsize(p)
        return total

    def n_rows(self, view: str, chunk_id: int) -> int:
        with open(os.path.join(self._chunk_dir(view, chunk_id), MANIFEST)) as f:
            return int(json.load(f)["n_rows"])

    def _chunk_dir(self, view: str, chunk_id: int) -> str:
        return os.path.join(self.root, view, f"chunk_{chunk_id:06d}")
