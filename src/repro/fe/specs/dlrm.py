"""DLRM-style dense + multi-hot scenario (matches ``configs/dlrm_mlperf.py``).

Same raw ads views, different shape: 13 dense features (10 engineered +
3 merged basic features) and 26 sparse fields (10 raw categorical hashes +
16 crosses) — the MLPerf DLRM layout — plus the interest list as a
multi-hot bag. No free-text columns are touched, so the loader projection
skips decoding ``query_text``/``title_text`` entirely.
"""

from __future__ import annotations

from repro.fe.datagen import AD_INVENTORY, BASIC_FEATURES, IMPRESSIONS, USER_PROFILE
from repro.fe.schema import ColType
from repro.fe.spec import (
    Bucketize,
    Cross,
    DenseOutput,
    FeatureSpec,
    Hash,
    Join,
    JsonExtract,
    LogNorm,
    Merge,
    Scale,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
)

BAG_LEN = 16

_CROSSES = (
    ("x_user_ad", "user_id", "ad_id"),
    ("x_user_adv", "user_id", "a_advertiser_id"),
    ("x_user_camp", "user_id", "a_campaign_id"),
    ("x_user_slot", "user_id", "slot"),
    ("x_user_geo", "user_id", "geo"),
    ("x_user_dev", "user_id", "device"),
    ("x_user_hour", "user_id", "hour"),
    ("x_ad_slot", "ad_id", "slot"),
    ("x_ad_geo", "ad_id", "geo"),
    ("x_ad_dev", "ad_id", "device"),
    ("x_ad_hour", "ad_id", "hour"),
    ("x_adv_slot", "a_advertiser_id", "slot"),
    ("x_adv_geo", "a_advertiser_id", "geo"),
    ("x_camp_slot", "a_campaign_id", "slot"),
    ("x_slot_geo", "slot", "geo"),
    ("x_geo_dev", "geo", "device"),
)

_HASHES = (
    ("f_user", "user_id", True),     # mixed: raw ids correlate with fields
    ("f_ad", "ad_id", True),
    ("f_adv", "a_advertiser_id", False),
    ("f_camp", "a_campaign_id", False),
    ("f_slot", "slot", False),
    ("f_geo", "geo", False),
    ("f_dev", "device", False),
    ("f_hour", "hour", False),
    ("f_age", "u_age_bucket", False),
    ("f_gender", "u_gender", False),
)


def build_spec() -> FeatureSpec:
    return FeatureSpec(
        name="dlrm",
        base="impressions",
        sources=(
            Source("impressions", IMPRESSIONS, json=(
                JsonExtract("context_json", (("slot", ColType.INT),
                                             ("device", ColType.INT),
                                             ("geo", ColType.INT))),
            )),
            Source("user_profile", USER_PROFILE),
            Source("ad_inventory", AD_INVENTORY),
            Source("basic_features", BASIC_FEATURES),
        ),
        joins=(
            Join("user_profile", key="user_id", prefix="u_"),
            Join("ad_inventory", key="ad_id", prefix="a_"),
        ),
        merges=(
            Merge("basic_features",
                  columns=("ctr_7d", "user_click_cnt", "ad_show_cnt")),
        ),
        transforms=(
            *(Cross(name, a, b) for name, a, b in _CROSSES),
            *(Hash(name, col, mix=mix) for name, col, mix in _HASHES),
            LogNorm("d_dwell", "dwell_time"),
            LogNorm("d_bid", "a_bid_price"),
            Scale("d_hour", "hour", denom=24.0),
            Scale("d_age", "u_age_bucket", denom=10.0),
            Scale("d_gender", "u_gender", denom=3.0),
            Scale("d_slot", "slot", denom=16.0),
            Scale("d_dev", "device", denom=4.0),
            Bucketize("d_dwell_b", "dwell_time", (0.5, 1, 2, 4, 8, 16)),
            Bucketize("d_bid_b", "a_bid_price", (0.1, 0.3, 1, 3)),
            Bucketize("d_hour_b", "hour", (6, 12, 18)),
            Sequence("interest_bag", "u_interests", max_len=BAG_LEN),
        ),
        outputs=(
            # 10 engineered + 3 merged basic = 13 dense (dlrm-mlperf n_dense)
            DenseOutput(("d_dwell", "d_bid", "d_hour", "d_age", "d_gender",
                         "d_slot", "d_dev", "d_dwell_b", "d_bid_b",
                         "d_hour_b")),
            # 26 sparse fields (dlrm-mlperf n_sparse)
            SparseOutput(tuple(n for n, _, _ in _CROSSES)
                         + tuple(n for n, _, _ in _HASHES)),
            SequenceOutput(("interest_bag",)),   # the multi-hot bag
        ),
        label="label",
    )
