"""Bundled feature-engineering scenario presets.

Each module defines one :class:`~repro.fe.spec.FeatureSpec` over the
synthetic ads views (``repro.fe.datagen``); all compile through
``repro.fe.featureplan.compile`` into ready-to-run plans:

* ``ads_ctr`` — the paper's standard ads pipeline (the legacy
  ``build_fe_graph()`` layout: 8 sparse fields, 9 dense, 3x16 sequences);
* ``dlrm``    — DLRM-style dense + multi-hot shape matching
  ``configs/dlrm_mlperf.py`` (13 dense, 26 sparse fields, interest bag);
* ``bst``     — behavior-sequence shape matching ``configs/bst.py``
  (4 sparse fields, a 20-step behavior sequence, no dense block).
"""

from __future__ import annotations

from typing import Callable, Dict, List

from repro.fe.spec import FeatureSpec
from repro.fe.specs import ads_ctr, bst, dlrm

_REGISTRY: Dict[str, Callable[[], FeatureSpec]] = {
    "ads_ctr": ads_ctr.build_spec,
    "dlrm": dlrm.build_spec,
    "bst": bst.build_spec,
}


def list_specs() -> List[str]:
    return sorted(_REGISTRY)


def get_spec(name: str) -> FeatureSpec:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown feature spec {name!r} (available: {list_specs()})"
        ) from None


__all__ = ["get_spec", "list_specs"]
