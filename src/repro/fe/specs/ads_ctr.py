"""The standard ads CTR pipeline as a declarative spec (paper Fig. 3).

This is the spec form of the original hand-wired ``build_fe_graph()``:
clean the three views, join on user/ad ids, extract JSON context, cross the
id columns, normalize the counters, tokenize the text fields, and merge the
materialized basic features — with identical layer structure, placements,
and output layout (8 sparse fields, 6+3 dense features, 3x16 sequence
block). ``tests/test_spec.py`` asserts schedule equivalence against the
legacy builder.
"""

from __future__ import annotations

from repro.fe.datagen import AD_INVENTORY, BASIC_FEATURES, IMPRESSIONS, USER_PROFILE
from repro.fe.schema import ColType
from repro.fe.spec import (
    Bucketize,
    Cross,
    DenseOutput,
    FeatureSpec,
    Hash,
    Join,
    JsonExtract,
    LogNorm,
    Merge,
    Scale,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
)

SEQ_LEN = 16


def build_spec() -> FeatureSpec:
    return FeatureSpec(
        name="ads_ctr",
        base="impressions",
        sources=(
            Source("impressions", IMPRESSIONS, json=(
                JsonExtract("context_json", (("slot", ColType.INT),
                                             ("device", ColType.INT),
                                             ("geo", ColType.INT))),
            )),
            Source("user_profile", USER_PROFILE),
            Source("ad_inventory", AD_INVENTORY),
            Source("basic_features", BASIC_FEATURES),
        ),
        joins=(
            Join("user_profile", key="user_id", prefix="u_"),
            Join("ad_inventory", key="ad_id", prefix="a_"),
        ),
        merges=(
            Merge("basic_features",
                  columns=("ctr_7d", "user_click_cnt", "ad_show_cnt")),
        ),
        transforms=(
            # engineered crosses (feature combination)
            Cross("x_user_ad", "user_id", "ad_id"),
            Cross("x_user_adv", "user_id", "a_advertiser_id"),
            Cross("x_slot_geo", "slot", "geo"),
            Cross("x_ad_slot", "ad_id", "slot"),
            # raw categorical fields
            Hash("f_user", "user_id"),
            Hash("f_ad", "ad_id"),
            Hash("f_slot", "slot"),
            Hash("f_geo", "geo"),
            # dense features
            LogNorm("d_dwell", "dwell_time"),
            LogNorm("d_bid", "a_bid_price"),
            Scale("d_hour", "hour", denom=24.0),
            Scale("d_age", "u_age_bucket", denom=10.0),
            Bucketize("d_dwell_b", "dwell_time", (0.5, 1, 2, 4, 8, 16)),
            Bucketize("d_bid_b", "a_bid_price", (0.1, 0.3, 1, 3)),
            # text / behavior sequences
            Sequence("interest", "u_interests", max_len=SEQ_LEN),
            Sequence("query", "u_query_text", max_len=SEQ_LEN, ngrams=2),
            Sequence("title", "a_title_text", max_len=SEQ_LEN, ngrams=2),
        ),
        outputs=(
            DenseOutput(("d_dwell", "d_bid", "d_hour", "d_age",
                         "d_dwell_b", "d_bid_b")),
            SparseOutput(("x_user_ad", "x_user_adv", "x_slot_geo",
                          "x_ad_slot", "f_user", "f_ad", "f_slot", "f_geo")),
            SequenceOutput(("interest", "query", "title")),
        ),
        label="label",
    )
