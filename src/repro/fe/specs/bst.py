"""Behavior-sequence scenario (matches ``configs/bst.py``).

The BST shape: four categorical fields — item (the target ad), user,
category (advertiser), context slot — plus the user's interest list as a
20-step behavior sequence for the transformer block. No dense features and
no basic-feature merge, so the loader projection drops the whole
``basic_features`` table and every text/counter column.
"""

from __future__ import annotations

from repro.fe.datagen import AD_INVENTORY, IMPRESSIONS, USER_PROFILE
from repro.fe.schema import ColType
from repro.fe.spec import (
    FeatureSpec,
    Hash,
    Join,
    JsonExtract,
    Sequence,
    SequenceOutput,
    Source,
    SparseOutput,
)

SEQ_LEN = 20   # bst config seq_len


def build_spec() -> FeatureSpec:
    return FeatureSpec(
        name="bst",
        base="impressions",
        sources=(
            Source("impressions", IMPRESSIONS, json=(
                JsonExtract("context_json", (("slot", ColType.INT),)),
            )),
            Source("user_profile", USER_PROFILE),
            Source("ad_inventory", AD_INVENTORY),
        ),
        joins=(
            Join("user_profile", key="user_id", prefix="u_"),
            Join("ad_inventory", key="ad_id", prefix="a_"),
        ),
        transforms=(
            Hash("f_item", "ad_id", mix=True),
            Hash("f_user", "user_id", mix=True),
            Hash("f_category", "a_advertiser_id"),
            Hash("f_slot", "slot"),
            Sequence("behavior", "u_interests", max_len=SEQ_LEN),
        ),
        outputs=(
            SparseOutput(("f_item", "f_user", "f_category", "f_slot")),
            SequenceOutput(("behavior",)),
        ),
        label="label",
    )
