"""Multi-view join (paper §III "Join views" / "Merge features").

Joins are the memory-intensive operators of the pipeline — "large table joins
(which corresponds to a large dictionary lookup)" — so the scheduler places
them on HOST (CPU workers) by default, matching the paper.

``hash_join`` performs a left join of a probe table against one build view
keyed on a shared column. ``merge_on_instance`` is the final merge of
extracted features with basic features on ``instance_id``.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from repro.fe.colstore import Columns, RaggedColumn


def _build_index(keys: np.ndarray) -> Dict[int, int]:
    """Last-writer-wins hash index key -> row (dictionary build side)."""
    return {int(k): i for i, k in enumerate(keys)}


def hash_join(
    left: Columns,
    right: Columns,
    *,
    key: str,
    right_prefix: str = "",
    default_int: int = 0,
    default_float: float = 0.0,
) -> Columns:
    """Left-join ``right`` onto ``left`` by ``key`` (host dictionary lookup).

    Unmatched rows get type-appropriate defaults, mirroring the cleaned-view
    guarantee that columns stay non-empty. Output keeps left's row order.
    """
    lkeys = np.asarray(left[key])
    rkeys = np.asarray(right[key])
    index = _build_index(rkeys)
    match = np.array([index.get(int(k), -1) for k in lkeys], dtype=np.int64)
    matched = match >= 0
    safe = np.where(matched, match, 0)

    out: Columns = dict(left)
    for name, data in right.items():
        if name == key:
            continue
        out_name = f"{right_prefix}{name}"
        if out_name in out:
            raise ValueError(f"join output column collision: {out_name!r}")
        if isinstance(data, RaggedColumn):
            taken = data.take(safe)
            lengths = np.where(matched, taken.lengths, 0).astype(np.int32)
            # re-take to drop values of unmatched rows
            offs = taken.offsets()
            parts = [taken.values[offs[i]: offs[i] + lengths[i]]
                     for i in range(len(lengths))]
            values = (np.concatenate(parts) if parts
                      else np.zeros((0,), np.int64))
            out[out_name] = RaggedColumn(values=values, lengths=lengths)
        else:
            arr = np.asarray(data)
            taken = arr[safe]
            if arr.dtype == object:
                out[out_name] = np.array(
                    [taken[i] if matched[i] else "" for i in range(len(matched))],
                    dtype=object)
            elif np.issubdtype(arr.dtype, np.floating):
                out[out_name] = np.where(matched, taken, default_float).astype(arr.dtype)
            else:
                out[out_name] = np.where(matched, taken, default_int).astype(arr.dtype)
    return out


def join_views(
    base: Columns,
    views: Sequence[Tuple[Columns, str]],
    *,
    prefix_with_index: bool = True,
) -> Columns:
    """Join a sequence of (view, key) pairs onto a base table (paper Fig. 3).

    Each view may use a different key (user_id, ad_id, ...), matching the
    paper's "joined with particular keys such as user id, ads id, etc."
    """
    out = base
    for i, (view, key) in enumerate(views):
        prefix = f"v{i}_" if prefix_with_index else ""
        out = hash_join(out, view, key=key, right_prefix=prefix)
    return out


def merge_on_instance(
    extracted: Columns, basic: Columns, *, instance_key: str = "instance_id"
) -> Columns:
    """Final merge of extracted features with basic features (paper §III):
    'realized by a join operation on the instance id'."""
    return hash_join(extracted, basic, key=instance_key, right_prefix="basic_")


def bytes_of(columns: Columns) -> int:
    total = 0
    for data in columns.values():
        if isinstance(data, RaggedColumn):
            total += data.values.nbytes + data.lengths.nbytes
        else:
            arr = np.asarray(data)
            if arr.dtype == object:
                total += sum(len(str(s)) for s in arr)
            else:
                total += arr.nbytes
    return total
