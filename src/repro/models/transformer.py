"""LM transformer family: dense GQA (yi/qwen) + MLA-MoE (DeepSeek).

Built for the production mesh (DESIGN.md §5):

* scan-over-layers with remat — HLO stays O(1) in depth, activations live
  only at layer boundaries;
* flash attention (O(S) memory) — 32k prefill never forms (S, S);
* chunked cross-entropy — the (B, S, V) logits tensor is never materialized;
  the loss scans sequence chunks against the (sharded) LM head;
* optional microbatch gradient accumulation for the 236B config;
* MoE layers dispatch via shard_map expert parallelism (``models.moe``).

Params are plain pytrees; ``abstract_params`` builds ShapeDtypeStructs so the
512-chip dry-run lowers without allocating 472 GB.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention as A
from repro.models.common import dense, rms_norm, softmax_xent
from repro.models.moe import MoEConfig, moe_ffn, moe_params_shape

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_base: float = 10000.0
    attn: str = "gqa"                       # "gqa" | "mla"
    mla: Optional[A.MLAConfig] = None
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0                  # leading dense-FFN layers (DeepSeek)
    dtype: Any = jnp.bfloat16
    # distribution
    grad_accum: int = 1                     # microbatch accumulation steps
    accum_dtype: Any = jnp.float32          # grad-accumulator dtype (bf16 for 236B)
    remat_group: int = 1                    # checkpoint every g layers (g>1 saves HBM)
    q_block: int = 512
    kv_block: int = 512
    loss_chunk: int = 2048                  # seq chunk for CE

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.first_k_dense if self.moe else 0

    @property
    def n_dense_layers(self) -> int:
        return self.n_layers if self.moe is None else self.first_k_dense


# ----------------------------------------------------------------- params
def _attn_shapes(c: LMConfig) -> Dict[str, Tuple[int, ...]]:
    if c.attn == "mla":
        assert c.mla is not None
        return A.mla_params_shape(c.mla)
    return A.gqa_params_shape(c.d_model, c.n_heads, c.n_kv, c.head_dim,
                              qkv_bias=c.qkv_bias)


def _dense_layer_shapes(c: LMConfig) -> Dict[str, Tuple[int, ...]]:
    shapes = {f"attn_{k}": v for k, v in _attn_shapes(c).items()}
    shapes.update({
        "ffn_w1": (c.d_model, c.d_ff),
        "ffn_w3": (c.d_model, c.d_ff),
        "ffn_w2": (c.d_ff, c.d_model),
        "norm1": (c.d_model,),
        "norm2": (c.d_model,),
    })
    return shapes


def _moe_layer_shapes(c: LMConfig) -> Dict[str, Tuple[int, ...]]:
    assert c.moe is not None
    shapes = {f"attn_{k}": v for k, v in _attn_shapes(c).items()}
    shapes.update({f"moe_{k}": v for k, v in moe_params_shape(c.d_model, c.moe).items()})
    shapes.update({"norm1": (c.d_model,), "norm2": (c.d_model,)})
    return shapes


def param_shapes(c: LMConfig) -> Dict[str, Any]:
    """Full parameter tree as name -> shape (layers stacked on axis 0)."""
    tree: Dict[str, Any] = {
        "embed": (c.vocab, c.d_model),
        "final_norm": (c.d_model,),
        "lm_head": (c.d_model, c.vocab),
    }
    if c.n_dense_layers:
        tree["dense_layers"] = {
            k: (c.n_dense_layers,) + v for k, v in _dense_layer_shapes(c).items()
        }
    if c.n_moe_layers:
        tree["moe_layers"] = {
            k: (c.n_moe_layers,) + v for k, v in _moe_layer_shapes(c).items()
        }
    return tree


def abstract_params(c: LMConfig) -> Params:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s, c.dtype), param_shapes(c),
        is_leaf=lambda x: isinstance(x, tuple))


def init_params(c: LMConfig, key: jax.Array) -> Params:
    def init_one(path_shape, k):
        shape = path_shape
        scale = 0.02
        return jax.random.normal(k, shape, jnp.float32).astype(c.dtype) * scale

    shapes = param_shapes(c)
    leaves, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(leaves))
    inited = [init_one(s, k) for s, k in zip(leaves, keys)]
    params = jax.tree.unflatten(treedef, inited)
    # norms start at 1
    def ones_norms(d, prefix=""):
        for name in list(d.keys()):
            if isinstance(d[name], dict):
                ones_norms(d[name])
            elif "norm" in name:
                d[name] = jnp.ones_like(d[name])
    ones_norms(params)
    return params


# ------------------------------------------------------------- param specs
def param_specs(c: LMConfig, *, dp: Tuple[str, ...] = ("data",),
                tp: Optional[str] = "model"):
    """PartitionSpec tree (2-D FSDP x TP for big weights).

    ``tp=None`` selects pure ZeRO-DP: every matrix row-sharded over ALL mesh
    axes, no tensor parallelism — the right-sized mapping for dense models
    whose layer weights fit one chip (EXPERIMENTS.md §Perf, yi-9b iteration).
    """
    if tp is None:
        all_axes = dp  # caller passes the flattened axes

        def spec_for(name: str, shape: Tuple[int, ...], stacked: bool):
            lead = (None,) if stacked else ()
            base = shape[1:] if stacked else shape
            if len(base) >= 2 and int(np.prod(base)) >= 1 << 16:
                return P(*lead, all_axes, *(None,) * (len(base) - 1))
            return P(*lead, *(None,) * len(base))

        shapes = param_shapes(c)
        out: Dict[str, Any] = {}
        for name, v in shapes.items():
            if isinstance(v, dict):
                out[name] = {k: spec_for(k, s, True) for k, s in v.items()}
            else:
                out[name] = spec_for(name, v, False)
        return out

    def spec_for(name: str, shape: Tuple[int, ...], stacked: bool):
        lead = (None,) if stacked else ()
        base = shape[1:] if stacked else shape
        if name == "embed":
            # vocab-sharded only: a (V/16, D) shard is ~65MB for the largest
            # vocab; 2-D sharding would force a full-table all-gather at the
            # token lookup (measured +1.05GiB/device transient)
            return P(tp, None)
        if name == "lm_head":
            return P(None, tp)
        if name in ("final_norm",):
            return P(None)
        if "norm" in name:
            return P(*lead, None)
        if name.startswith("attn_b"):
            return P(*lead, tp)
        if name.startswith("attn_w") or name.startswith("ffn_"):
            if len(base) == 2:
                # (d_in, d_out): FSDP on in, TP on out — except down-projections
                if name in ("attn_wo",) or name.endswith("_w2"):
                    return P(*lead, tp, "data")
                return P(*lead, "data", tp)
            return P(*lead, *(None,) * len(base))
        if name.startswith("moe_"):
            sub = name[len("moe_"):]
            if sub == "router":
                return P(*lead, None, None)
            if sub in ("w1", "w3"):
                ff = "data" if (c.moe and c.moe.shard_ff_over_data) else None
                return P(*lead, tp, None, ff)
            if sub == "w2":
                ff = "data" if (c.moe and c.moe.shard_ff_over_data) else None
                return P(*lead, tp, ff, None)
            if sub in ("sw1", "sw3"):
                return P(*lead, "data", tp)
            if sub == "sw2":
                return P(*lead, tp, "data")
        raise ValueError(f"no spec rule for {name}: {shape}")

    shapes = param_shapes(c)
    out: Dict[str, Any] = {}
    for name, v in shapes.items():
        if isinstance(v, dict):
            out[name] = {k: spec_for(k, s, True) for k, s in v.items()}
        else:
            out[name] = spec_for(name, v, False)
    return out


# ------------------------------------------------------------------ blocks
def _head_constraint(mesh, dp, n_heads: int, tp="model"):
    """Shard attention heads over 'model' when divisible (SPMD hint; without
    it propagation replicates attention activations across the TP axis)."""
    if mesh is None or tp is None or n_heads % mesh.shape[tp] != 0:
        return None
    from jax.sharding import NamedSharding

    def constrain(x):  # (B, S, H, Dh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(dp, None, tp, None)))
    return constrain


def _attn_block(lp: Params, x: jax.Array, c: LMConfig, *, positions=None,
                mesh=None, dp=("data",), tp="model"):
    prefix = {k[len("attn_"):]: v for k, v in lp.items() if k.startswith("attn_")}
    hc = _head_constraint(mesh, dp, c.n_heads, tp)
    if c.attn == "mla":
        return A.mla_attention(prefix, x, c.mla, positions=positions,
                               q_block=c.q_block, kv_block=c.kv_block,
                               head_constraint=hc)
    return A.gqa_attention(prefix, x, n_heads=c.n_heads, n_kv=c.n_kv,
                           head_dim=c.head_dim, positions=positions,
                           rope_base=c.rope_base,
                           q_block=c.q_block, kv_block=c.kv_block,
                           head_constraint=hc)


def _dense_block(lp: Params, x: jax.Array, c: LMConfig, *, mesh=None,
                 dp=("data",), tp="model", constraint=None):
    h = x + _attn_block(lp, rms_norm(x, lp["norm1"]), c, mesh=mesh, dp=dp, tp=tp)
    if constraint is not None:
        h = constraint(h)
    hn = rms_norm(h, lp["norm2"])
    ff = jax.nn.silu(dense(hn, lp["ffn_w1"])) * dense(hn, lp["ffn_w3"])
    out = h + dense(ff, lp["ffn_w2"])
    return out if constraint is None else constraint(out), jnp.float32(0.0)


def _moe_block(lp: Params, x: jax.Array, c: LMConfig, *, mesh=None,
               dp=("data",), tp="model", constraint=None):
    assert tp is not None, "MoE layers require a tensor/expert-parallel axis"
    h = x + _attn_block(lp, rms_norm(x, lp["norm1"]), c, mesh=mesh, dp=dp, tp=tp)
    if constraint is not None:
        h = constraint(h)
    hn = rms_norm(h, lp["norm2"])
    b, s, d = hn.shape
    moe_p = {k[len("moe_"):]: v for k, v in lp.items() if k.startswith("moe_")}
    out2d, aux = moe_ffn(moe_p, hn.reshape(b * s, d), c.moe, mesh=mesh,
                         dp_axes=dp, tp_axis="model")
    out = h + out2d.reshape(b, s, d)
    return out if constraint is None else constraint(out), aux


# ----------------------------------------------------------------- forward
def _make_constraint(mesh, dp, tp="model"):
    if mesh is None:
        return None
    from jax.sharding import NamedSharding

    def constrain(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(dp, None, tp)))
    return constrain


def _strip_axis(spec: P, axis: str) -> P:
    """Remove one mesh axis from a PartitionSpec (FSDP gather-at-use)."""
    parts = []
    for entry in spec:
        if entry == axis:
            parts.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != axis)
            parts.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            parts.append(entry)
    return P(*parts)


def _make_weight_gather(mesh, c: "LMConfig", group: str, dp=("data",), tp="model"):
    """Constraint tree forcing per-layer FSDP weight gather along 'data'.

    Without it GSPMD keeps weights data-sharded at their (twice-nested-loop)
    use sites and ALL-REDUCES the (B,S,d_ff) activations over 'data' instead
    — measured 3.7 TB/device/step of activation collectives on yi-9b vs
    ~36 GB of weight gathers (EXPERIMENTS.md §Perf iteration 3).
    """
    if mesh is None:
        return None
    from jax.sharding import NamedSharding

    specs = param_specs(c, dp=dp, tp=tp)[group]
    strip = ("data",) if tp is not None else tuple(
        a for axes in dp for a in (axes if isinstance(axes, tuple) else (axes,)))

    def constrain(lp):
        out = {}
        for k, v in lp.items():
            if k in ("moe_w1", "moe_w3", "moe_w2"):
                # routed-expert weights keep their ZeRO sharding: moe_ffn
                # all-gathers them INSIDE shard_map (per expert shard)
                out[k] = v
                continue
            spec = specs[k]
            # drop the stacked-layer leading entry, strip the fsdp axes
            layer_spec = P(*tuple(spec)[1:])
            for ax in strip:
                layer_spec = _strip_axis(layer_spec, ax)
            out[k] = jax.lax.with_sharding_constraint(
                v, NamedSharding(mesh, layer_spec))
        return out
    return constrain


def hidden_states(params: Params, tokens: jax.Array, c: LMConfig,
                  *, mesh=None, dp=("data",), tp="model") -> Tuple[jax.Array, jax.Array]:
    """Embed + all layers; returns (hidden (B,S,D), aux loss)."""
    constraint = _make_constraint(mesh, dp, tp)
    x = jnp.take(params["embed"], tokens, axis=0).astype(c.dtype)
    if constraint is not None:
        x = constraint(x)
    aux_total = jnp.float32(0.0)

    def scan_blocks(x, aux_total, stacked, block_fn, n_layers, gather=None):
        """scan-over-layers with remat every ``c.remat_group`` layers.

        g > 1 stores boundary activations only every g layers (recomputing
        the inner g-1 on backward) — the standard depth/memory trade used to
        fit the 236B config in 16 GB HBM.
        """
        g = max(1, min(c.remat_group, n_layers))
        if n_layers % g:
            g = 1

        def one_layer(x, lp):
            if gather is not None:
                lp = gather(lp)  # FSDP: gather weights, don't reduce activations
            return block_fn(lp, x)

        if g == 1:
            def body(carry, lp):
                x, aux = carry
                x, a = jax.checkpoint(one_layer)(x, lp)
                return (x, aux + a), None
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
            return x, aux_total

        grouped = jax.tree.map(
            lambda a: a.reshape((n_layers // g, g) + a.shape[1:]), stacked)

        def group_fn(x, group_params):
            # nested remat: outer checkpoint keeps only group boundaries;
            # inner checkpoint bounds the recompute working set to one layer
            def inner(carry, lp):
                x, aux = carry
                x, a = jax.checkpoint(one_layer)(x, lp)
                return (x, aux + a), None
            (x, aux), _ = jax.lax.scan(inner, (x, jnp.float32(0.0)), group_params)
            return x, aux

        def body(carry, group_params):
            x, aux = carry
            x, a = jax.checkpoint(group_fn)(x, group_params)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), grouped)
        return x, aux_total

    if c.n_dense_layers:
        x, aux_total = scan_blocks(
            x, aux_total, params["dense_layers"],
            lambda lp, x: _dense_block(lp, x, c, mesh=mesh, dp=dp, tp=tp,
                                       constraint=constraint),
            c.n_dense_layers,
            gather=_make_weight_gather(mesh, c, "dense_layers", dp=dp, tp=tp))

    if c.n_moe_layers:
        x, aux_total = scan_blocks(
            x, aux_total, params["moe_layers"],
            lambda lp, x: _moe_block(lp, x, c, mesh=mesh, dp=dp, tp=tp,
                                     constraint=constraint),
            c.n_moe_layers,
            gather=_make_weight_gather(mesh, c, "moe_layers", dp=dp, tp=tp))

    return rms_norm(x, params["final_norm"]), aux_total


def lm_loss(params: Params, tokens: jax.Array, labels: jax.Array, c: LMConfig,
            *, mesh=None, dp=("data",), tp="model",
            aux_weight: float = 0.01) -> jax.Array:
    """Mean CE over tokens with seq-chunked logits (never (B,S,V) at once)."""
    h, aux = hidden_states(params, tokens, c, mesh=mesh, dp=dp, tp=tp)
    b, s, d = h.shape
    chunk = min(c.loss_chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    s_pad = n_chunks * chunk
    if s_pad != s:
        h = jnp.pad(h, ((0, 0), (0, s_pad - s), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, s_pad - s)))
    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    valid = (jnp.arange(s_pad) < s).reshape(n_chunks, chunk)

    def chunk_loss(carry, xs):
        hx, lx, vx = xs
        logits = jnp.einsum("bsd,dv->bsv", hx, params["lm_head"].astype(hx.dtype))
        ce = softmax_xent(logits, lx) * vx[None, :]
        return carry + ce.sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hc, lc, valid))
    return total / (b * s) + aux_weight * aux


# -------------------------------------------------------------- train step
def make_train_step(c: LMConfig, optimizer, *, mesh=None, dp=("data",), tp="model"):
    """Build train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``optimizer`` follows repro.train.optimizer (init/update pair). With
    ``c.grad_accum > 1`` microbatches are scanned and grads accumulated in
    fp32 before one optimizer step.
    """

    def loss_fn(params, tokens, labels):
        return lm_loss(params, tokens, labels, c, mesh=mesh, dp=dp, tp=tp)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if c.grad_accum > 1:
            b = tokens.shape[0]
            a = c.grad_accum
            assert b % a == 0, (b, a)
            tok = tokens.reshape(a, b // a, -1)
            lab = labels.reshape(a, b // a, -1)

            def micro(carry, xs):
                loss_acc, grad_acc = carry
                t, l = xs
                loss, grads = jax.value_and_grad(loss_fn)(params, t, l)
                grad_acc = jax.tree.map(
                    lambda g_acc, g: (g_acc.astype(jnp.float32)
                                      + g.astype(jnp.float32) / a).astype(c.accum_dtype),
                    grad_acc, grads)
                return (loss_acc + loss / a, grad_acc), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, c.accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0.0), zero), (tok, lab))
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss}

    return train_step


# ------------------------------------------------------------ serve (decode)
def make_cache(c: LMConfig, batch: int, max_len: int, *, abstract: bool = False):
    """KV cache pytree. GQA: k/v per layer; MLA: latent + rope key."""
    def mk(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, c.dtype)
        return jnp.zeros(shape, c.dtype)

    if c.attn == "mla":
        m = c.mla
        cache = {
            "ckv": mk((c.n_layers, batch, max_len, m.kv_lora_rank)),
            "krope": mk((c.n_layers, batch, max_len, m.qk_rope_dim)),
        }
    else:
        cache = {
            "k": mk((c.n_layers, batch, max_len, c.n_kv, c.head_dim)),
            "v": mk((c.n_layers, batch, max_len, c.n_kv, c.head_dim)),
        }
    return cache


def cache_specs(c: LMConfig, *, dp=("data",), tp: str = "model"):
    if c.attn == "mla":
        return {"ckv": P(None, dp, None, tp), "krope": P(None, dp, None, None)}
    # shard head_dim over tp (n_kv may not divide the tp axis)
    return {"k": P(None, dp, None, None, tp), "v": P(None, dp, None, None, tp)}


def serve_step(params: Params, token: jax.Array, cache, cache_len: jax.Array,
               c: LMConfig, *, mesh=None, dp=("data",)):
    """One decode step: token (B, 1) int32 -> (logits (B, V), new cache)."""
    constraint = None  # decode activations are small; let GSPMD propagate
    x = jnp.take(params["embed"], token, axis=0).astype(c.dtype)

    def layer(x, lp, layer_cache):
        prefix = {k[len("attn_"):]: v for k, v in lp.items() if k.startswith("attn_")}
        xn = rms_norm(x, lp["norm1"])
        if c.attn == "mla":
            out, (ckv, krope) = A.mla_decode_step(
                prefix, xn, layer_cache["ckv"], layer_cache["krope"], cache_len, c.mla)
            new_cache = {"ckv": ckv, "krope": krope}
        else:
            out, (k, v) = A.gqa_decode_step(
                prefix, xn, layer_cache["k"], layer_cache["v"], cache_len,
                n_heads=c.n_heads, n_kv=c.n_kv, head_dim=c.head_dim,
                rope_base=c.rope_base)
            new_cache = {"k": k, "v": v}
        h = x + out
        hn = rms_norm(h, lp["norm2"])
        if "ffn_w1" in lp:
            ff = jax.nn.silu(dense(hn, lp["ffn_w1"])) * dense(hn, lp["ffn_w3"])
            h = h + dense(ff, lp["ffn_w2"])
        else:
            moe_p = {k[len("moe_"):]: v for k, v in lp.items() if k.startswith("moe_")}
            b = hn.shape[0]
            out2d, _ = moe_ffn(moe_p, hn.reshape(b, -1), c.moe, mesh=mesh,
                               dp_axes=dp, tp_axis="model")
            h = h + out2d.reshape(hn.shape)
        return h, new_cache

    new_cache = {}
    # dense layers (cache slices [0, n_dense))
    if c.n_dense_layers:
        nd = c.n_dense_layers
        def dense_scan(x, xs):
            lp, lcache = xs
            return layer(x, lp, lcache)
        x, nc = jax.lax.scan(
            dense_scan, x,
            (params["dense_layers"], jax.tree.map(lambda a: a[:nd], cache)))
        for k, v in nc.items():
            new_cache.setdefault(k, []).append(v)
    if c.n_moe_layers:
        nd = c.n_dense_layers
        def moe_scan(x, xs):
            lp, lcache = xs
            return layer(x, lp, lcache)
        x, nc = jax.lax.scan(
            moe_scan, x,
            (params["moe_layers"], jax.tree.map(lambda a: a[nd:], cache)))
        for k, v in nc.items():
            new_cache.setdefault(k, []).append(v)
    cache_out = {
        k: (jnp.concatenate(v, axis=0) if len(v) > 1 else v[0])
        for k, v in new_cache.items()
    }
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0], cache_out


def prefill(params: Params, tokens: jax.Array, c: LMConfig,
            *, mesh=None, dp=("data",), tp="model"):
    """Prefill: full forward; returns last-position logits (B, V).

    (Cache materialization for decode handoff is a gather over the layer
    scan; for the dry-run cost model the transformer forward dominates.)
    """
    h, _ = hidden_states(params, tokens, c, mesh=mesh, dp=dp, tp=tp)
    last = h[:, -1]
    return jnp.einsum("bd,dv->bv", last, params["lm_head"].astype(last.dtype))
