"""PNA (Principal Neighbourhood Aggregation, arXiv:2004.05718).

Message passing is built on ``jax.ops.segment_sum/max/min`` over an
edge-index (src -> dst) scatter — JAX has no sparse SpMM path for this, so
the segment-op formulation IS the system (kernel taxonomy §GNN).

PNA layer (degree-general):
  m_ij   = M([h_i ; h_j])                      per-edge message (pre-MLP)
  agg    = [mean | max | min | std]_j m_ij     4 aggregators
  scaled = [agg ; agg*amp(d_i) ; agg*att(d_i)] 3 degree scalers
  h_i'   = U([h_i ; scaled])                   post-MLP update

Shapes served: full-graph training (Cora/ogbn-products scale), fanout-sampled
mini-batching (Reddit scale — the sampler is a host op in the FeatureBox
pipeline sense), and batched small molecule graphs (graph-level readout).

Distribution: edges sharded over all mesh axes; nodes replicated; each edge
shard scatter-adds into the full node accumulator and XLA all-reduces the
partials (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

compat.install()  # jax.shard_map on older jax

from repro.models.common import he_init, softmax_xent

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    delta: float = 2.5          # avg log-degree normalizer (dataset statistic)
    graph_level: bool = False   # molecule: mean-pool readout + graph labels
    dtype: Any = jnp.float32
    halo_bf16: bool = False     # compress the halo all-gather to bf16 (§Perf)


N_AGG = 4     # mean, max, min, std
N_SCALE = 3   # identity, amplification, attenuation


def param_shapes(c: PNAConfig) -> Dict[str, Tuple[int, ...]]:
    shapes: Dict[str, Tuple[int, ...]] = {"in_w": (c.d_in, c.d_hidden), "in_b": (c.d_hidden,)}
    for i in range(c.n_layers):
        shapes[f"l{i}_msg_w"] = (2 * c.d_hidden, c.d_hidden)
        shapes[f"l{i}_msg_b"] = (c.d_hidden,)
        shapes[f"l{i}_upd_w"] = (c.d_hidden * (1 + N_AGG * N_SCALE), c.d_hidden)
        shapes[f"l{i}_upd_b"] = (c.d_hidden,)
    shapes["out_w"] = (c.d_hidden, c.n_classes)
    shapes["out_b"] = (c.n_classes,)
    return shapes


def abstract_params(c: PNAConfig) -> Params:
    return {k: jax.ShapeDtypeStruct(s, c.dtype) for k, s in param_shapes(c).items()}


def init_params(c: PNAConfig, key: jax.Array) -> Params:
    params = {}
    for i, (name, shape) in enumerate(param_shapes(c).items()):
        k = jax.random.fold_in(key, i)
        params[name] = (jnp.zeros(shape, c.dtype) if name.endswith("_b")
                        else he_init(k, shape, c.dtype))
    return params


def pna_layer(params: Params, i: int, h: jax.Array, src: jax.Array, dst: jax.Array,
              c: PNAConfig, n_nodes: int) -> jax.Array:
    """One PNA layer over edge lists (src -> dst)."""
    msg_in = jnp.concatenate([h[dst], h[src]], axis=-1)          # (E, 2D)
    m = jax.nn.relu(msg_in @ params[f"l{i}_msg_w"] + params[f"l{i}_msg_b"])

    ones = jnp.ones((m.shape[0],), m.dtype)
    deg = jax.ops.segment_sum(ones, dst, num_segments=n_nodes)   # (N,)
    deg_safe = jnp.maximum(deg, 1.0)

    s = jax.ops.segment_sum(m, dst, num_segments=n_nodes)
    mean = s / deg_safe[:, None]
    mx = jax.ops.segment_max(m, dst, num_segments=n_nodes)
    mx = jnp.where(deg[:, None] > 0, mx, 0.0)
    mn = jax.ops.segment_min(m, dst, num_segments=n_nodes)
    mn = jnp.where(deg[:, None] > 0, mn, 0.0)
    sq = jax.ops.segment_sum(m * m, dst, num_segments=n_nodes) / deg_safe[:, None]
    std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-5)

    agg = jnp.concatenate([mean, mx, mn, std], axis=-1)          # (N, 4D)
    logd = jnp.log1p(deg)[:, None]
    amp = logd / c.delta
    att = c.delta / jnp.maximum(logd, 1e-5)
    scaled = jnp.concatenate([agg, agg * amp, agg * att], axis=-1)  # (N, 12D)

    upd_in = jnp.concatenate([h, scaled], axis=-1)
    return jax.nn.relu(upd_in @ params[f"l{i}_upd_w"] + params[f"l{i}_upd_b"])


def forward(params: Params, c: PNAConfig, batch: Dict[str, jax.Array],
            *, mesh=None, node_axes=None) -> jax.Array:
    """batch: features (N, d_in), edge src/dst (E,), [graph_ids (N,)].

    Returns per-node logits (N, n_classes) or per-graph logits if graph_level.

    At scale (ogb_products: 2.45M nodes) node tensors are sharded over
    ``node_axes`` and each layer is rematerialized — otherwise the (N, 12D)
    aggregate concat saved for backward is ~9 GB/layer replicated.
    """
    feats, src, dst = batch["features"], batch["src"], batch["dst"]
    n_nodes = feats.shape[0]

    constrain = lambda x: x
    if mesh is not None and node_axes is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        def constrain(x):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(node_axes, None)))

    h = constrain(jax.nn.relu(feats.astype(c.dtype) @ params["in_w"] + params["in_b"]))
    for i in range(c.n_layers):
        h = jax.checkpoint(
            lambda h, i=i: constrain(pna_layer(params, i, h, src, dst, c, n_nodes))
        )(h)
    if c.graph_level:
        gid = batch["graph_ids"]
        n_graphs = batch["n_graphs"]
        pooled = jax.ops.segment_sum(h, gid, num_segments=n_graphs)
        cnt = jax.ops.segment_sum(jnp.ones((n_nodes,), h.dtype), gid,
                                  num_segments=n_graphs)
        h = pooled / jnp.maximum(cnt, 1.0)[:, None]
    return h @ params["out_w"] + params["out_b"]


def loss_fn(params: Params, c: PNAConfig, batch: Dict[str, jax.Array],
            *, mesh=None, node_axes=None) -> jax.Array:
    if mesh is not None and node_axes is not None and not c.graph_level:
        logits = forward_sharded(params, c, batch, mesh=mesh, node_axes=node_axes)
    else:
        logits = forward(params, c, batch, mesh=mesh, node_axes=node_axes)
    labels = batch["labels"]
    mask = batch.get("label_mask")
    ce = softmax_xent(logits, labels)
    if mask is not None:
        return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce.mean()


def make_train_step(c: PNAConfig, optimizer, *, mesh=None, node_axes=None):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, c, batch, mesh=mesh, node_axes=node_axes))(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss}
    return train_step


def param_specs(c: PNAConfig, *, dp=("data",), tp: str = "model"):
    """Small model: replicate params; edges are the sharded quantity."""
    from jax.sharding import PartitionSpec as P
    return {k: P(*(None,) * len(s)) for k, s in param_shapes(c).items()}


# ------------------------------------------------- distributed (shard_map)
def partition_edges(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                    n_shards: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Partition edges by dst node range (host-side, part of the FE pipeline).

    Shard k receives edges whose dst lies in [k*rows, (k+1)*rows); every
    shard is padded to the max shard size with OOB edges (dst = n_nodes)
    that segment ops drop. Returns (src_p, dst_p, per_shard).
    """
    rows = (n_nodes + n_shards - 1) // n_shards
    owner = dst // rows
    order = np.argsort(owner, kind="stable")
    src_s, dst_s, owner_s = src[order], dst[order], owner[order]
    counts = np.bincount(owner_s, minlength=n_shards)
    per_shard = int(counts.max())
    src_p = np.zeros((n_shards, per_shard), src.dtype)
    dst_p = np.full((n_shards, per_shard), n_nodes, dst.dtype)  # OOB padding
    start = 0
    for k in range(n_shards):
        c = counts[k]
        src_p[k, :c] = src_s[start:start + c]
        dst_p[k, :c] = dst_s[start:start + c]
        start += c
    return src_p.reshape(-1), dst_p.reshape(-1), per_shard


def _pna_layer_local(params_i: Dict[str, jax.Array], h_full: jax.Array,
                     h_local: jax.Array, src: jax.Array, dst_local: jax.Array,
                     c: PNAConfig, local_rows: int) -> jax.Array:
    """One PNA layer over a local edge shard writing a local node range."""
    msg_w, msg_b, upd_w, upd_b = (params_i["msg_w"], params_i["msg_b"],
                                  params_i["upd_w"], params_i["upd_b"])
    dst_clamped = jnp.minimum(dst_local, local_rows)  # OOB -> dropped below
    h_dst = jnp.take(h_local, jnp.minimum(dst_clamped, local_rows - 1), axis=0)
    h_src = jnp.take(h_full, src, axis=0)
    m = jax.nn.relu(jnp.concatenate([h_dst, h_src], -1) @ msg_w + msg_b)

    ones = jnp.where(dst_local < local_rows, 1.0, 0.0).astype(m.dtype)
    m = m * ones[:, None]
    deg = jax.ops.segment_sum(ones, dst_local, num_segments=local_rows)
    deg_safe = jnp.maximum(deg, 1.0)
    s = jax.ops.segment_sum(m, dst_local, num_segments=local_rows)
    mean = s / deg_safe[:, None]
    mx = jnp.where(deg[:, None] > 0,
                   jax.ops.segment_max(m, dst_local, num_segments=local_rows), 0.0)
    mn = jnp.where(deg[:, None] > 0,
                   jax.ops.segment_min(m, dst_local, num_segments=local_rows), 0.0)
    sq = jax.ops.segment_sum(m * m, dst_local, num_segments=local_rows) / deg_safe[:, None]
    std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-5)
    agg = jnp.concatenate([mean, mx, mn, std], -1)
    logd = jnp.log1p(deg)[:, None]
    scaled = jnp.concatenate(
        [agg, agg * logd / c.delta, agg * c.delta / jnp.maximum(logd, 1e-5)], -1)
    upd_in = jnp.concatenate([h_local, scaled], -1)
    return jax.nn.relu(upd_in @ upd_w + upd_b)


def forward_sharded(params: Params, c: PNAConfig, batch: Dict[str, jax.Array],
                    *, mesh, node_axes: Tuple[str, ...]) -> jax.Array:
    """Distributed PNA: node tensors sharded, edges dst-partitioned.

    Per layer (inside shard_map): all-gather h (the halo exchange), compute
    messages for the local edge shard, segment-reduce into the LOCAL node
    range only. Node-sharded aggregates never replicate — the structure that
    makes 2.4M-node full-batch training fit (see dry-run ogb_products).
    """
    from jax.sharding import PartitionSpec as P

    feats, src, dst = batch["features"], batch["src"], batch["dst"]
    n_nodes = feats.shape[0]
    n_shards = int(np.prod([mesh.shape[a] for a in node_axes]))
    local_rows = n_nodes // n_shards
    h0 = jax.nn.relu(feats.astype(c.dtype) @ params["in_w"] + params["in_b"])

    def layer_fn(i, h_shard, src_l, dst_l):
        def f(h_loc, src_loc, dst_loc):
            idx = jax.lax.axis_index(node_axes)
            if c.halo_bf16:
                # halo exchange in bf16: halves the dominant collective term
                h_wire = jax.lax.all_gather(
                    h_loc.astype(jnp.bfloat16), node_axes, axis=0, tiled=True)
                h_full = h_wire.astype(h_loc.dtype)
            else:
                h_full = jax.lax.all_gather(h_loc, node_axes, axis=0, tiled=True)
            dst_local = dst_loc - idx * local_rows
            dst_local = jnp.where(
                (dst_local >= 0) & (dst_local < local_rows), dst_local, local_rows)
            lp = {k.split("_", 1)[1]: v for k, v in params.items()
                  if k.startswith(f"l{i}_")}
            return _pna_layer_local(lp, h_full, h_loc, src_loc, dst_local,
                                    c, local_rows)

        return jax.shard_map(
            f, mesh=mesh,
            in_specs=(P(node_axes, None), P(node_axes), P(node_axes)),
            out_specs=P(node_axes, None),
            check_vma=False,
        )(h_shard, src_l, dst_l)

    h = h0
    for i in range(c.n_layers):
        h = jax.checkpoint(lambda h, i=i: layer_fn(i, h, src, dst))(h)
    return h @ params["out_w"] + params["out_b"]


# ----------------------------------------------------------- host sampler
class NeighborSampler:
    """Fanout neighbor sampler over a CSR adjacency (host op, numpy).

    GraphSAGE-style [arXiv:1706.02216]: for each seed, sample ``fanout[0]``
    neighbors, then ``fanout[1]`` of each of those, etc. Returns the union
    subgraph with node ids remapped densely — note the remap IS a dedup
    (the FeatureBox working-set construction applied to graph nodes).
    """

    def __init__(self, indptr: np.ndarray, indices: np.ndarray, *, seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self.rng = np.random.default_rng(seed)

    @staticmethod
    def from_edges(n_nodes: int, src: np.ndarray, dst: np.ndarray, **kw) -> "NeighborSampler":
        order = np.argsort(dst, kind="stable")
        src_sorted = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return NeighborSampler(indptr.astype(np.int64), src_sorted.astype(np.int64), **kw)

    def sample(self, seeds: np.ndarray, fanout: Tuple[int, ...]):
        """Returns (node_ids, src_local, dst_local, seed_local)."""
        nodes = list(seeds)
        node_set = {int(n): i for i, n in enumerate(seeds)}
        src_l: List[int] = []
        dst_l: List[int] = []
        frontier = list(seeds)
        for f in fanout:
            nxt: List[int] = []
            for u in frontier:
                lo, hi = self.indptr[u], self.indptr[u + 1]
                neigh = self.indices[lo:hi]
                if len(neigh) == 0:
                    continue
                take = neigh if len(neigh) <= f else self.rng.choice(neigh, f, replace=False)
                for v in take:
                    v = int(v)
                    if v not in node_set:
                        node_set[v] = len(nodes)
                        nodes.append(v)
                        nxt.append(v)
                    src_l.append(node_set[v])
                    dst_l.append(node_set[int(u)])
            frontier = nxt
        return (np.asarray(nodes, np.int64), np.asarray(src_l, np.int32),
                np.asarray(dst_l, np.int32),
                np.arange(len(seeds), dtype=np.int32))


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 *, seed: int = 0) -> Dict[str, np.ndarray]:
    """Synthetic graph batch for smokes/benches."""
    rng = np.random.default_rng(seed)
    return {
        "features": rng.normal(size=(n_nodes, d_feat)).astype(np.float32),
        "src": rng.integers(0, n_nodes, n_edges).astype(np.int32),
        "dst": rng.integers(0, n_nodes, n_edges).astype(np.int32),
        "labels": rng.integers(0, n_classes, n_nodes).astype(np.int32),
    }
