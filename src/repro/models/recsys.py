"""CTR/recsys models on the embedding substrate: DLRM, DCN-v2, AutoInt, BST.

These are the paper's own workload class (FeatureBox trains CTR models with
10^12-dim sparse inputs on a hierarchical GPU parameter server). All four
share:

* one packed :class:`~repro.embedding.table.MultiTable` for all sparse fields
  (rows sharded over the flattened ('data','model') axes at scale);
* ``lookup_dedup`` (the working-set path) or plain ``lookup`` — switchable so
  §Perf can measure the dedup win;
* sigmoid BCE training, batched serving, and a vectorized 10^6-candidate
  retrieval scorer (batched dot, not a loop).

The DLRM pairwise-dot interaction has a Pallas kernel
(``kernels/interaction_dot``) used on TPU; under dry-run/pjit the pure-jnp
form (same math) lowers through XLA.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.embedding.table import MultiTable, TableSpec, lookup, lookup_dedup
from repro.models.common import (
    dense as dense_layer,
    he_init,
    layer_norm,
    mlp,
    sigmoid_bce,
)

Params = Dict[str, Any]

# MLPerf DLRM (Criteo 1TB) per-field vocabulary sizes [arXiv:1906.00091].
CRITEO_1TB_VOCABS: Tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # "dlrm" | "dcnv2" | "autoint" | "bst"
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_sizes: Tuple[int, ...]
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    n_cross_layers: int = 0
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    seq_len: int = 0                # BST behavior-sequence length
    n_blocks: int = 0               # BST transformer blocks
    dtype: Any = jnp.float32
    dedup_lookup: bool = True       # FeatureBox working-set path
    dedup_capacity: int = 0         # 0 -> batch*fields (safe upper bound)
    # which sparse field holds the candidate item (retrieval scoring)
    item_field: int = 0
    # physical row padding so the packed table shards evenly on any mesh
    row_align: int = 512

    def multi_table(self) -> MultiTable:
        specs = [TableSpec(f"f{i}", v, self.embed_dim)
                 for i, v in enumerate(self.vocab_sizes)]
        return MultiTable.build(specs)

    @property
    def padded_rows(self) -> int:
        rows = self.multi_table().total_rows
        return (rows + self.row_align - 1) // self.row_align * self.row_align


# ------------------------------------------------------------------ params
def _mlp_shapes(dims: Sequence[int], d_in: int, prefix: str) -> Dict[str, Tuple[int, ...]]:
    shapes = {}
    prev = d_in
    for i, d in enumerate(dims):
        shapes[f"{prefix}_w{i}"] = (prev, d)
        shapes[f"{prefix}_b{i}"] = (d,)
        prev = d
    return shapes


def param_shapes(c: RecsysConfig) -> Dict[str, Tuple[int, ...]]:
    shapes: Dict[str, Tuple[int, ...]] = {"embed": (c.padded_rows, c.embed_dim)}
    if c.kind == "dlrm":
        shapes.update(_mlp_shapes(c.bot_mlp, c.n_dense, "bot"))
        n_fields = c.n_sparse + 1
        d_inter = n_fields * (n_fields - 1) // 2 + c.bot_mlp[-1]
        shapes.update(_mlp_shapes(c.top_mlp, d_inter, "top"))
    elif c.kind == "dcnv2":
        d0 = c.n_dense + c.n_sparse * c.embed_dim
        for i in range(c.n_cross_layers):
            shapes[f"cross_w{i}"] = (d0, d0)
            shapes[f"cross_b{i}"] = (d0,)
        shapes.update(_mlp_shapes(tuple(c.top_mlp) + (1,), d0, "deep"))
    elif c.kind == "autoint":
        d = c.embed_dim
        for i in range(c.n_attn_layers):
            d_out = c.d_attn * c.n_heads
            shapes[f"attn{i}_wq"] = (d, d_out)
            shapes[f"attn{i}_wk"] = (d, d_out)
            shapes[f"attn{i}_wv"] = (d, d_out)
            shapes[f"attn{i}_wres"] = (d, d_out)
            d = d_out
        shapes["out_w"] = (c.n_sparse * d, 1)
        shapes["out_b"] = (1,)
    elif c.kind == "bst":
        d = c.embed_dim
        shapes["pos_embed"] = (c.seq_len + 1, d)
        for i in range(c.n_blocks):
            shapes[f"blk{i}_wq"] = (d, d)
            shapes[f"blk{i}_wk"] = (d, d)
            shapes[f"blk{i}_wv"] = (d, d)
            shapes[f"blk{i}_wo"] = (d, d)
            shapes[f"blk{i}_ln1_w"] = (d,)
            shapes[f"blk{i}_ln1_b"] = (d,)
            shapes[f"blk{i}_ffn_w1"] = (d, 4 * d)
            shapes[f"blk{i}_ffn_b1"] = (4 * d,)
            shapes[f"blk{i}_ffn_w2"] = (4 * d, d)
            shapes[f"blk{i}_ffn_b2"] = (d,)
            shapes[f"blk{i}_ln2_w"] = (d,)
            shapes[f"blk{i}_ln2_b"] = (d,)
        d_in = (c.seq_len + 1) * d + (c.n_sparse - 1) * d
        shapes.update(_mlp_shapes(tuple(c.top_mlp) + (1,), d_in, "top"))
    else:
        raise ValueError(f"unknown recsys kind {c.kind!r}")
    return shapes


def abstract_params(c: RecsysConfig) -> Params:
    return {k: jax.ShapeDtypeStruct(s, c.dtype) for k, s in param_shapes(c).items()}


def init_params(c: RecsysConfig, key: jax.Array, *,
                include_embed: bool = True) -> Params:
    """Materialize params. ``include_embed=False`` skips the embedding table
    (the hierarchical-PS path keeps it on SSD/host, never in device memory)
    while leaving every dense param bitwise identical to the full init —
    the fold_in indices are enumeration positions over the *full* shape
    dict, not the filtered one."""
    params: Params = {}
    for i, (name, shape) in enumerate(param_shapes(c).items()):
        if name == "embed" and not include_embed:
            continue
        k = jax.random.fold_in(key, i)
        if name == "embed":
            scale = 1.0 / np.sqrt(c.embed_dim)
            params[name] = jax.random.uniform(k, shape, c.dtype, -scale, scale)
        elif name.endswith(tuple(f"_b{j}" for j in range(10))) or name.endswith("_b"):
            params[name] = jnp.zeros(shape, c.dtype)
        elif "ln" in name and name.endswith("_w"):
            params[name] = jnp.ones(shape, c.dtype)
        elif "ln" in name and name.endswith("_b"):
            params[name] = jnp.zeros(shape, c.dtype)
        elif len(shape) == 1:
            params[name] = jnp.zeros(shape, c.dtype)
        else:
            params[name] = he_init(k, shape, c.dtype)
    return params


def param_specs(c: RecsysConfig, *, dp: Tuple[str, ...] = ("data",), tp: str = "model"):
    """Embedding rows sharded over every device; small dense nets replicated."""
    specs = {}
    for name, shape in param_shapes(c).items():
        if name == "embed":
            specs[name] = P(dp + (tp,), None)
        else:
            specs[name] = P(*(None,) * len(shape))
    return specs


# ----------------------------------------------------------------- lookups
def collect_gids(c: RecsysConfig, batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """All packed global row ids this batch will look up, keyed by site.

    Shared by the in-graph lookup paths and by the sparse working-set train
    step (which gathers the working set OUTSIDE the differentiated region —
    the hierarchical-PS training scheme of [37]/FeatureBox).
    """
    mt = c.multi_table()
    gids: Dict[str, jax.Array] = {}
    if c.kind == "bst":
        seq_plus = jnp.concatenate(
            [batch["seq"], batch["sparse"][:, c.item_field][:, None]], axis=1)
        gids["seq"] = seq_plus.astype(jnp.int32) + int(mt.offsets[c.item_field])
        other = jnp.delete(batch["sparse"], c.item_field, axis=1,
                           assume_unique_indices=True)
        other_offs = jnp.asarray(
            np.delete(np.asarray(mt.offsets), c.item_field), jnp.int32)
        gids["other"] = other.astype(jnp.int32) + other_offs[None, :]
    else:
        gids["sparse"] = mt.global_ids(batch["sparse"])
    return gids


def _embed_fields(params: Params, c: RecsysConfig, field_ids: jax.Array,
                  mt: MultiTable) -> jax.Array:
    """(B, F) per-field ids -> (B, F, D) rows via packed global ids."""
    gids = mt.global_ids(field_ids)
    if c.dedup_lookup:
        cap = c.dedup_capacity or int(np.prod(gids.shape))
        return lookup_dedup(params["embed"], gids, capacity=cap)
    return lookup(params["embed"], gids)


# ----------------------------------------------------------------- forward
def _dlrm_forward(params, c, batch, mt):
    dense_x = batch["dense"].astype(c.dtype)
    emb = batch.get("_rows_sparse")
    if emb is None:
        emb = _embed_fields(params, c, batch["sparse"], mt)      # (B, F, D)
    n_bot = len(c.bot_mlp)
    bot = mlp(dense_x,
              [params[f"bot_w{i}"] for i in range(n_bot)],
              [params[f"bot_b{i}"] for i in range(n_bot)],
              act=jax.nn.relu, final_act=jax.nn.relu)            # (B, D)
    fields = jnp.concatenate([bot[:, None, :], emb], axis=1)     # (B, F+1, D)
    f = fields.shape[1]
    scores = jnp.einsum("bfd,bgd->bfg", fields, fields)
    rows, cols = np.tril_indices(f, k=-1)
    inter = scores[:, rows, cols]                                # (B, P)
    top_in = jnp.concatenate([bot, inter], axis=1)
    n_top = len(c.top_mlp)
    logit = mlp(top_in,
                [params[f"top_w{i}"] for i in range(n_top)],
                [params[f"top_b{i}"] for i in range(n_top)])
    return logit[:, 0]


def _dcnv2_forward(params, c, batch, mt):
    emb = batch.get("_rows_sparse")
    if emb is None:
        emb = _embed_fields(params, c, batch["sparse"], mt)
    b = emb.shape[0]
    x0 = jnp.concatenate([batch["dense"].astype(c.dtype), emb.reshape(b, -1)], axis=1)
    x = x0
    for i in range(c.n_cross_layers):
        xw = dense_layer(x, params[f"cross_w{i}"], params[f"cross_b{i}"])
        x = x0 * xw + x                                           # DCN-v2 cross
    n_deep = len(c.top_mlp) + 1
    logit = mlp(x,
                [params[f"deep_w{i}"] for i in range(n_deep)],
                [params[f"deep_b{i}"] for i in range(n_deep)])
    return logit[:, 0]


def _autoint_forward(params, c, batch, mt):
    emb = batch.get("_rows_sparse")
    if emb is None:
        emb = _embed_fields(params, c, batch["sparse"], mt)      # (B, F, D)
    x = emb
    for i in range(c.n_attn_layers):
        q = dense_layer(x, params[f"attn{i}_wq"])
        k = dense_layer(x, params[f"attn{i}_wk"])
        v = dense_layer(x, params[f"attn{i}_wv"])
        b, f, _ = q.shape
        qh = q.reshape(b, f, c.n_heads, c.d_attn)
        kh = k.reshape(b, f, c.n_heads, c.d_attn)
        vh = v.reshape(b, f, c.n_heads, c.d_attn)
        scores = jnp.einsum("bfhd,bghd->bhfg", qh, kh) / np.sqrt(c.d_attn)
        attn = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhfg,bghd->bfhd", attn, vh).reshape(b, f, -1)
        x = jax.nn.relu(out + dense_layer(x, params[f"attn{i}_wres"]))
    b = x.shape[0]
    logit = dense_layer(x.reshape(b, -1), params["out_w"], params["out_b"])
    return logit[:, 0]


def _bst_forward(params, c, batch, mt):
    # sparse field 0 = target item; remaining fields = user/context features
    seq = batch["seq"]                                            # (B, L) item ids
    target = batch["sparse"][:, c.item_field]
    other = jnp.delete(batch["sparse"], c.item_field, axis=1,
                       assume_unique_indices=True)
    b, l = seq.shape
    # behavior sequence + target share the item table (field 0 id space)
    x = batch.get("_rows_seq")
    if x is None:
        seq_plus = jnp.concatenate([seq, target[:, None]], axis=1)  # (B, L+1)
        gids = seq_plus.astype(jnp.int32) + int(mt.offsets[c.item_field])
        if c.dedup_lookup:
            cap = c.dedup_capacity or int(np.prod(gids.shape))
            x = lookup_dedup(params["embed"], gids, capacity=cap)
        else:
            x = lookup(params["embed"], gids)                     # (B, L+1, D)
    x = x.astype(c.dtype) + params["pos_embed"][None, :, :].astype(c.dtype)
    for i in range(c.n_blocks):
        q = dense_layer(x, params[f"blk{i}_wq"])
        k = dense_layer(x, params[f"blk{i}_wk"])
        v = dense_layer(x, params[f"blk{i}_wv"])
        d_h = c.embed_dim // c.n_heads
        qh = q.reshape(b, l + 1, c.n_heads, d_h)
        kh = k.reshape(b, l + 1, c.n_heads, d_h)
        vh = v.reshape(b, l + 1, c.n_heads, d_h)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qh, kh) / np.sqrt(d_h)
        attn = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", attn, vh).reshape(b, l + 1, -1)
        h = layer_norm(x + dense_layer(out, params[f"blk{i}_wo"]),
                       params[f"blk{i}_ln1_w"], params[f"blk{i}_ln1_b"])
        ff = dense_layer(
            jax.nn.relu(dense_layer(h, params[f"blk{i}_ffn_w1"], params[f"blk{i}_ffn_b1"])),
            params[f"blk{i}_ffn_w2"], params[f"blk{i}_ffn_b2"])
        x = layer_norm(h + ff, params[f"blk{i}_ln2_w"], params[f"blk{i}_ln2_b"])
    other_emb = batch.get("_rows_other")
    if other_emb is None:
        other_offs = jnp.asarray(np.delete(np.asarray(mt.offsets), c.item_field),
                                 jnp.int32)
        other_gids = other.astype(jnp.int32) + other_offs[None, :]
        if c.dedup_lookup:
            cap = c.dedup_capacity or int(np.prod(other_gids.shape))
            other_emb = lookup_dedup(params["embed"], other_gids, capacity=cap)
        else:
            other_emb = lookup(params["embed"], other_gids)       # (B, F-1, D)
    feat = jnp.concatenate([x.reshape(b, -1), other_emb.reshape(b, -1)], axis=1)
    n_top = len(c.top_mlp) + 1
    logit = mlp(feat,
                [params[f"top_w{i}"] for i in range(n_top)],
                [params[f"top_b{i}"] for i in range(n_top)])
    return logit[:, 0]


_FORWARDS: Dict[str, Callable] = {
    "dlrm": _dlrm_forward,
    "dcnv2": _dcnv2_forward,
    "autoint": _autoint_forward,
    "bst": _bst_forward,
}


def forward(params: Params, c: RecsysConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """Batch -> CTR logits (B,)."""
    return _FORWARDS[c.kind](params, c, batch, c.multi_table())


def loss_fn(params: Params, c: RecsysConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    logits = forward(params, c, batch)
    return sigmoid_bce(logits, batch["label"]).mean()


def make_train_step(c: RecsysConfig, optimizer):
    """Dense train step: differentiates the whole tree (small-table path)."""
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, c, batch))(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss}
    return train_step


def make_sparse_train_step(c: RecsysConfig, dense_optimizer, *,
                           embed_lr: float = 0.01, embed_eps: float = 1e-10,
                           mesh=None, batch_axes=None,
                           local_dedup_capacity: int = 0):
    """Hierarchical-PS train step ([37]/FeatureBox): working-set embeddings.

    1. dedup the batch's global ids into a fixed working set (OUTSIDE grad);
    2. gather working rows + their Adagrad accumulators (the only table
       traffic — proportional to unique ids, not batch x fields x dim);
    3. differentiate w.r.t. (working rows, dense params);
    4. Adagrad the working rows, Adam/whatever the dense params;
    5. scatter updated rows + accumulators back.

    The optimizer state carries a per-row Adagrad accumulator ``embed_accum``
    (f32[V_total]) next to the dense optimizer's state.
    """
    from repro.embedding.dedup import dedup

    def init(params):
        dense_params = {k: v for k, v in params.items() if k != "embed"}
        return {
            "dense": dense_optimizer.init(dense_params),
            "embed_accum": jnp.full((params["embed"].shape[0],), 0.1, jnp.float32),
        }

    def abstract_state(params):
        dense_params = {k: v for k, v in params.items() if k != "embed"}
        return {
            "dense": dense_optimizer.abstract_state(dense_params),
            "embed_accum": jax.ShapeDtypeStruct((params["embed"].shape[0],), jnp.float32),
        }

    def train_step(params, opt_state, batch):
        gids = collect_gids(c, batch)
        sites = sorted(gids.keys())
        flat_all = jnp.concatenate([gids[s].reshape(-1) for s in sites])
        cap = c.dedup_capacity or int(flat_all.shape[0])
        if mesh is not None and batch_axes is not None and local_dedup_capacity:
            # two-stage dedup: shrink the globally-sorted pool (§Perf pair 1)
            from repro.embedding.dedup import dedup_hierarchical
            unique, inverse, n_unique = dedup_hierarchical(
                flat_all, capacity=cap, mesh=mesh, axes=batch_axes,
                local_capacity=local_dedup_capacity)
        else:
            unique, inverse, n_unique = dedup(flat_all, capacity=cap)
        safe = jnp.where(unique == jnp.int32(2**31 - 1), 0, unique)
        working = jnp.take(params["embed"], safe, axis=0)        # (cap, D)

        # split inverse back per call site
        inv_by_site = {}
        off = 0
        for s in sites:
            n = int(np.prod(gids[s].shape))
            inv_by_site[s] = inverse[off: off + n].reshape(gids[s].shape)
            off += n

        dense_params = {k: v for k, v in params.items() if k != "embed"}

        def local_loss(dense_p, working_rows):
            rows = {f"_rows_{s}": jnp.take(working_rows, inv_by_site[s], axis=0)
                    for s in sites}
            b2 = dict(batch)
            b2.update(rows)
            p2 = dict(dense_p)
            p2["embed"] = params["embed"]  # untouched by grad (rows injected)
            logits = forward(p2, c, b2)
            return sigmoid_bce(logits, batch["label"]).mean()

        loss, (gd, gw) = jax.value_and_grad(local_loss, argnums=(0, 1))(
            dense_params, working)

        # dense update
        new_dense, new_dense_state = dense_optimizer.update(
            dense_params, gd, opt_state["dense"])

        # Adagrad on working rows only
        gw = gw.astype(jnp.float32)
        valid = (unique != jnp.int32(2**31 - 1)).astype(jnp.float32)[:, None]
        gw = gw * valid
        gsq = jnp.sum(gw * gw, axis=-1)
        accum_rows = jnp.take(opt_state["embed_accum"], safe) + gsq
        scale = embed_lr / (jnp.sqrt(accum_rows) + embed_eps)
        new_rows = (working.astype(jnp.float32) - scale[:, None] * gw)
        # mode="drop": FILL ids are out of bounds, so padded slots write
        # nothing. Scattering via ``safe`` would alias every pad slot onto
        # row 0 and could clobber row 0's real update (duplicate-index
        # scatter order is unspecified) — observed as a one-row divergence
        # from the hierarchical-PS path, which pads host-side and never
        # pushes pad slots.
        embed = params["embed"].at[unique].set(
            new_rows.astype(params["embed"].dtype), mode="drop")
        accum = opt_state["embed_accum"].at[unique].set(accum_rows, mode="drop")

        new_params = dict(new_dense)
        new_params["embed"] = embed
        # "unique"/"n_ids" feed the train-feed tier's dedup accounting
        # (TrainFeedStats.unique_ratio: collective traffic is proportional
        # to unique, not batch x fields — the [37]/FeatureBox win).
        metrics = {"loss": loss, "unique": n_unique,
                   "n_ids": jnp.int32(flat_all.shape[0])}
        return new_params, {"dense": new_dense_state, "embed_accum": accum}, metrics

    return train_step, init, abstract_state


def dense_param_elems(c: RecsysConfig) -> int:
    """Total element count of the dense (non-embedding) parameter tree —
    the gradient volume the mesh step's cross-pod all-reduce carries."""
    return int(sum(np.prod(s) for k, s in param_shapes(c).items()
                   if k != "embed"))


def batch_id_count(c: RecsysConfig, rows: int) -> int:
    """Flat id count :func:`collect_gids` yields for ``rows`` examples
    (the comm plan's per-device raw-id volume)."""
    if c.kind == "bst":
        return rows * (c.seq_len + 1) + rows * (c.n_sparse - 1)
    return rows * c.n_sparse


def make_mesh_train_step(c: RecsysConfig, dense_optimizer, *,
                         mesh, embed_lr: float = 0.01,
                         embed_eps: float = 1e-10,
                         local_dedup_capacity: int = 0,
                         compress: Any = None, hierarchical: bool = True,
                         pod_axis: str = "pod", data_axis: str = "data"):
    """Data-parallel working-set train step on a ('pod', 'data') mesh.

    The scale-out form of :func:`make_sparse_train_step` — same arithmetic,
    distributed per the FeatureBox authors' recipe (arXiv 2201.05500 +
    2003.05622): the packed table and its Adagrad accumulators are
    **row-sharded** over the flattened mesh (``P(('pod','data'), None)``),
    the batch is row-split the same way, and each device runs this body
    under ``shard_map``:

    1. **two-stage dedup** — local ``jnp.unique`` bounds the pooled sort to
       ``n_devices x local_capacity`` ids, then a global unique of the
       all-gathered pool (:func:`repro.embedding.dedup.dedup_two_stage_local`);
    2. **working-set exchange** — each device contributes the unique rows +
       accumulators it owns (out-of-shard slots zeroed), one fp32
       hierarchical reduction replicates the working set everywhere;
    3. forward/backward on the local batch rows against the replicated
       working set (identical ``local_loss`` to the single-device step);
    4. **gradient reduction** — working-set grads and the flattened dense
       grads each go through :func:`repro.train.compression.hierarchical_psum`
       (reduce-scatter in-pod, *compressed* wire + fp32 accumulation
       across pods, all-gather in-pod) or :func:`flat_psum` when
       ``hierarchical=False``. The dense reduction carries the codec's
       error-feedback residual in ``opt_state["comm_residual"]``
       (``f32[n_pods, padded_dense_elems]``, sharded so each device owns
       exactly its reduce-scattered shard's residual). Working-set grads
       are compressed statelessly: their slots map to *different* rows
       every step, so a carried residual would mix rows.
    5. replicated Adagrad on the working set, each device scattering back
       only the rows it owns (``mode="drop"``); dense update replicated.

    On a **1x1 mesh with compression off** every collective is an
    identity and every pad/slice is a no-op, so losses, params, and
    optimizer state are bitwise-identical to
    :func:`make_sparse_train_step` (asserted in ``tests/test_mesh.py``).
    ``metrics["local_unique"]`` adds the summed stage-1 unique counts (the
    pooled-exchange volume the ``comm`` tier reports).
    """
    from repro.embedding.dedup import FILL, dedup_two_stage_local
    from repro.train.compression import (
        codec_name, flat_psum, hierarchical_psum)

    axes = (pod_axis, data_axis)
    n_pods = int(mesh.shape[pod_axis])
    inner = int(mesh.shape[data_axis])
    n_dev = n_pods * inner
    codec = codec_name(compress)
    if c.padded_rows % n_dev:
        raise ValueError(
            f"padded table rows {c.padded_rows} do not shard evenly over "
            f"{n_dev} devices — raise RecsysConfig.row_align")

    n_dense = dense_param_elems(c)
    npad_dense = -(-n_dense // inner) * inner  # reduce-scatter granularity

    def _pad_to_inner(v):
        n = int(v.shape[0])
        npad = -(-n // inner) * inner
        if npad == n:
            return v
        return jnp.concatenate([v, jnp.zeros((npad - n,), v.dtype)])

    def _reduce(vec, *, codec=None, residual=None):
        """All-reduce a 1-D fp32 vector over the whole mesh."""
        if hierarchical:
            return hierarchical_psum(vec, pod_axis=pod_axis,
                                     inner_axis=data_axis,
                                     compress=codec, residual=residual)
        return flat_psum(vec, pod_axis=pod_axis, inner_axis=data_axis), residual

    def init(params):
        dense_params = {k: v for k, v in params.items() if k != "embed"}
        st = {
            "dense": dense_optimizer.init(dense_params),
            "embed_accum": jnp.full((params["embed"].shape[0],), 0.1,
                                    jnp.float32),
        }
        if codec is not None:
            st["comm_residual"] = jnp.zeros((n_pods, npad_dense), jnp.float32)
        return st

    def abstract_state(params):
        dense_params = {k: v for k, v in params.items() if k != "embed"}
        st = {
            "dense": dense_optimizer.abstract_state(dense_params),
            "embed_accum": jax.ShapeDtypeStruct((params["embed"].shape[0],),
                                                jnp.float32),
        }
        if codec is not None:
            st["comm_residual"] = jax.ShapeDtypeStruct(
                (n_pods, npad_dense), jnp.float32)
        return st

    def _device_step(params, opt_state, batch):
        embed_shard = params["embed"]                   # (rows/n_dev, D)
        accum_shard = opt_state["embed_accum"]          # (rows/n_dev,)
        shard_rows = int(embed_shard.shape[0])
        dense_params = {k: v for k, v in params.items() if k != "embed"}
        dev = (jax.lax.axis_index(pod_axis) * inner
               + jax.lax.axis_index(data_axis))
        lo = dev * shard_rows                           # first owned row

        gids = collect_gids(c, batch)                   # local batch shard
        sites = sorted(gids.keys())
        flat_local = jnp.concatenate([gids[s].reshape(-1) for s in sites])
        n_local = int(flat_local.shape[0])
        cap = c.dedup_capacity or n_local * n_dev
        local_cap = local_dedup_capacity or min(cap, n_local)
        if n_dev == 1:
            # stage 1 must never overflow when it IS the whole dedup
            local_cap = min(cap, n_local)

        unique, inverse, n_unique, local_count = dedup_two_stage_local(
            flat_local, capacity=cap, local_capacity=local_cap,
            gather_axes=axes)

        # -------- working-set exchange: each device contributes owned rows
        local_idx = unique - lo                         # FILL -> huge
        owned = (local_idx >= 0) & (local_idx < shard_rows)
        idx = jnp.clip(local_idx, 0, shard_rows - 1)
        contrib = jnp.where(owned[:, None],
                            jnp.take(embed_shard, idx, axis=0), 0.0)
        acc_contrib = jnp.where(owned, jnp.take(accum_shard, idx), 0.0)
        packed = jnp.concatenate([
            contrib.astype(jnp.float32).reshape(-1), acc_contrib])
        red, _ = _reduce(_pad_to_inner(packed))         # fp32, never quantized
        working = red[:cap * c.embed_dim].reshape(cap, c.embed_dim)
        accum_rows0 = red[cap * c.embed_dim: cap * c.embed_dim + cap]

        inv_by_site = {}
        off = 0
        for s in sites:
            n = int(np.prod(gids[s].shape))
            inv_by_site[s] = inverse.reshape(-1)[off: off + n].reshape(
                gids[s].shape)
            off += n

        def local_loss(dense_p, working_rows):
            rows = {f"_rows_{s}": jnp.take(working_rows, inv_by_site[s],
                                           axis=0)
                    for s in sites}
            b2 = dict(batch)
            b2.update(rows)
            logits = forward(dict(dense_p), c, b2)
            return sigmoid_bce(logits, batch["label"]).mean()

        loss, (gd, gw) = jax.value_and_grad(local_loss, argnums=(0, 1))(
            dense_params, working.astype(c.dtype))

        # -------- gradient reduction (the compressed inter-pod wire)
        gw = gw.astype(jnp.float32)
        valid = (unique != FILL).astype(jnp.float32)[:, None]
        gw = gw * valid
        # stateless codec: working-set slots alias different rows each step
        gw_red, _ = _reduce(_pad_to_inner(gw.reshape(-1)), codec=codec)
        gw = gw_red[:cap * c.embed_dim].reshape(cap, c.embed_dim)

        gd_leaves, gd_def = jax.tree.flatten(gd)
        gd_flat = jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in gd_leaves])
        residual = (opt_state["comm_residual"][0]
                    if codec is not None else None)
        gd_red, new_residual = _reduce(_pad_to_inner(gd_flat), codec=codec,
                                       residual=residual)
        if n_dev > 1:
            inv_ndev = np.float32(1.0 / n_dev)
            loss = jax.lax.psum(loss, axes) * inv_ndev
            gw = gw * inv_ndev
            gd_red = gd_red * inv_ndev
            local_count = jax.lax.psum(local_count, axes)
        parts, off = [], 0
        for leaf in gd_leaves:
            n = int(np.prod(leaf.shape))
            parts.append(gd_red[off: off + n].reshape(leaf.shape)
                         .astype(leaf.dtype))
            off += n
        gd = jax.tree.unflatten(gd_def, parts)

        # -------- replicated updates, sharded write-back
        new_dense, new_dense_state = dense_optimizer.update(
            dense_params, gd, opt_state["dense"])

        gsq = jnp.sum(gw * gw, axis=-1)
        accum_rows = accum_rows0 + gsq
        scale = embed_lr / (jnp.sqrt(accum_rows) + embed_eps)
        new_rows = working - scale[:, None] * gw
        # scatter only the rows this shard owns; everything else (other
        # shards' rows AND FILL pad slots) routes out of bounds -> dropped
        target = jnp.where(owned, local_idx, shard_rows)
        embed_shard = embed_shard.at[target].set(
            new_rows.astype(embed_shard.dtype), mode="drop")
        accum_shard = accum_shard.at[target].set(accum_rows, mode="drop")

        new_params = dict(new_dense)
        new_params["embed"] = embed_shard
        new_opt = {"dense": new_dense_state, "embed_accum": accum_shard}
        if codec is not None:
            new_opt["comm_residual"] = new_residual[None]
        metrics = {"loss": loss, "unique": n_unique,
                   "n_ids": jnp.int32(n_local * n_dev),
                   "local_unique": local_count}
        return new_params, new_opt, metrics

    def train_step(params, opt_state, batch):
        rows = int(batch["label"].shape[0])
        if rows % n_dev:
            raise ValueError(
                f"batch of {rows} rows does not split over {n_dev} mesh "
                f"devices — pick a batch size divisible by the mesh")
        pspec = {k: (P(axes, None) if k == "embed" else P())
                 for k in params}
        ospec = {
            "dense": jax.tree.map(lambda _: P(), opt_state["dense"]),
            "embed_accum": P(axes),
        }
        if codec is not None:
            ospec["comm_residual"] = P(pod_axis, data_axis)
        bspec = {k: P(axes) for k in batch}
        mspec = {"loss": P(), "unique": P(), "n_ids": P(),
                 "local_unique": P()}
        fn = jax.shard_map(_device_step, mesh=mesh,
                           in_specs=(pspec, ospec, bspec),
                           out_specs=(pspec, ospec, mspec),
                           check_vma=False)
        return fn(params, opt_state, batch)

    return train_step, init, abstract_state


def shard_train_state(mesh, params: Params, opt_state: Dict[str, Any], *,
                      pod_axis: str = "pod", data_axis: str = "data"):
    """Place (params, opt_state) per the mesh step's sharding contract:
    embedding rows + Adagrad accumulators split over the flattened mesh,
    the dense tree replicated, the codec residual (when present) split so
    each device owns its reduce-scattered shard."""
    from jax.sharding import NamedSharding

    axes = (pod_axis, data_axis)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    new_params = {k: put(v, P(axes, None) if k == "embed" else P())
                  for k, v in params.items()}
    new_opt: Dict[str, Any] = {
        "dense": jax.tree.map(lambda v: put(v, P()), opt_state["dense"]),
        "embed_accum": put(opt_state["embed_accum"], P(axes)),
    }
    if "comm_residual" in opt_state:
        new_opt["comm_residual"] = put(opt_state["comm_residual"],
                                       P(pod_axis, data_axis))
    return new_params, new_opt


def gid_site_shapes(c: RecsysConfig, batch: Dict[str, Any]) -> Dict[str, Tuple[int, ...]]:
    """Shapes of :func:`collect_gids`'s per-site id arrays, without tracing
    the id arithmetic. Shared by the hierarchy train step (which splits a
    host-computed inverse back per site) and its host twin
    :func:`repro.embedding.psfeed.collect_gids_np` — the flat concat order
    is ``sorted(sites)`` in both."""
    if c.kind == "bst":
        b, l = batch["seq"].shape
        return {"other": (b, c.n_sparse - 1), "seq": (b, l + 1)}
    return {"sparse": tuple(batch["sparse"].shape)}


def make_hierarchy_train_step(c: RecsysConfig, dense_optimizer, *,
                              embed_lr: float = 0.01, embed_eps: float = 1e-10):
    """Working-set train step for the hierarchical PS backend.

    Same arithmetic as :func:`make_sparse_train_step`, but the working set
    arrives *in the batch* (pulled host-side by
    :class:`repro.embedding.psfeed.HierarchyFeed`) instead of being gathered
    from a device-resident table:

    * ``_ws_rows``    f32[cap, D]  pulled working rows (FILL slots padded);
    * ``_ws_accum``   f32[cap]     pulled Adagrad accumulators;
    * ``_ws_unique``  int32[cap]   unique global ids, FILL-padded;
    * ``_ws_inverse`` int32[N]     flat inverse over the sorted-site concat.

    ``params`` carries the dense tree only (no ``"embed"``); the updated
    rows/accumulators come back in the metrics (``ws_rows``/``ws_accum``)
    for the async write-back ``push()``. For valid (non-FILL) slots the
    loss and row updates are bitwise-identical to the in-memory step as
    long as the pulled rows/accumulators match the table — asserted in
    ``tests/test_hierarchy.py``.
    """
    FILL = jnp.int32(2**31 - 1)

    def init(params):
        return {"dense": dense_optimizer.init(params)}

    def abstract_state(params):
        return {"dense": dense_optimizer.abstract_state(params)}

    def train_step(params, opt_state, batch):
        working = batch["_ws_rows"]
        unique = batch["_ws_unique"]
        inverse = batch["_ws_inverse"]
        shapes = gid_site_shapes(c, batch)
        sites = sorted(shapes)

        inv_by_site = {}
        off = 0
        for s in sites:
            n = int(np.prod(shapes[s]))
            inv_by_site[s] = inverse[off: off + n].reshape(shapes[s])
            off += n

        def local_loss(dense_p, working_rows):
            rows = {f"_rows_{s}": jnp.take(working_rows, inv_by_site[s], axis=0)
                    for s in sites}
            b2 = dict(batch)
            b2.update(rows)
            logits = forward(dict(dense_p), c, b2)
            return sigmoid_bce(logits, batch["label"]).mean()

        loss, (gd, gw) = jax.value_and_grad(local_loss, argnums=(0, 1))(
            params, working)

        new_dense, new_dense_state = dense_optimizer.update(
            params, gd, opt_state["dense"])

        # Adagrad on working rows only (same math as the in-memory step;
        # padded FILL slots carry zero grads and keep their pulled values).
        gw = gw.astype(jnp.float32)
        valid = (unique != FILL).astype(jnp.float32)[:, None]
        gw = gw * valid
        gsq = jnp.sum(gw * gw, axis=-1)
        accum_rows = batch["_ws_accum"] + gsq
        scale = embed_lr / (jnp.sqrt(accum_rows) + embed_eps)
        new_rows = (working.astype(jnp.float32) - scale[:, None] * gw)
        new_rows = jnp.where(valid > 0, new_rows, working)

        metrics = {"loss": loss,
                   "unique": jnp.sum(unique != FILL).astype(jnp.int32),
                   "n_ids": jnp.int32(inverse.shape[0]),
                   "ws_rows": new_rows, "ws_accum": accum_rows}
        return new_dense, {"dense": new_dense_state}, metrics

    return train_step, init, abstract_state


def serve_step(params: Params, c: RecsysConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """Online/offline scoring: batch -> pCTR (B,)."""
    return jax.nn.sigmoid(forward(params, c, batch))


def retrieval_score(params: Params, c: RecsysConfig, user_batch: Dict[str, jax.Array],
                    candidate_ids: jax.Array) -> jax.Array:
    """Score ONE user context against many candidates (batched, no loop).

    User-side features (batch size 1) are broadcast across the candidate
    axis; the candidate id replaces the item field. This is full-model
    scoring at candidate batch size — the `retrieval_cand` shape.
    """
    n = candidate_ids.shape[0]
    batch: Dict[str, jax.Array] = {}
    for key, v in user_batch.items():
        if key == "label":
            continue
        batch[key] = jnp.broadcast_to(v, (n,) + v.shape[1:])
    sparse = batch["sparse"].at[:, c.item_field].set(candidate_ids.astype(jnp.int32))
    batch["sparse"] = sparse
    return jax.nn.sigmoid(forward(params, c, batch))
