"""Mixture-of-Experts FFN (DeepSeek-style: shared + fine-grained routed).

Dispatch is sort-based with per-expert capacity (GShard-style dropping, no
giant one-hot einsum): tokens' (token, k) assignments are sorted by expert,
positions within each expert come from the sorted order, and tokens beyond
capacity are dropped. The heavy compute is two grouped einsums on the MXU.

Distribution (DESIGN.md §5): experts are sharded over the ``model`` mesh axis
(EP), tokens over ``data``(+``pod``). Inside ``shard_map`` each model rank
routes its replicated token shard, builds ONLY its local experts' dispatch
buffer, runs the expert FFN, scatters partial outputs back to token order and
``psum``s over ``model``. For very large expert weights (DeepSeek-V2) the
hidden dim ``f`` is additionally sharded over ``data`` and all-gathered at
use (ZeRO-3); the gather shows up in the roofline's collective term.

The same math runs without a mesh (``mesh=None``) for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

compat.install()  # jax.shard_map on older jax

from jax.sharding import PartitionSpec as P

from repro.models.common import dense


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    # sharding: experts over tp_axis; expert hidden dim over fsdp_axis (ZeRO-3)
    shard_ff_over_data: bool = False


def moe_params_shape(d_model: int, c: MoEConfig) -> Dict[str, Tuple[int, ...]]:
    e, f = c.n_experts, c.d_ff_expert
    shapes = {
        "router": (d_model, e),
        "w1": (e, d_model, f),
        "w3": (e, d_model, f),
        "w2": (e, f, d_model),
    }
    if c.n_shared:
        fs = c.n_shared * f
        shapes.update({
            "sw1": (d_model, fs),
            "sw3": (d_model, fs),
            "sw2": (fs, d_model),
        })
    return shapes


def _route(x: jax.Array, router: jax.Array, c: MoEConfig):
    """Softmax routing + top-k with renormalized combine weights."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    top_p, top_e = jax.lax.top_k(probs, c.top_k)                # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    me = probs.mean(axis=0)
    onehot = jax.nn.one_hot(top_e[:, 0], c.n_experts, dtype=jnp.float32)
    fe = onehot.mean(axis=0)
    aux = c.n_experts * jnp.sum(fe * me)
    return top_e.astype(jnp.int32), top_p, aux


def _dispatch_indices(top_e: jax.Array, c: MoEConfig, capacity: int):
    """Sort-based dispatch plan: for each (token, k) -> (expert, slot, keep)."""
    t, k = top_e.shape
    flat_e = top_e.reshape(-1)                                   # (T*K,)
    order = jnp.argsort(flat_e, stable=True)                     # sort by expert
    sorted_e = flat_e[order]
    counts = jax.ops.segment_sum(
        jnp.ones_like(sorted_e), sorted_e, num_segments=c.n_experts)
    starts = jnp.cumsum(counts) - counts                         # expert offsets
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos < capacity
    token = (order // k).astype(jnp.int32)
    return order, sorted_e, pos, keep, token


def _expert_ffn(xe: jax.Array, w1, w3, w2) -> jax.Array:
    """Grouped SwiGLU over (E_loc, C, d) with weights (E_loc, d, f)/(E_loc, f, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w1.astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w3.astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(xe.dtype))


def _moe_local(x, router, w1, w3, w2, c: MoEConfig, *, n_local: int,
               local_offset, fsdp_axis=None, tp_axis=None):
    """Token shard + local experts -> partial output (psum'd by caller)."""
    t, d = x.shape
    capacity = int(np.ceil(t * c.top_k / c.n_experts * c.capacity_factor))
    capacity = max(capacity, 1)
    top_e, top_p, aux = _route(x, router, c)
    order, sorted_e, pos, keep, token = _dispatch_indices(top_e, c, capacity)

    if fsdp_axis is not None:
        # ZeRO-3: expert hidden dim gathered at use
        w1 = jax.lax.all_gather(w1, fsdp_axis, axis=2, tiled=True)
        w3 = jax.lax.all_gather(w3, fsdp_axis, axis=2, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp_axis, axis=1, tiled=True)

    local_lo = local_offset * n_local
    is_local = keep & (sorted_e >= local_lo) & (sorted_e < local_lo + n_local)
    local_slot = (sorted_e - local_lo) * capacity + jnp.minimum(pos, capacity - 1)
    safe_slot = jnp.where(is_local, local_slot, n_local * capacity)

    gathered = x[token] * is_local[:, None].astype(x.dtype)      # (T*K, d)
    buf = jnp.zeros((n_local * capacity + 1, d), x.dtype)
    buf = buf.at[safe_slot].set(gathered)                         # unique slots
    xe = buf[:-1].reshape(n_local, capacity, d)

    ye = _expert_ffn(xe, w1, w3, w2)                              # (E_loc, C, d)
    ye_flat = ye.reshape(-1, d)
    back = ye_flat[jnp.minimum(safe_slot, n_local * capacity - 1)]
    back = back * is_local[:, None].astype(back.dtype)
    wsorted = top_p.reshape(-1)[order].astype(back.dtype)
    out = jax.ops.segment_sum(back * wsorted[:, None], token, num_segments=t)
    if tp_axis is not None:
        out = jax.lax.psum(out, tp_axis)
        aux = jax.lax.pmean(aux, tp_axis)
    return out, aux


def moe_ffn(
    params: Dict[str, jax.Array],
    x: jax.Array,                     # (T, d) flattened tokens
    c: MoEConfig,
    *,
    mesh=None,
    dp_axes: Tuple[str, ...] = ("data",),
    tp_axis: str = "model",
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN over flattened tokens. Returns (out (T, d), aux loss scalar)."""
    if mesh is None:
        out, aux = _moe_local(
            x, params["router"], params["w1"], params["w3"], params["w2"], c,
            n_local=c.n_experts, local_offset=jnp.int32(0))
    else:
        n_tp = mesh.shape[tp_axis]
        if c.n_experts % n_tp:
            raise ValueError(f"{c.n_experts} experts not divisible by tp={n_tp}")
        n_local = c.n_experts // n_tp
        ff_spec = P(tp_axis, None, "data") if c.shard_ff_over_data else P(tp_axis, None, None)
        ff_spec_w2 = P(tp_axis, "data", None) if c.shard_ff_over_data else P(tp_axis, None, None)
        fsdp_axis = "data" if c.shard_ff_over_data else None

        def fn(xs, router, w1, w3, w2):
            return _moe_local(
                xs, router, w1, w3, w2, c,
                n_local=n_local,
                local_offset=jax.lax.axis_index(tp_axis),
                fsdp_axis=fsdp_axis,
                tp_axis=tp_axis,
            )

        out, aux = jax.shard_map(
            fn,
            mesh=mesh,
            in_specs=(P(dp_axes, None), P(None, None), ff_spec, ff_spec, ff_spec_w2),
            out_specs=(P(dp_axes, None), P()),
            check_vma=False,
        )(x, params["router"], params["w1"], params["w3"], params["w2"])
        aux = aux.mean() if aux.ndim else aux

    if c.n_shared:
        h = jax.nn.silu(dense(x, params["sw1"])) * dense(x, params["sw3"])
        out = out + dense(h, params["sw2"])
    return out, aux


def moe_ffn_ref(params: Dict[str, jax.Array], x: jax.Array, c: MoEConfig) -> jax.Array:
    """Dense oracle: every expert computed for every token (tests only).

    Matches ``moe_ffn`` exactly when no token exceeds capacity.
    """
    top_e, top_p, _ = _route(x, params["router"], c)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w1"].astype(x.dtype)))
    h = h * jnp.einsum("td,edf->tef", x, params["w3"].astype(x.dtype))
    ye = jnp.einsum("tef,efd->ted", h, params["w2"].astype(x.dtype))  # (T, E, d)
    combine = jnp.zeros((x.shape[0], c.n_experts), x.dtype)
    for k in range(c.top_k):
        combine = combine + jax.nn.one_hot(top_e[:, k], c.n_experts,
                                           dtype=x.dtype) * top_p[:, k:k + 1].astype(x.dtype)
    out = jnp.einsum("te,ted->td", combine, ye)
    if c.n_shared:
        hs = jax.nn.silu(dense(x, params["sw1"])) * dense(x, params["sw3"])
        out = out + dense(hs, params["sw2"])
    return out
