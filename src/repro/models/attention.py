"""Attention: GQA + MLA (DeepSeek-V2), RoPE, chunked flash, KV-cache decode.

Memory discipline is the point here: prefill at 32k never materializes an
(S, S) score matrix — ``flash_attention`` scans KV blocks with running
max/denominator (online softmax), so peak live memory per (batch, head) is
O(q_block * kv_block). Decode paths read the cache once per token.

MLA (Multi-head Latent Attention, DeepSeek-V2 [arXiv:2405.04434]) stores only
the compressed latent ``c_kv`` (kv_lora_rank) + shared rope key per token; the
decode path scores against the latent directly via weight absorption, so the
32k cache is ~(512+64) per token instead of 2*H*Dh.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import dense

NEG_INF = -1e30


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, *, base: float = 10000.0) -> np.ndarray:
    return 1.0 / (base ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, *, base: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: int (..., S) or (S,)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, base=base))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- chunked flash attention
#
# Forward: online-softmax over kv blocks (never materializes (S, S)).
# Backward: custom VJP that RECOMPUTES scores blockwise from the saved
# (q, k, v, out, lse) — without it, jax's scan-transpose stacks every
# block's probabilities as residuals, i.e. O(S^2) HBM traffic per layer
# (measured: 25 TB/device/step on qwen-32b train_4k; see EXPERIMENTS §Perf).


def _flash_fwd_padded(q, k, v, causal, q_block, kv_block, s_orig):
    """Core forward on padded arrays. Returns out and per-query lse.

    q: (B, Sq, H, Dh); k: (B, Skv, Hk, Dh); v: (B, Skv, Hk, Dv).
    out: (B, Sq, H, Dv); lse: (B, Hk, G, Sq) float32.
    """
    b, s_pad, h, dh = q.shape
    skv_pad = k.shape[1]
    hk = k.shape[2]
    dv = v.shape[-1]
    g = h // hk
    scale = 1.0 / np.sqrt(dh)
    nq, nkv = s_pad // q_block, skv_pad // kv_block
    qr = q.reshape(b, nq, q_block, hk, g, dh)
    kr = k.reshape(b, nkv, kv_block, hk, dh)
    vr = v.reshape(b, nkv, kv_block, hk, dv)
    kv_pos = jnp.arange(skv_pad).reshape(nkv, kv_block)
    q_pos = jnp.arange(s_pad).reshape(nq, q_block)

    def per_qblock(qi):
        qb = qr[:, qi]

        def body(carry, kv_i):
            m, l, acc = carry
            kb, vb = kr[:, kv_i], vr[:, kv_i]
            scores = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb, preferred_element_type=jnp.float32
            ) * scale
            mask = (kv_pos[kv_i][None, :] <= q_pos[qi][:, None]) if causal else (
                jnp.ones((q_block, kv_block), bool))
            mask = mask & (kv_pos[kv_i] < s_orig)[None, :]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
            if causal:
                keep = (kv_i * kv_block) <= qi * q_block + (q_block - 1)
                m_new, l_new, acc_new = jax.tree.map(
                    lambda n, o: jnp.where(keep, n, o),
                    (m_new, l_new, acc_new), (m, l, acc))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, hk, g, q_block, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse  # (B,Hk,G,Qb,Dv), (B,Hk,G,Qb)

    outs, lses = jax.lax.map(per_qblock, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hk, g, s_pad, dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s_pad, h, dv).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hk, g, s_pad)
    return out, lse


def _flash_bwd_padded(q, k, v, out, lse, dout, causal, q_block, kv_block, s_orig):
    """Blockwise-recompute backward (FlashAttention-style)."""
    b, s_pad, h, dh = q.shape
    skv_pad = k.shape[1]
    hk = k.shape[2]
    dv = v.shape[-1]
    g = h // hk
    scale = 1.0 / np.sqrt(dh)
    nq, nkv = s_pad // q_block, skv_pad // kv_block
    qr = q.reshape(b, nq, q_block, hk, g, dh)
    kr = k.reshape(b, nkv, kv_block, hk, dh)
    vr = v.reshape(b, nkv, kv_block, hk, dv)
    do = dout.reshape(b, nq, q_block, hk, g, dv)
    o = out.reshape(b, nq, q_block, hk, g, dv)
    lse_r = lse.reshape(b, hk, g, nq, q_block)
    kv_pos = jnp.arange(skv_pad).reshape(nkv, kv_block)
    q_pos = jnp.arange(s_pad).reshape(nq, q_block)

    def per_qblock(carry, qi):
        dk_full, dv_full = carry
        qb = qr[:, qi].astype(jnp.float32)               # (B,Qb,Hk,G,Dh)
        dob = do[:, qi].astype(jnp.float32)
        ob = o[:, qi].astype(jnp.float32)
        lse_b = lse_r[:, :, :, qi]                       # (B,Hk,G,Qb)
        delta = jnp.einsum("bqhgd,bqhgd->bhgq", dob, ob)  # rowsum(do*o)

        def kv_body(carry_q, kv_i):
            dq_acc, dk_full, dv_full = carry_q
            kb = kr[:, kv_i].astype(jnp.float32)
            vb = vr[:, kv_i].astype(jnp.float32)
            scores = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb) * scale
            mask = (kv_pos[kv_i][None, :] <= q_pos[qi][:, None]) if causal else (
                jnp.ones((q_block, kv_block), bool))
            mask = mask & (kv_pos[kv_i] < s_orig)[None, :]
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            p = jnp.exp(scores - lse_b[..., None])       # normalized probs
            dvb = jnp.einsum("bhgqk,bqhgd->bkhd", p, dob)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob, vb)
            ds = p * (dp - delta[..., None]) * scale
            dqb = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb)
            dkb = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb)
            if causal:
                keep = (kv_i * kv_block) <= qi * q_block + (q_block - 1)
                zero = jnp.float32(0.0)
                dqb = jnp.where(keep, dqb, zero)
                dkb = jnp.where(keep, dkb, zero)
                dvb = jnp.where(keep, dvb, zero)
            dq_acc = dq_acc + dqb
            start = kv_i * kv_block
            dk_full = jax.lax.dynamic_update_slice_in_dim(
                dk_full,
                jax.lax.dynamic_slice_in_dim(dk_full, start, kv_block, 1) + dkb,
                start, axis=1)
            dv_full = jax.lax.dynamic_update_slice_in_dim(
                dv_full,
                jax.lax.dynamic_slice_in_dim(dv_full, start, kv_block, 1) + dvb,
                start, axis=1)
            return (dq_acc, dk_full, dv_full), None

        dq0 = jnp.zeros((b, q_block, hk, g, dh), jnp.float32)
        (dqb, dk_full, dv_full), _ = jax.lax.scan(
            kv_body, (dq0, dk_full, dv_full), jnp.arange(nkv))
        return (dk_full, dv_full), dqb

    dk0 = jnp.zeros((b, skv_pad, hk, dh), jnp.float32)
    dv0 = jnp.zeros((b, skv_pad, hk, dv), jnp.float32)
    (dk, dv_), dqs = jax.lax.scan(per_qblock, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, s_pad, h, dh)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv_.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_core(q, k, v, causal, q_block, kv_block, s_orig):
    out, _ = _flash_fwd_padded(q, k, v, causal, q_block, kv_block, s_orig)
    return out


def _flash_core_fwd(q, k, v, causal, q_block, kv_block, s_orig):
    out, lse = _flash_fwd_padded(q, k, v, causal, q_block, kv_block, s_orig)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, q_block, kv_block, s_orig, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_padded(q, k, v, out, lse, dout,
                             causal, q_block, kv_block, s_orig)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "kv_block"))
def flash_attention(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,  # (B, S, Hk, Dh)
    v: jax.Array,  # (B, S, Hk, Dv)
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Online-softmax attention with GQA head grouping, O(S) memory in S —
    in BOTH directions (custom VJP recomputes scores blockwise)."""
    b, s, h, dh = q.shape
    assert h % k.shape[2] == 0, (h, k.shape[2])

    s_pad = (s + q_block - 1) // q_block * q_block
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    skv_pad = (s + kv_block - 1) // kv_block * kv_block
    if skv_pad != s:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - s), (0, 0), (0, 0)))
    out = _flash_core(q, k, v, causal, q_block, kv_block, s)
    return out[:, :s]


def attention_ref(q, k, v, *, causal=True):
    """Quadratic oracle for flash_attention (tests only)."""
    b, s, h, dh = q.shape
    hk = k.shape[2]
    dv = v.shape[-1]
    g = h // hk
    qr = q.reshape(b, s, hk, g, dh).astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32))
    scores = scores / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, dv).astype(q.dtype)


# --------------------------------------------------------------- GQA block
def gqa_params_shape(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                     *, qkv_bias: bool) -> Dict[str, Tuple[int, ...]]:
    shapes = {
        "wq": (d_model, n_heads * head_dim),
        "wk": (d_model, n_kv * head_dim),
        "wv": (d_model, n_kv * head_dim),
        "wo": (n_heads * head_dim, d_model),
    }
    if qkv_bias:
        shapes.update({
            "bq": (n_heads * head_dim,),
            "bk": (n_kv * head_dim,),
            "bv": (n_kv * head_dim,),
        })
    return shapes


def gqa_attention(
    p: Dict[str, jax.Array],
    x: jax.Array,                    # (B, S, D)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions: Optional[jax.Array] = None,
    rope_base: float = 10000.0,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
    head_constraint=None,            # shard heads explicitly (SPMD hint)
) -> jax.Array:
    b, s, _ = x.shape
    pos = positions if positions is not None else jnp.arange(s)
    q = dense(x, p["wq"], p.get("bq")).reshape(b, s, n_heads, head_dim)
    k = dense(x, p["wk"], p.get("bk")).reshape(b, s, n_kv, head_dim)
    v = dense(x, p["wv"], p.get("bv")).reshape(b, s, n_kv, head_dim)
    q = apply_rope(q, pos, base=rope_base)
    k = apply_rope(k, pos, base=rope_base)
    if head_constraint is not None:
        # without this, sharding propagation through the custom-VJP reshapes
        # replicates attention activations over 'model' and all-reduces them
        # (measured 2.3 TB/device/step on yi-9b — EXPERIMENTS.md §Perf)
        q = head_constraint(q)
    out = flash_attention(q, k, v, causal=causal, q_block=q_block, kv_block=kv_block)
    if head_constraint is not None:
        out = head_constraint(out)
    return dense(out.reshape(b, s, n_heads * head_dim), p["wo"])


def gqa_decode_step(
    p: Dict[str, jax.Array],
    x: jax.Array,                    # (B, 1, D) current token
    cache_k: jax.Array,              # (B, S_cache, Hk, Dh)
    cache_v: jax.Array,
    cache_len: jax.Array,            # int32[] valid cache length
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_base: float = 10000.0,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One decode step against a fixed-size cache; returns (out, new kv)."""
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    pos = cache_len[None]  # current position
    q = dense(x, p["wq"], p.get("bq")).reshape(b, 1, n_heads, head_dim)
    k_new = dense(x, p["wk"], p.get("bk")).reshape(b, 1, n_kv, head_dim)
    v_new = dense(x, p["wv"], p.get("bv")).reshape(b, 1, n_kv, head_dim)
    q = apply_rope(q, pos, base=rope_base)
    k_new = apply_rope(k_new, pos, base=rope_base)
    k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), cache_len, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), cache_len, axis=1)

    g = n_heads // n_kv
    qr = q.reshape(b, n_kv, g, head_dim)
    scores = jnp.einsum("bhgd,bshd->bhgs", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(head_dim)
    valid = jnp.arange(s_cache) <= cache_len
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    pa = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgs,bshd->bhgd", pa, k_v_cast(v))
    out = dense(ctx.reshape(b, 1, n_heads * head_dim).astype(x.dtype), p["wo"])
    return out, (k, v)


def k_v_cast(v):
    return v.astype(jnp.float32)


# --------------------------------------------------------------- MLA block
@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


def mla_params_shape(c: MLAConfig) -> Dict[str, Tuple[int, ...]]:
    h = c.n_heads
    return {
        "wdq": (c.d_model, c.q_lora_rank),
        "wuq": (c.q_lora_rank, h * (c.qk_nope_dim + c.qk_rope_dim)),
        "wdkv": (c.d_model, c.kv_lora_rank),
        "wkrope": (c.d_model, c.qk_rope_dim),
        "wuk": (c.kv_lora_rank, h * c.qk_nope_dim),
        "wuv": (c.kv_lora_rank, h * c.v_head_dim),
        "wo": (h * c.v_head_dim, c.d_model),
    }


def mla_attention(p: Dict[str, jax.Array], x: jax.Array, c: MLAConfig,
                  *, positions: Optional[jax.Array] = None,
                  causal: bool = True, q_block: int = 512, kv_block: int = 512,
                  head_constraint=None) -> jax.Array:
    """Train/prefill MLA: reconstruct per-head K/V from the latent, flash attn."""
    b, s, _ = x.shape
    h = c.n_heads
    pos = positions if positions is not None else jnp.arange(s)
    q = dense(dense(x, p["wdq"]), p["wuq"]).reshape(b, s, h, c.qk_nope_dim + c.qk_rope_dim)
    q_nope, q_rope = q[..., : c.qk_nope_dim], q[..., c.qk_nope_dim:]
    q_rope = apply_rope(q_rope, pos)
    c_kv = dense(x, p["wdkv"])                               # (B,S,R)
    k_rope = apply_rope(dense(x, p["wkrope"])[:, :, None, :], pos)  # (B,S,1,rope)
    k_nope = dense(c_kv, p["wuk"]).reshape(b, s, h, c.qk_nope_dim)
    v = dense(c_kv, p["wuv"]).reshape(b, s, h, c.v_head_dim)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, c.qk_rope_dim))], axis=-1)
    if head_constraint is not None:
        qf, kf, v = head_constraint(qf), head_constraint(kf), head_constraint(v)
    out = flash_attention(qf, kf, v, causal=causal, q_block=q_block, kv_block=kv_block)
    if head_constraint is not None:
        out = head_constraint(out)
    return dense(out.reshape(b, s, h * c.v_head_dim), p["wo"])


def mla_decode_step(
    p: Dict[str, jax.Array],
    x: jax.Array,            # (B, 1, D)
    cache_ckv: jax.Array,    # (B, S_cache, R) latent cache
    cache_krope: jax.Array,  # (B, S_cache, rope_dim)
    cache_len: jax.Array,
    c: MLAConfig,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Decode against the COMPRESSED cache via weight absorption.

    score = q_nope^T W_uk c + q_rope^T k_rope ; ctx = softmax . c ; v = ctx W_uv
    — the per-token cache is kv_lora_rank + rope_dim elements, the MLA win.
    """
    b = x.shape[0]
    h = c.n_heads
    s_cache = cache_ckv.shape[1]
    pos = cache_len[None]
    q = dense(dense(x, p["wdq"]), p["wuq"]).reshape(b, h, c.qk_nope_dim + c.qk_rope_dim)
    q_nope, q_rope = q[..., : c.qk_nope_dim], q[..., c.qk_nope_dim:]
    q_rope = apply_rope(q_rope[:, None], pos, base=10000.0)[:, 0]  # treat heads dim as head axis
    ckv_new = dense(x, p["wdkv"])[:, 0]                        # (B,R)
    krope_new = apply_rope(dense(x, p["wkrope"])[:, :, None, :], pos)[:, 0, 0]  # (B,rope)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, ckv_new[:, None].astype(cache_ckv.dtype), cache_len, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, krope_new[:, None].astype(cache_krope.dtype), cache_len, axis=1)

    # absorb W_uk into the query: q_c (B, H, R)
    wuk = p["wuk"].reshape(c.kv_lora_rank, h, c.qk_nope_dim)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                     wuk.astype(jnp.float32))
    scores = jnp.einsum("bhr,bsr->bhs", q_c, ckv.astype(jnp.float32))
    scores = scores + jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                                 krope.astype(jnp.float32))
    scores = scores / np.sqrt(c.qk_nope_dim + c.qk_rope_dim)
    valid = jnp.arange(s_cache) <= cache_len
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    pa = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhs,bsr->bhr", pa, ckv.astype(jnp.float32))  # latent ctx
    wuv = p["wuv"].reshape(c.kv_lora_rank, h, c.v_head_dim)
    ctx = jnp.einsum("bhr,rhd->bhd", ctx_c, wuv.astype(jnp.float32))
    out = dense(ctx.reshape(b, 1, h * c.v_head_dim).astype(x.dtype), p["wo"])
    return out, (ckv, krope)
