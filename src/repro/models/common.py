"""Common model layers and initializers (pure-jnp, pytree params)."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def rms_norm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               *, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def swiglu(x: jax.Array, w1: jax.Array, w3: jax.Array, w2: jax.Array) -> jax.Array:
    """SwiGLU FFN: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(dense(x, w1)) * dense(x, w3)
    return dense(h, w2)


def mlp(x: jax.Array, ws, bs, *, act=jax.nn.relu, final_act=None) -> jax.Array:
    """Plain MLP over lists of weights/biases (recsys towers)."""
    for i, (w, b) in enumerate(zip(ws, bs)):
        x = dense(x, w, b)
        if i < len(ws) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ------------------------------------------------------------------- inits
def he_init(key, shape, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 1 else 1
    return jax.random.normal(key, shape, dtype) * np.sqrt(2.0 / max(fan_in, 1))


def glorot_init(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    lim = np.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


def keygen(key):
    """Infinite key splitter."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def sigmoid_bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Numerically-stable binary cross entropy from logits (CTR loss)."""
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE from integer labels; logits f[*, V], labels int[*]."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold
