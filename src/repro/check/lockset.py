"""Concurrency lockset audit (LK4xx): static checks on the pipeline threads.

The three-thread pipeline (fe-worker -> h2d-feeder -> main train loop, plus
the loader's reader pool) shares mutable state across threads. The
convention in :mod:`repro.check.annotations` declares that state —
``@guarded_by`` names the lock writes must hold, ``@shared_entry`` names
the methods other threads call into, ``@single_writer`` documents
deliberately unsynchronized single-owner fields — and this module verifies
the declarations against the source with ``ast`` alone (no imports of the
audited modules, no execution).

Model
-----
*Entry points* (roots) are methods that start a thread context on the
instance: discovered ``threading.Thread(target=self._x)`` targets (label
``thread:_x``), the spawning method itself (label ``main``), and declared
``@shared_entry`` methods (label prefix before ``:``, defaulting to the
method name). The checker walks the ``self.``-call graph from each root
and tags every reachable method with the root's thread labels.

*Writes* are ``Assign``/``AugAssign``/valued-``AnnAssign`` targets on
dotted ``self.`` paths (subscripts unwrapped: ``self._ring[b] = ...``
writes ``_ring``). A write is *lock-held* when it sits lexically inside
``with self.<lock>:`` — code deferred into nested ``def``/``lambda``
bodies is treated as running without the lock (it executes later).
``__init__``/``__post_init__`` are exempt (single-threaded construction).

Two write paths *conflict* when one is a prefix of the other (rebinding
``self.stats`` conflicts with a reader updating ``self.stats.shards``).
Declarations match by the same prefix rule.

Rules
-----
``LK401`` (error) — a ``self.`` path is written from two or more distinct
    thread labels with no ``guarded_by``/``single_writer`` declaration
    covering it. Undeclared cross-thread mutation is the bug class that
    produced the FeedStats races fixed in this PR; declare it, then hold
    the lock.

``LK402`` (error) — a write to a ``@guarded_by``-declared path outside
    ``with self.<lock>:`` in a method reachable from a thread entry point.
    Regression notes: this rule caught (a) ``DeviceFeeder._await_completion``
    bumping ``stats.donated`` / ``stats.stall_seconds`` without ``_lock``
    while reachable from both the h2d-feeder thread (``stage`` ->
    ``_claim_buffer``) and the main thread (``flush``), and (b)
    ``StreamingLoader.__iter__`` updating ``stats.consumer_stall_seconds``/
    ``stats.max_queue_depth``/``stats.wall_seconds`` (and rebinding
    ``stats``) without ``_lock`` while reader threads update sibling
    fields under it. Both were fixed in this PR by taking the declared
    lock around the writes.

``LK403`` (error) — a declaration that cannot hold: ``guarded_by`` names a
    lock attribute the class never assigns, or ``shared_entry`` names a
    method the class does not define.

``LK404`` (error) — a ``@single_writer`` path provably written from two or
    more distinct thread labels: the single-owner claim is false; guard it
    instead.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.check.findings import Finding

# Audited by default: the files owning the pipeline's thread-shared
# state (relative to the repro package root).
DEFAULT_FILES = ("core/pipeline.py", "core/devicefeed.py", "io/stream.py",
                 "embedding/psfeed.py", "train/fault.py", "io/chaos.py")

_DECOS = {"guarded_by", "shared_entry", "single_writer"}
_CTOR = {"__init__", "__post_init__"}


# --------------------------------------------------------------- AST helpers
def _self_path(node: ast.AST) -> Optional[str]:
    """Dotted attribute path rooted at ``self`` (subscripts unwrapped),
    e.g. ``self._inflight[b]`` -> ``_inflight``; non-self -> None."""
    parts: List[str] = []
    while True:
        while isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
            continue
        break
    if isinstance(node, ast.Name) and node.id == "self" and parts:
        return ".".join(reversed(parts))
    return None


def _conflicts(a: str, b: str) -> bool:
    """True when writes to paths ``a`` and ``b`` can race (prefix rule)."""
    return a == b or a.startswith(b + ".") or b.startswith(a + ".")


def _deco_call_name(deco: ast.expr) -> Optional[str]:
    fn = deco.func if isinstance(deco, ast.Call) else deco
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _str_args(call: ast.Call) -> List[str]:
    return [a.value for a in call.args
            if isinstance(a, ast.Constant) and isinstance(a.value, str)]


# ------------------------------------------------------------- method model
@dataclasses.dataclass
class _Write:
    path: str
    lineno: int
    locks: frozenset  # lock attribute names lexically held


@dataclasses.dataclass
class _Method:
    name: str
    lineno: int
    writes: List[_Write] = dataclasses.field(default_factory=list)
    calls: Set[str] = dataclasses.field(default_factory=set)
    spawns: List[str] = dataclasses.field(default_factory=list)


def _scan_method(fn: ast.AST) -> _Method:
    m = _Method(name=fn.name, lineno=fn.lineno)

    def collect_target(t: ast.expr, lineno: int, locks: frozenset) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                collect_target(el, lineno, locks)
            return
        if isinstance(t, ast.Starred):
            collect_target(t.value, lineno, locks)
            return
        path = _self_path(t)
        if path is not None:
            m.writes.append(_Write(path=path, lineno=lineno, locks=locks))

    def scan(node: ast.AST, locks: frozenset) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # Deferred execution: no lock is guaranteed held when this runs.
            for child in ast.iter_child_nodes(node):
                scan(child, frozenset())
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            held = set(locks)
            for item in node.items:
                p = _self_path(item.context_expr)
                if p is not None:
                    held.add(p)
                scan(item.context_expr, locks)
            for stmt in node.body:
                scan(stmt, frozenset(held))
            return
        if isinstance(node, ast.Assign):
            for t in node.targets:
                collect_target(t, node.lineno, locks)
        elif isinstance(node, ast.AugAssign):
            collect_target(node.target, node.lineno, locks)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            collect_target(node.target, node.lineno, locks)
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name)
                    and f.value.id == "self"):
                m.calls.add(f.attr)
            name = _deco_call_name(node)
            if name == "Thread":
                for kw in node.keywords:
                    if kw.arg == "target":
                        p = _self_path(kw.value)
                        if p is not None:
                            m.spawns.append(p)
        for child in ast.iter_child_nodes(node):
            scan(child, locks)

    for stmt in fn.body:
        scan(stmt, frozenset())
    return m


# --------------------------------------------------------------- class model
@dataclasses.dataclass
class _ClassInfo:
    name: str
    lineno: int
    guarded: Dict[str, str] = dataclasses.field(default_factory=dict)
    entries: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    single: List[str] = dataclasses.field(default_factory=list)
    methods: Dict[str, _Method] = dataclasses.field(default_factory=dict)
    assigned: Set[str] = dataclasses.field(default_factory=set)  # incl. ctor


def _parse_class(node: ast.ClassDef) -> _ClassInfo:
    info = _ClassInfo(name=node.name, lineno=node.lineno)
    for deco in node.decorator_list:
        if not isinstance(deco, ast.Call):
            continue
        name = _deco_call_name(deco)
        if name not in _DECOS:
            continue
        args = _str_args(deco)
        if name == "guarded_by" and len(args) >= 2:
            lock, attrs = args[0], args[1:]
            for a in attrs:
                info.guarded[a] = lock
        elif name == "shared_entry":
            for a in args:
                label, _, meth = a.rpartition(":")
                info.entries.append((label or meth, meth))
        elif name == "single_writer":
            info.single.extend(args)
    for stmt in node.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            m = _scan_method(stmt)
            info.methods[m.name] = m
            info.assigned.update(w.path.split(".")[0] for w in m.writes)
    return info


def _roots(info: _ClassInfo) -> Dict[str, Set[str]]:
    """Map entry-point method name -> set of thread labels."""
    roots: Dict[str, Set[str]] = {}
    for label, meth in info.entries:
        roots.setdefault(meth, set()).add(label)
    for m in info.methods.values():
        if m.spawns:
            roots.setdefault(m.name, set()).add("main")
            for tgt in m.spawns:
                roots.setdefault(tgt, set()).add(f"thread:{tgt}")
    return roots


def _reachable_labels(info: _ClassInfo,
                      roots: Dict[str, Set[str]]) -> Dict[str, Set[str]]:
    """Thread labels under which each method may run (call-graph BFS)."""
    labels: Dict[str, Set[str]] = {}
    for root, root_labels in roots.items():
        if root not in info.methods:
            continue
        seen: Set[str] = set()
        frontier = [root]
        while frontier:
            name = frontier.pop()
            if name in seen or name not in info.methods:
                continue
            seen.add(name)
            labels.setdefault(name, set()).update(root_labels)
            frontier.extend(info.methods[name].calls)
    return labels


# ------------------------------------------------------------------ checking
def _check_class(info: _ClassInfo, filename: str) -> List[Finding]:
    findings: List[Finding] = []
    loc = lambda line: f"{filename}:{line}"  # noqa: E731

    roots = _roots(info)
    # LK403: declarations that cannot hold.
    for lock in sorted(set(info.guarded.values())):
        if lock not in info.assigned:
            findings.append(Finding(
                rule="LK403", severity="error", location=loc(info.lineno),
                message=(f"{info.name}: @guarded_by names lock {lock!r}, "
                         f"but the class never assigns self.{lock}"),
                hint="create the lock in __init__ or fix the declaration"))
    for _, meth in info.entries:
        if meth not in info.methods:
            findings.append(Finding(
                rule="LK403", severity="error", location=loc(info.lineno),
                message=(f"{info.name}: @shared_entry names {meth!r}, "
                         f"which is not a method of the class"),
                hint="fix the method name in the declaration"))

    labels = _reachable_labels(info, roots)

    # Gather reachable writes with their thread labels (ctors exempt).
    writes: List[Tuple[_Write, Set[str], str]] = []
    for name, m in info.methods.items():
        if name in _CTOR:
            continue
        mlabels = labels.get(name)
        if not mlabels:
            continue
        for w in m.writes:
            writes.append((w, mlabels, name))

    # Union of thread labels across all conflicting writes, per path.
    path_labels: Dict[str, Set[str]] = {}
    for w, mlabels, _ in writes:
        path_labels.setdefault(w.path, set()).update(mlabels)

    def conflict_labels(path: str) -> Set[str]:
        out: Set[str] = set()
        for q, ls in path_labels.items():
            if _conflicts(q, path):
                out.update(ls)
        return out

    def guard_for(path: str) -> Optional[str]:
        for decl, lock in info.guarded.items():
            if _conflicts(path, decl):
                return lock
        return None

    def is_single(path: str) -> bool:
        return any(_conflicts(path, s) for s in info.single)

    flagged: Set[Tuple[str, str]] = set()  # (rule, path) dedup
    for w, _, meth in writes:
        lock = guard_for(w.path)
        if lock is not None:
            if lock not in w.locks:
                findings.append(Finding(
                    rule="LK402", severity="error", location=loc(w.lineno),
                    message=(f"{info.name}.{meth}: writes self.{w.path} "
                             f"(declared guarded by {lock!r}) without "
                             f"holding the lock"),
                    hint=f"wrap the write in `with self.{lock}:`"))
            continue
        racy = len(conflict_labels(w.path)) >= 2
        if not racy:
            continue
        if is_single(w.path):
            key = ("LK404", w.path)
            if key not in flagged:
                flagged.add(key)
                findings.append(Finding(
                    rule="LK404", severity="error", location=loc(w.lineno),
                    message=(f"{info.name}: self.{w.path} is declared "
                             f"@single_writer but is written from multiple "
                             f"thread entry points "
                             f"({', '.join(sorted(conflict_labels(w.path)))})"),
                    hint="guard it with a lock and declare @guarded_by"))
        else:
            key = ("LK401", w.path)
            if key not in flagged:
                flagged.add(key)
                findings.append(Finding(
                    rule="LK401", severity="error", location=loc(w.lineno),
                    message=(f"{info.name}: self.{w.path} is written from "
                             f"multiple thread entry points "
                             f"({', '.join(sorted(conflict_labels(w.path)))}) "
                             f"with no guarded_by/single_writer declaration"),
                    hint=("declare @guarded_by(<lock>, ...) and hold the "
                          "lock, or @single_writer if one thread owns it")))
    return findings


# ------------------------------------------------------------------- entries
def check_source(src: str, filename: str = "<memory>") -> List[Finding]:
    """Audit one module's source text; returns LK4xx findings."""
    tree = ast.parse(src, filename=filename)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            findings.extend(_check_class(_parse_class(node), filename))
    return findings


def check_file(path) -> List[Finding]:
    p = Path(path)
    return check_source(p.read_text(), filename=p.name)


def audit_default(root=None,
                  files: Sequence[str] = DEFAULT_FILES) -> List[Finding]:
    """Audit the pipeline's thread-owning modules (the CI surface)."""
    base = Path(root) if root is not None else Path(__file__).resolve().parents[1]
    findings: List[Finding] = []
    for rel in files:
        findings.extend(check_file(base / rel))
    return findings
