"""Concurrency annotations consumed by the ``repro.check`` lockset audit.

These decorators attach *declarations* to classes whose instances are
shared across the pipeline's threads (fe-worker, h2d-feeder, loader
readers, main train loop). They are pure metadata — zero runtime cost,
stdlib-only (this module must stay import-light: ``core/devicefeed.py``
imports it, and pulling analyzer machinery here would create an import
cycle through ``fe.compiler``) — and the AST checker in
:mod:`repro.check.lockset` verifies the declarations against the source.

Conventions
-----------
``@guarded_by("lock", "attr", ...)``
    Every write to ``self.attr`` (or any dotted path under it, e.g.
    ``self.stats.donated`` when ``"stats"`` is declared) that is reachable
    from more than one thread entry point must happen lexically inside
    ``with self.lock:``.

``@shared_entry("method", ...)``
    Marks methods that are thread entry points on the instance — extra
    roots for the checker's reachability walk beyond
    ``threading.Thread(target=self._x)`` targets it discovers on its own.
    Each entry may carry a thread label, ``"feeder:stage"``: entries
    sharing a label run on the same thread (writes reachable from only
    that label never race each other); an unlabeled entry gets its own
    implicit label. Discovered thread targets are labeled
    ``thread:<method>`` and the spawning method ``main``.

``@single_writer("attr", ...)``
    Documents attributes that are intentionally unsynchronized because
    exactly one thread ever writes them (e.g. per-field stats each owned
    by one worker). The checker suppresses LK402 for these but still
    flags them with LK404 if it can prove two distinct entry points write
    them.

Example::

    @guarded_by("_lock", "stats", "_inflight")
    @shared_entry("stage", "flush")
    class DeviceFeeder: ...
"""

from __future__ import annotations

from typing import Dict, Tuple, Type, TypeVar

T = TypeVar("T")

GUARDED_ATTR = "__guarded_by__"
SHARED_ENTRY_ATTR = "__shared_entry__"
SINGLE_WRITER_ATTR = "__single_writer__"


def guarded_by(lock: str, *attrs: str):
    """Declare that writes to ``attrs`` require holding ``self.<lock>``."""
    if not attrs:
        raise ValueError("guarded_by needs at least one attribute name")

    def deco(cls: Type[T]) -> Type[T]:
        table: Dict[str, str] = dict(getattr(cls, GUARDED_ATTR, ()) or {})
        # Copy, never mutate a base class's table in place.
        table = dict(table)
        for a in attrs:
            table[a] = lock
        setattr(cls, GUARDED_ATTR, table)
        return cls

    return deco


def shared_entry(*methods: str):
    """Declare methods invoked from other threads (checker roots)."""
    if not methods:
        raise ValueError("shared_entry needs at least one method name")

    def deco(cls: Type[T]) -> Type[T]:
        prev: Tuple[str, ...] = tuple(getattr(cls, SHARED_ENTRY_ATTR, ()) or ())
        setattr(cls, SHARED_ENTRY_ATTR, tuple(dict.fromkeys(prev + methods)))
        return cls

    return deco


def single_writer(*attrs: str):
    """Declare attributes intentionally owned by exactly one thread."""
    if not attrs:
        raise ValueError("single_writer needs at least one attribute name")

    def deco(cls: Type[T]) -> Type[T]:
        prev: Tuple[str, ...] = tuple(getattr(cls, SINGLE_WRITER_ATTR, ()) or ())
        setattr(cls, SINGLE_WRITER_ATTR, tuple(dict.fromkeys(prev + attrs)))
        return cls

    return deco
