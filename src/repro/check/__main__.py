"""CLI for the static pipeline checks: ``python -m repro.check``.

Runs all four analyzers (plan verifier, arena/donation aliasing,
jaxpr effects, lockset audit) against one FE preset x model arch pair,
without executing a batch.  Exit contract matches
``benchmarks/run.py --compare``: 0 clean, 1 an analyzer crashed, 2 error
findings.  ``--json`` emits the machine-readable report (the same shape
``MetricsRegistry`` records under the ``check`` namespace).

Examples::

    python -m repro.check --preset ads_ctr --arch dlrm-mlperf
    python -m repro.check --preset bst --arch bst --json
    python -m repro.check --preset dlrm --arch dlrm-mlperf \
        --analyzers plan,aliasing
"""

from __future__ import annotations

from typing import Sequence

from repro.check import run_check

_ANALYZERS = ("plan", "aliasing", "effects", "lockset")


def main(argv: Sequence[str] = None) -> int:
    import argparse

    from repro.configs import list_archs
    from repro.fe import list_specs

    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="static plan/arena/effects/lockset checks (no execution)")
    ap.add_argument("--preset", required=True, choices=list_specs(),
                    help="FE preset spec to compile and verify")
    ap.add_argument("--arch", required=True, choices=list_archs(),
                    help="model arch whose smoke config consumes the feed")
    ap.add_argument("--rows", type=int, default=8, metavar="N",
                    help="abstract batch rows for shape flow (default 8)")
    ap.add_argument("--analyzers", default=",".join(_ANALYZERS),
                    metavar="A,B", help="comma-separated subset of "
                    f"{'/'.join(_ANALYZERS)} (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="emit the machine-readable report")
    args = ap.parse_args(argv)

    analyzers = tuple(a for a in args.analyzers.split(",") if a)
    unknown = sorted(set(analyzers) - set(_ANALYZERS))
    if unknown:
        ap.error(f"unknown analyzers: {unknown} (choose from {_ANALYZERS})")

    report = run_check(args.preset, args.arch, rows=args.rows,
                       analyzers=analyzers)
    print(report.to_json() if args.json else report.render())
    return report.exit_code


if __name__ == "__main__":
    raise SystemExit(main())
