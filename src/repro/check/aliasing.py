"""Arena/donation aliasing analysis (AL2xx): static interference checks.

Audits the block-planned staging arena (paper §V, Alg. 1) **without
allocating or staging anything**: given a :class:`~repro.core.devicefeed.
FeedLayout` (or raw slot byte sizes and a placement), it proves the slot
intervals can never overlap, stay 128-byte aligned, fit int32 offsets, and
that every planner in the repo — the jit prefix-sum
(:func:`repro.core.mempool.plan_offsets` via ``FeedLayout.plan``), the
Pallas kernel path (:func:`repro.kernels.mempool_alloc.ops.plan_block`),
and the runtime :class:`~repro.core.mempool.ArenaPool` — agrees with the
analyzer's own shadow plan. A disagreement is exactly the bug class of
PR 3's review fixes (silent int32 divergence in ``plan_block``).

The donation-safety pass models claim lifetimes on the buffer ring: batch
``k`` occupies ring slot ``k % buffers`` from stage until its consumer
completes, and rewinding that slot for batch ``k + buffers`` awaits batch
``k``'s completion — for a donated batch, the ``seq``-th donation fence.
The pass proves the fence the feeder waits on can always have been
registered given the feed queue's capacity (otherwise every reclaim stalls
until ``DeviceFeeder.DONATION_FENCE_TIMEOUT``).

Rules
-----
``AL201`` (error)   — two slot intervals overlap in the arena plan.
``AL202`` (error)   — a slot offset or the arena total violates the layout
    alignment (zero-copy eligibility in ``device_put`` depends on it).
``AL203`` (error)   — sizes negative or the aligned total exceeds int32
    (the planners' offset dtype): silent wrap territory.
``AL204`` (error)   — planner disagreement: prefix-sum plan, Pallas kernel
    plan, ArenaPool block allocation, and the analyzer's shadow plan must
    place every slot identically.
``AL205`` (warning) — ring under-provisioned: fewer buffers than the
    pipeline's concurrent claim lifetimes (writer + feed queue +
    consumer), so staging serializes on the completion gate.
``AL206`` (error)   — donated-buffer reclaim can await a donation fence
    the consumer cannot yet have registered (stalls every batch until the
    fence timeout).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.check.findings import Finding
from repro.core.mempool import ALIGN, ArenaPool, align_up

_I32_MAX = np.iinfo(np.int32).max


def _shadow_plan(sizes: Sequence[int], align: int) -> Tuple[List[int], int]:
    """The analyzer's own Alg. 1 oracle: exclusive prefix sum of aligned
    sizes, in plain Python ints (no dtype to overflow)."""
    offsets: List[int] = []
    off = 0
    for n in sizes:
        offsets.append(off)
        off += align_up(int(n), align)
    return offsets, off


# ------------------------------------------------------------ plan auditing
def check_plan(sizes: Sequence[int], offsets: Sequence[int], total: int,
               *, align: int = ALIGN, names: Optional[Sequence[str]] = None,
               location: str = "block-plan") -> List[Finding]:
    """Audit one concrete placement (slot sizes + offsets + arena total)."""
    findings: List[Finding] = []
    sizes = [int(n) for n in sizes]
    offsets = [int(o) for o in offsets]
    names = list(names) if names is not None else [
        f"slot{i}" for i in range(len(sizes))]
    if len(offsets) != len(sizes):
        return [Finding(
            rule="AL204", severity="error", location=location,
            message=(f"plan has {len(offsets)} offsets for "
                     f"{len(sizes)} slots"),
            hint="regenerate the plan from the layout's slot list")]

    for name, n in zip(names, sizes):
        if n < 0:
            findings.append(Finding(
                rule="AL203", severity="error", location=location,
                message=f"slot {name!r} has negative size {n}",
                hint="slot sizes are rows*width*itemsize; check the layout"))
    if any(n < 0 for n in sizes):
        return findings

    aligned_total = sum(align_up(n, align) for n in sizes)
    if aligned_total > _I32_MAX:
        findings.append(Finding(
            rule="AL203", severity="error", location=location,
            message=(f"aligned arena total {aligned_total} overflows int32 "
                     f"(planner offset dtype)"),
            hint="split the batch or widen the planner to int64"))

    # Alignment of every slot start and of the declared total.
    for name, off in zip(names, offsets):
        if off % align:
            findings.append(Finding(
                rule="AL202", severity="error", location=location,
                message=(f"slot {name!r} starts at offset {off}, not "
                         f"{align}-byte aligned"),
                hint="offsets must be multiples of the layout alignment"))
    if int(total) % align:
        findings.append(Finding(
            rule="AL202", severity="error", location=location,
            message=f"arena total {total} is not {align}-byte aligned",
            hint="round the arena capacity up to the alignment"))

    # Interval disjointness + containment, in offset order.
    order = sorted(range(len(sizes)), key=lambda i: offsets[i])
    for a, b in zip(order, order[1:]):
        end_a = offsets[a] + sizes[a]
        if end_a > offsets[b]:
            findings.append(Finding(
                rule="AL201", severity="error", location=location,
                message=(f"slots {names[a]!r} [{offsets[a]}, {end_a}) and "
                         f"{names[b]!r} [{offsets[b]}, "
                         f"{offsets[b] + sizes[b]}) overlap"),
                hint="a staged write to one slot corrupts the other; "
                     "re-plan with disjoint intervals"))
    if order:
        last = order[-1]
        if offsets[last] + sizes[last] > int(total):
            findings.append(Finding(
                rule="AL201", severity="error", location=location,
                message=(f"slot {names[last]!r} ends at "
                         f"{offsets[last] + sizes[last]}, past the arena "
                         f"total {total}"),
                hint="the last slot overruns the arena; grow the capacity"))
    return findings


def check_agreement(plans: Dict[str, Tuple[Sequence[int], int]],
                    *, location: str = "block-plan") -> List[Finding]:
    """AL204: every planner must produce the identical placement."""
    findings: List[Finding] = []
    items = sorted(plans.items())
    ref_name, (ref_offsets, ref_total) = items[0]
    ref_offsets = [int(o) for o in ref_offsets]
    for name, (offsets, total) in items[1:]:
        offsets = [int(o) for o in offsets]
        if offsets != ref_offsets or int(total) != int(ref_total):
            findings.append(Finding(
                rule="AL204", severity="error", location=location,
                message=(f"planner {name!r} places slots at {offsets} "
                         f"(total {total}), but {ref_name!r} places them at "
                         f"{ref_offsets} (total {ref_total})"),
                hint="planners diverged (the PR 3 int32 bug class); fix "
                     "whichever disagrees with the aligned prefix sum"))
    return findings


def check_feed_layout(layout, rows: int, *,
                      location: str = "feed-layout") -> List[Finding]:
    """Audit a FeedLayout's placement for ``rows``-row batches against
    every planner in the repo (tri-oracle + the analyzer's shadow plan)."""
    sizes = layout.sizes(rows)
    names = list(layout.slot_names)
    align = layout.align
    shadow_offsets, shadow_end = _shadow_plan(sizes, align)
    shadow_total = align_up(shadow_end, align)

    findings = check_plan(sizes, shadow_offsets, shadow_total,
                          align=align, names=names, location=location)
    if any(f.rule == "AL203" for f in findings):
        # The real planners raise OverflowError here by design; the static
        # finding already reports the hazard.
        return findings

    plans: Dict[str, Tuple[Sequence[int], int]] = {
        "shadow": (shadow_offsets, shadow_total)}
    offsets, total = layout.plan(rows)
    plans["plan_offsets"] = (list(np.asarray(offsets)), int(total))
    try:
        from repro.kernels.mempool_alloc.ops import plan_block
        k_offsets, k_total = plan_block(sizes, align=align)
        plans["pallas_kernel"] = (list(np.asarray(k_offsets)), int(k_total))
    except ImportError:  # kernel path absent on this install: skip oracle
        pass
    pool = ArenaPool(shadow_total, align=align)
    allocs = pool.alloc_block(sizes)
    plans["arena_pool"] = ([a.offset for a in allocs], shadow_total)

    findings += check_agreement(plans, location=location)
    for name, (offs, total) in sorted(plans.items()):
        if name == "shadow":
            continue
        findings += check_plan(sizes, offs, total, align=align, names=names,
                               location=f"{location}/{name}")
    return findings


# ----------------------------------------------------- ring/donation safety
def check_ring(layout, rows: int, *, buffers: int,
               queue_capacity: Optional[int] = None, donate: bool = True,
               location: str = "feed-ring") -> List[Finding]:
    """Audit the buffer ring's claim-lifetime plan for a pipeline run.

    ``queue_capacity`` defaults to the :class:`~repro.core.pipeline.
    PipelinedRunner` bound ``max(1, buffers - 2)``. The lifetime model:
    staging batch ``k`` rewinds ring slot ``k % buffers``, which requires
    batch ``k - buffers`` complete; the queue bound guarantees the
    consumer has dequeued at least ``k - queue_capacity - 1`` batches at
    that point.
    """
    findings: List[Finding] = []
    if queue_capacity is None:
        queue_capacity = max(1, buffers - 2)
    if buffers < 1:
        return [Finding(
            rule="AL205", severity="error", location=location,
            message=f"ring needs at least one buffer, got {buffers}",
            hint="DeviceFeeder(buffers=...) must be >= 1")]

    # AL205: steady state wants one buffer being written, queue_capacity
    # staged-but-unconsumed, and one held by the consumer.
    lifetimes = 1 + queue_capacity + 1
    if buffers < lifetimes:
        findings.append(Finding(
            rule="AL205", severity="warning", location=location,
            message=(f"{buffers} ring buffer(s) for {lifetimes} concurrent "
                     f"claim lifetimes (1 staging + {queue_capacity} queued "
                     f"+ 1 held by the consumer): every claim waits on the "
                     f"completion gate"),
            hint="size buffers >= queue_capacity + 2 to overlap staging"))

    # AL206: reclaiming slot (k % buffers) for batch k awaits the fence of
    # batch k - buffers; the consumer has provably dequeued (and fenced)
    # batches up to k - queue_capacity - 1 when the feeder stages batch k.
    if donate and buffers < queue_capacity + 1:
        findings.append(Finding(
            rule="AL206", severity="error", location=location,
            message=(f"donated-buffer reclaim of batch k awaits fence "
                     f"seq k-{buffers}, but with a {queue_capacity}-deep "
                     f"feed queue the consumer has only registered fences "
                     f"through k-{queue_capacity + 1}: every reclaim "
                     f"stalls until DONATION_FENCE_TIMEOUT"),
            hint="size buffers >= queue_capacity + 1 (PipelinedRunner's "
                 "maxsize=max(1, buffers-2) satisfies this for buffers>=2)"))

    # The ring stages real bytes: its per-buffer plan inherits the block
    # plan's invariants for this row count.
    if rows >= 0:
        try:
            arena = layout.arena_bytes(rows)
        except OverflowError:
            arena = None
        if arena is not None and arena * buffers > _I32_MAX:
            findings.append(Finding(
                rule="AL203", severity="warning", location=location,
                message=(f"{buffers} x {arena}-byte arenas exceed int32 "
                         f"total host staging bytes"),
                hint="large but legal (buffers are independent allocations);"
                     " consider fewer buffers or smaller batches"))
    return findings
