"""repro.check — static analysis over compiled plans, arenas, and threads.

Four analyzers behind one :class:`~repro.check.findings.Finding`-based
report, run as a driver preflight (``launch/train.py --check``) and CI gate
(``python -m repro.check --preset ... --arch ...``), with NO execution of
the plan:

* :mod:`repro.check.planverify` — abstract dtype/shape flow over the
  compiled OpGraph/Schedule, placement-boundary legality, OutputLayout
  contract, projection completeness, ModelFeed remap bounds (PV1xx);
* :mod:`repro.check.aliasing`   — arena block-plan interference (interval
  disjointness, alignment, int32 safety, planner-oracle agreement) and
  ring/donation lifetime safety (AL2xx);
* :mod:`repro.check.effects`    — jaxpr effects scan of every fused
  superlayer and the fused train step on abstract shapes, plus donation
  marker verification (EF3xx);
* :mod:`repro.check.lockset`    — AST lockset audit of the pipeline's
  thread-shared state against the :mod:`repro.check.annotations`
  convention (LK4xx).

This ``__init__`` stays import-light on purpose: :mod:`repro.core` modules
import the annotation decorators from here, so pulling the analyzers in
eagerly would create an import cycle through :mod:`repro.fe`. Analyzers
load lazily inside :func:`run_check`.
"""

from repro.check.annotations import guarded_by, shared_entry, single_writer
from repro.check.findings import SEVERITIES, Finding, Report

__all__ = [
    "SEVERITIES",
    "Finding",
    "Report",
    "guarded_by",
    "run_check",
    "shared_entry",
    "single_writer",
]


def run_check(preset: str, arch: str, *, rows: int = 8,
              analyzers=("plan", "aliasing", "effects", "lockset")) -> Report:
    """Run the static analyzers against one FE preset x model arch pair.

    Compiles the ``preset`` FeatureSpec and the ``arch``'s smoke config
    exactly the way ``launch/train.py`` streaming mode wires them, then
    audits the compiled artifacts without executing a batch. Returns a
    :class:`Report` whose ``exit_code`` follows the 0/1/2 contract of
    ``benchmarks/run.py --compare`` (0 clean, 1 analyzer crashed, 2 error
    findings).
    """
    report = Report()

    if "lockset" in analyzers:
        try:
            from repro.check import lockset
            report.record_analyzer("lockset", lockset.audit_default())
        except Exception as e:  # noqa: BLE001 - crash IS the report payload
            report.record_crash("lockset", e)

    plan = mf = None
    try:
        from repro.configs import get_arch
        from repro.fe import featureplan, get_spec

        spec = get_spec(preset)
        plan = featureplan.compile(spec)
        cfg = get_arch(arch).smoke()
        mf = plan.model_feed(cfg, split_sparse_fields=True)
    except Exception as e:  # noqa: BLE001
        report.record_crash("compile", e)
        return report

    if "plan" in analyzers:
        try:
            from repro.check import planverify
            findings = planverify.verify_plan(plan, rows=rows)
            findings += planverify.verify_model_feed(
                mf, plan.feed_layout(split_sparse_fields=mf.split))
            report.record_analyzer("plan", findings)
        except Exception as e:  # noqa: BLE001
            report.record_crash("plan", e)

    if "aliasing" in analyzers:
        try:
            from repro.check import aliasing
            findings = []
            for split in (False, True):
                layout = plan.feed_layout(split_sparse_fields=split)
                where = (f"{preset}/feed_layout"
                         f"{'[split]' if split else '[packed]'}")
                findings += aliasing.check_feed_layout(layout, rows,
                                                       location=where)
                findings += aliasing.check_ring(layout, rows, buffers=3,
                                                location=where)
            report.record_analyzer("aliasing", findings)
        except Exception as e:  # noqa: BLE001
            report.record_crash("aliasing", e)

    if "effects" in analyzers:
        try:
            from repro.check import effects
            report.record_analyzer(
                "effects", effects.scan_preset(plan, mf, rows=rows))
        except Exception as e:  # noqa: BLE001
            report.record_crash("effects", e)

    return report
