"""Static plan verifier (PV1xx): abstract flow over a compiled FeaturePlan.

Replays a plan's layer executables on :class:`jax.ShapeDtypeStruct`
environments — ``jax.eval_shape`` over each fused super-layer jit, host-op
outputs synthesized from the spec's column table — so dtype/shape flow,
placement legality, the OutputLayout contract, projection completeness,
and the ModelFeed remap bounds are all proven **without executing a single
batch** (host ops run numpy and cannot be traced; their output shapes are
fully determined by the spec, which is what the synthesis rules encode).

Rules
-----
``PV101`` (error) — OutputLayout contract violation: a ``feed_slots()``
    slot the plan never produces, a produced ``batch_*`` output the layout
    does not declare, or a shape/dtype mismatch between the abstract flow
    and the declared (width, dtype, rank).
``PV102`` (error) — placement-boundary illegality: a host-placed op inside
    a coalesced SuperLayer (host ops may only ride at the super-layer's
    first member layer; anywhere deeper, the fused device dispatch would
    have to stop mid-flight for a host barrier the executor never takes).
``PV103`` (error) — abstract flow failure: a device input slot no host op
    synthesis rule nor earlier executable produces, a slot produced twice,
    or a fused jit that fails shape tracing.
``PV104`` (error) — projection incompleteness: ``plan.required_columns``
    is missing a column the compiled spec reads; the loader's projection
    pushdown would hand the pipeline a batch with the column never decoded.
``PV105`` (error) — ModelFeed remap out of bounds: a model sparse field
    without a vocab-modulo entry, a nonpositive modulo, a modulo larger
    than the embedding table it indexes, or a field source outside the
    spec's field range — each means ids can index past the table.
``PV106`` (error) — feed contract mismatch: the train feed consumes a slot
    the staging layout does not provide.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.check.findings import Finding
from repro.fe import compiler
from repro.fe.schema import ColType
from repro.fe.spec import Sequence as SeqTransform


# ----------------------------------------------------- abstract environment
def _host_slot_rules(spec) -> Tuple[Dict[str, object], Dict[str, object],
                                    Dict[str, int]]:
    table = compiler._column_table(spec)
    seqs = {t.name: t for t in spec.transforms
            if isinstance(t, SeqTransform)}
    merge_widths = {f"{m.prefix}dense": len(m.columns) for m in spec.merges}
    return table, seqs, merge_widths


def _abstract_host_slot(slot: str, rows: int, spec, table, seqs,
                        merge_widths) -> Optional[jax.ShapeDtypeStruct]:
    """Abstract value of one host-op-produced slot, from the spec alone.

    Encodes the compiler's host-op output contracts: ``to_device`` emits
    float32 for FLOAT columns and the label, int64 otherwise;
    ``extract_text`` emits int64 ids + float32 masks at the sequence's
    ``max_len``; ``merge_<view>`` emits a float32 [rows, n_columns] block.
    """
    if slot.endswith("_col"):
        base = slot[: -len("_col")]
        rc = table.get(base)
        if rc is None:
            return None
        if base == spec.label or rc.ctype == ColType.FLOAT:
            return jax.ShapeDtypeStruct((rows,), np.float32)
        return jax.ShapeDtypeStruct((rows,), np.int64)
    if slot.endswith("_ids") and slot[: -len("_ids")] in seqs:
        t = seqs[slot[: -len("_ids")]]
        return jax.ShapeDtypeStruct((rows, t.max_len), np.int64)
    if slot.endswith("_mask") and slot[: -len("_mask")] in seqs:
        t = seqs[slot[: -len("_mask")]]
        return jax.ShapeDtypeStruct((rows, t.max_len), np.float32)
    if slot in merge_widths:
        return jax.ShapeDtypeStruct((rows, merge_widths[slot]), np.float32)
    return None


def abstract_flow(plan, rows: int = 8
                  ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], List[Finding]]:
    """Flow ShapeDtypeStructs through the plan's executables (PV103)."""
    spec = plan.spec
    table, seqs, merge_widths = _host_slot_rules(spec)
    env: Dict[str, jax.ShapeDtypeStruct] = {}
    findings: List[Finding] = []
    for ex in plan.layers:
        where = f"plan {plan.name!r}/layer {ex.index}"
        for slot in ex.device_input_slots:
            if slot in env:
                continue
            sds = _abstract_host_slot(slot, rows, spec, table, seqs,
                                      merge_widths)
            if sds is None:
                findings.append(Finding(
                    rule="PV103", severity="error", location=where,
                    message=(f"device input slot {slot!r} has no producer: "
                             f"no earlier executable emits it and no host-op "
                             f"synthesis rule covers it"),
                    hint="host ops feeding the device must emit *_col, "
                         "<seq>_ids/_mask, or <merge>dense slots"))
                return env, findings
            env[slot] = sds
        if ex.fused_fn is None:
            continue
        try:
            out = jax.eval_shape(ex.fused_fn,
                                 {s: env[s] for s in ex.device_input_slots})
        except Exception as e:  # noqa: BLE001 - tracing failure IS the finding
            findings.append(Finding(
                rule="PV103", severity="error", location=where,
                message=(f"fused dispatch fails abstract tracing: "
                         f"{type(e).__name__}: {e}"),
                hint="the device ops' shape contract is inconsistent with "
                     "the host-op outputs"))
            return env, findings
        for name, sds in out.items():
            if name in env:
                findings.append(Finding(
                    rule="PV103", severity="error", location=where,
                    message=f"slot {name!r} is produced twice",
                    hint="each slot must have exactly one producer"))
            env[name] = sds
    return env, findings


# ------------------------------------------------------------------- checks
def check_placement(plan) -> List[Finding]:
    """PV102: host ops only at each executable's first member layer."""
    findings: List[Finding] = []
    depth_of = plan.schedule.depth_of
    for ex in plan.layers:
        if not ex.layer_indices or len(ex.layer_indices) == 1:
            continue
        barrier = ex.layer_indices[0]
        for placed in ex.host_ops:
            depth = depth_of.get(placed.op.name)
            if depth != barrier:
                findings.append(Finding(
                    rule="PV102", severity="error",
                    location=f"plan {plan.name!r}/layer {ex.index}",
                    message=(f"host op {placed.op.name!r} sits at schedule "
                             f"depth {depth} inside a super-layer coalesced "
                             f"over layers {ex.layer_indices} (host barrier "
                             f"at {barrier})"),
                    hint="coalescing must break before every host-op layer "
                         "(scheduler.coalesce_layers invariant)"))
    return findings


def check_output_layout(plan, env: Dict[str, jax.ShapeDtypeStruct],
                        rows: int) -> List[Finding]:
    """PV101: the abstract flow must land exactly on OutputLayout."""
    findings: List[Finding] = []
    where = f"plan {plan.name!r}/output_layout"
    declared = {name: (width, dtype, rank1)
                for name, width, dtype, rank1 in plan.layout.feed_slots()}
    produced = {k: v for k, v in env.items() if k.startswith("batch_")}
    for name, (width, dtype, rank1) in declared.items():
        got = produced.get(name)
        if got is None:
            findings.append(Finding(
                rule="PV101", severity="error", location=where,
                message=f"layout declares slot {name!r}, which the plan "
                        f"never produces",
                hint="OutputLayout and the final_batch op diverged"))
            continue
        want_shape = (rows,) if rank1 else (rows, width)
        if tuple(got.shape) != want_shape or got.dtype != np.dtype(dtype):
            findings.append(Finding(
                rule="PV101", severity="error", location=where,
                message=(f"slot {name!r}: plan produces "
                         f"{tuple(got.shape)}/{got.dtype}, layout declares "
                         f"{want_shape}/{dtype}"),
                hint="the staging arena would be mis-sized for this slot"))
    for name in sorted(set(produced) - set(declared)):
        findings.append(Finding(
            rule="PV101", severity="error", location=where,
            message=f"plan produces {name!r}, which OutputLayout does not "
                    f"declare",
            hint="undeclared outputs are never staged; extend feed_slots()"))
    return findings


def check_projection(plan) -> List[Finding]:
    """PV104: plan.required_columns covers everything the spec reads."""
    findings: List[Finding] = []
    where = f"plan {plan.name!r}/required_columns"
    want = compiler.required_columns(plan.spec)
    have = {v: set(cols) for v, cols in plan.required_columns.items()}
    for view, cols in sorted(want.items()):
        missing = sorted(set(cols) - have.get(view, set()))
        for col in missing:
            findings.append(Finding(
                rule="PV104", severity="error", location=where,
                message=(f"view {view!r} column {col!r} is read by the "
                         f"compiled spec but absent from the projection"),
                hint="the loader would never decode it; recompute "
                     "required_columns from the spec"))
    return findings


def verify_plan(plan, *, rows: int = 8) -> List[Finding]:
    """Full static verification of one compiled FeaturePlan (PV101-104)."""
    findings = check_placement(plan)
    env, flow_findings = abstract_flow(plan, rows)
    findings += flow_findings
    if not flow_findings:  # layout contract needs a completed flow
        findings += check_output_layout(plan, env, rows)
    findings += check_projection(plan)
    return findings


def verify_model_feed(mf, feed_layout) -> List[Finding]:
    """PV105/PV106: remap bounds + staging/feed slot contract for one
    compiled :class:`~repro.fe.modelfeed.ModelFeed` against the staging
    :class:`~repro.core.devicefeed.FeedLayout` it will consume."""
    findings: List[Finding] = []
    cfg = mf.config
    where = f"model_feed {cfg.name!r}"
    tables = tuple(int(v) for v in cfg.vocab_sizes[:cfg.n_sparse])
    vocab = np.asarray(mf.vocab).ravel()
    sources = np.asarray(mf.field_sources).ravel()

    if cfg.n_sparse and mf.n_spec_fields <= 0:
        findings.append(Finding(
            rule="PV105", severity="error", location=where,
            message=(f"model wants {cfg.n_sparse} sparse fields but the "
                     f"spec emits none"),
            hint="pick a spec with a SparseOutput block for this arch"))
        return findings
    for j in range(cfg.n_sparse):
        if j >= len(vocab):
            findings.append(Finding(
                rule="PV105", severity="error", location=where,
                message=(f"model field {j} has no vocab-modulo entry "
                         f"(vector covers {len(vocab)} of {cfg.n_sparse} "
                         f"fields): raw hash ids up to the spec's "
                         f"field_size would index its embedding table"),
                hint="the modulo vector must cover every sparse field"))
            continue
        mod = int(vocab[j])
        if mod <= 0:
            findings.append(Finding(
                rule="PV105", severity="error", location=where,
                message=f"model field {j} has nonpositive modulo {mod}",
                hint="modulo entries come from cfg.vocab_sizes; must be >=1"))
        elif j < len(tables) and mod > tables[j]:
            findings.append(Finding(
                rule="PV105", severity="error", location=where,
                message=(f"model field {j}: modulo {mod} exceeds its "
                         f"embedding table size {tables[j]} — remapped ids "
                         f"in [{tables[j]}, {mod}) index out of bounds"),
                hint="modulo must be <= the table's vocab size"))
        if j < len(sources) and not (0 <= int(sources[j]) < mf.n_spec_fields):
            findings.append(Finding(
                rule="PV105", severity="error", location=where,
                message=(f"model field {j} sources spec field "
                         f"{int(sources[j])}, outside the spec's "
                         f"{mf.n_spec_fields} fields"),
                hint="field_sources indices must be < n_spec_fields"))
    if len(sources) < cfg.n_sparse:
        findings.append(Finding(
            rule="PV105", severity="error", location=where,
            message=(f"field_sources covers {len(sources)} of "
                     f"{cfg.n_sparse} model fields"),
            hint="every model field needs a spec field source"))

    available = set(feed_layout.slot_names)
    if "batch_sparse" in available:
        # The device feeder derives per-field columns from a packed block.
        available.update(compiler.field_slots(mf.n_spec_fields))
    for slot in mf.slots:
        if slot not in available:
            findings.append(Finding(
                rule="PV106", severity="error", location=where,
                message=(f"train feed consumes slot {slot!r}, which the "
                         f"staging layout does not provide "
                         f"(staged: {sorted(feed_layout.slot_names)})"),
                hint="feed_layout(split_sparse_fields=...) must match the "
                     "model feed's split setting"))
    return findings
