"""Finding/report model shared by every ``repro.check`` analyzer.

Mirrors the shape of :mod:`repro.obs.validate`'s trace report — a typed
result object with a JSON form and a CLI exit contract — generalized to
many analyzers:

* a :class:`Finding` is one violation: rule id, severity, location,
  message, and a fix hint;
* a :class:`Report` collects findings across analyzers, remembers which
  analyzers ran and which crashed, and maps the whole run onto the same
  0/1/2 exit contract as ``benchmarks/run.py --compare``:

  - ``0`` — every analyzer ran and no error-severity finding;
  - ``1`` — an analyzer itself crashed (tooling failure; takes precedence
    over findings so a broken checker is never mistaken for a clean run);
  - ``2`` — error-severity findings (the gated outcome).

Severities: ``error`` gates the exit code; ``warning`` is reported but
non-gating (advisory invariants); ``info`` is context. All three appear in
the JSON payload and the :meth:`Report.as_metrics` counters, so the
:class:`repro.obs.MetricsRegistry` can track finding counts per run.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Iterable, List, Tuple

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis violation."""

    rule: str       # stable rule id, e.g. "PV102" / "AL201" / "LK402"
    severity: str   # "error" | "warning" | "info"
    location: str   # where: "plan ads_ctr/final_batch", "devicefeed.py:123"
    message: str    # what is wrong
    hint: str = ""  # how to fix it

    def __post_init__(self) -> None:
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, got {self.severity!r}")

    def to_dict(self) -> Dict[str, str]:
        return {"rule": self.rule, "severity": self.severity,
                "location": self.location, "message": self.message,
                "hint": self.hint}

    def render(self) -> str:
        line = f"{self.severity.upper()} {self.rule} [{self.location}] {self.message}"
        if self.hint:
            line += f"  (fix: {self.hint})"
        return line


@dataclasses.dataclass
class Report:
    """Findings from one ``repro.check`` run, with the exit-code contract."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    analyzers_run: List[str] = dataclasses.field(default_factory=list)
    # analyzer name -> one-line crash description (exception repr)
    crashed: Dict[str, str] = dataclasses.field(default_factory=dict)

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def record_analyzer(self, name: str, findings: Iterable[Finding]) -> None:
        self.analyzers_run.append(name)
        self.extend(findings)

    def record_crash(self, name: str, exc: BaseException) -> None:
        self.analyzers_run.append(name)
        self.crashed[name] = f"{type(exc).__name__}: {exc}"

    # ------------------------------------------------------------- rollups
    def by_severity(self, severity: str) -> Tuple[Finding, ...]:
        return tuple(f for f in self.findings if f.severity == severity)

    @property
    def errors(self) -> Tuple[Finding, ...]:
        return self.by_severity("error")

    @property
    def warnings(self) -> Tuple[Finding, ...]:
        return self.by_severity("warning")

    @property
    def exit_code(self) -> int:
        """0 clean / 1 analyzer crashed (takes precedence) / 2 errors —
        the same contract as ``benchmarks/run.py --compare``."""
        if self.crashed:
            return 1
        if self.errors:
            return 2
        return 0

    # --------------------------------------------------------------- output
    def to_dict(self) -> Dict[str, object]:
        return {
            "analyzers": list(self.analyzers_run),
            "crashed": dict(self.crashed),
            "n_findings": len(self.findings),
            "n_errors": len(self.errors),
            "n_warnings": len(self.warnings),
            "exit_code": self.exit_code,
            "findings": [f.to_dict() for f in self.findings],
        }

    def to_json(self, *, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def as_metrics(self) -> Dict[str, float]:
        """Finding counters for :class:`repro.obs.MetricsRegistry`."""
        out: Dict[str, float] = {
            "analyzers": len(self.analyzers_run),
            "crashed": len(self.crashed),
            "findings": len(self.findings),
            "exit_code": self.exit_code,
        }
        for sev in SEVERITIES:
            out[f"{sev}s"] = len(self.by_severity(sev))
        return out

    def render(self) -> str:
        """Human-readable multi-line summary (findings first, then totals)."""
        lines = [f.render() for f in self.findings]
        for name, why in self.crashed.items():
            lines.append(f"CRASH {name}: {why}")
        lines.append(
            f"repro.check: {len(self.analyzers_run)} analyzers, "
            f"{len(self.errors)} errors, {len(self.warnings)} warnings, "
            f"{len(self.by_severity('info'))} info -> exit {self.exit_code}")
        return "\n".join(lines)
