"""Jaxpr effects scan (EF3xx): prove the compiled hot path is effect-free
and actually donates.

Lowers every fused super-layer dispatch and the fused train step on
:class:`jax.ShapeDtypeStruct` arguments only — ``jax.make_jaxpr`` /
``AOT lower`` trace without executing, so this is a static proof, not a
smoke run. Two properties of the paper's pipeline depend on it:

* **No host effects inside coalesced layers.** A ``jax.debug.print``,
  ``io_callback``, or ``host_callback`` smuggled into a device op forces
  XLA to break the fused dispatch with a host sync — exactly the barrier
  super-layer coalescing (PR 4) exists to remove. The jaxpr's ``effects``
  set exposes these statically.
* **Donation really happened.** ``donate_argnums`` is a *request*: jit
  silently keeps non-donatable or unused arguments. The lowered StableHLO
  text carries a ``tf.aliasing_output`` attribute (older emitters:
  ``jax.buffer_donor``) per donated invar; its absence means the arena's
  staged buffers are copied, not reused, and the donation-fence handshake
  guards nothing.

Rules
-----
``EF301`` (error)   — a coalesced super-layer's fused dispatch carries jaxpr
    effects (host callback / debug print / IO) that force a host sync.
``EF302`` (error)   — the train step was built with ``donate=True`` but its
    lowering shows no donated invars (no aliasing/buffer-donor markers).
``EF303`` (error)   — the fused train step itself carries jaxpr effects.
"""

from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.check.findings import Finding

_DONATION_MARKERS = ("tf.aliasing_output", "jax.buffer_donor")


def _effects_of(fn, *abstract_args) -> Tuple[Optional[frozenset], Optional[str]]:
    """(effects, error) of tracing ``fn`` on abstract arguments."""
    try:
        jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    except Exception as e:  # noqa: BLE001 - tracing failure IS the finding
        return None, f"{type(e).__name__}: {e}"
    return frozenset(jaxpr.effects), None


def scan_executables(layers: Sequence, env: Dict[str, jax.ShapeDtypeStruct],
                     *, location: str = "plan") -> List[Finding]:
    """EF301 over every fused super-layer dispatch in ``layers``.

    ``env`` maps slot names to abstract values for every device input slot
    (:func:`repro.check.planverify.abstract_flow` produces it).
    """
    findings: List[Finding] = []
    for ex in layers:
        if ex.fused_fn is None:
            continue
        where = f"{location}/layer {ex.index}"
        missing = [s for s in ex.device_input_slots if s not in env]
        if missing:
            findings.append(Finding(
                rule="EF301", severity="error", location=where,
                message=f"cannot trace fused dispatch: no abstract value "
                        f"for input slots {missing}",
                hint="run the plan verifier first; its PV103 finding is the "
                     "root cause"))
            continue
        effects, err = _effects_of(
            ex.fused_fn, {s: env[s] for s in ex.device_input_slots})
        if err is not None:
            findings.append(Finding(
                rule="EF301", severity="error", location=where,
                message=f"fused dispatch fails abstract tracing: {err}",
                hint="see the plan verifier's PV103 output"))
            continue
        if effects:
            names = sorted(str(e) for e in effects)
            findings.append(Finding(
                rule="EF301", severity="error", location=where,
                message=(f"coalesced dispatch over layers "
                         f"{ex.layer_indices} carries jaxpr effects "
                         f"{names}: XLA must break the fusion with a host "
                         f"sync"),
                hint="remove debug.print/io_callback from device ops, or "
                     "mark the op host-placed so the scheduler splits the "
                     "layer"))
    return findings


def check_step(jitted, abstract_args: Tuple, *, expect_donation: bool,
               location: str = "train-step") -> List[Finding]:
    """EF302/EF303 on one jitted train step, traced on abstract args."""
    findings: List[Finding] = []
    effects, err = _effects_of(jitted, *abstract_args)
    if err is not None:
        return [Finding(
            rule="EF303", severity="error", location=location,
            message=f"train step fails abstract tracing: {err}",
            hint="the model feed's slot shapes diverge from the train "
                 "step's batch contract")]
    if effects:
        names = sorted(str(e) for e in effects)
        findings.append(Finding(
            rule="EF303", severity="error", location=location,
            message=f"fused train step carries jaxpr effects {names}",
            hint="an effectful primitive inside the step forces a host "
                 "sync every batch; strip debug/callback ops"))

    if expect_donation:
        with warnings.catch_warnings():
            # jit's partial-donation advisory; the marker scan below makes
            # the authoritative call (EF302 only when NOTHING was donated).
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            text = jitted.lower(*abstract_args).as_text()
        if not any(m in text for m in _DONATION_MARKERS):
            findings.append(Finding(
                rule="EF302", severity="error", location=location,
                message=("step was built with donate=True but its lowering "
                         "shows no donated invars (no "
                         f"{'/'.join(_DONATION_MARKERS)} markers): params, "
                         "opt state, and the staged feed are copied every "
                         "batch"),
                hint="donation silently degrades when dtypes/shapes of "
                     "inputs and outputs stop matching; diff the step's "
                     "in/out avals"))
    return findings


def abstract_step_args(plan, mf, *, rows: int = 8,
                       abstract_state=None) -> Tuple:
    """Abstract ``(params, opt_state, feed)`` for ``mf``'s fused step.

    Everything is derived without allocating: params via ``eval_shape``
    over the initializer, optimizer state via the train-step factory's
    ``abstract_state`` (pass ``abstract_state=`` for a non-default step
    family, e.g. the mesh step's codec residual), and the feed from the
    staging layout's slot specs (what :meth:`DeviceFeeder.claim_views`
    stages, post H2D).
    """
    from repro.models import recsys as R
    from repro.train.optimizer import adamw

    cfg = mf.config
    params = jax.eval_shape(lambda k: R.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    if abstract_state is None:
        _, _, abstract_state = R.make_sparse_train_step(cfg, adamw(1e-3))
    opt_state = abstract_state(params)

    layout = plan.feed_layout(split_sparse_fields=mf.split)
    by_name = {s.name: s for s in layout.slots}
    feed = {}
    for slot in mf.slots:
        s = by_name[slot]
        shape = (rows,) if s.rank1 else (rows, s.width)
        feed[slot] = jax.ShapeDtypeStruct(shape, np.dtype(s.dtype))
    return params, opt_state, feed


def scan_preset(plan, mf, *, rows: int = 8) -> List[Finding]:
    """Full effects scan of one compiled preset: every super-layer jit plus
    the fused, donated train step."""
    from repro.check import planverify

    env, flow_findings = planverify.abstract_flow(plan, rows)
    findings: List[Finding] = []
    if not flow_findings:  # PV103 already reports broken flow
        findings += scan_executables(plan.layers, env,
                                     location=f"plan {plan.name!r}")

    args = abstract_step_args(plan, mf)
    step = mf.make_step(_null_train_step, fused=True, donate=True)
    findings += check_step(
        step.jitted, args, expect_donation=True,
        location=f"train-step {mf.config.name!r}[null]")

    from repro.models import recsys as R
    from repro.train.optimizer import adamw
    raw, _, _ = R.make_sparse_train_step(mf.config, adamw(1e-3))
    real = mf.make_step(raw, fused=True, donate=True)
    findings += check_step(
        real.jitted, args, expect_donation=True,
        location=f"train-step {mf.config.name!r}")

    # The mesh-sharded step must survive the same scan: shard_map can
    # smuggle in effects (ordered collectives, debug callbacks) and its
    # sharded outputs can silently break donation. Scan on the largest
    # mesh the visible devices allow: (2, n/2) when simulated devices are
    # forced (the CI mesh job), else the 1x1 degenerate mesh — the shape
    # the bitwise-equivalence guarantee covers.
    from repro.launch.mesh import make_train_mesh

    n_dev = len(jax.devices())
    shape = (2, n_dev // 2) if (n_dev > 1 and n_dev % 2 == 0) else (1, 1)
    mesh = make_train_mesh(*shape)
    mesh_rows = -(-rows // mesh.size) * mesh.size
    raw_mesh, _, mesh_abstract = R.make_mesh_train_step(
        mf.config, adamw(1e-3), mesh=mesh, compress="bf16")
    margs = abstract_step_args(plan, mf, rows=mesh_rows,
                               abstract_state=mesh_abstract)
    msh = mf.make_step(raw_mesh, fused=True, donate=True)
    findings += check_step(
        msh.jitted, margs, expect_donation=True,
        location=(f"train-step {mf.config.name!r}"
                  f"[mesh {shape[0]}x{shape[1]}]"))
    return findings


def _null_train_step(params, opt_state, batch):
    """Donation-shaped identity step: same (params, opt, metrics) contract
    as the real step, zero model math — isolates the model feed's own
    adaptation in the effects/donation scan."""
    metrics = {"loss": jax.numpy.zeros((), np.float32)}
    return params, opt_state, metrics
