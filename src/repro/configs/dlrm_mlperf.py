"""dlrm-mlperf: MLPerf DLRM (Criteo 1TB): 13 dense + 26 sparse, embed 128,
bot 512-256-128, top 1024-1024-512-256-1, dot interaction [arXiv:1906.00091]."""

import functools

from repro.configs.base import ArchSpec, recsys_cell
from repro.models.recsys import CRITEO_1TB_VOCABS, RecsysConfig

CONFIG = RecsysConfig(
    name="dlrm-mlperf", kind="dlrm", n_dense=13, n_sparse=26, embed_dim=128,
    vocab_sizes=CRITEO_1TB_VOCABS,
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
)


def smoke():
    return RecsysConfig(
        name="dlrm-smoke", kind="dlrm", n_dense=13, n_sparse=6, embed_dim=16,
        vocab_sizes=(64, 32, 100, 16, 8, 40),
        bot_mlp=(32, 16), top_mlp=(64, 32, 1), dedup_capacity=512,
    )


ARCH = ArchSpec(
    arch_id="dlrm-mlperf", family="recsys",
    shapes=("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"),
    build_cell=functools.partial(recsys_cell, CONFIG),
    smoke=smoke,
    describe="MLPerf DLRM on Criteo-1TB vocabularies (dot interaction)",
)
