"""deepseek-moe-16b: 28L d2048 16H MoE 2 shared + 64 routed top-6
(d_ff_expert=1408), vocab=102400 [arXiv:2401.06066]."""

import functools

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_cell
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16, n_kv=16,
    d_ff=10944,  # layer-0 dense FFN
    vocab=102400, head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  capacity_factor=1.25),
    first_k_dense=1,
    dtype=jnp.bfloat16, grad_accum=8,
)


def smoke():
    return LMConfig(
        name="deepseek-moe-smoke", n_layers=3, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=256, head_dim=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                      capacity_factor=2.0),
        first_k_dense=1,
        dtype=jnp.float32, q_block=16, kv_block=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="deepseek-moe-16b", family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    build_cell=functools.partial(lm_cell, CONFIG),
    smoke=smoke,
    describe="fine-grained MoE (2 shared + 64 routed top-6), MHA",
)
