"""qwen2.5-32b: 64L d5120 40H (GQA kv=8) d_ff=27648 vocab=152064, QKV bias."""

import functools

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_cell
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-32b", n_layers=64, d_model=5120, n_heads=40, n_kv=8,
    d_ff=27648, vocab=152064, head_dim=128, qkv_bias=True,
    rope_base=1_000_000.0, dtype=jnp.bfloat16, grad_accum=16,
)


def smoke():
    return LMConfig(
        name="qwen2.5-32b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_ff=192, vocab=256, head_dim=16, qkv_bias=True,
        dtype=jnp.float32, q_block=16, kv_block=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="qwen2.5-32b", family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    build_cell=functools.partial(lm_cell, CONFIG),
    smoke=smoke,
    describe="GQA dense transformer with QKV bias (32B)",
)
