"""Architecture registry: --arch <id> resolves here."""

from typing import Dict, List

from repro.configs.base import ArchSpec, Cell, dp_axes_for

_ARCH_MODULES = (
    "yi_9b",
    "qwen2_5_32b",
    "qwen2_5_14b",
    "deepseek_v2_236b",
    "deepseek_moe_16b",
    "pna",
    "bst",
    "autoint",
    "dcn_v2",
    "dlrm_mlperf",
)


def _load() -> Dict[str, ArchSpec]:
    import importlib

    out: Dict[str, ArchSpec] = {}
    for mod in _ARCH_MODULES:
        m = importlib.import_module(f"repro.configs.{mod}")
        out[m.ARCH.arch_id] = m.ARCH
    return out


_REGISTRY: Dict[str, ArchSpec] = {}


def registry() -> Dict[str, ArchSpec]:
    global _REGISTRY
    if not _REGISTRY:
        _REGISTRY = _load()
    return _REGISTRY


def get_arch(arch_id: str) -> ArchSpec:
    reg = registry()
    if arch_id not in reg:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(reg)}")
    return reg[arch_id]


def list_archs() -> List[str]:
    return sorted(registry())


__all__ = ["ArchSpec", "Cell", "dp_axes_for", "get_arch", "list_archs", "registry"]
