"""autoint: 39 sparse fields (13 bucketized dense + 26 categorical), embed 16,
3 self-attention layers, 2 heads, d_attn=32 [arXiv:1810.11921].

AutoInt was evaluated on Criteo-Kaggle; vocabularies follow that scale
(frequency-thresholded), with the 13 dense features bucketized to 100 bins.
"""

import functools

from repro.configs.base import ArchSpec, recsys_cell
from repro.models.recsys import CRITEO_1TB_VOCABS, RecsysConfig

# 13 bucketized dense (100 bins) + 26 categorical capped at Kaggle scale
VOCABS = tuple([100] * 13) + tuple(min(v, 100_000) for v in CRITEO_1TB_VOCABS)

CONFIG = RecsysConfig(
    name="autoint", kind="autoint", n_dense=0, n_sparse=39, embed_dim=16,
    vocab_sizes=VOCABS,
    n_attn_layers=3, n_heads=2, d_attn=32,
)


def smoke():
    return RecsysConfig(
        name="autoint-smoke", kind="autoint", n_dense=0, n_sparse=8, embed_dim=8,
        vocab_sizes=(30,) * 8,
        n_attn_layers=2, n_heads=2, d_attn=8, dedup_capacity=256,
    )


ARCH = ArchSpec(
    arch_id="autoint", family="recsys",
    shapes=("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"),
    build_cell=functools.partial(recsys_cell, CONFIG),
    smoke=smoke,
    describe="AutoInt field self-attention interaction",
)
