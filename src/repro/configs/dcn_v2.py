"""dcn-v2: 13 dense + 26 sparse, embed 16, 3 cross layers, deep 1024-1024-512
[arXiv:2008.13535]."""

import functools

from repro.configs.base import ArchSpec, recsys_cell
from repro.models.recsys import CRITEO_1TB_VOCABS, RecsysConfig

CONFIG = RecsysConfig(
    name="dcn-v2", kind="dcnv2", n_dense=13, n_sparse=26, embed_dim=16,
    vocab_sizes=CRITEO_1TB_VOCABS,
    n_cross_layers=3, top_mlp=(1024, 1024, 512),
)


def smoke():
    return RecsysConfig(
        name="dcnv2-smoke", kind="dcnv2", n_dense=13, n_sparse=6, embed_dim=8,
        vocab_sizes=(64, 32, 100, 16, 8, 40),
        n_cross_layers=3, top_mlp=(64, 32), dedup_capacity=512,
    )


ARCH = ArchSpec(
    arch_id="dcn-v2", family="recsys",
    shapes=("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"),
    build_cell=functools.partial(recsys_cell, CONFIG),
    smoke=smoke,
    describe="DCN-v2 cross network (full-rank crosses) + deep tower",
)
