"""bst: Behavior Sequence Transformer (Alibaba): embed 32, seq_len 20,
1 block, 8 heads, MLP 1024-512-256 [arXiv:1905.06874].

Fields: item (target, shares the behavior-sequence table), user, category,
context slot — Taobao-scale vocabularies.
"""

import functools

from repro.configs.base import ArchSpec, recsys_cell
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="bst", kind="bst", n_dense=0, n_sparse=4, embed_dim=32,
    vocab_sizes=(4_000_000, 1_000_000, 10_000, 128),  # item, user, category, slot
    seq_len=20, n_blocks=1, n_heads=8, top_mlp=(1024, 512, 256),
    item_field=0,
)


def smoke():
    return RecsysConfig(
        name="bst-smoke", kind="bst", n_dense=0, n_sparse=3, embed_dim=16,
        vocab_sizes=(100, 20, 10),
        seq_len=5, n_blocks=1, n_heads=4, top_mlp=(64, 32),
        dedup_capacity=512,
    )


ARCH = ArchSpec(
    arch_id="bst", family="recsys",
    shapes=("train_batch", "serve_p99", "serve_bulk", "retrieval_cand"),
    build_cell=functools.partial(recsys_cell, CONFIG),
    smoke=smoke,
    describe="Behavior Sequence Transformer over user click history",
)
