"""yi-9b: 48L d4096 32H (GQA kv=4) d_ff=11008 vocab=64000 [arXiv:2403.04652]."""

import functools

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_cell
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="yi-9b", n_layers=48, d_model=4096, n_heads=32, n_kv=4,
    d_ff=11008, vocab=64000, head_dim=128, qkv_bias=False,
    rope_base=5_000_000.0, dtype=jnp.bfloat16, grad_accum=8,
)


def smoke():
    cfg = LMConfig(
        name="yi-9b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_ff=128, vocab=256, head_dim=16, qkv_bias=False,
        dtype=jnp.float32, q_block=16, kv_block=16, loss_chunk=16,
    )
    return cfg


ARCH = ArchSpec(
    arch_id="yi-9b", family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    build_cell=functools.partial(lm_cell, CONFIG),
    smoke=smoke,
    describe="llama-arch GQA dense transformer",
)
