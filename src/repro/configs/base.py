"""Config substrate: per-(arch x shape) dry-run cells.

Each architecture file exports an :class:`ArchSpec`; ``cells_for`` turns an
(arch, shape, mesh) triple into a :class:`Cell` — the jit-able step function,
abstract inputs (ShapeDtypeStruct, no allocation), and shardings — consumed
by ``launch/dryrun.py`` and the roofline analysis.

Variants (``--variant``) select paper-faithful vs optimized configurations
for §Perf (e.g. recsys embedding lookup with/without the FeatureBox dedup).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.train import optimizer as opt_lib


@dataclasses.dataclass
class Cell:
    """One dry-run unit: fn + abstract args + shardings + roofline metadata."""

    arch_id: str
    shape_name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Any
    out_shardings: Any = None
    donate_argnums: Tuple[int, ...] = ()
    model_flops: float = 0.0          # analytic 6·N·D (train) / 2·N·D (serve)
    skip: Optional[str] = None
    static_argnames: Tuple[str, ...] = ()


@dataclasses.dataclass
class ArchSpec:
    arch_id: str
    family: str                        # "lm" | "recsys" | "gnn"
    shapes: Tuple[str, ...]
    build_cell: Callable[..., Cell]    # (shape, mesh, dp, variant) -> Cell
    smoke: Callable[[], Any]           # returns (config, batch_builder)
    describe: str = ""


def _shard_tree(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def dp_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


# =============================================================== LM family
def lm_active_params(cfg: T.LMConfig) -> float:
    """Active (per-token) parameter count for 6·N·D (MoE counts top-k only)."""
    shapes = T.param_shapes(cfg)
    total = 0.0
    for group, v in shapes.items():
        if isinstance(v, dict):
            for name, s in v.items():
                n = float(np.prod(s))
                if name.startswith("moe_w") and cfg.moe:
                    n *= cfg.moe.top_k / cfg.moe.n_experts
                total += n
        else:
            if group == "embed":
                continue  # lookup, not matmul
            total += float(np.prod(v))
    return total


LM_SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "long_decode", "seq": 524288, "batch": 1},
}


def lm_cell(cfg: T.LMConfig, shape: str, mesh: Mesh, *, variant: str = "base") -> Cell:
    info = LM_SHAPES[shape]
    dp = dp_axes_for(mesh)
    # §Perf variants (hypothesis-driven; see EXPERIMENTS.md §Perf).
    # Combine with '+': e.g. "accum8+cf100".
    tp = "model"
    for v in variant.split("+"):
        if v == "puredp":
            # pure ZeRO-DP mapping of the same mesh: batch over ALL axes,
            # no TP (dense models only; the whole layer fits one chip)
            if cfg.moe is not None:
                raise ValueError("puredp applies to dense LMs only")
            tp = None
            cfg = dataclasses.replace(cfg, grad_accum=1)
        elif v.startswith("accum"):
            cfg = dataclasses.replace(cfg, grad_accum=int(v[len("accum"):]))
        elif v.startswith("lchunk"):
            cfg = dataclasses.replace(cfg, loss_chunk=int(v[len("lchunk"):]))
        elif v.startswith("qb"):
            qb = int(v[2:])
            cfg = dataclasses.replace(cfg, q_block=qb, kv_block=qb)
        elif v.startswith("cf"):
            assert cfg.moe, "capacity-factor variant needs MoE"
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe,
                                             capacity_factor=float(v[2:]) / 100))
        elif v != "base":
            raise ValueError(f"unknown LM variant {v!r}")
    if info["kind"] == "long_decode":
        return Cell(
            arch_id=cfg.name, shape_name=shape, fn=None, args=(),
            in_shardings=None,
            skip=("full-attention architecture: 524k decode requires "
                  "sub-quadratic attention (DESIGN.md §4)"),
        )

    if tp is None:
        if info["kind"] == "decode":
            # puredp targets train/prefill; decode keeps the standard
            # mapping (its cache shards head_dim over 'model')
            tp = "model"
        else:
            dp = dp + ("model",)  # flatten: batch/weights over every axis
    params = T.abstract_params(cfg)
    pspecs = T.param_specs(cfg, dp=dp, tp=tp)
    psh = _shard_tree(mesh, pspecs)
    seq, batch = info["seq"], info["batch"]
    n_active = lm_active_params(cfg)

    if info["kind"] == "train":
        huge = count_params(params) > 5e10
        moment_dtype = jnp.bfloat16 if huge else jnp.float32
        optimizer = opt_lib.adamw(
            1e-4, moment_dtype=moment_dtype,
            compute_dtype=jnp.bfloat16 if huge else jnp.float32)
        opt_state = optimizer.abstract_state(params)
        osh = {
            "m": psh, "v": psh,
            "step": NamedSharding(mesh, P()),
        }
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        bsh = {
            "tokens": NamedSharding(mesh, P(dp, None)),
            "labels": NamedSharding(mesh, P(dp, None)),
        }
        fn = T.make_train_step(cfg, optimizer, mesh=mesh, dp=dp, tp=tp)
        return Cell(
            arch_id=cfg.name, shape_name=shape, fn=fn,
            args=(params, opt_state, batch_sds),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
            model_flops=6.0 * n_active * batch * seq,
        )

    if info["kind"] == "prefill":
        tokens = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        return Cell(
            arch_id=cfg.name, shape_name=shape,
            fn=lambda params, tokens: T.prefill(params, tokens, cfg,
                                                mesh=mesh, dp=dp, tp=tp),
            args=(params, tokens),
            in_shardings=(psh, NamedSharding(mesh, P(dp, None))),
            model_flops=2.0 * n_active * batch * seq,
        )

    # decode: one new token against a seq-long cache
    cache = T.make_cache(cfg, batch, seq, abstract=True)
    csh = _shard_tree(mesh, T.cache_specs(cfg, dp=dp))
    token = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda params, token, cache, cache_len: T.serve_step(
        params, token, cache, cache_len, cfg, mesh=mesh, dp=dp)
    return Cell(
        arch_id=cfg.name, shape_name=shape, fn=fn,
        args=(params, token, cache, cache_len),
        in_shardings=(psh, NamedSharding(mesh, P(dp, None)), csh,
                      NamedSharding(mesh, P())),
        out_shardings=(None, csh),
        donate_argnums=(2,),
        model_flops=2.0 * n_active * batch,  # one token per sequence
    )


# ============================================================ RecSys family
RECSYS_SHAPES = {
    "train_batch": {"kind": "train", "batch": 65536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "candidates": 1_000_000},
}


def recsys_dedup_cap(c: R.RecsysConfig, n_rows_per_field: int,
                     seq_rows: int = 0) -> int:
    """Exact upper bound on unique ids: sum over fields of min(B, vocab)."""
    cap = sum(min(n_rows_per_field, v) for v in c.vocab_sizes)
    cap += min(seq_rows, c.vocab_sizes[c.item_field])
    return int(cap)


def recsys_batch_sds(c: R.RecsysConfig, batch: int) -> Dict[str, Any]:
    sds = {
        "sparse": jax.ShapeDtypeStruct((batch, c.n_sparse), jnp.int32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }
    if c.n_dense:
        sds["dense"] = jax.ShapeDtypeStruct((batch, c.n_dense), jnp.float32)
    if c.kind == "bst":
        sds["seq"] = jax.ShapeDtypeStruct((batch, c.seq_len), jnp.int32)
    return sds


def recsys_dense_flops(c: R.RecsysConfig) -> float:
    """Per-example dense-net forward FLOPs (2·params of the towers)."""
    n = 0.0
    for name, s in R.param_shapes(c).items():
        if name != "embed" and len(s) == 2:
            n += float(np.prod(s))
    return 2.0 * n


def recsys_cell(cfg: R.RecsysConfig, shape: str, mesh: Mesh, *,
                variant: str = "base") -> Cell:
    info = RECSYS_SHAPES[shape]
    dp = dp_axes_for(mesh)
    all_axes = dp + ("model",)
    flags = set(variant.split("+"))
    unknown = flags - {"base", "nodedup", "cap_expected", "batchall", "hierdedup"}
    if unknown:
        raise ValueError(f"unknown recsys variant parts {unknown}")
    if "nodedup" in flags:
        cfg = dataclasses.replace(cfg, dedup_lookup=False)
    batch_axes = all_axes if "batchall" in flags else dp

    batch = info.get("batch", 1)
    seq_rows = batch * (cfg.seq_len + 1) if cfg.kind == "bst" else 0
    if "cap_expected" in flags:
        # expected-unique capacity (x1.15 safety) instead of the worst-case
        # sum(min(B, v)) — the same E[unique] model the streaming driver's
        # dedup_capacity_hint(mode="expected") uses
        from repro.embedding.dedup import expected_unique
        exp = sum(expected_unique(batch, v) for v in cfg.vocab_sizes)
        if cfg.kind == "bst":
            exp += expected_unique(seq_rows, cfg.vocab_sizes[cfg.item_field])
        cap = int(exp * 1.15)
    else:
        cap = recsys_dedup_cap(cfg, batch, seq_rows)
    # round capacity to device-count multiple for clean sharding
    nd = int(np.prod(list(mesh.shape.values())))
    cap = (cap + nd - 1) // nd * nd
    cfg = dataclasses.replace(cfg, dedup_capacity=cap)

    params = R.abstract_params(cfg)
    pspecs = R.param_specs(cfg, dp=dp)
    psh = _shard_tree(mesh, pspecs)
    flops1 = recsys_dense_flops(cfg)

    if info["kind"] == "train":
        sds = recsys_batch_sds(cfg, batch)
        bsh = {k: NamedSharding(mesh, P(batch_axes) if v.ndim == 1
                                else P(batch_axes, None))
               for k, v in sds.items()}
        if "nodedup" in flags:
            # pre-FeatureBox baseline: dense embedding grads + full-table
            # optimizer state/update (what [37]'s working-set scheme removes)
            optimizer = opt_lib.adamw(1e-3)
            step = R.make_train_step(cfg, optimizer)
            opt_state = optimizer.abstract_state(params)
            osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}
        else:
            dense_opt = opt_lib.adamw(1e-3)
            hier_kw = {}
            if "hierdedup" in flags:
                n_shards = int(np.prod([mesh.shape[a] for a in batch_axes]))
                b_loc = batch // n_shards
                seq_loc = b_loc * (cfg.seq_len + 1) if cfg.kind == "bst" else 0
                local_cap = recsys_dedup_cap(cfg, b_loc, seq_loc)
                hier_kw = {"mesh": mesh, "batch_axes": batch_axes,
                           "local_dedup_capacity": local_cap}
            step, init_st, abstract_st = R.make_sparse_train_step(
                cfg, dense_opt, **hier_kw)
            opt_state = abstract_st(params)
            dense_psh = {k: v for k, v in psh.items() if k != "embed"}
            osh = {
                "dense": {
                    "m": dense_psh, "v": dense_psh,
                    "step": NamedSharding(mesh, P()),
                },
                "embed_accum": NamedSharding(mesh, P(all_axes)),
            }
        return Cell(
            arch_id=cfg.name, shape_name=shape, fn=step,
            args=(params, opt_state, sds),
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
            model_flops=6.0 * flops1 / 2.0 * batch,  # 3x fwd cost, fwd=2*p
        )

    if info["kind"] == "serve":
        sds = recsys_batch_sds(cfg, batch)
        sds.pop("label")
        bsh = {k: NamedSharding(mesh, P(batch_axes) if v.ndim == 1
                                else P(batch_axes, None))
               for k, v in sds.items()}
        fn = lambda params, batch_: R.serve_step(params, cfg, batch_)
        return Cell(
            arch_id=cfg.name, shape_name=shape, fn=fn,
            args=(params, sds),
            in_shardings=(psh, bsh),
            model_flops=flops1 * batch,
        )

    # retrieval: one user, 10^6 candidates (candidate axis sharded over dp)
    n_cand = info["candidates"]
    cfg = dataclasses.replace(
        cfg, dedup_capacity=recsys_dedup_cap(cfg, 1, seq_rows) + min(
            n_cand, cfg.vocab_sizes[cfg.item_field]))
    user = recsys_batch_sds(cfg, 1)
    user.pop("label")
    ush = {k: NamedSharding(mesh, P(None) if v.ndim == 1 else P(None, None))
           for k, v in user.items()}
    cands = jax.ShapeDtypeStruct((n_cand,), jnp.int32)
    fn = lambda params, user_, cands_: R.retrieval_score(params, cfg, user_, cands_)
    return Cell(
        arch_id=cfg.name, shape_name=shape, fn=fn,
        args=(params, user, cands),
        in_shardings=(psh, ush, NamedSharding(mesh, P(dp))),
        model_flops=flops1 * n_cand,
    )


# =============================================================== GNN family
GNN_SHAPES = {
    "full_graph_sm": {"kind": "full", "n_nodes": 2708, "n_edges": 10556,
                      "d_feat": 1433, "n_classes": 7},
    "minibatch_lg": {"kind": "sampled", "seeds": 1024, "fanout": (15, 10),
                     "d_feat": 602, "n_classes": 41},
    "ogb_products": {"kind": "full", "n_nodes": 2449029, "n_edges": 61859140,
                     "d_feat": 100, "n_classes": 47},
    "molecule": {"kind": "graphs", "n_graphs": 128, "nodes_per": 30,
                 "edges_per": 64, "d_feat": 28, "n_classes": 2},
}


def gnn_config_for(base_name: str, shape: str, *, n_layers=4, d_hidden=75) -> G.PNAConfig:
    info = GNN_SHAPES[shape]
    return G.PNAConfig(
        name=f"{base_name}", n_layers=n_layers, d_in=info["d_feat"],
        d_hidden=d_hidden, n_classes=info["n_classes"],
        graph_level=(info["kind"] == "graphs"),
    )


def gnn_cell(base_name: str, shape: str, mesh: Mesh, *, variant: str = "base") -> Cell:
    info = GNN_SHAPES[shape]
    dp = dp_axes_for(mesh)
    all_axes = dp + ("model",)
    cfg = gnn_config_for(base_name, shape)
    if variant == "halo_bf16":
        cfg = dataclasses.replace(cfg, halo_bf16=True)
    elif variant != "base":
        raise ValueError(f"unknown gnn variant {variant!r}")
    params = G.abstract_params(cfg)
    psh = _shard_tree(mesh, G.param_specs(cfg))

    if info["kind"] == "sampled":
        n_nodes = info["seeds"] * (1 + info["fanout"][0] * (1 + info["fanout"][1]))
        n_edges = info["seeds"] * info["fanout"][0] * (1 + info["fanout"][1])
    elif info["kind"] == "graphs":
        n_nodes = info["n_graphs"] * info["nodes_per"]
        n_edges = info["n_graphs"] * info["edges_per"]
    else:
        n_nodes, n_edges = info["n_nodes"], info["n_edges"]
    # pad the edge list to a device-count multiple: padding edges carry
    # dst = n_nodes (out of range), which segment ops drop — zero contribution
    nd = int(np.prod(list(mesh.shape.values())))
    n_edges = (n_edges + nd - 1) // nd * nd

    # node tensors: replicate small graphs; shard (and pad) big ones —
    # the (N, 12D) PNA aggregates replicated are ~9 GB/layer at ogb scale
    shard_nodes = n_nodes > 100_000
    node_axes = all_axes if shard_nodes else None
    if shard_nodes:
        n_nodes = (n_nodes + nd - 1) // nd * nd
    node_spec = P(all_axes, None) if shard_nodes else P(None, None)
    node_spec1 = P(all_axes) if shard_nodes else P(None)

    sds = {
        "features": jax.ShapeDtypeStruct((n_nodes, info["d_feat"]), jnp.float32),
        "src": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
    }
    bsh = {
        "features": NamedSharding(mesh, node_spec),
        "src": NamedSharding(mesh, P(all_axes)),          # edges sharded
        "dst": NamedSharding(mesh, P(all_axes)),
    }
    if info["kind"] == "graphs":
        sds["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        sds["labels"] = jax.ShapeDtypeStruct((info["n_graphs"],), jnp.int32)
        bsh["graph_ids"] = NamedSharding(mesh, P(None))
        bsh["labels"] = NamedSharding(mesh, P(None))
    else:
        sds["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        bsh["labels"] = NamedSharding(mesh, node_spec1)
        if shard_nodes:  # padded nodes are masked out of the loss
            sds["label_mask"] = jax.ShapeDtypeStruct((n_nodes,), jnp.float32)
            bsh["label_mask"] = NamedSharding(mesh, node_spec1)

    optimizer = opt_lib.adamw(1e-3)
    opt_state = optimizer.abstract_state(params)
    osh = {
        "m": psh, "v": psh, "step": NamedSharding(mesh, P()),
    }

    step_fn = G.make_train_step(cfg, optimizer, mesh=mesh, node_axes=node_axes)
    if info["kind"] == "graphs":
        def fn(params, opt_state, batch):
            batch = dict(batch)
            batch["n_graphs"] = info["n_graphs"]
            return step_fn(params, opt_state, batch)
    else:
        fn = step_fn

    # model flops: messages/updates dominate — 2 flops per weight per unit
    per_edge = 2.0 * 2 * cfg.d_hidden * cfg.d_hidden          # msg MLP
    per_node = 2.0 * (cfg.d_hidden * 13) * cfg.d_hidden       # update MLP
    fwd = cfg.n_layers * (per_edge * n_edges + per_node * n_nodes)
    return Cell(
        arch_id=base_name, shape_name=shape, fn=fn,
        args=(params, opt_state, sds),
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1),
        model_flops=3.0 * fwd,
    )
