"""deepseek-v2-236b: 60L d5120 128H MLA kv_lora=512, MoE 2 shared + 160
routed top-6 (d_ff_expert=1536), vocab=102400 [arXiv:2405.04434]."""

import functools

import jax.numpy as jnp

from repro.configs.base import ArchSpec, lm_cell
from repro.models.attention import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-v2-236b", n_layers=60, d_model=5120, n_heads=128, n_kv=128,
    d_ff=12288,  # layer-0 dense FFN (first_k_dense_replace=1)
    vocab=102400, head_dim=128,
    attn="mla",
    mla=MLAConfig(d_model=5120, n_heads=128, q_lora_rank=1536,
                  kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                  capacity_factor=1.25, shard_ff_over_data=True),
    first_k_dense=1,
    dtype=jnp.bfloat16, grad_accum=16, accum_dtype=jnp.bfloat16,
)


def smoke():
    return LMConfig(
        name="deepseek-v2-smoke", n_layers=3, d_model=64, n_heads=4, n_kv=4,
        d_ff=128, vocab=256,
        attn="mla",
        mla=MLAConfig(d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                      capacity_factor=2.0),
        first_k_dense=1,
        dtype=jnp.float32, q_block=16, kv_block=16, loss_chunk=16,
    )


ARCH = ArchSpec(
    arch_id="deepseek-v2-236b", family="lm",
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    build_cell=functools.partial(lm_cell, CONFIG),
    smoke=smoke,
    describe="MLA + fine-grained MoE (2 shared + 160 routed top-6)",
)
