"""pna: Principal Neighbourhood Aggregation, 4 layers d_hidden=75,
aggregators mean/max/min/std, scalers id/amp/atten [arXiv:2004.05718].

d_in / n_classes are per-dataset (per shape); see configs.base.GNN_SHAPES.
"""

import functools

from repro.configs.base import ArchSpec, gnn_cell
from repro.models.gnn import PNAConfig


def smoke():
    return PNAConfig(name="pna-smoke", n_layers=2, d_in=16, d_hidden=24,
                     n_classes=5)


ARCH = ArchSpec(
    arch_id="pna", family="gnn",
    shapes=("full_graph_sm", "minibatch_lg", "ogb_products", "molecule"),
    build_cell=functools.partial(gnn_cell, "pna"),
    smoke=smoke,
    describe="PNA multi-aggregator message passing (segment ops)",
)
