"""Gradient compression for cross-pod reduction (distributed-optimization).

At 2+ pods the inter-pod links are the scarce resource (data-center network
vs in-pod ICI), so cross-pod gradient all-reduce benefits from compression:

* ``bf16_compress`` — cast fp32 grads to bf16 for the wire (2x), with
  **error feedback** (residual carrying) so quantization error is not lost
  but applied next step [Seide et al. 2014; 1-bit SGD lineage].
* ``int8_compress`` — per-tensor scale + int8 (4x), also with error feedback.
* ``hierarchical_psum`` — shard_map helper: reduce-scatter inside the pod,
  compressed all-reduce across pods, all-gather inside the pod. Inter-pod
  bytes drop by (pod_size x compression) vs a flat all-reduce.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------- codecs (+feedback)
def bf16_compress(grads: Any, residual: Optional[Any] = None) -> Tuple[Any, Any]:
    """fp32 -> bf16 with error feedback. Returns (wire_grads, new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    adjusted = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    wire = jax.tree.map(lambda a: a.astype(jnp.bfloat16), adjusted)
    new_residual = jax.tree.map(
        lambda a, w: a - w.astype(jnp.float32), adjusted, wire)
    return wire, new_residual


def bf16_decompress(wire: Any) -> Any:
    return jax.tree.map(lambda w: w.astype(jnp.float32), wire)


def int8_compress(grads: Any, residual: Optional[Any] = None) -> Tuple[Any, Any, Any]:
    """fp32 -> (int8, scale) with error feedback."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    adjusted = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)

    def enc(a):
        scale = jnp.maximum(jnp.max(jnp.abs(a)), 1e-30) / 127.0
        q = jnp.clip(jnp.round(a / scale), -127, 127).astype(jnp.int8)
        return q, scale

    qs = jax.tree.map(enc, adjusted)
    wire = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    new_residual = jax.tree.map(
        lambda a, q, s: a - q.astype(jnp.float32) * s, adjusted, wire, scales)
    return wire, scales, new_residual


def int8_decompress(wire: Any, scales: Any) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, wire, scales)


def compressed_bytes(tree: Any) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree)))


# ------------------------------------------------ hierarchical cross-pod sum
def hierarchical_psum(x: jax.Array, *, pod_axis: str = "pod",
                      inner_axis: str = "data",
                      compress: bool = True) -> jax.Array:
    """Two-level all-reduce for use INSIDE shard_map.

    reduce_scatter(inner) -> [compress] psum(pod) [decompress] -> all_gather(inner).
    Inter-pod traffic: N/inner_size elements (xN less) in bf16 (x2 less).
    """
    shard = jax.lax.psum_scatter(x, inner_axis, scatter_dimension=0, tiled=True)
    if compress:
        wire = shard.astype(jnp.bfloat16)
        reduced = jax.lax.psum(wire, pod_axis).astype(shard.dtype)
    else:
        reduced = jax.lax.psum(shard, pod_axis)
    return jax.lax.all_gather(reduced, inner_axis, axis=0, tiled=True)


def flat_psum(x: jax.Array, *, pod_axis: str = "pod",
              inner_axis: str = "data") -> jax.Array:
    """Baseline: single flat all-reduce over both axes (for §Perf compare)."""
    return jax.lax.psum(x, (pod_axis, inner_axis))
